"""FIFO-sizing design-space exploration with incremental re-simulation.

    PYTHONPATH=src python examples/fifo_sizing_dse.py

The paper's Table 6 workflow at design scale: pick a dataflow accelerator
(the SkyNet-like deep pipeline), sweep every internal channel depth, and use
incremental re-simulation to evaluate each point in ~microseconds instead of
a full run.  Points whose constraints break fall back to a full re-sim
automatically.

Two modes are shown:

  * one-at-a-time ``resimulate`` — one depth vector per call (the paper's
    original Table 6 flow);
  * ``resimulate_batch`` — the whole candidate set as ONE (K, n_fifos)
    matrix.  All K configurations share a single compiled-graph cache and
    one vectorized fixpoint/constraint pass; structurally-infeasible or
    constraint-violating rows fall back to a full re-sim individually.
    This is the API to use for real sweeps (10^3-10^5 configs):

        depths = np.stack([...])                 # (K, n_fifos)
        out = resimulate_batch(base_result, depths)
        best = depths[int(np.argmin(out.cycles))]

    ``out.ok`` marks reused rows, ``out.cycles`` is exact for every row,
    ``out.reasons[k]`` explains any fallback.
"""
import time

import numpy as np

from repro.core import resimulate, resimulate_batch, simulate
from repro.designs.typea import skynet_like


def main():
    base_prog = skynet_like(items=512, depth=12)
    t0 = time.perf_counter()
    base = simulate(base_prog)
    t_full = time.perf_counter() - t0
    print(f"initial run: cycles={base.cycles}  ({t_full*1e3:.0f} ms)\n")
    print(f"{'depths':>10s} {'cycles':>8s} {'method':>12s} {'time':>10s} "
          f"{'speedup':>8s}")

    n_chan = len(base.depths)
    for d in (1, 2, 4, 8, 16):
        new_depths = tuple([d] * n_chan)
        t0 = time.perf_counter()
        inc = resimulate(base, new_depths)
        dt = time.perf_counter() - t0
        method = "incremental" if inc.ok else "full-resim"
        # verify against a from-scratch simulation
        check = simulate(skynet_like(items=512, depth=12), depths=new_depths)
        assert check.cycles == inc.result.cycles, (d, check.cycles,
                                                   inc.result.cycles)
        print(f"{d:10d} {inc.result.cycles:8d} {method:>12s} "
              f"{dt*1e3:9.2f}ms {t_full/dt:7.1f}x")
    print("\nall points verified exact against full re-simulation")

    # ---- batched sweep: the whole design space in one call ----
    rng = np.random.default_rng(0)
    K = 512
    D = rng.integers(2, 17, size=(K, n_chan))
    resimulate_batch(base, D[:2])                # warm the compiled cache
    t0 = time.perf_counter()
    out = resimulate_batch(base, D)
    dt = time.perf_counter() - t0
    best = int(np.argmin(out.cycles))
    print(f"\nbatched sweep: {K} configs in {dt*1e3:.1f} ms "
          f"({out.us_per_config():.0f} us/config), "
          f"{out.n_reused} reused / {out.n_fallback} full re-sims")
    print(f"best config: cycles={int(out.cycles[best])} "
          f"depths={tuple(int(x) for x in D[best])}")


if __name__ == "__main__":
    main()
