"""FIFO-sizing design-space exploration with incremental re-simulation.

    PYTHONPATH=src python examples/fifo_sizing_dse.py

The paper's Table 6 workflow at design scale: pick a dataflow accelerator
(the SkyNet-like deep pipeline), sweep every internal channel depth, and use
incremental re-simulation to evaluate each point in ~microseconds instead of
a full run.  Points whose constraints break fall back to a full re-sim
automatically.
"""
import time

from repro.core import resimulate, simulate
from repro.designs.typea import skynet_like


def main():
    base_prog = skynet_like(items=512, depth=12)
    t0 = time.perf_counter()
    base = simulate(base_prog)
    t_full = time.perf_counter() - t0
    print(f"initial run: cycles={base.cycles}  ({t_full*1e3:.0f} ms)\n")
    print(f"{'depths':>10s} {'cycles':>8s} {'method':>12s} {'time':>10s} "
          f"{'speedup':>8s}")

    n_chan = len(base.depths)
    for d in (1, 2, 4, 8, 16):
        new_depths = tuple([d] * n_chan)
        t0 = time.perf_counter()
        inc = resimulate(base, new_depths)
        dt = time.perf_counter() - t0
        method = "incremental" if inc.ok else "full-resim"
        # verify against a from-scratch simulation
        check = simulate(skynet_like(items=512, depth=12), depths=new_depths)
        assert check.cycles == inc.result.cycles, (d, check.cycles,
                                                   inc.result.cycles)
        print(f"{d:10d} {inc.result.cycles:8d} {method:>12s} "
              f"{dt*1e3:9.2f}ms {t_full/dt:7.1f}x")
    print("\nall points verified exact against full re-simulation")


if __name__ == "__main__":
    main()
