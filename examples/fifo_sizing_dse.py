"""FIFO-sizing design-space exploration, served.

    PYTHONPATH=src python examples/fifo_sizing_dse.py

The paper's Table 6 workflow at design scale, in four acts:

  1. **One-at-a-time** ``resimulate`` — one depth vector per call (the
     paper's original flow), each point verified against a from-scratch
     simulation.
  2. **The sweep service** (``repro.sweep``): submit whole candidate
     matrices against a warm compiled-graph cache.  Heterogeneous
     requests coalesce into shared solver blocks, duplicate depth
     vectors are solved once, results stream back per config, and small
     interactive queries jump the bulk queue via the priority lane.
  3. **Search drivers** (``repro.sweep.search``) consuming the stream:
     random search and successive-halving FIFO-area minimization, both
     reporting the Pareto frontier of (total FIFO depth, latency) — the
     designer's actual decision surface.
  4. **The edit loop** (``repro.delta``): edit one module's body and
     ``EditSession.update()`` — only the edited module is re-recorded;
     the other modules' traces, the compiled skeleton and the solved
     times are patched and verified, then sweeps of the *edited* design
     serve from the patched graph.

Every cycle count below is exact: reused configs come from the shared
batched fixpoint, diverging configs from automatic full re-simulation.
"""
import time

import numpy as np

from repro.core import resimulate, simulate
from repro.designs.typea import skynet_like
from repro.sweep import SweepService, random_search, successive_halving


def main():
    base_prog = skynet_like(items=512, depth=12)
    t0 = time.perf_counter()
    base = simulate(base_prog)
    t_full = time.perf_counter() - t0
    print(f"initial run: cycles={base.cycles}  ({t_full*1e3:.0f} ms)\n")

    # ---- act 1: the paper's one-at-a-time incremental flow ----
    print(f"{'depths':>10s} {'cycles':>8s} {'method':>12s} {'time':>10s} "
          f"{'speedup':>8s}")
    n_chan = len(base.depths)
    for d in (1, 2, 4, 8, 16):
        new_depths = tuple([d] * n_chan)
        t0 = time.perf_counter()
        inc = resimulate(base, new_depths)
        dt = time.perf_counter() - t0
        method = "incremental" if inc.ok else "full-resim"
        # verify against a from-scratch simulation
        check = simulate(skynet_like(items=512, depth=12), depths=new_depths)
        assert check.cycles == inc.result.cycles, (d, check.cycles,
                                                   inc.result.cycles)
        print(f"{d:10d} {inc.result.cycles:8d} {method:>12s} "
              f"{dt*1e3:9.2f}ms {t_full/dt:7.1f}x")
    print("all points verified exact against full re-simulation\n")

    # ---- acts 2+3: the served sweep ----
    with SweepService(block=128, shards=2) as svc:
        svc.warm(base)                       # adopt the base run (no re-sim)

        # a bulk random sweep and an interactive what-if, concurrently:
        # the 2-config query rides the priority lane past the bulk blocks
        rng = np.random.default_rng(0)
        D = rng.integers(2, 17, size=(512, n_chan))
        bulk = svc.submit(base, D, priority="bulk")
        probe = svc.submit(base, np.array([[4] * n_chan, [16] * n_chan]))
        t0 = time.perf_counter()
        po = probe.result()
        t_probe = time.perf_counter() - t0
        print(f"interactive probe (2 cfgs) answered in {t_probe*1e3:.1f} ms "
              f"while the bulk sweep runs: "
              f"depth-4 {int(po.cycles[0])} / depth-16 {int(po.cycles[1])} "
              f"cycles")
        out = bulk.result()
        best = int(np.argmin(np.where(out.cycles < 0, 1 << 60, out.cycles)))
        print(f"bulk sweep: {len(D)} configs ({out.n_unique} unique) in "
              f"{out.elapsed_s*1e3:.1f} ms, {out.n_reused} reused / "
              f"{out.n_fallback} full re-sims; best cycles="
              f"{int(out.cycles[best])}")

        # search drivers: FIFO-area minimization on a smaller instance
        prog = skynet_like(items=96, depth=8)
        ro = random_search(svc, prog, n=128, lo=1, hi=16, seed=1)
        sh = successive_halving(svc, prog, n0=32, rounds=3, eta=2,
                                lo=1, hi=16, seed=1)
        print(f"\nrandom search : {ro.summary()}")
        print(f"succ. halving : {sh.summary()}")
        print("\npareto frontier (total depth, cycles) from halving:")
        for dv, area, cyc in sh.pareto:
            print(f"  area={area:4d}  cycles={cyc:6d}")

        st = svc.stats()
        print(f"\nservice stats: cache hit rate "
              f"{st['cache']['hit_rate']:.2f}, "
              f"{st['scheduler']['blocks']} blocks, dedup "
              f"{st['scheduler']['dedup_ratio']:.2f}x, "
              f"{st['scheduler']['fallbacks']} fallback re-sims")

    # ---- act 4: the edit-and-resimulate loop (repro.delta) ----
    from repro.corpus import edit_pairs
    pair = edit_pairs(11, scale=60, kinds=("delay",))[0]
    with SweepService() as svc:
        sess = svc.edit_session(pair.base())
        n = len(sess.program.fifos)
        D = np.random.default_rng(2).integers(2, 9, size=(16, n))
        before = sess.sweep(D)
        t0 = time.perf_counter()
        outcome = sess.update(pair.edited())     # one module body edited
        dt = time.perf_counter() - t0
        after = sess.sweep(D)
        print(f"\nedit loop (60-module corpus design): update() -> "
              f"{outcome.mode}, {outcome.reused_modules}/"
              f"{outcome.total_modules} module traces reused "
              f"({outcome.reuse_fraction:.1%}) in {dt*1e3:.1f} ms")
        live = (before.cycles >= 0) & (after.cycles >= 0)
        print(f"re-swept {len(D)} configs of the edited design: "
              f"median cycles {int(np.median(before.cycles[live]))} -> "
              f"{int(np.median(after.cycles[live]))}")
        d = svc.stats()["cache"]
        print(f"delta tiers: {d['delta_hits']} patched, "
              f"{d['delta_rejects']} rejected to cold")


if __name__ == "__main__":
    main()
