"""Quickstart: simulate a Type C dataflow design three ways and compare.

    PYTHONPATH=src python examples/quickstart.py

Shows the paper's core result in miniature: C-sim gets the functionality
wrong, the cycle-stepped RTL oracle is exact but slow, and OmniSim matches
the oracle exactly at a fraction of the cost — then re-simulates a FIFO
resize incrementally in microseconds.
"""
import time

from repro.core import classify, csim, resimulate, simulate, simulate_rtl
from repro.core.program import (Delay, Emit, Full, Program, Read, ReadNB,
                                Write, WriteNB)


def congestion_router(n=500):
    """A little Type C design: drop-on-backpressure video pipeline."""
    prog = Program("quickstart_router", declared_type="C")
    frames = prog.fifo("frames", 3)

    @prog.module("camera")
    def camera():
        dropped = 0
        for i in range(1, n + 1):
            ok = yield WriteNB(frames, i)
            if not ok:
                dropped += 1           # frame dropped under backpressure
        yield Emit("dropped", dropped)

    @prog.module("encoder")               # 4 cycles per frame
    def encoder():
        total = frames_seen = 0
        for _ in range(n):
            ok, v = yield ReadNB(frames)
            if ok:
                frames_seen += 1
                total += v
            yield Delay(3)
        yield Emit("encoded", frames_seen)
        yield Emit("checksum", total)

    return prog


def main():
    print("=" * 64)
    print("1) Vitis-style C simulation (sequential, untimed)")
    r = csim(congestion_router())
    print("   ", {k: v for k, v in r.outputs.items() if k != "__warnings__"})
    print("    -> WRONG: no frame is ever dropped under C semantics\n")

    print("2) cycle-stepped RTL oracle (co-sim stand-in)")
    t0 = time.perf_counter()
    rtl = simulate_rtl(congestion_router())
    t_rtl = time.perf_counter() - t0
    print(f"    {rtl.outputs}  cycles={rtl.cycles}  ({t_rtl*1e3:.1f} ms)\n")

    print("3) OmniSim (coupled functionality+performance simulation)")
    t0 = time.perf_counter()
    omni = simulate(congestion_router())
    t_omni = time.perf_counter() - t0
    print(f"    {omni.outputs}  cycles={omni.cycles}  ({t_omni*1e3:.1f} ms)")
    assert omni.outputs == rtl.outputs and omni.cycles == rtl.cycles
    print(f"    == oracle exactly; {t_rtl/t_omni:.1f}x faster than "
          f"cycle-stepping")
    print("   ", classify(congestion_router(), omni), "\n")

    print("4) incremental re-simulation: frames FIFO 3 -> 64")
    inc = resimulate(omni, (64,))
    full = simulate(congestion_router(), depths=(64,))
    status = "graph reused" if inc.ok else f"full re-sim ({inc.reason})"
    print(f"    {status}; cycles={inc.result.cycles} "
          f"(verified == full re-sim: {inc.result.cycles == full.cycles}); "
          f"outputs now {full.outputs}")


if __name__ == "__main__":
    main()
