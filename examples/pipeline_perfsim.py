"""Distributed-schedule DSE with the OmniSim engine.

    PYTHONPATH=src python examples/pipeline_perfsim.py

The paper's technique integrated into the training framework: a pipeline-
parallel step is a dataflow design (stages = modules, activation queues =
FIFOs).  OmniSim predicts step time for GPipe vs 1F1B across microbatch
counts and buffer depths, using incremental re-simulation for the depth
sweep.  If dry-run roofline records exist (reports/dryrun), tick costs come
from the real compiled step of qwen2.5-14b.
"""
import dataclasses

from repro.perfsim.pipeline import (PipelineSpec, buffer_depth_dse,
                                    simulate_pipeline)
from repro.perfsim.stepmodel import load_record, spec_from_roofline


def main():
    rec = load_record("reports/dryrun", "qwen2.5-14b", "train_4k")
    if rec is not None and "roofline" in rec:
        spec = spec_from_roofline(rec, stages=8, microbatches=32)
        print(f"tick costs from qwen2.5-14b train_4k dry-run: "
              f"fwd={spec.fwd_ticks} bwd={spec.bwd_ticks} ticks/stage/mb\n")
    else:
        spec = PipelineSpec(stages=8, microbatches=32, fwd_ticks=40,
                            bwd_ticks=80)
        print("no dry-run records found; using synthetic tick costs\n")

    print(f"{'schedule':>9s} {'mb':>4s} {'depth':>6s} {'step(ticks)':>12s} "
          f"{'bubble':>8s}")
    for schedule in ("gpipe", "1f1b"):
        for mb in (8, 16, 32, 64):
            s = dataclasses.replace(spec, schedule=schedule, microbatches=mb)
            r = simulate_pipeline(s)
            print(f"{schedule:>9s} {mb:4d} {s.buffer_depth:6d} "
                  f"{r.step_ticks:12d} {r.bubble_fraction:7.1%}")

    print("\nbuffer-depth DSE via incremental re-simulation (gpipe, mb=32):")
    g = dataclasses.replace(spec, schedule="gpipe", microbatches=32,
                            buffer_depth=1)
    for depth, res, incr_s in buffer_depth_dse(g, [1, 2, 4, 8]):
        how = "" if incr_s is None else (
            f"  incr {abs(incr_s)*1e3:.2f} ms"
            + ("" if incr_s >= 0 else " (constraints broke -> full)"))
        print(f"  depth={depth:3d}  step={res.step_ticks:8d}  "
              f"bubble={res.bubble_fraction:6.1%}{how}")


if __name__ == "__main__":
    main()
