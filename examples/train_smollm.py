"""End-to-end training driver: a ~reduced smollm for a few hundred steps with
checkpoint/restart (kill it mid-run and re-launch: it resumes exactly).

    PYTHONPATH=src python examples/train_smollm.py --steps 300

This is the runnable end-to-end example required by deliverable (b); the
full-scale path is ``python -m repro.launch.train --arch smollm-135m``.
"""
import argparse
import subprocess
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "smollm-135m", "--smoke",
           "--steps", str(args.steps), "--batch", "8", "--seq", "128",
           "--ckpt-every", "100", "--log-every", "20"]
    raise SystemExit(subprocess.call(cmd))


if __name__ == "__main__":
    main()
