"""Batched serving engine: prefill once, decode in lockstep.

Continuous batching at production scale would admit new requests into freed
slots between decode steps; the slot bookkeeping here (per-slot position,
done mask) is exactly that structure, exercised single-host.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models import api


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, batch: int, max_len: int):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self._decode = jax.jit(
            lambda p, t, c: api.decode_step(p, t, c, cfg),
            donate_argnums=(2,))

    def prefill(self, prompts: np.ndarray):
        """Sequential prefill through the decode path (exactness over speed
        on the CPU host; the TPU path would run the fused prefill step)."""
        B, S = prompts.shape
        cache = api.init_cache(self.cfg, B, self.max_len)
        logits = None
        for t in range(S):
            logits, cache = self._decode(self.params,
                                         prompts[:, t:t + 1].astype(np.int32),
                                         cache)
        return logits, cache

    def generate(self, prompts: np.ndarray, gen_len: int) -> np.ndarray:
        logits, cache = self.prefill(prompts)
        tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
        out = [np.asarray(tok)]
        for _ in range(gen_len - 1):
            logits, cache = self._decode(self.params, tok, cache)
            tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
            out.append(np.asarray(tok))
        return np.concatenate(out, axis=1)


class ContinuousBatchingEngine(ServeEngine):
    """Slot-based continuous batching: new requests are admitted into freed
    slots between decode steps (the vLLM-style serving loop, exercised
    single-host).  The decode step is compiled once for the fixed slot
    count; per-slot position/done bookkeeping lives host-side."""

    def __init__(self, cfg: ArchConfig, params, batch: int, max_len: int,
                 eos_id: int = 0):
        super().__init__(cfg, params, batch, max_len)
        self.eos_id = eos_id
        self.cache = api.init_cache(cfg, batch, max_len)
        self.active = np.zeros(batch, bool)
        self.slot_tokens = np.zeros((batch, 1), np.int32)
        self.generated = [[] for _ in range(batch)]
        self.remaining = np.zeros(batch, np.int64)
        self.completed = []

    def _free_slots(self):
        return [i for i in range(self.batch) if not self.active[i]]

    def admit(self, prompt: np.ndarray, gen_len: int) -> bool:
        """Admit one request into a free slot; prefill runs via the decode
        path with per-slot masking (positions are per-slot independent)."""
        free = self._free_slots()
        if not free:
            return False
        slot = free[0]
        # reset the slot position (cache rows are per-slot; stale KV beyond
        # pos is masked out by the causal validity test)
        pos = np.array(self.cache["pos"], copy=True)
        pos[slot] = 0
        self.cache["pos"] = jnp.asarray(pos)
        for t in prompt:
            self.slot_tokens[slot, 0] = t
            tok = jnp.asarray(self.slot_tokens)
            logits, self.cache = self._decode(self.params, tok, self.cache)
        self.generated[slot] = []
        self.remaining[slot] = gen_len
        self.active[slot] = True
        self.slot_tokens[slot, 0] = int(jnp.argmax(logits[slot, -1]))
        return True

    def step(self) -> int:
        """One lockstep decode across all slots; returns #completed."""
        if not self.active.any():
            return 0
        tok = jnp.asarray(self.slot_tokens)
        logits, self.cache = self._decode(self.params, tok, self.cache)
        nxt = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))
        done_now = 0
        for i in range(self.batch):
            if not self.active[i]:
                continue
            self.generated[i].append(int(self.slot_tokens[i, 0]))
            self.remaining[i] -= 1
            self.slot_tokens[i, 0] = int(nxt[i])
            if self.remaining[i] <= 0:
                self.active[i] = False
                self.completed.append((i, list(self.generated[i])))
                done_now += 1
        return done_now

    def run(self, requests, gen_len: int):
        """Drive admission + decode until every request completes."""
        pending = list(requests)
        while pending or self.active.any():
            while pending and self._free_slots():
                self.admit(pending.pop(0), gen_len)
            self.step()
        return list(self.completed)
