"""Served edit sessions: the interactive edit-compile-sim loop.

An :class:`EditSession` is the front door the sweep service hands out via
``SweepService.edit_session(design)``.  It pins the design's current cache
entry and delta state; each ``update(new_program)`` classifies the edit
(``repro.delta.fingerprint``), asks the warm cache for the best reuse tier
(exact-key hit → per-module patch → cold rebuild, ``sweep/cache.py``) and
repoints the session at the resulting entry.  Subsequent ``submit`` /
``sweep`` calls serve depth sweeps of the *edited* design from the patched
graph — no re-record of the untouched modules, no service restart.

Patched entries are inserted under the edited design's own fingerprint as
*new* cache entries, never by mutating the old one in place: the scheduler
coalesces queued rows by entry identity, so rows submitted before an edit
keep solving against the graph they were submitted for.
"""
from __future__ import annotations

import time as _time
from dataclasses import dataclass, field
from typing import Dict, Optional

from ..core.dse import program_mutation_lock
from ..core.program import Program
from .fingerprint import DesignDelta, DesignFingerprint, diff, \
    fingerprint_design

__all__ = ["EditOutcome", "EditSession"]


@dataclass
class EditOutcome:
    """What one ``EditSession.update`` call did."""

    mode: str                       # "unchanged" | "exact" | "patched" | "cold"
    delta: Optional[DesignDelta]    # vs the session's previous program
    reused_modules: int
    total_modules: int
    elapsed_s: float
    reason: str = ""                # reject/why-cold detail (may be empty)
    key: str = ""                   # the now-active cache key

    @property
    def reuse_fraction(self) -> float:
        return self.reused_modules / max(self.total_modules, 1)


class EditSession:
    """Handle for one tenant's edit-and-resimulate loop.

    Created by ``SweepService.edit_session``; holds the service, the
    current program + fingerprint table, the active ``CacheEntry`` and the
    reusable :class:`~repro.delta.patch.DeltaState` (``None`` for dynamic
    designs — those always rebuild cold, but still get exact-key reuse).
    """

    def __init__(self, service, program: Program, key: Optional[str] = None):
        self._service = service
        self._cache = service.cache
        self.program = program
        with program_mutation_lock(program):
            self.fps: DesignFingerprint = fingerprint_design(program)
        if key is not None and key != self.fps.key:
            raise ValueError("key does not match the design fingerprint")
        self.key = self.fps.key
        look = self._cache.get_or_patch(program, self.fps, None)
        self.entry = look.entry
        self.state = look.state
        self.updates = 0
        self.counts: Dict[str, int] = {"unchanged": 0, "exact": 0,
                                       "patched": 0, "cold": 0,
                                       "rejected": 0}

    # ------------------------------------------------------------------
    def update(self, new_program: Program) -> EditOutcome:
        """Swap the session to an edited design, reusing what the delta
        allows.  Always succeeds — the worst case is a cold rebuild."""
        t0 = _time.perf_counter()
        with program_mutation_lock(new_program):
            new_fps = fingerprint_design(new_program)
        delta = diff(self.fps, new_fps)
        total = len(new_fps.modules)
        if new_fps.key == self.key:
            mode, reason = "unchanged", ""
            reused = total
        else:
            # hand the classification down iff it is the one the cache
            # would compute (vs the *state's* fingerprint — after an
            # exact-tier hit the session fps can be ahead of the state)
            d = delta if (self.state is not None
                          and self.state.fps is self.fps) else None
            look = self._cache.get_or_patch(new_program, new_fps,
                                            self.state, delta=d)
            mode, reason = look.mode, look.reason
            self.entry = look.entry
            if look.state is not None:
                self.state = look.state
            elif mode == "cold":
                self.state = None          # dynamic design: no delta state
            reused = look.reused_modules if mode == "patched" else (
                total if mode == "exact" else 0)
            if reason and mode == "cold":
                self.counts["rejected"] += 1
        self.program = new_program
        self.fps = new_fps
        self.key = new_fps.key
        self.updates += 1
        self.counts[mode] += 1
        return EditOutcome(mode=mode, delta=delta, reused_modules=reused,
                           total_modules=total,
                           elapsed_s=_time.perf_counter() - t0,
                           reason=reason, key=self.key)

    # ------------------------------------------------------------------
    # serving passthroughs: sweeps of the *current* program
    def submit(self, depth_blocks, **kw):
        return self._service.submit(self.program, depth_blocks, **kw)

    def sweep(self, depth_blocks, **kw):
        return self._service.sweep(self.program, depth_blocks, **kw)

    def result(self) -> "Program":
        return self.program

    def stats(self) -> Dict[str, object]:
        return {"updates": self.updates, "key": self.key,
                "patchable": self.state is not None, **self.counts}
