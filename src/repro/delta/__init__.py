"""repro.delta — structural deltas: edit-and-resimulate as a served workload.

The warm-cache architecture (``sweep/cache.py``) reuses work only on an
exact ``program_fingerprint`` match.  This package factors that key into a
per-module table (:mod:`~repro.delta.fingerprint`), classifies design
edits (``diff -> DesignDelta``), patches recorded traces and compiled
graphs for body-only edits with a mandatory pointwise re-verification pass
(:mod:`~repro.delta.patch`), and exposes the interactive loop as served
:class:`~repro.delta.session.EditSession` handles
(``SweepService.edit_session``).

Soundness contract: a patched result is bit-identical to a cold run or it
is rejected to a cold rebuild — stale timing is never served.
"""
from .fingerprint import (ADDED, BODY_EDITED, INTERFACE_CHANGED, KEPT,
                          REMOVED, RENAMED, RETYPED, UNCHANGED,
                          DesignDelta, DesignFingerprint, ModuleFingerprint,
                          diff, fingerprint_design)
from .patch import (DeltaState, PatchOutcome, PatchReject, apply_patch,
                    cold_build, snapshot)
from .session import EditOutcome, EditSession

__all__ = [
    "UNCHANGED", "BODY_EDITED", "INTERFACE_CHANGED", "ADDED", "REMOVED",
    "KEPT", "RETYPED", "RENAMED",
    "ModuleFingerprint", "DesignFingerprint", "DesignDelta",
    "fingerprint_design", "diff",
    "DeltaState", "PatchOutcome", "PatchReject",
    "snapshot", "apply_patch", "cold_build",
    "EditOutcome", "EditSession",
]
