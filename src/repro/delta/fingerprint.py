"""Per-module structural fingerprints and the design-delta classifier.

``program_fingerprint`` (``core/trace.py``) gates the sweep service's warm
cache all-or-nothing: one edited module changes the whole-design key and
invalidates everything.  This module factors that key into a per-module
:class:`ModuleFingerprint` table so an *edit* can be classified
structurally — which modules changed, how, and whether the recorded trace
of everything else is still reusable (LightningSim's incremental
resimulation story, one level up: code deltas, not just depth deltas).

Hash flavors per module (all via ``core.trace._fp_update``):

* ``sig``   — FIFOs by name only (depth-insensitive, the ``HybridCache``
  flavor): equal ``sig`` ⇒ the module's recorded op stream and values are
  reusable verbatim under any depth vector.  This is the only *eagerly*
  computed flavor: the whole-design key composes per-FIFO (name, depth)
  rows with per-module ``sig`` digests and equals
  ``core.trace.program_fingerprint`` bit-for-bit, so fingerprinting a
  design costs one hash walk per module, not three.
* ``body``  — FIFOs as position-free placeholders: invariant under FIFO
  renames/re-depthing, so a ``sig`` change with an equal ``body`` is an
  *interface* change (re-wiring), not a code edit.  Computed lazily — the
  classifier only consults it for modules whose ``sig`` changed (a
  handful per edit), never for the unchanged bulk of the design.
* ``interface`` (a FIFO-name set, not a hash) — likewise lazy.

Classification is deliberately conservative: any module whose ``sig``
changed is re-recorded by ``repro.delta.patch`` and its writes verified
against the original streams — the labels route work, the verifier
guarantees correctness.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from ..core.program import Fifo, Program
from ..core.trace import _fp_plain, _fp_update, module_content_hash

__all__ = [
    "UNCHANGED", "BODY_EDITED", "INTERFACE_CHANGED", "ADDED", "REMOVED",
    "KEPT", "RETYPED", "RENAMED",
    "ModuleFingerprint", "DesignFingerprint", "DesignDelta",
    "fingerprint_design", "diff",
]

# module labels
UNCHANGED = "unchanged"
BODY_EDITED = "body_edited"
INTERFACE_CHANGED = "interface_changed"
ADDED = "added"
REMOVED = "removed"
# FIFO labels (ADDED / REMOVED are shared with the module labels)
KEPT = "kept"
RETYPED = "retyped"
RENAMED = "renamed"


def _collect_fifos(obj, acc: set, depth: int = 0,
                   memo: Optional[dict] = None) -> None:
    """Best-effort static walk collecting ``Fifo`` names reachable from a
    module closure (mirrors ``_fp_update``'s traversal).

    ``memo`` caches per-container name sets keyed by ``(id, depth)`` so a
    capture shared between modules (generated designs close every module
    over one FIFO list) is walked once per design, not once per module.
    Entries must not outlive the walked objects.
    """
    if depth > 8:
        return
    import types
    if isinstance(obj, Fifo):
        acc.add(obj.name)
    elif isinstance(obj, types.FunctionType):
        if obj.__closure__:
            for cell in obj.__closure__:
                try:
                    _collect_fifos(cell.cell_contents, acc, depth + 1, memo)
                except ValueError:
                    pass
        for v in (obj.__defaults__ or ()):
            _collect_fifos(v, acc, depth + 1, memo)
        for v in (obj.__kwdefaults__ or {}).values():
            _collect_fifos(v, acc, depth + 1, memo)
        g = obj.__globals__
        gkey = (id(obj.__code__), id(g), "gnames") \
            if memo is not None else None
        if gkey is not None and gkey in memo:
            gnames = memo[gkey]
        else:
            gnames = set(obj.__code__.co_names) & set(g)
            if gkey is not None:
                memo[gkey] = gnames
        for name in gnames:
            v = g[name]
            if not isinstance(v, types.ModuleType):
                _collect_fifos(v, acc, depth + 1, memo)
    elif isinstance(obj, (list, tuple, set, frozenset)):
        if _fp_plain(obj, depth):
            return                  # pure primitive data: no FIFOs inside
        key = (id(obj), depth) if memo is not None else None
        if key is not None and key in memo:
            acc |= memo[key]
            return
        sub: set = set()
        for x in obj:
            _collect_fifos(x, sub, depth + 1, memo)
        if key is not None:
            memo[key] = frozenset(sub)
        acc |= sub
    elif isinstance(obj, dict):
        for k, v in obj.items():
            _collect_fifos(k, acc, depth + 1, memo)
            _collect_fifos(v, acc, depth + 1, memo)
    elif type(obj).__repr__ is object.__repr__:
        try:
            _collect_fifos(vars(obj), acc, depth + 1, memo)
        except TypeError:
            pass


class ModuleFingerprint:
    """One module's structural identity: the eager ``sig`` content hash
    plus lazily computed ``body`` hash and FIFO-interface signature.

    ``sig`` (FIFOs by name, depth-insensitive) is computed when the
    design is fingerprinted; ``body`` (FIFO-blind) and ``interface``
    (sorted reachable FIFO names) are derived from the retained module
    function on first access and cached — the delta classifier only needs
    them for modules whose ``sig`` changed.  ``ctx`` is the per-design
    lazy-memo context (shared-capture digest caches), so even the lazy
    flavors stay linear when many modules are consulted.
    """

    __slots__ = ("name", "sig", "_fn", "_ctx", "_body", "_interface")

    def __init__(self, name: str, sig: str, fn=None, ctx: Optional[dict] = None):
        self.name = name
        self.sig = sig
        self._fn = fn
        self._ctx = ctx if ctx is not None else {"body": {}, "if": {},
                                                 "sort": {}}
        self._body: Optional[str] = None
        self._interface: Optional[Tuple[str, ...]] = None

    @property
    def body(self) -> str:
        """FIFO-blind content hash (lazy, cached)."""
        if self._body is None:
            self._body = module_content_hash(self._fn, fifo_depth="blind",
                                             memo=self._ctx["body"])
        return self._body

    @property
    def interface(self) -> Tuple[str, ...]:
        """Sorted statically reachable FIFO names (lazy, cached)."""
        if self._interface is None:
            names: set = set()
            _collect_fifos(self._fn, names, memo=self._ctx["if"])
            fs = frozenset(names)
            cached = self._ctx["sort"].get(fs)
            if cached is None:
                cached = self._ctx["sort"][fs] = tuple(sorted(fs))
            self._interface = cached
        return self._interface

    def __repr__(self) -> str:
        return f"ModuleFingerprint(name={self.name!r}, sig={self.sig!r})"


@dataclass(frozen=True)
class DesignFingerprint:
    """Per-module fingerprint table + FIFO rows; composes the same
    whole-design key as ``core.trace.program_fingerprint``."""

    program: str
    fifo_rows: Tuple[Tuple[str, int], ...]      # (name, depth) per position
    modules: Tuple[ModuleFingerprint, ...]
    key: str                                    # == program_fingerprint
    depth_hash: str                             # depth-vector hash alone

    @property
    def module_names(self) -> Tuple[str, ...]:
        return tuple(m.name for m in self.modules)


def fingerprint_design(program: Program) -> DesignFingerprint:
    """Build the per-module fingerprint table of ``program``.

    ``.key`` equals ``program_fingerprint(program)`` exactly — the table is
    a factored form of the warm-cache key, so an exact-key cache hit and a
    delta classification read the same structure.
    """
    fifo_rows = tuple((f.name, int(f.depth)) for f in program.fifos)
    mods: List[ModuleFingerprint] = []
    h = hashlib.sha256()
    h.update(program.name.encode())
    for f in program.fifos:
        h.update(b"|F")
        _fp_update(h, f)
    # one eager hash walk per module (``sig`` flavor), with a shared-
    # capture memo so the one FIFO list every generated module closes
    # over hashes once per design; the lazy flavors share a per-design
    # context of their own memos
    memo_sig: dict = {}
    ctx: dict = {"body": {}, "if": {}, "sort": {}}
    for m in program.modules:
        sig = module_content_hash(m.fn, fifo_depth=False, memo=memo_sig)
        mods.append(ModuleFingerprint(m.name, sig, fn=m.fn, ctx=ctx))
        h.update(b"|M")
        h.update(m.name.encode())
        h.update(sig.encode())
    dh = hashlib.sha256(repr(tuple(d for _, d in fifo_rows)).encode())
    return DesignFingerprint(program=program.name, fifo_rows=fifo_rows,
                             modules=tuple(mods), key=h.hexdigest(),
                             depth_hash=dh.hexdigest())


@dataclass
class DesignDelta:
    """Classified difference between two design fingerprints.

    ``modules`` maps every module name seen on either side to a label
    (UNCHANGED / BODY_EDITED / INTERFACE_CHANGED / ADDED / REMOVED);
    ``fifos`` lists per-position ``(name, label)`` rows (KEPT / RETYPED /
    RENAMED plus ADDED / REMOVED for count changes).  ``patchable`` means
    the trace-patching fast path may *attempt* reuse: same ordered module
    names, same FIFO count and names (depth changes allowed).  The patch
    layer still re-records and verifies every non-UNCHANGED module — a
    patchable delta can be rejected, never the other way around.
    """

    modules: Dict[str, str]
    fifos: List[Tuple[str, str]]
    patchable: bool
    reason: str = ""                # why not patchable (empty when it is)
    edited: Tuple[str, ...] = ()    # non-UNCHANGED common module names

    @property
    def n_unchanged(self) -> int:
        return sum(1 for v in self.modules.values() if v == UNCHANGED)

    @property
    def identical(self) -> bool:
        return (all(v == UNCHANGED for v in self.modules.values())
                and all(lbl == KEPT for _, lbl in self.fifos))

    def summary(self) -> Dict[str, int]:
        """Label histogram (modules and FIFOs), for stats/logging."""
        out: Dict[str, int] = {}
        for v in self.modules.values():
            out[f"module_{v}"] = out.get(f"module_{v}", 0) + 1
        for _, v in self.fifos:
            out[f"fifo_{v}"] = out.get(f"fifo_{v}", 0) + 1
        return out


def diff(old: DesignFingerprint, new: DesignFingerprint) -> DesignDelta:
    """Classify the structural delta from ``old`` to ``new``.

    Module labels (by name): missing from ``new`` → REMOVED, missing from
    ``old`` → ADDED; common modules compare hashes — equal ``sig`` →
    UNCHANGED (depth-only perturbations are invisible by construction),
    equal ``body`` but different ``sig`` or a changed interface set →
    INTERFACE_CHANGED (re-wiring / FIFO-table change), otherwise
    BODY_EDITED.  FIFO labels align by position: same name+depth → KEPT,
    same name → RETYPED, different name → RENAMED.
    """
    old_by = {m.name: m for m in old.modules}
    new_by = {m.name: m for m in new.modules}
    labels: Dict[str, str] = {}
    edited: List[str] = []
    for m in old.modules:
        if m.name not in new_by:
            labels[m.name] = REMOVED
    for m in new.modules:
        o = old_by.get(m.name)
        if o is None:
            labels[m.name] = ADDED
            continue
        if o.sig == m.sig:
            labels[m.name] = UNCHANGED
        elif o.body == m.body or o.interface != m.interface:
            labels[m.name] = INTERFACE_CHANGED
            edited.append(m.name)
        else:
            labels[m.name] = BODY_EDITED
            edited.append(m.name)

    fifo_lbls: List[Tuple[str, str]] = []
    n_common = min(len(old.fifo_rows), len(new.fifo_rows))
    for i in range(n_common):
        (on, od), (nn, nd) = old.fifo_rows[i], new.fifo_rows[i]
        if on != nn:
            fifo_lbls.append((nn, RENAMED))
        elif od != nd:
            fifo_lbls.append((nn, RETYPED))
        else:
            fifo_lbls.append((nn, KEPT))
    for (on, _d) in old.fifo_rows[n_common:]:
        fifo_lbls.append((on, REMOVED))
    for (nn, _d) in new.fifo_rows[n_common:]:
        fifo_lbls.append((nn, ADDED))

    reason = ""
    if old.module_names != new.module_names:
        if any(v == ADDED for v in labels.values()):
            reason = "module set changed (added modules)"
        elif any(v == REMOVED for v in labels.values()):
            reason = "module set changed (removed modules)"
        else:
            reason = "module order changed"
    elif any(lbl in (RENAMED, ADDED, REMOVED) for _, lbl in fifo_lbls):
        reason = "FIFO table changed (rename/add/remove)"
    return DesignDelta(modules=labels, fifos=fifo_lbls,
                       patchable=not reason, reason=reason,
                       edited=tuple(edited))
