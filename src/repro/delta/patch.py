"""Trace and graph patching: re-record only the edited modules.

The cold trace path (``core/trace.py``) records *every* module generator,
then compiles and solves.  For an edit that touches one module of a
300-module design that is 299 re-recordings too many.  This module keeps a
:class:`DeltaState` snapshot of the last recorded run — op rows *plus* the
functional capture (per-FIFO written-value streams, per-module emits) —
and on an edit:

1. re-runs **only** the edited modules' generators in a KPN sandbox whose
   Reads are fed from the recorded value streams;
2. requires each edited module's **write streams to be byte-identical** to
   the recorded ones.  Under KPN determinism that equality proves every
   unchanged module's functional behavior is unchanged (their inputs are
   literally the same values), so splicing their recorded rows is *exact*
   — any deviation (different values, counts, targets, a live NB op, a
   read past the recorded stream) rejects to a cold rebuild;
3. splices the re-recorded rows into the compiled skeleton — patching only
   the edited modules' SEQ weights in place when their op structure is
   unchanged, recompiling the (numpy-cheap) skeleton otherwise — and
   re-solves;
4. re-verifies the solved times with the pointwise max-plus + Table-2 pass
   (``core.incremental.verify_times``, the PR 9 ``_FullRun`` verifier
   pattern).  A verified solution is *the* solution; a failed verification
   rejects to cold.

The result is bit-identical to a cold ``simulate`` of the edited design or
it is not served at all.
"""
from __future__ import annotations

import dataclasses
import time as _time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from ..core.program import (Delay, Emit, Empty, Full, Program, Read, ReadNB,
                            SimResult, Write, WriteNB)
from ..core.trace import (OP_READ, OP_WRITE, CompiledTrace, ModuleTrace,
                          RecordedTrace, TraceUnsupported,
                          build_traced_result, compile_trace, record_trace,
                          _cross_buckets, _solve_times, to_compiled_graph)
from ..core.incremental import verify_times
from .fingerprint import (UNCHANGED, DesignDelta, DesignFingerprint, diff,
                          fingerprint_design)

__all__ = ["DeltaState", "PatchOutcome", "PatchReject", "snapshot",
           "apply_patch", "cold_build"]


class PatchReject(Exception):
    """The delta cannot be patched soundly — fall back to a cold rebuild.

    Never an error condition: rejection is the verifier doing its job.
    """


@dataclass
class DeltaState:
    """Reusable snapshot of one recorded design: fingerprint table, the
    value-carrying :class:`RecordedTrace`, the compiled skeleton, and the
    solved node times + depth vector they were solved under (the warm
    seed for the next patch's fixpoint)."""

    fps: DesignFingerprint
    rec: RecordedTrace              # recorded with keep_values=True
    ct: CompiledTrace
    program: Program
    times: Optional[np.ndarray] = None
    depths: Optional[Tuple[int, ...]] = None
    # solver cross-edge buckets (``core.trace._cross_buckets``) — valid
    # for this skeleton + this depth vector's WAR edges; reused by pure
    # timing patches so the warm solve skips bucket reconstruction
    buckets: Optional[dict] = None


@dataclass
class PatchOutcome:
    """Result of one :func:`apply_patch` attempt."""

    ok: bool
    mode: str                       # "seqw" | "recompiled" | "rejected"
    reason: str
    result: Optional[SimResult]
    state: Optional["DeltaState"]
    reused_modules: int
    edited_modules: int
    total_modules: int
    elapsed_s: float

    @property
    def reuse_fraction(self) -> float:
        return self.reused_modules / max(self.total_modules, 1)


def snapshot(program: Program, max_steps: int = 50_000_000,
             fps: Optional[DesignFingerprint] = None,
             ) -> Tuple[SimResult, DeltaState]:
    """Cold record + compile + solve, capturing the delta state.

    One pass: functionally identical to ``simulate_traced`` (same
    ``SimResult``, ``engine="omnisim-trace"``) but records with
    ``keep_values=True`` so subsequent edits can be patched.  Raises
    :class:`TraceUnsupported` for dynamic designs — callers fall back to
    ``simulate`` with no delta state.  ``fps`` lets callers that already
    fingerprinted the design (the cache lookup did, to classify the edit)
    skip re-hashing it here.
    """
    rec = record_trace(program, max_steps, keep_values=True)
    ct = compile_trace(rec, len(program.fifos))
    depths = program.depths()
    war_dst, war_src = ct.war_edges(depths)
    starts = np.asarray([lo for (lo, _) in ct.slices] or [0], np.int64)
    buckets = _cross_buckets(ct, war_dst, war_src, starts)
    times, sweeps = _solve_times(ct, war_dst, war_src, buckets=buckets)
    res = build_traced_result(program, rec, ct, times, war_dst, war_src,
                              sweeps)
    state = DeltaState(fps=fps or fingerprint_design(program), rec=rec,
                       ct=ct, program=program, times=times,
                       depths=tuple(int(d) for d in depths),
                       buckets=buckets)
    return res, state


def cold_build(program: Program, hybrid_cache=None,
               max_steps: int = 50_000_000,
               fps: Optional[DesignFingerprint] = None,
               ) -> Tuple[SimResult, Optional[DeltaState]]:
    """Cold build with best-effort delta capture.

    Traceable (blocking-only) designs go through :func:`snapshot` and
    return a :class:`DeltaState`; dynamic designs fall back to the normal
    ``simulate`` front door (threaded through ``hybrid_cache`` so the
    sweep cache's shared :class:`~repro.core.trace.HybridCache` learns the
    run) and return ``state=None``.
    """
    try:
        return snapshot(program, max_steps, fps=fps)
    except TraceUnsupported:
        from ..core.engine import simulate
        return simulate(program, max_steps=max_steps,
                        hybrid_cache=hybrid_cache), None


def _val_eq(a, b) -> bool:
    """Robust payload equality (ndarray payloads compare by content)."""
    if isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
        return np.array_equal(a, b)
    try:
        return bool(a == b)
    except Exception:
        return False


class _Rerecord:
    """One edited module's sandbox re-recording."""

    __slots__ = ("kind", "fifo", "gap", "end_gap", "writes", "reads",
                 "emits", "skips")

    def __init__(self, kind, fifo, gap, end_gap, writes, reads, emits,
                 skips):
        self.kind = kind
        self.fifo = fifo
        self.gap = gap
        self.end_gap = end_gap
        self.writes = writes        # fid -> [values written]
        self.reads = reads          # fid -> count consumed
        self.emits = emits          # [(key, value)]
        self.skips = skips          # dead probes


def _rerecord_module(module, values: List[list],
                     max_steps: int) -> _Rerecord:
    """Run one module generator in isolation, Reads fed from the recorded
    per-FIFO value streams (sound by KPN determinism *if* the module's own
    writes verify against the recorded streams — the caller checks)."""
    gen = module.fn()
    kinds: List[int] = []
    fids: List[int] = []
    gaps: List[int] = []
    writes: Dict[int, list] = {}
    reads: Dict[int, int] = {}
    emits: List[tuple] = []
    skips = 0
    gap = 1
    send = None
    steps = 0
    while True:
        steps += 1
        if steps > max_steps:
            raise PatchReject(
                f"module '{module.name}': step budget exceeded re-recording")
        try:
            op = gen.send(send)
        except StopIteration:
            end_gap = gap
            break
        send = None
        cls = op.__class__
        if cls is Read:
            fid = op.fifo.fid
            pos = reads.get(fid, 0)
            stream = values[fid] if fid < len(values) else []
            if pos >= len(stream):
                raise PatchReject(
                    f"module '{module.name}' reads past the recorded "
                    f"stream of FIFO '{op.fifo.name}' — would block")
            send = stream[pos]
            reads[fid] = pos + 1
            kinds.append(OP_READ)
            fids.append(fid)
            gaps.append(gap)
            gap = 1
        elif cls is Write:
            fid = op.fifo.fid
            writes.setdefault(fid, []).append(op.value)
            kinds.append(OP_WRITE)
            fids.append(fid)
            gaps.append(gap)
            gap = 1
        elif cls is Delay:
            gap += op.cycles
        elif cls is Emit:
            emits.append((op.key, op.value))
        elif (cls is Empty or cls is Full) and not op.used:
            skips += 1
            gap += 1
        elif cls in (ReadNB, WriteNB, Empty, Full):
            raise PatchReject(
                f"module '{module.name}' issues {cls.__name__} — "
                f"cycle-dependent, not patchable")
        else:
            raise PatchReject(f"module '{module.name}': unknown op {op!r}")
    return _Rerecord(
        kind=np.asarray(kinds, dtype=np.int8),
        fifo=np.asarray(fids, dtype=np.int64),
        gap=np.asarray(gaps, dtype=np.int64),
        end_gap=end_gap, writes=writes, reads=reads, emits=emits,
        skips=skips)


def _module_write_fids(mt: ModuleTrace) -> set:
    kind, fifo, _ = mt.expand()
    return set(int(f) for f in np.unique(fifo[kind == OP_WRITE]))


def apply_patch(state: DeltaState, new_program: Program,
                delta: Optional[DesignDelta] = None,
                max_steps: int = 50_000_000,
                new_fps: Optional[DesignFingerprint] = None) -> PatchOutcome:
    """Patch ``state`` into a verified result for ``new_program``.

    Returns ``ok=False`` (with a reason) instead of raising when the delta
    is not patchable or fails verification — the caller falls back to
    :func:`cold_build`.  A returned ``ok=True`` outcome carries a result
    bit-identical to a cold run plus the refreshed :class:`DeltaState`.
    ``new_fps`` (and ``delta``) let the serving path hash and classify
    once instead of per tier.
    """
    t0 = _time.perf_counter()
    if new_fps is None:
        new_fps = fingerprint_design(new_program)
    if delta is None:
        delta = diff(state.fps, new_fps)
    total = len(new_program.modules)

    def _reject(reason: str) -> PatchOutcome:
        return PatchOutcome(ok=False, mode="rejected", reason=reason,
                            result=None, state=None, reused_modules=0,
                            edited_modules=len(delta.edited),
                            total_modules=total,
                            elapsed_s=_time.perf_counter() - t0)

    if not delta.patchable:
        return _reject(delta.reason or "delta not patchable")
    if state.rec.values is None:
        return _reject("snapshot lacks value capture")

    old_rec = state.rec
    values = old_rec.values
    name_to_mid = {m.name: i for i, m in enumerate(new_program.modules)}
    edited_mids = sorted(name_to_mid[nm] for nm in delta.edited)
    try:
        new_modules = list(old_rec.modules)
        new_emits = list(old_rec.module_emits or [[]] * total)
        new_skips = list(old_rec.module_skips or [0] * total)
        structure_same = True
        reads_delta: Dict[int, int] = {}    # fid -> edited read-count change
        for mid in edited_mids:
            module = new_program.modules[mid]
            rr = _rerecord_module(module, values, max_steps)
            old_mt = old_rec.modules[mid]
            # --- write verification: the KPN-determinism soundness gate.
            # SPSC means this module was the sole writer of each FIFO it
            # wrote, so its recorded per-FIFO write stream is the whole
            # values[fid] stream — require exact equality.
            if set(rr.writes) != _module_write_fids(old_mt):
                raise PatchReject(
                    f"module '{module.name}' writes a different FIFO set")
            for fid, ws in rr.writes.items():
                old_ws = values[fid]
                if len(ws) != len(old_ws) or not all(
                        _val_eq(a, b) for a, b in zip(ws, old_ws)):
                    raise PatchReject(
                        f"module '{module.name}' write stream diverged on "
                        f"FIFO {fid} — functional change, not a timing "
                        f"edit")
            ok_kind, ok_fifo, _ = old_mt.expand()
            if (len(rr.kind) != len(ok_kind)
                    or not np.array_equal(rr.kind, ok_kind)
                    or not np.array_equal(rr.fifo, ok_fifo)):
                structure_same = False
            old_rf = ok_fifo[ok_kind == OP_READ]
            for fid, cnt in zip(*np.unique(old_rf, return_counts=True)):
                reads_delta[int(fid)] = reads_delta.get(int(fid), 0) \
                    - int(cnt)
            for fid, cnt in rr.reads.items():
                reads_delta[fid] = reads_delta.get(fid, 0) + cnt
            new_modules[mid] = ModuleTrace(
                mid=mid, name=module.name, kind=rr.kind, fifo=rr.fifo,
                gap=rr.gap, end_gap=rr.end_gap).periodize()
            new_emits[mid] = list(rr.emits)
            new_skips[mid] = rr.skips

        # functional splice: leftovers from total read counts — derived
        # incrementally (old totals from the recorded leftovers, adjusted
        # by the edited modules' read-count change) so the splice is
        # O(edited), not O(all modules) — outputs from per-module emit
        # lists, dead-probe totals from per-module counts
        n_fifos = len(new_program.fifos)
        reads_total = [len(values[fid]) - len(old_rec.leftovers[fid])
                       for fid in range(n_fifos)]
        for fid, d in reads_delta.items():
            reads_total[fid] += d
        for fid in range(n_fifos):
            if reads_total[fid] > len(values[fid]) or reads_total[fid] < 0:
                raise PatchReject(
                    f"FIFO {fid}: spliced reads ({reads_total[fid]}) exceed "
                    f"recorded writes ({len(values[fid])})")
        leftovers = [list(values[fid][reads_total[fid]:])
                     for fid in range(n_fifos)]
        outputs: Dict[str, Any] = {}
        for em in new_emits:
            for k, v in em:
                outputs[k] = v
        new_rec = RecordedTrace(
            program=new_program.name, modules=new_modules, outputs=outputs,
            leftovers=leftovers, skipped_probes=sum(new_skips),
            steps=old_rec.steps, activations=old_rec.activations,
            values=values, module_emits=new_emits, module_skips=new_skips)

        # graph splice: patch SEQ weights in place when the edited modules'
        # op structure is unchanged (pure timing edit), else recompile the
        # numpy-cheap skeleton from the spliced rows
        if structure_same:
            seq_w = state.ct.seq_w.copy()
            for mid in edited_mids:
                lo, hi = state.ct.slices[mid]
                _, _, gaps = new_modules[mid].expand()
                seq_w[lo + 1:hi - 1] = gaps
                seq_w[hi - 1] = new_modules[mid].end_gap
            ct = dataclasses.replace(state.ct, seq_w=seq_w, trace=new_rec)
            mode = "seqw"
        else:
            ct = compile_trace(new_rec, n_fifos)
            mode = "recompiled"

        depths = new_program.depths()
        war_dst, war_src = ct.war_edges(depths)
        # warm-start the fixpoint from the old solution when the graph
        # skeleton and depth vector are unchanged (pure timing edit):
        # only the edited chains start dirty, so the solve cost tracks
        # the edit's cone of influence, not the design.  Sound for weight
        # increases; a decrease can overshoot the least fixpoint, which
        # the pointwise verification below catches — then we re-solve
        # cold once before giving up.
        warm_ok = (mode == "seqw" and state.times is not None
                   and state.depths == tuple(int(d) for d in depths))
        # when additionally no SEQ weight *decreased*, the old solution is
        # a provable lower bound of the new least fixpoint (same node set,
        # pointwise-larger weights), and ascending Gauss-Seidel from any
        # lower bound lands exactly on the least fixpoint — the same
        # solution the cold NEGI-seeded solve computes.  The pointwise
        # check is then mathematically redundant and skipped; any other
        # shape of patch still verifies before being served.  (The
        # differential suite pins this equivalence bit-for-bit.)
        monotone = warm_ok and bool(np.all(ct.seq_w >= state.ct.seq_w))
        if warm_ok and state.buckets is not None:
            # same skeleton + same depths => identical cross-edge content:
            # reuse the snapshot's bucket table
            buckets = state.buckets
        else:
            starts = np.asarray([lo for (lo, _) in ct.slices] or [0],
                                np.int64)
            buckets = _cross_buckets(ct, war_dst, war_src, starts)
        if warm_ok:
            times, sweeps = _solve_times(ct, war_dst, war_src,
                                         warm=(state.times, edited_mids),
                                         buckets=buckets)
        else:
            times, sweeps = _solve_times(ct, war_dst, war_src,
                                         buckets=buckets)
        graph = to_compiled_graph(ct)
        if not monotone:
            err = verify_times(graph, times, depths)
            if err is not None and warm_ok:
                times, sweeps = _solve_times(ct, war_dst, war_src)
                err = verify_times(graph, times, depths)
            if err is not None:
                raise PatchReject(f"verification failed: {err}")
    except PatchReject as e:
        return _reject(str(e))
    except TraceUnsupported as e:
        return _reject(f"splice not trace-compilable: {e}")

    res = build_traced_result(new_program, new_rec, ct, times, war_dst,
                              war_src, sweeps, graph=graph)
    new_state = DeltaState(fps=new_fps, rec=new_rec, ct=ct,
                           program=new_program, times=times,
                           depths=tuple(int(d) for d in depths),
                           buckets=buckets)
    return PatchOutcome(
        ok=True, mode=mode, reason="", result=res, state=new_state,
        reused_modules=total - len(edited_mids),
        edited_modules=len(edited_mids), total_modules=total,
        elapsed_s=_time.perf_counter() - t0)
