"""Train-step and serve-step factories.

``make_train_step`` builds the jittable update: loss -> grad -> global-norm
clip -> AdamW -> new params.  The LR schedule is traced from the step
counter inside the optimizer state, so one compiled executable serves the
whole run.  ``make_prefill_step``/``make_decode_step`` build the serving
entry points.  All factories are pure closures over the config — the same
functions are used by the real trainer, the smoke tests and the multi-pod
dry-run (which lowers them with ShapeDtypeStructs).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..distrib.sharding import active_mesh, param_specs
from ..models import api
from ..optim.adamw import AdamWState, adamw_update, clip_by_global_norm, \
    init_adamw
from ..optim.schedules import cosine_schedule, wsd_schedule


def constrain_like_params(tree):
    """Pin a params-shaped tree (grads, moments) to the param shardings —
    the scan backward otherwise leaves XLA free to replicate gradients."""
    mesh = active_mesh()
    if mesh is None:
        return tree
    from jax.sharding import NamedSharding
    specs = param_specs(tree)
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, s)), tree, specs)


def lr_for(cfg: ArchConfig, step, total_steps: int = 10_000,
           peak_lr: float = 3e-4):
    if cfg.name.startswith("minicpm"):
        # MiniCPM trains with WSD (arXiv:2404.06395)
        return wsd_schedule(step, peak_lr=peak_lr, warmup_steps=100,
                            stable_steps=int(total_steps * 0.8),
                            decay_steps=int(total_steps * 0.1))
    return cosine_schedule(step, peak_lr=peak_lr, warmup_steps=100,
                           total_steps=total_steps)


def make_train_step(cfg: ArchConfig, total_steps: int = 10_000,
                    peak_lr: float = 3e-4, max_grad_norm: float = 1.0,
                    cast_bf16: bool = True,
                    grad_compression: bool = False) -> Callable:
    def train_step(params, opt_state: AdamWState, batch: Dict[str, Any]):
        def loss(p):
            if cast_bf16:
                # cast once at step entry: FSDP all-gathers then move bf16
                # payloads (2x collective reduction); fp32 masters stay in
                # the optimizer.  (§Perf iteration A)
                p = jax.tree.map(
                    lambda a: a.astype(jnp.bfloat16)
                    if a.dtype == jnp.float32 else a, p)
            return api.loss_fn(p, batch["tokens"], batch["targets"], cfg,
                               batch.get("frontend"))

        loss_val, grads = jax.value_and_grad(loss)(params)
        if grad_compression:
            # error-feedback int8: quantize (residual carried step to step —
            # here within-step demo), transport-sized like the compressed
            # DP all-reduce, then dequantize before the update.
            from ..optim.compression import compress, decompress, \
                init_residuals
            q, scales, _ = compress(grads, init_residuals(grads))
            grads = decompress(q, scales)
        grads = constrain_like_params(grads)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        lr = lr_for(cfg, opt_state.step, total_steps, peak_lr)
        params, opt_state = adamw_update(grads, opt_state, params, lr)
        metrics = {"loss": loss_val, "grad_norm": gnorm, "lr": lr}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig) -> Callable:
    def prefill_step(params, batch: Dict[str, Any]):
        logits = api.forward(params, batch["tokens"], cfg,
                             batch.get("frontend"))
        # serving returns only the last position's logits
        return logits[:, -1, :]

    return prefill_step


def make_decode_step(cfg: ArchConfig) -> Callable:
    def decode_step(params, tokens, cache):
        logits, cache = api.decode_step(params, tokens, cache, cfg)
        next_token = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
        return next_token.astype(jnp.int32), cache

    return decode_step


def init_train_state(key, cfg: ArchConfig):
    params = api.init_params(key, cfg)
    return params, init_adamw(params)
