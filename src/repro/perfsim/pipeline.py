"""OmniSim applied to distributed training schedules — the paper's technique
as a first-class framework feature.

A pipeline-parallel training step IS a dataflow design: stages are modules,
the activation/grad queues between them are finite-depth FIFOs, microbatches
are tokens flowing through.  GPipe and 1F1B are just different module bodies.
The OmniSim engine then gives, *for free*:

  * cycle-accurate step-time prediction (ticks = microseconds here),
  * deadlock detection for under-provisioned buffer depths — the classic
    pipeline-schedule bug, caught by the engine instead of a hung job,
  * incremental re-simulation over buffer depths (paper Sec. 7.2): schedule
    DSE sweeps depths in microseconds instead of re-simulating each point,
  * bubble-fraction accounting from the simulation graph.

Tick costs come from the dry-run roofline terms (launch/roofline.py):
per-stage forward/backward compute ticks and inter-stage P2P ticks.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from ..core.engine import simulate
from ..core.incremental import IncrementalOutcome, resimulate
from ..core.program import Delay, Emit, Program, Read, Write
from ..core.rtlsim import simulate_rtl


@dataclass
class PipelineSpec:
    stages: int
    microbatches: int
    fwd_ticks: int                  # per-stage per-microbatch forward time
    bwd_ticks: int                  # per-stage per-microbatch backward time
    p2p_ticks: int = 1              # inter-stage activation/grad transfer
    buffer_depth: int = 2           # activation queue slots between stages
    schedule: str = "1f1b"          # "gpipe" | "1f1b"
    dp_allreduce_ticks: int = 0     # overlapped DP gradient all-reduce


def build_pipeline_program(spec: PipelineSpec) -> Program:
    """Construct the dataflow program for a pipeline schedule."""
    prog = Program(f"pipeline_{spec.schedule}_{spec.stages}s_{spec.microbatches}mb",
                   declared_type="B")
    S, M = spec.stages, spec.microbatches
    # FIFOs: forward activations fwd[i] from stage i -> i+1;
    #        backward grads bwd[i] from stage i+1 -> i.
    fwd = [prog.fifo(f"act{i}", spec.buffer_depth) for i in range(S - 1)]
    bwd = [prog.fifo(f"grad{i}", spec.buffer_depth) for i in range(S - 1)]
    grads_out = prog.fifo("grads_out", M)   # per-microbatch grad chunks to DP

    def make_stage(i: int):
        first, last = i == 0, i == S - 1

        def gpipe():
            # all forwards, then all backwards
            for m in range(M):
                if not first:
                    yield Read(fwd[i - 1])
                yield Delay(spec.fwd_ticks)
                if not last:
                    yield Delay(spec.p2p_ticks)
                    yield Write(fwd[i], ("a", m))
            for m in range(M):
                if not last:
                    yield Read(bwd[i])
                yield Delay(spec.bwd_ticks)
                if not first:
                    yield Delay(spec.p2p_ticks)
                    yield Write(bwd[i - 1], ("g", m))
            if first:
                yield Write(grads_out, i)
            yield Emit(f"stage{i}_done", True)

        def one_f_one_b():
            # warmup forwards = stages - i - 1, then steady 1F1B
            warmup = min(S - 1 - i, M)
            done_f = done_b = 0
            for _ in range(warmup):
                if not first:
                    yield Read(fwd[i - 1])
                yield Delay(spec.fwd_ticks)
                done_f += 1
                if not last:
                    yield Delay(spec.p2p_ticks)
                    yield Write(fwd[i], ("a", done_f))
            while done_b < M:
                if done_f < M:
                    if not first:
                        yield Read(fwd[i - 1])
                    yield Delay(spec.fwd_ticks)
                    done_f += 1
                    if not last:
                        yield Delay(spec.p2p_ticks)
                        yield Write(fwd[i], ("a", done_f))
                if not last:
                    yield Read(bwd[i])
                yield Delay(spec.bwd_ticks)
                done_b += 1
                if not first:
                    yield Delay(spec.p2p_ticks)
                    yield Write(bwd[i - 1], ("g", done_b))
            if first:
                yield Write(grads_out, i)
            yield Emit(f"stage{i}_done", True)

        return one_f_one_b if spec.schedule == "1f1b" else gpipe

    for i in range(S):
        prog.add_module(f"stage{i}", make_stage(i))

    # DP gradient all-reduce, overlapped: starts when the first stage
    # finishes its grads; a Type B consumer of the grads_out channel.
    if spec.dp_allreduce_ticks:
        @prog.module("dp_allreduce")
        def dp_allreduce():
            yield Read(grads_out)
            yield Delay(spec.dp_allreduce_ticks)
            yield Emit("allreduce_done", True)
    else:
        @prog.module("dp_sink")
        def dp_sink():
            yield Read(grads_out)

    return prog


@dataclass
class PipelineResult:
    step_ticks: int
    bubble_fraction: float
    deadlock: bool
    result: object


def simulate_pipeline(spec: PipelineSpec, engine: str = "omnisim"
                      ) -> PipelineResult:
    prog = build_pipeline_program(spec)
    res = simulate(prog) if engine == "omnisim" else simulate_rtl(prog)
    ideal = spec.microbatches * (spec.fwd_ticks + spec.bwd_ticks) \
        + (spec.stages - 1) * (spec.fwd_ticks + spec.bwd_ticks + 2 * spec.p2p_ticks)
    busy = spec.microbatches * (spec.fwd_ticks + spec.bwd_ticks)
    bubble = 1.0 - busy / res.cycles if res.cycles and not res.deadlock else 1.0
    return PipelineResult(step_ticks=res.cycles, bubble_fraction=bubble,
                          deadlock=res.deadlock, result=res)


def buffer_depth_dse(spec: PipelineSpec, depths: List[int]
                     ) -> List[Tuple[int, PipelineResult, Optional[float]]]:
    """FIFO-sizing DSE via incremental re-simulation (paper Sec. 7.2/Table 6
    retargeted at pipeline buffers).  Returns (depth, result, incr_time_s)."""
    base_spec = dataclasses.replace(spec, buffer_depth=depths[0])
    base = simulate_pipeline(base_spec)
    out = [(depths[0], base, None)]
    for d in depths[1:]:
        n_chan = 2 * (spec.stages - 1)
        new_depths = tuple([d] * n_chan + [spec.microbatches])
        inc = resimulate(base.result, new_depths)
        res = inc.result
        busy = spec.microbatches * (spec.fwd_ticks + spec.bwd_ticks)
        bubble = 1.0 - busy / res.cycles if res.cycles and not res.deadlock else 1.0
        out.append((d, PipelineResult(res.cycles, bubble, res.deadlock, res),
                    inc.elapsed_s if inc.ok else -inc.elapsed_s))
    return out
