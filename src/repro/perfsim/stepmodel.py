"""Bridge: dry-run roofline records -> pipeline dataflow specs.

Takes the per-cell roofline terms produced by ``launch/dryrun.py`` and
derives tick costs for a hypothetical pipeline-parallel deployment of the
same model (stages split layers; microbatches split the global batch), so
``perfsim.pipeline`` can predict step time and sweep schedules before any
hardware run — the OmniSim use case transplanted to distributed training.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass
from typing import Dict, Optional

from .pipeline import PipelineSpec

TICK_US = 1.0        # one simulation cycle == 1 microsecond


def spec_from_roofline(record: Dict, stages: int = 8, microbatches: int = 32,
                       buffer_depth: int = 2, schedule: str = "1f1b"
                       ) -> PipelineSpec:
    """record: one dry-run JSON (launch/dryrun.py).  The cell's dominant-term
    step time is split: forward = 1/3 compute, backward = 2/3 (standard
    fwd:bwd FLOP ratio); per-stage per-microbatch ticks follow."""
    roof = record["roofline"]
    step_s = max(roof["compute_s"], roof["memory_s"], roof["collective_s"])
    total_ticks = step_s * 1e6 / TICK_US
    per_mb_stage = max(1, int(round(total_ticks / (stages * microbatches))))
    fwd = max(1, per_mb_stage // 3)
    bwd = max(1, per_mb_stage - fwd)
    coll = int(roof["collective_s"] * 1e6 / TICK_US / stages)
    return PipelineSpec(stages=stages, microbatches=microbatches,
                        fwd_ticks=fwd, bwd_ticks=bwd, p2p_ticks=1,
                        buffer_depth=buffer_depth, schedule=schedule,
                        dp_allreduce_ticks=max(0, coll))


def load_record(out_dir: str, arch: str, shape: str,
                mesh: str = "sp") -> Optional[Dict]:
    path = os.path.join(out_dir, f"{arch}__{shape}__{mesh}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)
