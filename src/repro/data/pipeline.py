"""Deterministic synthetic token pipeline — shard-aware and checkpointable.

A real deployment would stream tokenized shards from object storage; the
interface here is identical (``state()`` / ``restore()`` for exact resume,
per-host sharding by ``host_id``/``num_hosts``) but the source is a counter-
seeded PRNG so experiments are reproducible bit-for-bit and runnable offline.
The iterator yields host-local batches; ``launch/train.py`` assembles them
into a global array with ``jax.make_array_from_process_local_data``-style
placement (single-host here: direct device_put with the batch sharding).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frontend_tokens: int = 0
    d_model: int = 0


class SyntheticTokenStream:
    """Counter-based deterministic stream: batch i is a pure function of
    (seed, i, host), so restart-after-failure resumes exactly."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, num_hosts: int = 1):
        assert cfg.global_batch % num_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.local_batch = cfg.global_batch // num_hosts
        self._step = 0

    # ---- checkpointable iterator state ----
    def state(self) -> Dict[str, int]:
        return {"step": self._step, "seed": self.cfg.seed,
                "host_id": self.host_id}

    def restore(self, state: Dict[str, int]) -> None:
        assert state["seed"] == self.cfg.seed, "seed mismatch on restore"
        self._step = int(state["step"])

    # ---- iteration ----
    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.cfg.seed * 1_000_003 + step) * 4096 + self.host_id)

    def next_batch(self) -> Dict[str, np.ndarray]:
        rng = self._rng(self._step)
        self._step += 1
        c = self.cfg
        tokens = rng.integers(0, c.vocab_size,
                              size=(self.local_batch, c.seq_len),
                              dtype=np.int32)
        batch = {
            "tokens": tokens,
            # next-token targets (synthetic stream: shifted tokens)
            "targets": np.roll(tokens, -1, axis=1),
        }
        if c.frontend_tokens:
            batch["frontend"] = rng.standard_normal(
                (self.local_batch, c.frontend_tokens, c.d_model),
                dtype=np.float32) * 0.02
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()
