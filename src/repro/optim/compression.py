"""Error-feedback int8 gradient compression (1-bit-Adam-style residuals).

For bandwidth-limited DP all-reduces: gradients are quantized to int8 with a
per-tensor scale before the (implicit, XLA-inserted) all-reduce; quantization
error is carried in a residual buffer and re-added the next step, which keeps
convergence unbiased in expectation.  Enabled via ``--grad-compression`` in
launch/train.py; off by default (bf16 grads already halve DP traffic).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def compress(grads, residuals):
    """Returns (int8 grads, scales, new residuals)."""
    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        new_r = g32 - q.astype(jnp.float32) * scale
        return q, scale, new_r

    flat, treedef = jax.tree.flatten(grads)
    rflat = jax.tree.leaves(residuals)
    out = [one(g, r) for g, r in zip(flat, rflat)]
    qs = jax.tree.unflatten(treedef, [o[0] for o in out])
    scales = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_res = jax.tree.unflatten(treedef, [o[2] for o in out])
    return qs, scales, new_res


def decompress(qs, scales):
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s, qs, scales)
