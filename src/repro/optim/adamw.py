"""AdamW optimizer (from scratch — no optax in this environment).

Optimizer state is a pytree mirroring the params, so the same sharding
specs apply (fully sharded optimizer states = ZeRO-style memory scaling).
Supports decoupled weight decay, global-norm clipping, and an optional
error-feedback int8 gradient-compression hook (optim/compression.py) for
bandwidth-constrained DP all-reduces.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def init_adamw(params) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm


def adamw_update(grads, state: AdamWState, params, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1):
    step = state.step + 1
    t = step.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g32
        v2 = b2 * v + (1 - b2) * g32 * g32
        mhat = m2 / (1 - b1 ** t)
        vhat = v2 / (1 - b2 ** t)
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat, treedef = jax.tree.flatten(params)
    gflat = jax.tree.leaves(grads)
    mflat = jax.tree.leaves(state.mu)
    vflat = jax.tree.leaves(state.nu)
    out = [upd(g, m, v, p) for g, m, v, p in zip(gflat, mflat, vflat, flat)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v)
