"""LR schedules: cosine and WSD (warmup-stable-decay, MiniCPM arXiv:2404.06395).

WSD is the schedule minicpm-2b was trained with; it is the default for that
arch in launch/train.py.
"""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, *, peak_lr: float, warmup_steps: int,
                    total_steps: int, final_frac: float = 0.1):
    t = step.astype(jnp.float32)
    warm = t / jnp.maximum(1.0, warmup_steps)
    prog = jnp.clip((t - warmup_steps) / jnp.maximum(1.0, total_steps - warmup_steps), 0, 1)
    cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return peak_lr * jnp.where(t < warmup_steps, warm, cos)


def wsd_schedule(step, *, peak_lr: float, warmup_steps: int,
                 stable_steps: int, decay_steps: int,
                 final_frac: float = 0.01):
    """Warmup -> Stable (constant) -> Decay (exponential-ish linear)."""
    t = step.astype(jnp.float32)
    warm = t / jnp.maximum(1.0, warmup_steps)
    in_decay = t - (warmup_steps + stable_steps)
    decay = final_frac ** jnp.clip(in_decay / jnp.maximum(1.0, decay_steps), 0, 1)
    lr = jnp.where(t < warmup_steps, warm,
                   jnp.where(in_decay < 0, 1.0, decay))
    return peak_lr * lr
