"""The sweep service front door: submit / stream / stats.

:class:`SweepService` ties the warm cache (``cache.py``) to the
continuous-batching scheduler (``scheduler.py``) behind a three-call API:

    with SweepService() as svc:
        handle = svc.submit(program, depths=D)       # non-blocking
        for cfg in handle.stream():                  # per-config results
            ...
        outcome = handle.result()                    # BatchOutcome view

``submit`` resolves the design against the warm cache on the *caller's*
thread (a cold miss pays the one-off initial simulation + graph hoisting
there, keeping the scheduler loop hot for everyone else), then enqueues
the depth matrix.  Requests with at most ``interactive_max`` rows ride
the interactive priority lane; big sweeps go bulk.  ``sweep()`` is the
blocking convenience wrapper, ``stream()`` the one-shot iterator.

Fault tolerance (ISSUE 6): ``submit`` takes a ``tenant`` and an optional
``deadline_s`` — the deadline is enforced end-to-end by the scheduler
(undelivered rows of an expired request terminate ``TIMED_OUT``, never
hang).  Before a request touches the cache, the service checks the
design's :class:`~repro.sweep.faults.DesignQuarantine` (a poisoned design
is refused fast) and the :class:`~repro.sweep.admission.AdmissionController`
(per-tenant in-flight row quotas + queue-depth load shedding); a refused
request returns a handle whose every row is ``REJECTED`` with a reason —
a definite verdict, not an exception and not a stuck stream.  Admission
reservations are released when the request's stream finishes for *any*
reason (delivered, cancelled, faulted, timed out).  ``close(drain=True)``
flushes in-flight sweeps before shutting down and fails never-scheduled
ones loudly.

Every verdict that IS delivered is exactly what a direct
``resimulate_batch`` — and therefore a from-scratch ``simulate`` — would
report for that depth vector; the golden conformance suite
(``tests/test_golden.py``) pins this bit-for-bit across block splits,
shard counts, cache states and injected faults.
"""
from __future__ import annotations

import queue
import threading
import time as _time
from typing import Dict, Iterator, Optional, Union

import numpy as np

from ..core.dse import (CANCELLED, REJECTED, BatchOutcome,
                        program_mutation_lock)
from ..core.program import Program, SimResult
from ..core.trace import program_fingerprint
from .admission import DEFAULT_TENANT, AdmissionController
from .cache import GraphCache
from .faults import DesignQuarantine, FaultInjector, RetryPolicy
from .scheduler import (BULK, INTERACTIVE, _DONE, BlockScheduler,
                        ConfigResult, _Request)


class SweepTimeoutError(TimeoutError):
    """``SweepHandle.stream/result(timeout=...)`` saw no result within
    ``timeout`` seconds.  The handle stays live: call ``stream()`` or
    ``result()`` again to keep consuming from where it stopped."""

    def __init__(self, request_id: int, delivered: int, total: int,
                 timeout: float):
        super().__init__(
            f"sweep request {request_id}: no result within {timeout:.6g}s "
            f"({delivered}/{total} configs delivered so far; the handle "
            f"is still live — call stream()/result() again to resume)")
        self.request_id = request_id
        self.delivered = delivered
        self.total = total
        self.timeout = timeout


class SweepHandle:
    """Client-side view of one submitted sweep (single consumer)."""

    def __init__(self, request: _Request, scheduler: BlockScheduler):
        self._req = request
        self._sched = scheduler
        self._collected: Dict[int, ConfigResult] = {}
        self._closed = False
        self._lock = threading.Lock()

    @property
    def request_id(self) -> int:
        return self._req.rid

    @property
    def n_configs(self) -> int:
        return self._req.K

    @property
    def done(self) -> bool:
        return self._closed

    @property
    def cancelled(self) -> bool:
        return self._req.cancelled.is_set()

    @property
    def rejected(self) -> bool:
        """True when admission control or quarantine refused this sweep
        (every row reports ``REJECTED`` with the reason)."""
        return self._req.reject_reason is not None

    @property
    def tenant(self) -> str:
        return self._req.tenant

    def cancel(self) -> None:
        """Stop scheduling this sweep at the next block boundary.

        Results already streamed stay valid; rows never solved surface as
        ``CANCELLED`` entries in :meth:`result`.
        """
        self._req.cancelled.set()
        self._sched.kick()

    def stream(self, timeout: Optional[float] = None
               ) -> Iterator[ConfigResult]:
        """Yield per-config results as blocks complete (completion order;
        each :class:`ConfigResult` carries its row ``index``).  Ends when
        every row was delivered or the request was cancelled; raises
        ``RuntimeError`` if the scheduler aborted the request (fault or
        service shutdown), and :class:`SweepTimeoutError` if ``timeout``
        seconds pass without a result (the handle stays resumable)."""
        while not self._closed:
            try:
                item = self._req.out_q.get(timeout=timeout)
            except queue.Empty:
                raise SweepTimeoutError(
                    self._req.rid, len(self._collected), self._req.K,
                    timeout if timeout is not None else 0.0) from None
            if item is _DONE:
                self._closed = True
                break
            self._collected[item.index] = item
            yield item
        if self._req.error:        # also on re-entry after a fault
            raise RuntimeError(self._req.error)

    def result(self, timeout: Optional[float] = None) -> BatchOutcome:
        """Drain the stream and assemble a :class:`BatchOutcome` indexed
        like the submitted depth matrix (blocking)."""
        for _ in self.stream(timeout=timeout):
            pass
        K = self._req.K
        ok = np.zeros(K, dtype=bool)
        cycles = np.full(K, -1, dtype=np.int64)
        violated = np.zeros(K, dtype=np.int64)
        if self._req.reject_reason is not None:
            status = np.full(K, REJECTED, dtype=np.int8)
            reasons = [self._req.reject_reason] * K
        else:
            status = np.full(K, CANCELLED, dtype=np.int8)
            reasons = ["request cancelled before this config was "
                       "scheduled"] * K
        results = [None] * K
        for i, cfg in self._collected.items():
            ok[i] = cfg.ok
            cycles[i] = cfg.cycles
            status[i] = cfg.status
            violated[i] = cfg.violated
            reasons[i] = cfg.reason
            results[i] = cfg.result
        uniq = (len(np.unique(self._req.D, axis=0))
                if K > 1 else K)
        return BatchOutcome(ok=ok, cycles=cycles, status=status,
                            violated=violated, reasons=reasons,
                            results=results,
                            elapsed_s=_time.perf_counter()
                            - self._req.t_submit, n_unique=uniq)


class SweepService:
    """Served design-space exploration over a warm compiled-graph cache."""

    def __init__(self, cache_capacity: int = 8, block: int = 128,
                 shards: int = 1, mode: str = "thread",
                 interactive_max: int = 16, starvation_limit: int = 4,
                 backend: str = "numpy", autostart: bool = True,
                 min_shard_rows: int = 8,
                 retry: Optional[RetryPolicy] = None,
                 injector: Optional[FaultInjector] = None,
                 shard_timeout_s: Optional[float] = 30.0,
                 quarantine_after: int = 3,
                 quarantine_cooldown_s: Optional[float] = None,
                 max_pool_respawns: int = 2,
                 max_inflight_rows_per_tenant: Optional[int] = None,
                 max_queued_rows: Optional[int] = None,
                 default_deadline_s: Optional[float] = None,
                 jax_interpret: bool = True,
                 memo_capacity: int = 4096):
        self.cache = GraphCache(capacity=cache_capacity)
        quarantine = DesignQuarantine(threshold=quarantine_after,
                                      cooldown_s=quarantine_cooldown_s)
        self.scheduler = BlockScheduler(block=block, shards=shards,
                                        mode=mode,
                                        starvation_limit=starvation_limit,
                                        backend=backend,
                                        min_shard_rows=min_shard_rows,
                                        retry=retry, injector=injector,
                                        shard_timeout_s=shard_timeout_s,
                                        quarantine=quarantine,
                                        max_pool_respawns=max_pool_respawns,
                                        jax_interpret=jax_interpret,
                                        memo_capacity=memo_capacity)
        self.scheduler.hybrid = self.cache.hybrid
        self.admission = AdmissionController(
            max_inflight_rows_per_tenant=max_inflight_rows_per_tenant,
            max_queued_rows=max_queued_rows)
        self.quarantine = quarantine
        self.interactive_max = interactive_max
        self.default_deadline_s = default_deadline_s
        self._autostart = autostart
        self._rid = 0
        self._rid_lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------ runtime
    def _loop(self) -> None:
        consec_faults = 0
        while not self._stop.is_set():
            try:
                progressed = self.scheduler.step()
                consec_faults = 0
            except Exception as exc:        # noqa: BLE001 — must not die
                # step() already failed exactly the faulting block's
                # requests (error + terminal sentinel) — other tenants'
                # queued sweeps keep being served.  Only a *persistently*
                # faulting scheduler (e.g. a broken assemble path that
                # fails before any block exists) aborts everything rather
                # than spinning hot forever.
                consec_faults += 1
                if consec_faults >= 5:
                    self.scheduler.abort_pending(
                        f"sweep scheduler failing persistently: {exc!r}")
                    consec_faults = 0
                continue
            if not progressed:
                self.scheduler.wait_for_work(timeout=0.05)

    def _ensure_thread(self) -> None:
        if not self._autostart or (self._thread and self._thread.is_alive()):
            return
        self._thread = threading.Thread(target=self._loop,
                                        name="sweep-scheduler", daemon=True)
        self._thread.start()

    def step(self) -> bool:
        """Manual-mode progress (``autostart=False``): run one scheduler
        block on the calling thread.  Deterministic tests drive this."""
        return self.scheduler.step()

    def close(self, drain: bool = True) -> None:
        """Shut the service down.

        ``drain=True`` (default) flushes gracefully: requests that already
        have rows in completed blocks finish their remaining rows; queued
        requests that never reached a block fail loudly (error + terminal
        sentinel).  ``drain=False`` aborts everything immediately.  Either
        way no client stream is left hanging.
        """
        self._stop.set()
        self.scheduler.kick()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
        if drain:
            self.scheduler.drain("sweep service closed")
        # anything still queued (drain=False, or a request the drain could
        # not flush) gets its terminal sentinel instead of leaving its
        # consumer blocked forever
        self.scheduler.abort_pending("sweep service closed")
        self.scheduler.close()

    def __enter__(self) -> "SweepService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------- intake
    def warm(self, design: Union[Program, SimResult],
             key: Optional[str] = None):
        """Pre-populate the cache for ``design`` (cold-start off the
        request path); returns the warm entry."""
        return self.cache.get_or_build(design, key=key)

    def edit_session(self, design: Program,
                     key: Optional[str] = None) -> "EditSession":
        """Open an interactive edit-and-resimulate session on ``design``.

        Returns a :class:`repro.delta.EditSession`: call
        ``update(new_program)`` after each code edit and the service
        re-records only what the structural delta requires (exact-key hit
        → per-module trace patch → cold rebuild, see ``repro.delta``),
        then serve sweeps of the edited design through the handle's
        ``submit``/``sweep`` passthroughs.  Patched graphs land in the
        warm cache under the edited design's own fingerprint, so queued
        rows against the pre-edit design are unaffected.
        """
        if self._stop.is_set():
            raise RuntimeError("sweep service is closed")
        from ..delta.session import EditSession
        return EditSession(self, design, key=key)

    def _rejected_handle(self, D: np.ndarray, reason: str, tenant: str,
                         fallback: bool) -> SweepHandle:
        """A handle that never touches the scheduler: every row reports
        ``REJECTED`` with ``reason`` — definite, immediate, no hang."""
        with self._rid_lock:
            self._rid += 1
            rid = self._rid
        req = _Request(rid, None, D, INTERACTIVE, fallback, queue.Queue(),
                       tenant=tenant)
        req.reject_reason = reason
        req.finalized = True
        req.out_q.put(_DONE)
        return SweepHandle(req, self.scheduler)

    def submit(self, design: Union[Program, SimResult], depths,
               key: Optional[str] = None, priority: Optional[str] = None,
               fallback: bool = True, tenant: str = DEFAULT_TENANT,
               deadline_s: Optional[float] = None) -> SweepHandle:
        """Enqueue a sweep of ``depths`` (one row = one candidate depth
        vector) against ``design`` and return a :class:`SweepHandle`.

        ``design`` is a :class:`Program` or a finished base
        :class:`SimResult`; repeat designs (by content fingerprint or
        explicit ``key``) are served from the warm cache.  ``priority``
        defaults to ``"interactive"`` for at most ``interactive_max`` rows
        and ``"bulk"`` otherwise.  ``tenant`` names the client for
        admission-control quotas; ``deadline_s`` (default
        ``default_deadline_s``) bounds the request end-to-end — rows not
        delivered in time terminate ``TIMED_OUT``.  A request refused by
        quarantine or admission control returns a handle whose rows are
        all ``REJECTED`` (see :attr:`SweepHandle.rejected`).
        """
        if self._stop.is_set():
            raise RuntimeError("sweep service is closed")
        if deadline_s is None:
            deadline_s = self.default_deadline_s
        D = np.asarray(depths, dtype=np.int64)
        if D.ndim == 1:
            D = D[None, :]
        program = (design.graph.program if isinstance(design, SimResult)
                   else design)
        if key is None:
            with program_mutation_lock(program):
                key = program_fingerprint(program)
        # refuse before building: a quarantined design must not cost a
        # cache build, and a shed request must not evict a warm entry
        if self.quarantine.is_quarantined(key):
            why = self.quarantine.reason(key)
            return self._rejected_handle(
                D, "design quarantined after repeated solve faults"
                   f"{': ' + why if why else ''}", tenant, fallback)
        shed = self.admission.try_admit(tenant, len(D))
        if shed is not None:
            return self._rejected_handle(D, shed, tenant, fallback)
        try:
            entry = self.cache.get_or_build(design, key=key)
            if D.ndim != 2 or D.shape[1] != entry.n_fifos:
                raise ValueError(f"depth matrix {D.shape} does not match "
                                 f"{entry.n_fifos} FIFOs")
        except Exception as exc:
            self.admission.release(tenant, len(D))
            if not isinstance(exc, ValueError):
                self.quarantine.strike(key, f"cache build faulted: {exc!r}")
            raise
        if priority is None:
            priority = INTERACTIVE if len(D) <= self.interactive_max else BULK
        assert priority in (INTERACTIVE, BULK), priority
        with self._rid_lock:
            self._rid += 1
            rid = self._rid
        req = _Request(rid, entry, D, priority, fallback, queue.Queue(),
                       tenant=tenant, deadline_s=deadline_s,
                       on_finalize=lambda r:
                           self.admission.release(r.tenant, r.K))
        handle = SweepHandle(req, self.scheduler)
        if req.K == 0:
            # an empty sweep completes immediately — it must never reach
            # the scheduler (a zero-row block would fault the loop)
            req.finalized = True
            req.out_q.put(_DONE)
            return handle
        self.scheduler.submit(req)
        self._ensure_thread()
        return handle

    def stream(self, design: Union[Program, SimResult], depths,
               **kw) -> Iterator[ConfigResult]:
        """Submit and iterate per-config results (one-shot convenience)."""
        return self.submit(design, depths, **kw).stream()

    def sweep(self, design: Union[Program, SimResult], depths,
              **kw) -> BatchOutcome:
        """Submit and block for the assembled :class:`BatchOutcome`."""
        handle = self.submit(design, depths, **kw)
        if not self._autostart:
            while self.scheduler.step():
                pass
        return handle.result()

    # -------------------------------------------------------------- stats
    def stats(self) -> Dict[str, Dict[str, float]]:
        out = {"cache": self.cache.stats(),
               "scheduler": self.scheduler.stats(),
               "admission": self.admission.stats(),
               "quarantine": self.quarantine.stats()}
        if self.scheduler.injector is not None:
            out["faults"] = self.scheduler.injector.stats()
        return out
