"""The sweep service front door: submit / stream / stats.

:class:`SweepService` ties the warm cache (``cache.py``) to the
continuous-batching scheduler (``scheduler.py``) behind a three-call API:

    with SweepService() as svc:
        handle = svc.submit(program, depths=D)       # non-blocking
        for cfg in handle.stream():                  # per-config results
            ...
        outcome = handle.result()                    # BatchOutcome view

``submit`` resolves the design against the warm cache on the *caller's*
thread (a cold miss pays the one-off initial simulation + graph hoisting
there, keeping the scheduler loop hot for everyone else), then enqueues
the depth matrix.  Requests with at most ``interactive_max`` rows ride
the interactive priority lane; big sweeps go bulk.  ``sweep()`` is the
blocking convenience wrapper, ``stream()`` the one-shot iterator.

Every verdict is exactly what a direct ``resimulate_batch`` — and
therefore a from-scratch ``simulate`` — would report for that depth
vector; the golden conformance suite (``tests/test_golden.py``) pins this
bit-for-bit across block splits, shard counts and cache states.
"""
from __future__ import annotations

import queue
import threading
import time as _time
from typing import Dict, Iterator, Optional, Union

import numpy as np

from ..core.dse import BatchOutcome
from ..core.program import Program, SimResult
from .cache import GraphCache
from .scheduler import (BULK, CANCELLED, INTERACTIVE, _DONE, BlockScheduler,
                        ConfigResult, _Request)


class SweepHandle:
    """Client-side view of one submitted sweep (single consumer)."""

    def __init__(self, request: _Request, scheduler: BlockScheduler):
        self._req = request
        self._sched = scheduler
        self._collected: Dict[int, ConfigResult] = {}
        self._closed = False
        self._lock = threading.Lock()

    @property
    def request_id(self) -> int:
        return self._req.rid

    @property
    def n_configs(self) -> int:
        return self._req.K

    @property
    def done(self) -> bool:
        return self._closed

    @property
    def cancelled(self) -> bool:
        return self._req.cancelled.is_set()

    def cancel(self) -> None:
        """Stop scheduling this sweep at the next block boundary.

        Results already streamed stay valid; rows never solved surface as
        ``CANCELLED`` entries in :meth:`result`.
        """
        self._req.cancelled.set()
        self._sched.kick()

    def stream(self, timeout: Optional[float] = None
               ) -> Iterator[ConfigResult]:
        """Yield per-config results as blocks complete (completion order;
        each :class:`ConfigResult` carries its row ``index``).  Ends when
        every row was delivered or the request was cancelled; raises
        ``RuntimeError`` if the scheduler aborted the request (fault or
        service shutdown)."""
        while not self._closed:
            item = self._req.out_q.get(timeout=timeout)
            if item is _DONE:
                self._closed = True
                break
            self._collected[item.index] = item
            yield item
        if self._req.error:        # also on re-entry after a fault
            raise RuntimeError(self._req.error)

    def result(self, timeout: Optional[float] = None) -> BatchOutcome:
        """Drain the stream and assemble a :class:`BatchOutcome` indexed
        like the submitted depth matrix (blocking)."""
        for _ in self.stream(timeout=timeout):
            pass
        K = self._req.K
        ok = np.zeros(K, dtype=bool)
        cycles = np.full(K, -1, dtype=np.int64)
        status = np.full(K, CANCELLED, dtype=np.int8)
        violated = np.zeros(K, dtype=np.int64)
        reasons = ["request cancelled before this config was scheduled"] * K
        results = [None] * K
        for i, cfg in self._collected.items():
            ok[i] = cfg.ok
            cycles[i] = cfg.cycles
            status[i] = cfg.status
            violated[i] = cfg.violated
            reasons[i] = cfg.reason
            results[i] = cfg.result
        uniq = (len(np.unique(self._req.D, axis=0))
                if K > 1 else K)
        return BatchOutcome(ok=ok, cycles=cycles, status=status,
                            violated=violated, reasons=reasons,
                            results=results,
                            elapsed_s=_time.perf_counter()
                            - self._req.t_submit, n_unique=uniq)


class SweepService:
    """Served design-space exploration over a warm compiled-graph cache."""

    def __init__(self, cache_capacity: int = 8, block: int = 128,
                 shards: int = 1, mode: str = "thread",
                 interactive_max: int = 16, starvation_limit: int = 4,
                 backend: str = "numpy", autostart: bool = True):
        self.cache = GraphCache(capacity=cache_capacity)
        self.scheduler = BlockScheduler(block=block, shards=shards,
                                        mode=mode,
                                        starvation_limit=starvation_limit,
                                        backend=backend)
        self.interactive_max = interactive_max
        self._autostart = autostart
        self._rid = 0
        self._rid_lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------ runtime
    def _loop(self) -> None:
        consec_faults = 0
        while not self._stop.is_set():
            try:
                progressed = self.scheduler.step()
                consec_faults = 0
            except Exception as exc:        # noqa: BLE001 — must not die
                # step() already failed exactly the faulting block's
                # requests (error + terminal sentinel) — other tenants'
                # queued sweeps keep being served.  Only a *persistently*
                # faulting scheduler (e.g. a broken assemble path that
                # fails before any block exists) aborts everything rather
                # than spinning hot forever.
                consec_faults += 1
                if consec_faults >= 5:
                    self.scheduler.abort_pending(
                        f"sweep scheduler failing persistently: {exc!r}")
                    consec_faults = 0
                continue
            if not progressed:
                self.scheduler.wait_for_work(timeout=0.05)

    def _ensure_thread(self) -> None:
        if not self._autostart or (self._thread and self._thread.is_alive()):
            return
        self._thread = threading.Thread(target=self._loop,
                                        name="sweep-scheduler", daemon=True)
        self._thread.start()

    def step(self) -> bool:
        """Manual-mode progress (``autostart=False``): run one scheduler
        block on the calling thread.  Deterministic tests drive this."""
        return self.scheduler.step()

    def close(self) -> None:
        self._stop.set()
        self.scheduler.kick()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
        # any sweep still queued gets its terminal sentinel (and an
        # error) instead of leaving its consumer blocked forever
        self.scheduler.abort_pending("sweep service closed")
        self.scheduler.close()

    def __enter__(self) -> "SweepService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------- intake
    def warm(self, design: Union[Program, SimResult],
             key: Optional[str] = None):
        """Pre-populate the cache for ``design`` (cold-start off the
        request path); returns the warm entry."""
        return self.cache.get_or_build(design, key=key)

    def submit(self, design: Union[Program, SimResult], depths,
               key: Optional[str] = None, priority: Optional[str] = None,
               fallback: bool = True) -> SweepHandle:
        """Enqueue a sweep of ``depths`` (one row = one candidate depth
        vector) against ``design`` and return a :class:`SweepHandle`.

        ``design`` is a :class:`Program` or a finished base
        :class:`SimResult`; repeat designs (by content fingerprint or
        explicit ``key``) are served from the warm cache.  ``priority``
        defaults to ``"interactive"`` for at most ``interactive_max`` rows
        and ``"bulk"`` otherwise.
        """
        if self._stop.is_set():
            raise RuntimeError("sweep service is closed")
        entry = self.cache.get_or_build(design, key=key)
        D = np.asarray(depths, dtype=np.int64)
        if D.ndim == 1:
            D = D[None, :]
        if D.ndim != 2 or D.shape[1] != entry.n_fifos:
            raise ValueError(f"depth matrix {D.shape} does not match "
                             f"{entry.n_fifos} FIFOs")
        if priority is None:
            priority = INTERACTIVE if len(D) <= self.interactive_max else BULK
        assert priority in (INTERACTIVE, BULK), priority
        with self._rid_lock:
            self._rid += 1
            rid = self._rid
        req = _Request(rid, entry, D, priority, fallback,
                       queue.Queue())
        handle = SweepHandle(req, self.scheduler)
        if req.K == 0:
            # an empty sweep completes immediately — it must never reach
            # the scheduler (a zero-row block would fault the loop)
            req.finalized = True
            req.out_q.put(_DONE)
            return handle
        self.scheduler.submit(req)
        self._ensure_thread()
        return handle

    def stream(self, design: Union[Program, SimResult], depths,
               **kw) -> Iterator[ConfigResult]:
        """Submit and iterate per-config results (one-shot convenience)."""
        return self.submit(design, depths, **kw).stream()

    def sweep(self, design: Union[Program, SimResult], depths,
              **kw) -> BatchOutcome:
        """Submit and block for the assembled :class:`BatchOutcome`."""
        handle = self.submit(design, depths, **kw)
        if not self._autostart:
            while self.scheduler.step():
                pass
        return handle.result()

    # -------------------------------------------------------------- stats
    def stats(self) -> Dict[str, Dict[str, float]]:
        return {"cache": self.cache.stats(),
                "scheduler": self.scheduler.stats()}
