"""Warm compiled-graph cache: the state that makes served DSE fast.

A sweep request against a design the service has seen before should pay
for *nothing* but the per-config fixpoint: no trace recording, no graph
compilation, no ``_BatchArrays`` hoisting, no no-WAR seed solve.
:class:`GraphCache` holds exactly that warm state — a bounded LRU mapping
content-addressed design keys (:func:`repro.core.program_fingerprint`) to
:class:`CacheEntry` triples ``(SimResult, CompiledGraph, _BatchArrays)``:

  * ``result`` — the base simulation (the trace-compiled path when the
    design supports it, so even the cold miss is cheap);
  * ``graph``  — the :class:`~repro.core.incremental.CompiledGraph`
    hoisted from it (pre-built by ``core/trace.py`` for traced runs);
  * ``batch``  — the chain-major ``_BatchArrays`` view with its no-WAR
    seed fixpoint and the per-(FIFO, depth) WAR column cache, which keeps
    *warming itself* as more depth vectors are served.

Keys deliberately exclude nothing the closure captures: two Programs built
by the same builder with the same arguments share an entry; changing any
argument (or the module bytecode) misses.  The incremental-resimulation
contract serves *any* candidate depth vector from a base run, so one entry
answers a design's whole sweep space.

Thread safety: lookups/inserts are lock-protected, and the whole
fingerprint-and-build path serializes per design on
``core.dse.program_mutation_lock`` — the same lock the fallback
re-simulation holds while it transiently mutates that Program's FIFO
depths — so a build never observes (or races) another thread's in-place
depth mutation, a concurrent double miss builds once, and unrelated
designs proceed concurrently.  Hits, misses and evictions are counted
and exposed via :meth:`GraphCache.stats` — the benchmark's
``sweep_cache_hit_rate`` key comes straight from here.
"""
from __future__ import annotations

import dataclasses
import pickle
import threading
import time as _time
from collections import OrderedDict
from typing import Callable, Dict, Optional, Union

from ..core.dse import _batch_arrays, program_mutation_lock
from ..core.engine import simulate
from ..core.incremental import CompiledGraph, compile_graph
from ..core.program import Program, SimResult
from ..core.trace import program_fingerprint


class CacheEntry:
    """One warm design: base run + hoisted graph + batch view."""

    __slots__ = ("key", "result", "graph", "batch", "hits", "build_s",
                 "lock", "_graph_blob")

    def __init__(self, key: str, result: SimResult, graph: CompiledGraph,
                 batch, build_s: float = 0.0):
        self.key = key
        self.result = result
        self.graph = graph
        self.batch = batch
        self.hits = 0
        self.build_s = build_s
        # serializes engine-touching work (fallback re-simulation mutates
        # Program FIFO depths in place and restores them)
        self.lock = threading.Lock()
        self._graph_blob: Optional[bytes] = None

    @property
    def program(self) -> Program:
        return self.result.graph.program

    @property
    def n_fifos(self) -> int:
        return len(self.program.fifos)

    def graph_blob(self) -> bytes:
        """Pickled CompiledGraph for process-shard workers (cached).

        Serialized *without* the ``batch`` view: workers rebuild it once
        from the arrays (cheap) and then keep their own warm copy, which
        avoids shipping the no-WAR seed and WAR column cache over the
        pipe on every design change.  The scheduler hands this blob to
        process-pool *initializers* (and to need-blob reship round
        trips), so steady-state tasks, retries and pool respawns ship
        only the design key — never the serialized graph.
        """
        if self._graph_blob is None:
            # Pickle a shallow copy with the batch view stripped.  The graph
            # object is shared with concurrent thread-shard solvers, so it
            # must never be mutated here — not even transiently (an earlier
            # version nulled ``self.graph.batch`` around the dump without
            # holding ``self.lock``, and a concurrent solver on the same
            # warm entry could observe ``batch is None`` mid-solve).  The
            # copy shares every (immutable) array, so this costs one small
            # object, not a graph rebuild.
            clone = dataclasses.replace(self.graph, batch=None)
            self._graph_blob = pickle.dumps(clone, pickle.HIGHEST_PROTOCOL)
        return self._graph_blob


class GraphCache:
    """Bounded LRU of warm :class:`CacheEntry` objects, keyed by content."""

    def __init__(self, capacity: int = 8):
        assert capacity >= 1
        self.capacity = capacity
        self._entries: "OrderedDict[str, CacheEntry]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def lookup(self, key: str) -> Optional[CacheEntry]:
        """LRU-touching lookup; counts a hit or a miss."""
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            entry.hits += 1
            return entry

    def insert(self, entry: CacheEntry) -> CacheEntry:
        with self._lock:
            self._entries[entry.key] = entry
            self._entries.move_to_end(entry.key)
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self.evictions += 1
            return entry

    def get_or_build(self, design: Union[Program, SimResult],
                     key: Optional[str] = None,
                     simulate_fn: Callable = simulate) -> CacheEntry:
        """Return the warm entry for ``design``, building it on a miss.

        ``design`` is either a :class:`Program` (a miss runs the initial
        simulation through ``simulate_fn`` — the trace-compiled path by
        default) or an existing base :class:`SimResult` (a miss only
        hoists the compiled graph and batch view from it).  ``key``
        overrides the content fingerprint for callers that already know
        their design identity.
        """
        base: Optional[SimResult] = None
        if isinstance(design, SimResult):
            base = design
            program = design.graph.program
        else:
            program = design
        # fingerprinting reads Program FIFO depths, and a miss simulates
        # the Program — both must not observe another thread's transient
        # fallback depth mutation of the same Program (restored under the
        # same per-Program lock in core.dse.materialize_block); inserting
        # inside the lock also makes a concurrent double miss build once
        with program_mutation_lock(program):
            if key is None:
                key = program_fingerprint(program)
            entry = self.lookup(key)
            if entry is not None:
                return entry
            t0 = _time.perf_counter()
            if base is None:
                base = simulate_fn(program)
            graph = compile_graph(base.graph)
            batch = _batch_arrays(graph)
            entry = CacheEntry(key, base, graph, batch,
                               build_s=_time.perf_counter() - t0)
            return self.insert(entry)

    def stats(self) -> Dict[str, float]:
        with self._lock:
            total = self.hits + self.misses
            return {
                "size": len(self._entries),
                "capacity": self.capacity,
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "hit_rate": self.hits / total if total else 0.0,
            }
