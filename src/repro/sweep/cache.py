"""Warm compiled-graph cache: the state that makes served DSE fast.

A sweep request against a design the service has seen before should pay
for *nothing* but the per-config fixpoint: no trace recording, no graph
compilation, no ``_BatchArrays`` hoisting, no no-WAR seed solve.
:class:`GraphCache` holds exactly that warm state — a bounded LRU mapping
content-addressed design keys (:func:`repro.core.program_fingerprint`) to
:class:`CacheEntry` triples ``(SimResult, CompiledGraph, _BatchArrays)``:

  * ``result`` — the base simulation (the trace-compiled path when the
    design supports it, so even the cold miss is cheap);
  * ``graph``  — the :class:`~repro.core.incremental.CompiledGraph`
    hoisted from it (pre-built by ``core/trace.py`` for traced runs);
  * ``batch``  — the chain-major ``_BatchArrays`` view with its no-WAR
    seed fixpoint and the per-(FIFO, depth) WAR column cache, which keeps
    *warming itself* as more depth vectors are served (built lazily on
    first solve, so interactive edit-session updates don't pay for it).

Keys deliberately exclude nothing the closure captures: two Programs built
by the same builder with the same arguments share an entry; changing any
argument (or the module bytecode) misses.  The incremental-resimulation
contract serves *any* candidate depth vector from a base run, so one entry
answers a design's whole sweep space.

Thread safety: lookups/inserts are lock-protected, and the whole
fingerprint-and-build path serializes per design on
``core.dse.program_mutation_lock`` — the same lock the fallback
re-simulation holds while it transiently mutates that Program's FIFO
depths — so a build never observes (or races) another thread's in-place
depth mutation, a concurrent double miss builds once, and unrelated
designs proceed concurrently.  Hits, misses and evictions are counted
and exposed via :meth:`GraphCache.stats` — the benchmark's
``sweep_cache_hit_rate`` key comes straight from here.
"""
from __future__ import annotations

import dataclasses
import pickle
import threading
import time as _time
from collections import OrderedDict
from typing import Callable, Dict, NamedTuple, Optional, Union

from ..core.dse import _batch_arrays, program_mutation_lock
from ..core.engine import simulate
from ..core.incremental import CompiledGraph, compile_graph
from ..core.program import Program, SimResult
from ..core.trace import HybridCache, program_fingerprint
from ..delta.fingerprint import DesignDelta, DesignFingerprint, diff
from ..delta.patch import DeltaState, apply_patch, cold_build


class CacheEntry:
    """One warm design: base run + hoisted graph + batch view.

    ``full_run`` optionally spills the design's verified whole-run
    ``_FullRun`` entry (PR 9's hybrid replay artifact) alongside the
    graph: a cache hit reinstalls it into the shared
    :class:`~repro.core.trace.HybridCache`, so one tenant's completed
    dynamic run warms every other tenant's fallback re-simulations."""

    __slots__ = ("key", "result", "graph", "_batch", "hits", "build_s",
                 "lock", "_graph_blob", "full_run")

    def __init__(self, key: str, result: SimResult, graph: CompiledGraph,
                 batch=None, build_s: float = 0.0):
        self.key = key
        self.result = result
        self.graph = graph
        self._batch = batch
        self.hits = 0
        self.build_s = build_s
        # serializes engine-touching work (fallback re-simulation mutates
        # Program FIFO depths in place and restores them)
        self.lock = threading.Lock()
        self._graph_blob: Optional[bytes] = None
        self.full_run = None

    @property
    def batch(self):
        """Chain-major ``_BatchArrays`` view, built on first use.

        Entry construction defers this (it includes the no-WAR seed
        fixpoint — the most expensive part of warming a design) so
        interactive edit-session updates pay only for classification and
        patching; the first sweep solve against the entry builds it via
        the same ``_batch_arrays`` memo the shard solvers use."""
        if self._batch is None:
            self._batch = _batch_arrays(self.graph)
        return self._batch

    @property
    def program(self) -> Program:
        return self.result.graph.program

    @property
    def n_fifos(self) -> int:
        return len(self.program.fifos)

    def graph_blob(self) -> bytes:
        """Pickled CompiledGraph for process-shard workers (cached).

        Serialized *without* the ``batch`` view: workers rebuild it once
        from the arrays (cheap) and then keep their own warm copy, which
        avoids shipping the no-WAR seed and WAR column cache over the
        pipe on every design change.  The scheduler hands this blob to
        process-pool *initializers* (and to need-blob reship round
        trips), so steady-state tasks, retries and pool respawns ship
        only the design key — never the serialized graph.
        """
        if self._graph_blob is None:
            # Pickle a shallow copy with the batch view stripped.  The graph
            # object is shared with concurrent thread-shard solvers, so it
            # must never be mutated here — not even transiently (an earlier
            # version nulled ``self.graph.batch`` around the dump without
            # holding ``self.lock``, and a concurrent solver on the same
            # warm entry could observe ``batch is None`` mid-solve).  The
            # copy shares every (immutable) array, so this costs one small
            # object, not a graph rebuild.
            clone = dataclasses.replace(self.graph, batch=None)
            self._graph_blob = pickle.dumps(clone, pickle.HIGHEST_PROTOCOL)
        return self._graph_blob


class DeltaLookup(NamedTuple):
    """Result of the delta-aware lookup tiers (:meth:`GraphCache.get_or_patch`).

    ``mode`` is the reuse tier that answered: ``"exact"`` (whole-key hit),
    ``"patched"`` (per-module partial hit) or ``"cold"`` (miss / rejected
    patch).  ``state`` is the refreshed delta snapshot when one exists.
    """

    entry: CacheEntry
    mode: str
    reason: str
    state: Optional[DeltaState]
    reused_modules: int
    total_modules: int


class GraphCache:
    """Bounded LRU of warm :class:`CacheEntry` objects, keyed by content.

    Owns a shared :class:`~repro.core.trace.HybridCache`: cold builds of
    dynamic designs thread it into ``simulate`` so their verified
    ``_FullRun`` entries spill onto the cache entry and reinstall on every
    hit — served tenants warm each other's hybrid replays.
    """

    def __init__(self, capacity: int = 8,
                 hybrid: Optional[HybridCache] = None):
        assert capacity >= 1
        self.capacity = capacity
        self._entries: "OrderedDict[str, CacheEntry]" = OrderedDict()
        self._lock = threading.Lock()
        self.hybrid = hybrid if hybrid is not None else HybridCache(
            max_full=max(8, 2 * capacity))
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.delta_hits = 0
        self.delta_rejects = 0

    def __len__(self) -> int:
        return len(self._entries)

    def lookup(self, key: str) -> Optional[CacheEntry]:
        """LRU-touching lookup; counts a hit or a miss."""
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            entry.hits += 1
            if entry.full_run is not None:
                # reinstall the spilled whole-run entry: a fallback re-sim
                # of this design at these depths replays instead of
                # re-interpreting (dict ops are GIL-atomic; peek/store
                # race at worst re-stores an identical verified entry)
                self.hybrid.store_full(key, entry.full_run)
            return entry

    def insert(self, entry: CacheEntry) -> CacheEntry:
        with self._lock:
            self._entries[entry.key] = entry
            self._entries.move_to_end(entry.key)
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self.evictions += 1
            return entry

    def get_or_build(self, design: Union[Program, SimResult],
                     key: Optional[str] = None,
                     simulate_fn: Callable = simulate) -> CacheEntry:
        """Return the warm entry for ``design``, building it on a miss.

        ``design`` is either a :class:`Program` (a miss runs the initial
        simulation through ``simulate_fn`` — the trace-compiled path by
        default) or an existing base :class:`SimResult` (a miss only
        hoists the compiled graph and batch view from it).  ``key``
        overrides the content fingerprint for callers that already know
        their design identity.
        """
        base: Optional[SimResult] = None
        if isinstance(design, SimResult):
            base = design
            program = design.graph.program
        else:
            program = design
        # fingerprinting reads Program FIFO depths, and a miss simulates
        # the Program — both must not observe another thread's transient
        # fallback depth mutation of the same Program (restored under the
        # same per-Program lock in core.dse.materialize_block); inserting
        # inside the lock also makes a concurrent double miss build once
        with program_mutation_lock(program):
            if key is None:
                key = program_fingerprint(program)
            entry = self.lookup(key)
            if entry is not None:
                return entry
            t0 = _time.perf_counter()
            if base is None:
                if simulate_fn is simulate:
                    # default path: thread the shared HybridCache so a
                    # dynamic design's verified _FullRun lands in it
                    base = simulate(program, hybrid_cache=self.hybrid)
                else:
                    base = simulate_fn(program)
            entry = self._entry_from(key, base, t0)
            return self.insert(entry)

    def _entry_from(self, key: str, base: SimResult,
                    t0: float) -> CacheEntry:
        """Hoist the compiled graph from a base run and spill the hybrid
        whole-run entry (if the build produced one) onto the entry.  The
        batch view is deliberately *not* built here — see
        :attr:`CacheEntry.batch`."""
        graph = compile_graph(base.graph)
        entry = CacheEntry(key, base, graph,
                           build_s=_time.perf_counter() - t0)
        entry.full_run = self.hybrid.peek_full(key)
        return entry

    def get_or_patch(self, program: Program, fps: DesignFingerprint,
                     state: Optional[DeltaState],
                     delta: Optional["DesignDelta"] = None) -> DeltaLookup:
        """Delta-aware lookup: exact-key hit → per-module patch → cold.

        The tiers, in order: (1) ``fps.key`` already cached (another
        tenant — or a previous edit — built this exact design): reuse it
        outright.  (2) ``state`` holds a recorded snapshot and the delta
        from it is patchable: re-record only the edited modules, splice,
        verify (``repro.delta.patch``) — a verification reject falls
        through.  (3) cold rebuild (capturing a fresh snapshot for
        traceable designs).  ``delta_hits``/``delta_rejects`` count tier-2
        outcomes and surface in :meth:`stats`.

        ``delta`` optionally supplies the caller's already-classified
        ``diff(state.fps, fps)`` (the edit session computes one for its
        outcome report) so it isn't recomputed here.
        """
        total = len(fps.modules)
        with program_mutation_lock(program):
            entry = self.lookup(fps.key)
            if entry is not None:
                return DeltaLookup(entry, "exact", "", None, total, total)
            t0 = _time.perf_counter()
            reason = ""
            if state is not None:
                if delta is None:
                    delta = diff(state.fps, fps)
                if delta.patchable:
                    out = apply_patch(state, program, delta=delta,
                                      new_fps=fps)
                    if out.ok:
                        entry = self.insert(
                            self._entry_from(fps.key, out.result, t0))
                        with self._lock:
                            self.delta_hits += 1
                        return DeltaLookup(entry, "patched", "", out.state,
                                           out.reused_modules, total)
                    reason = out.reason
                else:
                    reason = delta.reason
                with self._lock:
                    self.delta_rejects += 1
            base, new_state = cold_build(program, hybrid_cache=self.hybrid,
                                         fps=fps)
            entry = self.insert(self._entry_from(fps.key, base, t0))
            return DeltaLookup(entry, "cold", reason, new_state, 0, total)

    def stats(self) -> Dict[str, float]:
        with self._lock:
            total = self.hits + self.misses
            return {
                "size": len(self._entries),
                "capacity": self.capacity,
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "hit_rate": self.hits / total if total else 0.0,
                "delta_hits": self.delta_hits,
                "delta_rejects": self.delta_rejects,
                "full_runs": sum(1 for e in self._entries.values()
                                 if e.full_run is not None),
            }
