"""Continuous batching of depth-vector requests over warm compiled graphs.

The serving loop of ``serve/engine.py::ContinuousBatchingEngine`` — admit
work into the next batch as slots free up, keep the expensive kernel hot —
transplanted onto the DSE solver.  The unit of execution here is a *block*:
up to ``block`` depth rows against ONE design, assembled fresh each step
from however many client requests are queued (heterogeneous requests
against the same design coalesce into shared blocks), deduplicated down to
unique rows, solved by :func:`repro.core.dse.solve_block_status`, and
streamed back **per config** — a client starts receiving results for its
first rows while its later rows are still queued behind other tenants.

Scheduling policy:

  * two lanes — ``"interactive"`` (small requests) and ``"bulk"``.  The
    interactive lane is always served first, so a 4-config what-if query
    lands in the very next block even while a 10^5-config sweep is
    draining; after ``starvation_limit`` consecutive interactive blocks
    one bulk block is forced through, so a flood of interactive queries
    cannot starve bulk sweeps either.
  * within a lane, requests are FIFO; a block anchors on the oldest live
    request and pulls same-design rows from every queued request (both
    lanes) to fill up — the cross-tenant coalescing that makes the batch
    solver earn its keep.
  * identical depth rows inside a block (across tenants!) are solved
    once; every duplicate row is answered from the same solve.

Sharding: a block's unique rows are split across ``shards`` workers —
``mode="thread"`` (the single-host fallback: numpy releases the GIL in the
cummax sweeps; all workers share the warm ``_BatchArrays`` view) or
``mode="process"`` (workers hold their own unpickled
:class:`~repro.core.incremental.CompiledGraph` per design key, the
multi-host/device stand-in — blocks-over-workers is the same
data-parallel decomposition ``distrib/sharding.py`` applies to batches
over mesh axes).  Chunks are concatenated in submission order, so results
are bit-identical for every ``shards``/``mode`` setting.

Exactness: a block's verdicts and cycle counts are exactly
``resimulate_batch``'s — REUSED rows from the shared fixpoint, failed rows
(deadlock / WAR cycle / constraint flip) through the same full
re-simulation fallback (run once per unique row, on the scheduler thread,
under the design's entry lock because it temporarily mutates Program FIFO
depths).

Cancellation: a cancelled request stops being scheduled at the next block
boundary; rows already solved are dropped, the client's stream is closed
with a terminal sentinel, and undelivered rows surface as ``CANCELLED`` in
the assembled outcome.
"""
from __future__ import annotations

import pickle
import threading
import time as _time
from collections import OrderedDict, deque
from typing import Dict, List, NamedTuple, Optional, Tuple

import numpy as np

from ..core.dse import REUSED, materialize_block, solve_block_status
from ..core.program import SimResult
from .cache import CacheEntry

# extends core.dse's per-config codes (REUSED/DEADLOCK/CYCLE/VIOLATED)
CANCELLED = 4

INTERACTIVE, BULK = "interactive", "bulk"

_DONE = object()                     # per-request stream terminator


class ConfigResult(NamedTuple):
    """One streamed per-config verdict (exactly ``resimulate_batch``'s)."""

    request_id: int
    index: int                       # row in the request's depth matrix
    depths: Tuple[int, ...]
    ok: bool
    status: int                      # REUSED/DEADLOCK/CYCLE/VIOLATED
    cycles: int                      # exact; -1 if fallback was disabled
    violated: int                    # flipped constraint outcomes
    reason: str
    result: Optional[SimResult]


class _Request:
    __slots__ = ("rid", "entry", "D", "K", "fallback", "priority", "out_q",
                 "cancelled", "cursor", "delivered", "finalized", "error",
                 "t_submit")

    def __init__(self, rid: int, entry: CacheEntry, D: np.ndarray,
                 priority: str, fallback: bool, out_q):
        self.rid = rid
        self.entry = entry
        self.D = D
        self.K = len(D)
        self.fallback = fallback
        self.priority = priority
        self.out_q = out_q
        self.cancelled = threading.Event()
        self.cursor = 0              # rows handed to blocks so far
        self.delivered = 0
        self.finalized = False
        self.error: Optional[str] = None   # set when aborted by a fault
        self.t_submit = _time.perf_counter()


class _Block(NamedTuple):
    entry: CacheEntry
    items: List[Tuple[_Request, int]]    # (request, row index) per row
    lane: str


# ---------------------------------------------------------------- process
# Worker-side graph cache for mode="process": each worker unpickles a
# design's CompiledGraph once and keeps it warm across blocks.  The blob
# rides along with every task (pool workers cannot be targeted), but
# unpickling is skipped on all but the first arrival per key.  Bounded
# LRU: host-side GraphCache evictions never reach the workers, so an
# unbounded dict would leak one graph per design ever swept.
_WORKER_GRAPHS: "OrderedDict[str, object]" = OrderedDict()
_WORKER_GRAPHS_CAP = 16


def _process_shard_solve(key: str, blob: bytes, Db: np.ndarray,
                         backend: str, block: int):
    graph = _WORKER_GRAPHS.get(key)
    if graph is None:
        graph = pickle.loads(blob)
        _WORKER_GRAPHS[key] = graph
        while len(_WORKER_GRAPHS) > _WORKER_GRAPHS_CAP:
            _WORKER_GRAPHS.popitem(last=False)
    else:
        _WORKER_GRAPHS.move_to_end(key)
    return solve_block_status(graph, Db, backend=backend, block=block)


class BlockScheduler:
    """Lane-based continuous batching of sweep requests (see module doc)."""

    def __init__(self, block: int = 128, shards: int = 1,
                 mode: str = "thread", starvation_limit: int = 4,
                 backend: str = "numpy", min_shard_rows: int = 8):
        assert mode in ("serial", "thread", "process"), mode
        self.block = max(int(block), 1)
        self.shards = max(int(shards), 1)
        self.mode = mode if self.shards > 1 else "serial"
        self.starvation_limit = max(int(starvation_limit), 1)
        self.backend = backend
        self.min_shard_rows = min_shard_rows
        self._lanes: Dict[str, deque] = {INTERACTIVE: deque(),
                                         BULK: deque()}
        self._cv = threading.Condition()
        self._consec_interactive = 0
        self._pool = None
        if self.mode == "thread":
            from concurrent.futures import ThreadPoolExecutor
            self._pool = ThreadPoolExecutor(
                max_workers=self.shards,
                thread_name_prefix="sweep-shard")
        elif self.mode == "process":
            from concurrent.futures import ProcessPoolExecutor
            self._pool = ProcessPoolExecutor(max_workers=self.shards)
        # counters (guarded by _cv's lock)
        self.stats_blocks = 0
        self.stats_blocks_interactive = 0
        self.stats_blocks_bulk = 0
        self.stats_rows = 0              # rows placed into blocks
        self.stats_rows_unique = 0       # rows actually solved
        self.stats_fallbacks = 0         # full re-simulations run
        self.stats_cancelled_rows = 0
        self.stats_requests = 0

    # ------------------------------------------------------------- intake
    def submit(self, request: _Request) -> None:
        with self._cv:
            self._lanes[request.priority].append(request)
            self.stats_requests += 1
            self._cv.notify_all()

    def kick(self) -> None:
        """Wake the loop (e.g. after a cancellation) so terminal sentinels
        are delivered promptly."""
        with self._cv:
            self._cv.notify_all()

    # ----------------------------------------------------------- assembly
    def _finalize(self, req: _Request) -> None:
        if not req.finalized:
            req.finalized = True
            self.stats_cancelled_rows += req.K - req.delivered
            req.out_q.put(_DONE)

    def _reap_cancelled(self, lane: deque) -> None:
        # reap ANYWHERE in the lane, not just the front: a cancelled
        # request's stream must close at the next scheduling point even
        # with a long bulk queue ahead of it
        for req in [r for r in lane if r.cancelled.is_set()]:
            lane.remove(req)
            self._finalize(req)

    def abort_pending(self, message: str) -> None:
        """Fail every queued request (scheduler fault or service close):
        mark the error and deliver the terminal sentinel so no client
        blocks forever on a stream that will never finish."""
        with self._cv:
            for lane in self._lanes.values():
                for req in list(lane):
                    req.error = req.error or message
                    self._finalize(req)
                lane.clear()

    def _pick_lane(self) -> Optional[str]:
        """Interactive first; one bulk block is forced through after
        ``starvation_limit`` consecutive interactive blocks."""
        self._reap_cancelled(self._lanes[INTERACTIVE])
        self._reap_cancelled(self._lanes[BULK])
        has_i = bool(self._lanes[INTERACTIVE])
        has_b = bool(self._lanes[BULK])
        if not has_b:
            # starvation debt only accrues while bulk work actually
            # waits — a stale counter must not let a fresh bulk sweep
            # preempt the interactive lane
            self._consec_interactive = 0
        if has_i and has_b:
            if self._consec_interactive >= self.starvation_limit:
                return BULK
            return INTERACTIVE
        if has_i:
            return INTERACTIVE
        if has_b:
            return BULK
        return None

    def _assemble(self) -> Optional[_Block]:
        """Build the next block: anchor on the chosen lane's oldest live
        request, fill with same-design rows from every queued request."""
        with self._cv:
            lane_name = self._pick_lane()
            if lane_name is None:
                return None
            lane = self._lanes[lane_name]
            anchor = lane[0]
            items: List[Tuple[_Request, int]] = []
            for scan in (lane_name, BULK if lane_name == INTERACTIVE
                         else INTERACTIVE):
                q = self._lanes[scan]
                for req in list(q):
                    if len(items) >= self.block:
                        break
                    if req.cancelled.is_set():
                        continue         # reaped at the front eventually
                    if req.entry is not anchor.entry:
                        continue
                    take = min(self.block - len(items), req.K - req.cursor)
                    items.extend((req, i) for i in
                                 range(req.cursor, req.cursor + take))
                    req.cursor += take
                    if req.cursor >= req.K:
                        q.remove(req)
            if lane_name == INTERACTIVE:
                # starvation debt accrues only while bulk work waits
                self._consec_interactive = (self._consec_interactive + 1
                                            if self._lanes[BULK] else 0)
                self.stats_blocks_interactive += 1
            else:
                self._consec_interactive = 0
                self.stats_blocks_bulk += 1
            self.stats_blocks += 1
            self.stats_rows += len(items)
            return _Block(anchor.entry, items, lane_name)

    # -------------------------------------------------------------- solve
    def _solve_unique(self, entry: CacheEntry, Du: np.ndarray):
        """Solve the unique rows of a block, sharded across workers."""
        U = len(Du)
        if (self._pool is None or U < self.min_shard_rows
                or self.shards == 1):
            return solve_block_status(entry.graph, Du,
                                      backend=self.backend,
                                      block=self.block)
        chunks = np.array_split(Du, min(self.shards, U))
        if self.mode == "process":
            blob = entry.graph_blob()
            futs = [self._pool.submit(_process_shard_solve, entry.key,
                                      blob, ch, self.backend, self.block)
                    for ch in chunks if len(ch)]
        else:
            futs = [self._pool.submit(solve_block_status, entry.graph, ch,
                                      backend=self.backend,
                                      block=self.block)
                    for ch in chunks if len(ch)]
        parts = [f.result() for f in futs]    # submission order: stable
        status = np.concatenate([p[0] for p in parts])
        cycles = np.concatenate([p[1] for p in parts])
        violated = np.concatenate([p[2] for p in parts])
        rounds = max(p[3] for p in parts)
        return status, cycles, violated, rounds

    # ------------------------------------------------------------ deliver
    def _deliver(self, blk: _Block) -> None:
        entry = blk.entry
        rows = np.stack([req.D[i] for (req, i) in blk.items])
        Du, inverse = np.unique(rows, axis=0, return_inverse=True)
        inverse = inverse.reshape(-1)
        with self._cv:
            self.stats_rows_unique += len(Du)
        status_u, cycles_u, violated_u, _ = self._solve_unique(entry, Du)

        # a failed unique row pays for its exact fallback only if a LIVE
        # request owning it asked for fallback (a cancelled tenant's rows
        # must not cost engine re-simulations nobody will receive)
        fb_mask = np.zeros(len(Du), dtype=bool)
        for pos, (req, _i) in enumerate(blk.items):
            if req.fallback and not req.cancelled.is_set():
                fb_mask[inverse[pos]] = True
        # exact fallback needs the engine: once per unique row, under the
        # design's entry lock (depths are mutated + restored); the shared
        # dse helper keeps verdicts byte-identical to resimulate_batch's
        results_u, reasons_u = materialize_block(
            entry.result, Du, status_u, cycles_u, violated_u, fb_mask,
            engine_label="omnisim-sweep", lock=entry.lock)
        n_fb = int((fb_mask & (status_u != REUSED)).sum())
        if n_fb:
            with self._cv:
                self.stats_fallbacks += n_fb

        for pos, (req, i) in enumerate(blk.items):
            if req.cancelled.is_set():
                continue
            u = int(inverse[pos])
            use_fb = req.fallback or status_u[u] == REUSED
            req.out_q.put(ConfigResult(
                request_id=req.rid, index=i,
                depths=tuple(int(d) for d in req.D[i]),
                ok=bool(status_u[u] == REUSED), status=int(status_u[u]),
                cycles=int(cycles_u[u]) if use_fb else -1,
                violated=int(violated_u[u]), reason=reasons_u[u],
                result=results_u[u] if use_fb else None))
            req.delivered += 1
            if req.delivered >= req.K:
                req.finalized = True
                req.out_q.put(_DONE)
        for req, _i in blk.items:
            if req.cancelled.is_set():
                self._finalize(req)

    # --------------------------------------------------------------- step
    def step(self) -> bool:
        """Assemble, solve and deliver ONE block; False when idle.

        The public unit of progress: the service's background thread calls
        it in a loop, and deterministic tests drive it directly.  A fault
        while solving/delivering fails exactly the block's requests (error
        + terminal sentinel, so no client stream hangs) and re-raises.
        """
        blk = self._assemble()
        if blk is None:
            return False
        try:
            self._deliver(blk)
        except Exception as exc:
            msg = f"sweep block failed: {exc!r}"
            with self._cv:
                for req, _i in blk.items:
                    req.error = req.error or msg
                    self._finalize(req)
                    for lane in self._lanes.values():
                        if req in lane:          # rows beyond this block
                            lane.remove(req)
            raise
        return True

    def wait_for_work(self, timeout: float = 0.2) -> None:
        with self._cv:
            if self._pick_lane() is None:
                self._cv.wait(timeout)

    def has_work(self) -> bool:
        with self._cv:
            return self._pick_lane() is not None

    def stats(self) -> Dict[str, float]:
        with self._cv:
            solved = max(self.stats_rows_unique, 1)
            return {
                "requests": self.stats_requests,
                "blocks": self.stats_blocks,
                "blocks_interactive": self.stats_blocks_interactive,
                "blocks_bulk": self.stats_blocks_bulk,
                "rows": self.stats_rows,
                "rows_unique": self.stats_rows_unique,
                "dedup_ratio": (self.stats_rows / solved
                                if self.stats_rows else 1.0),
                "fallbacks": self.stats_fallbacks,
                "cancelled_rows": self.stats_cancelled_rows,
                "shards": self.shards,
                "mode": self.mode,
            }

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None
