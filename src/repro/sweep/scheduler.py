"""Continuous batching of depth-vector requests over warm compiled graphs.

The serving loop of ``serve/engine.py::ContinuousBatchingEngine`` — admit
work into the next batch as slots free up, keep the expensive kernel hot —
transplanted onto the DSE solver.  The unit of execution here is a *block*:
up to ``block`` depth rows against ONE design, assembled fresh each step
from however many client requests are queued (heterogeneous requests
against the same design coalesce into shared blocks), deduplicated down to
unique rows, solved by :func:`repro.core.dse.solve_block_status`, and
streamed back **per config** — a client starts receiving results for its
first rows while its later rows are still queued behind other tenants.

Scheduling policy:

  * two lanes — ``"interactive"`` (small requests) and ``"bulk"``.  The
    interactive lane is always served first, so a 4-config what-if query
    lands in the very next block even while a 10^5-config sweep is
    draining; after ``starvation_limit`` consecutive interactive blocks
    one bulk block is forced through, so a flood of interactive queries
    cannot starve bulk sweeps either.
  * within a lane, requests are FIFO; a block anchors on the oldest live
    request and pulls same-design rows from every queued request (both
    lanes) to fill up — the cross-tenant coalescing that makes the batch
    solver earn its keep.
  * identical depth rows inside a block (across tenants!) are solved
    once; every duplicate row is answered from the same solve.

Sharding: a block's unique rows are split across ``shards`` workers —
``mode="thread"`` (the single-host fallback: numpy releases the GIL in the
cummax sweeps; all workers share the warm ``_BatchArrays`` view) or
``mode="process"`` (workers hold their own unpickled
:class:`~repro.core.incremental.CompiledGraph` per design key, the
multi-host/device stand-in — blocks-over-workers is the same
data-parallel decomposition ``distrib/sharding.py`` applies to batches
over mesh axes).  Chunks are concatenated in submission order, so results
are bit-identical for every ``shards``/``mode`` setting.  Process pools
are seeded through a *pool initializer*: the host keeps a bounded LRU of
pickled graphs per design key, every (re)spawned worker unpickles them
once at startup, and a task ships only the design key — a worker that has
never seen the key answers with a need-blob sentinel and the host resends
that one chunk with the blob attached, so steady state, retries and
respawns never re-pay graph serialization per task.

Exactness: a block's verdicts and cycle counts are exactly
``resimulate_batch``'s — REUSED rows from the shared fixpoint, failed rows
(deadlock / WAR cycle / constraint flip) through the same full
re-simulation fallback (run once per unique row, on the scheduler thread,
under the design's entry lock because it temporarily mutates Program FIFO
depths).

Fault tolerance (ISSUE 6): a shard that faults, times out or returns
corrupt arrays is retried on the surviving pool under the
:class:`~repro.sweep.faults.RetryPolicy` (exponential backoff, clipped to
the requests' remaining deadline budget); on exhaustion only that
*shard's* rows terminate — ``FAULTED`` or ``TIMED_OUT`` — while the rest
of the block (and every other tenant) delivers normally.  A broken worker
pool (``BrokenExecutor``) is respawned up to ``max_pool_respawns`` times.
Per-request deadlines (``deadline_s``) are enforced end-to-end: at
scheduling, while waiting on shards, and at delivery — an expired
request's undelivered rows terminate as ``TIMED_OUT``, never hang.
Repeated solve faults for one design strike its
:class:`~repro.sweep.faults.DesignQuarantine` circuit breaker; a tripped
design's queued rows fail fast so co-scheduled tenants keep being served.
Every fault path preserves the golden invariant: rows that ARE delivered
stay bit-identical to the generator engine.

Cancellation: a cancelled request stops being scheduled at the next block
boundary; rows already solved are dropped, the client's stream is closed
with a terminal sentinel, and undelivered rows surface as ``CANCELLED`` in
the assembled outcome.
"""
from __future__ import annotations

import pickle
import threading
import time as _time
from collections import OrderedDict, deque
from concurrent.futures import BrokenExecutor, CancelledError
from concurrent.futures import TimeoutError as _FutTimeout
from typing import Dict, List, NamedTuple, Optional, Tuple

import numpy as np

from ..core.dse import (CANCELLED, FAULTED, REUSED, TIMED_OUT,
                        materialize_block, solve_block_status)
from ..core.program import SimResult
from .cache import CacheEntry
from .faults import (POOL_BROKEN, SHARD_CORRUPT, SHARD_FAULT, SHARD_HANG,
                     DesignQuarantine, FaultInjector, InjectedFault,
                     RetryPolicy, _PoolBrokenFault)

INTERACTIVE, BULK = "interactive", "bulk"

_DONE = object()                     # per-request stream terminator


class ShardCorruption(ValueError):
    """A shard returned result arrays that do not match its chunk — the
    host-side validation that keeps a corrupting worker from ever
    delivering wrong verdicts (treated as a retryable shard fault)."""


class ConfigResult(NamedTuple):
    """One streamed per-config verdict (exactly ``resimulate_batch``'s)."""

    request_id: int
    index: int                       # row in the request's depth matrix
    depths: Tuple[int, ...]
    ok: bool
    status: int                      # REUSED/DEADLOCK/CYCLE/VIOLATED/...
    cycles: int                      # exact; -1 if fallback was disabled
    violated: int                    # flipped constraint outcomes
    reason: str
    result: Optional[SimResult]


class _Request:
    __slots__ = ("rid", "entry", "D", "K", "fallback", "priority", "out_q",
                 "cancelled", "cursor", "delivered", "finalized", "error",
                 "t_submit", "tenant", "t_deadline", "on_finalize",
                 "reject_reason")

    def __init__(self, rid: int, entry: Optional[CacheEntry], D: np.ndarray,
                 priority: str, fallback: bool, out_q,
                 tenant: str = "default",
                 deadline_s: Optional[float] = None,
                 on_finalize=None):
        self.rid = rid
        self.entry = entry
        self.D = D
        self.K = len(D)
        self.fallback = fallback
        self.priority = priority
        self.out_q = out_q
        self.cancelled = threading.Event()
        self.cursor = 0              # rows handed to blocks so far
        self.delivered = 0
        self.finalized = False
        self.error: Optional[str] = None   # set when aborted by a fault
        self.t_submit = _time.perf_counter()
        self.tenant = tenant
        self.t_deadline = (self.t_submit + deadline_s
                           if deadline_s is not None else None)
        self.on_finalize = on_finalize
        self.reject_reason: Optional[str] = None

    def expired(self, now: Optional[float] = None) -> bool:
        if self.t_deadline is None:
            return False
        return (now if now is not None
                else _time.perf_counter()) > self.t_deadline


class _Block(NamedTuple):
    entry: CacheEntry
    items: List[Tuple[_Request, int]]    # (request, row index) per row
    lane: str


class _Attempt(NamedTuple):
    fut: object                      # Future, or None for the inline path
    call: object                     # zero-arg callable, or None
    gen: int                         # pool generation the future targets


# ---------------------------------------------------------------- workers
# Worker-side graph cache for mode="process": each worker unpickles a
# design's CompiledGraph once (at pool-initializer time for every design
# the host has already sharded, or on first need-blob round trip for a
# design that appears later) and keeps it warm across blocks, retries and
# respawns.  Bounded LRU: host-side GraphCache evictions never reach the
# workers, so an unbounded dict would leak one graph per design ever
# swept.
_WORKER_GRAPHS: "OrderedDict[str, object]" = OrderedDict()
_WORKER_GRAPHS_CAP = 16

# sentinel result (a plain string: it must survive pickling by value) a
# worker returns when a task names a graph it does not hold — the host
# resends that chunk once with the blob attached
_NEED_BLOB = "__sweep_need_graph_blob__"


def _worker_init(entries) -> None:
    """Process-pool initializer: unpickle every known design graph once
    per worker, so tasks (and retries, and respawned pools) ship only the
    design key."""
    for key, blob in entries:
        if key not in _WORKER_GRAPHS:
            _WORKER_GRAPHS[key] = pickle.loads(blob)
    while len(_WORKER_GRAPHS) > _WORKER_GRAPHS_CAP:
        _WORKER_GRAPHS.popitem(last=False)


def _apply_shard_faults(out, hang_s: float, boom: bool, corrupt: bool):
    if hang_s:
        _time.sleep(hang_s)
    if boom:
        raise InjectedFault(SHARD_FAULT, -1)
    if corrupt and len(out[0]):
        return (out[0][:-1], out[1][:-1], out[2][:-1], out[3])
    return out


def _shard_task(graph, Db: np.ndarray, backend: str, block: int,
                hang_s: float = 0.0, boom: bool = False,
                corrupt: bool = False, jax_interpret: bool = True):
    """Thread/serial shard unit: solve one chunk (plus injected faults —
    the injector draws on the scheduler thread, deterministically, and
    ships only the outcome flags here)."""
    if hang_s:
        _time.sleep(hang_s)
    if boom:
        raise InjectedFault(SHARD_FAULT, -1)
    out = solve_block_status(graph, Db, backend=backend, block=block,
                             jax_interpret=jax_interpret)
    return _apply_shard_faults(out, 0.0, False, corrupt)


def _process_shard_solve(key: str, blob: Optional[bytes], Db: np.ndarray,
                         backend: str, block: int, hang_s: float = 0.0,
                         boom: bool = False, corrupt: bool = False,
                         jax_interpret: bool = True):
    graph = _WORKER_GRAPHS.get(key)
    if graph is None:
        if blob is None:
            return _NEED_BLOB          # host resends this chunk with the blob
        graph = pickle.loads(blob)
        _WORKER_GRAPHS[key] = graph
        while len(_WORKER_GRAPHS) > _WORKER_GRAPHS_CAP:
            _WORKER_GRAPHS.popitem(last=False)
    else:
        _WORKER_GRAPHS.move_to_end(key)
    if hang_s:
        _time.sleep(hang_s)
    if boom:
        raise InjectedFault(SHARD_FAULT, -1)
    out = solve_block_status(graph, Db, backend=backend, block=block,
                             jax_interpret=jax_interpret)
    return _apply_shard_faults(out, 0.0, False, corrupt)


class BlockScheduler:
    """Lane-based continuous batching of sweep requests (see module doc)."""

    def __init__(self, block: int = 128, shards: int = 1,
                 mode: str = "thread", starvation_limit: int = 4,
                 backend: str = "numpy", min_shard_rows: int = 8,
                 retry: Optional[RetryPolicy] = None,
                 injector: Optional[FaultInjector] = None,
                 shard_timeout_s: Optional[float] = 30.0,
                 quarantine: Optional[DesignQuarantine] = None,
                 max_pool_respawns: int = 2,
                 jax_interpret: bool = True,
                 memo_capacity: int = 4096):
        assert mode in ("serial", "thread", "process"), mode
        self.block = max(int(block), 1)
        self.shards = max(int(shards), 1)
        self.mode = mode if self.shards > 1 else "serial"
        self.starvation_limit = max(int(starvation_limit), 1)
        self.backend = backend
        self.jax_interpret = jax_interpret
        self.min_shard_rows = min_shard_rows
        self.retry = retry if retry is not None else RetryPolicy()
        self.injector = injector
        self.shard_timeout_s = shard_timeout_s
        self.quarantine = (quarantine if quarantine is not None
                           else DesignQuarantine())
        self.max_pool_respawns = max(int(max_pool_respawns), 0)
        self._lanes: Dict[str, deque] = {INTERACTIVE: deque(),
                                         BULK: deque()}
        self._cv = threading.Condition()
        self._consec_interactive = 0
        # pickled graphs per design key, fed to process-pool initializers
        # so respawned workers start warm (bounded like the worker cache)
        self._pool_blobs: "OrderedDict[str, bytes]" = OrderedDict()
        self._pool_gen = 0
        self._pool = self._make_pool()
        # cross-block memo of exact repeat configs: (design key, depth-row
        # bytes) -> (status, cycles, violated).  Content-addressed like the
        # graph cache, so it stays valid across entry eviction/rebuild and
        # across design edits (an edited design has a new key).  Bounded
        # LRU; 0 disables.  FAULTED/TIMED_OUT verdicts are transient and
        # never memoized.
        self.memo_capacity = max(int(memo_capacity), 0)
        self._memo: "OrderedDict[tuple, tuple]" = OrderedDict()
        # shared HybridCache (set by SweepService from its GraphCache):
        # threaded into fallback re-simulations so repeat fallbacks of a
        # dynamic design replay its spilled verified whole run
        self.hybrid = None
        # counters (guarded by _cv's lock)
        self.stats_blocks = 0
        self.stats_blocks_interactive = 0
        self.stats_blocks_bulk = 0
        self.stats_rows = 0              # rows placed into blocks
        self.stats_rows_unique = 0       # rows actually solved
        self.stats_fallbacks = 0         # full re-simulations run
        self.stats_cancelled_rows = 0
        self.stats_requests = 0
        self.stats_retries = 0           # shard attempts beyond the first
        self.stats_faulted_rows = 0      # rows terminally FAULTED
        self.stats_timed_out_rows = 0    # rows terminally TIMED_OUT
        self.stats_pool_respawns = 0
        self.stats_blob_reships = 0      # need-blob round trips (process)
        self.stats_memo_hits = 0         # rows answered without a solve

    # --------------------------------------------------------------- pool
    def _make_pool(self):
        if self.mode == "thread":
            from concurrent.futures import ThreadPoolExecutor
            return ThreadPoolExecutor(max_workers=self.shards,
                                      thread_name_prefix="sweep-shard")
        if self.mode == "process":
            from concurrent.futures import ProcessPoolExecutor
            return ProcessPoolExecutor(
                max_workers=self.shards, initializer=_worker_init,
                initargs=(tuple(self._pool_blobs.items()),))
        return None

    def _respawn_pool(self) -> bool:
        """Replace a broken pool (bounded); False once the budget is
        spent — the caller then fails its chunk instead of looping."""
        if self.stats_pool_respawns >= self.max_pool_respawns:
            return False
        self.stats_pool_respawns += 1
        old = self._pool
        self._pool_gen += 1
        self._pool = self._make_pool()
        if old is not None:
            try:
                old.shutdown(wait=False, cancel_futures=True)
            except Exception:      # a broken pool may refuse even shutdown
                pass
        return True

    def _submit(self, fn, *args):
        """Pool submit that converts a broken-at-submit pool into a
        failed future — _collect's respawn path handles both the same."""
        try:
            return self._pool.submit(fn, *args)
        except (BrokenExecutor, RuntimeError) as exc:
            from concurrent.futures import Future
            fut = Future()
            fut.set_exception(exc if isinstance(exc, BrokenExecutor)
                              else BrokenExecutor(str(exc)))
            return fut

    def _register_blob(self, entry: CacheEntry) -> bytes:
        blob = entry.graph_blob()
        self._pool_blobs[entry.key] = blob
        self._pool_blobs.move_to_end(entry.key)
        while len(self._pool_blobs) > _WORKER_GRAPHS_CAP:
            self._pool_blobs.popitem(last=False)
        return blob

    # ------------------------------------------------------------- intake
    def submit(self, request: _Request) -> None:
        with self._cv:
            self._lanes[request.priority].append(request)
            self.stats_requests += 1
            self._cv.notify_all()

    def kick(self) -> None:
        """Wake the loop (e.g. after a cancellation) so terminal sentinels
        are delivered promptly."""
        with self._cv:
            self._cv.notify_all()

    # ----------------------------------------------------------- assembly
    def _finish(self, req: _Request) -> None:
        """Deliver the terminal sentinel exactly once and release the
        request's admission reservation."""
        if req.finalized:
            return
        req.finalized = True
        req.out_q.put(_DONE)
        if req.on_finalize is not None:
            try:
                req.on_finalize(req)
            except Exception:        # bookkeeping must not kill the loop
                pass

    def _finalize(self, req: _Request) -> None:
        if not req.finalized:
            self.stats_cancelled_rows += req.K - req.delivered
            self._finish(req)

    def _fail_tail(self, req: _Request, status: int, reason: str) -> None:
        """Terminate every not-yet-scheduled row of ``req`` with a
        definite status (FAULTED / TIMED_OUT) and close its stream."""
        n = req.K - req.cursor
        for i in range(req.cursor, req.K):
            req.out_q.put(ConfigResult(
                request_id=req.rid, index=i,
                depths=tuple(int(d) for d in req.D[i]),
                ok=False, status=int(status), cycles=-1, violated=0,
                reason=reason, result=None))
            req.delivered += 1
        req.cursor = req.K
        if status == TIMED_OUT:
            self.stats_timed_out_rows += n
        elif status == FAULTED:
            self.stats_faulted_rows += n
        self._finish(req)

    def _reap_cancelled(self, lane: deque) -> None:
        # reap ANYWHERE in the lane, not just the front: a cancelled
        # request's stream must close at the next scheduling point even
        # with a long bulk queue ahead of it
        for req in [r for r in lane if r.cancelled.is_set()]:
            lane.remove(req)
            self._finalize(req)

    def _reap_expired(self, lane: deque) -> None:
        now = _time.perf_counter()
        for req in [r for r in lane if r.expired(now)]:
            lane.remove(req)
            self._fail_tail(req, TIMED_OUT,
                            "deadline exceeded before this config was "
                            "scheduled")

    def _reap_quarantined(self, lane: deque) -> None:
        for req in [r for r in lane
                    if r.entry is not None
                    and self.quarantine.is_quarantined(r.entry.key)]:
            lane.remove(req)
            why = self.quarantine.reason(req.entry.key)
            self._fail_tail(req, FAULTED,
                            "design quarantined after repeated solve "
                            f"faults{': ' + why if why else ''}")

    def abort_pending(self, message: str) -> None:
        """Fail every queued request (scheduler fault or service close):
        mark the error and deliver the terminal sentinel so no client
        blocks forever on a stream that will never finish."""
        with self._cv:
            for lane in self._lanes.values():
                for req in list(lane):
                    req.error = req.error or message
                    self._finalize(req)
                lane.clear()

    def drain(self, abort_message: str = "sweep service closed") -> None:
        """Graceful drain: fail requests that never reached a block
        (definite error, no hang), then flush every in-flight request —
        one that already has rows in completed blocks finishes its
        remaining rows before the service goes down."""
        with self._cv:
            for lane in self._lanes.values():
                for req in [r for r in lane if r.cursor == 0]:
                    req.error = req.error or abort_message
                    self._finalize(req)
                    lane.remove(req)
        while True:
            try:
                if not self.step():
                    break
            except Exception:
                # step() already failed the faulting block's requests;
                # draining continues with whatever is left
                continue

    def _pick_lane(self) -> Optional[str]:
        """Interactive first; one bulk block is forced through after
        ``starvation_limit`` consecutive interactive blocks."""
        for lane in (self._lanes[INTERACTIVE], self._lanes[BULK]):
            self._reap_cancelled(lane)
            self._reap_expired(lane)
            self._reap_quarantined(lane)
        has_i = bool(self._lanes[INTERACTIVE])
        has_b = bool(self._lanes[BULK])
        if not has_b:
            # starvation debt only accrues while bulk work actually
            # waits — a stale counter must not let a fresh bulk sweep
            # preempt the interactive lane
            self._consec_interactive = 0
        if has_i and has_b:
            if self._consec_interactive >= self.starvation_limit:
                return BULK
            return INTERACTIVE
        if has_i:
            return INTERACTIVE
        if has_b:
            return BULK
        return None

    def _assemble(self) -> Optional[_Block]:
        """Build the next block: anchor on the chosen lane's oldest live
        request, fill with same-design rows from every queued request."""
        with self._cv:
            lane_name = self._pick_lane()
            if lane_name is None:
                return None
            lane = self._lanes[lane_name]
            anchor = lane[0]
            items: List[Tuple[_Request, int]] = []
            for scan in (lane_name, BULK if lane_name == INTERACTIVE
                         else INTERACTIVE):
                q = self._lanes[scan]
                for req in list(q):
                    if len(items) >= self.block:
                        break
                    if req.cancelled.is_set():
                        continue         # reaped at the front eventually
                    if req.entry is not anchor.entry:
                        continue
                    take = min(self.block - len(items), req.K - req.cursor)
                    items.extend((req, i) for i in
                                 range(req.cursor, req.cursor + take))
                    req.cursor += take
                    if req.cursor >= req.K:
                        q.remove(req)
            if lane_name == INTERACTIVE:
                # starvation debt accrues only while bulk work waits
                self._consec_interactive = (self._consec_interactive + 1
                                            if self._lanes[BULK] else 0)
                self.stats_blocks_interactive += 1
            else:
                self._consec_interactive = 0
                self.stats_blocks_bulk += 1
            self.stats_blocks += 1
            self.stats_rows += len(items)
            return _Block(anchor.entry, items, lane_name)

    # -------------------------------------------------------------- solve
    def _launch(self, entry: CacheEntry, Db: np.ndarray,
                pooled: bool) -> _Attempt:
        """Start one shard attempt; injector sites are drawn HERE, on the
        scheduler thread, so fault patterns are deterministic in manual
        mode regardless of worker timing."""
        inj = self.injector
        hang_s = (inj.hang_s if inj is not None
                  and inj.draw(SHARD_HANG, key=entry.key) else 0.0)
        boom = bool(inj is not None and inj.draw(SHARD_FAULT,
                                                 key=entry.key))
        corrupt = bool(inj is not None and inj.draw(SHARD_CORRUPT,
                                                    key=entry.key))
        if not pooled:
            call = (lambda: _shard_task(entry.graph, Db, self.backend,
                                        self.block, hang_s, boom, corrupt,
                                        self.jax_interpret))
            return _Attempt(None, call, self._pool_gen)
        if self.mode == "process":
            self._register_blob(entry)
            fut = self._submit(_process_shard_solve, entry.key, None,
                               Db, self.backend, self.block,
                               hang_s, boom, corrupt, self.jax_interpret)
        else:
            fut = self._submit(_shard_task, entry.graph, Db,
                               self.backend, self.block,
                               hang_s, boom, corrupt, self.jax_interpret)
        return _Attempt(fut, None, self._pool_gen)

    def _collect(self, entry: CacheEntry, Db: np.ndarray,
                 attempt: _Attempt, pooled: bool,
                 t_deadline: Optional[float]):
        """Wait for one shard chunk, retrying per the RetryPolicy within
        the deadline budget.  Returns ``(status, cycles, violated, note)``
        for the chunk — on exhaustion the rows carry FAULTED/TIMED_OUT
        and ``note`` holds the human-readable cause."""
        K = len(Db)
        inj = self.injector

        def fail(code: int, note: str):
            if code == FAULTED:
                tripped = self.quarantine.strike(entry.key, note)
                if tripped:
                    note += " (design quarantined)"
                with self._cv:
                    self.stats_faulted_rows += K
            else:
                with self._cv:
                    self.stats_timed_out_rows += K
            return (np.full(K, code, np.int8), np.full(K, -1, np.int64),
                    np.zeros(K, np.int64), note)

        tries = 0
        while True:
            if t_deadline is not None:
                remaining = t_deadline - _time.perf_counter()
                if remaining <= 0:
                    return fail(TIMED_OUT,
                                "deadline exceeded while solving this "
                                "shard")
            else:
                remaining = None
            kind, note = "fault", ""
            eff = self.shard_timeout_s
            try:
                if inj is not None and inj.draw(POOL_BROKEN,
                                                key=entry.key):
                    raise _PoolBrokenFault(POOL_BROKEN, -1)
                if attempt.fut is not None:
                    if remaining is not None:
                        eff = (min(eff, remaining) if eff is not None
                               else remaining)
                    out = attempt.fut.result(timeout=eff)
                else:
                    out = attempt.call()
                if isinstance(out, str) and out == _NEED_BLOB:
                    # worker spawned after this design appeared: reship
                    # the blob once for this chunk (not a retry)
                    with self._cv:
                        self.stats_blob_reships += 1
                    fut = self._submit(
                        _process_shard_solve, entry.key,
                        self._register_blob(entry), Db, self.backend,
                        self.block, 0.0, False, False,
                        self.jax_interpret)
                    attempt = _Attempt(fut, None, self._pool_gen)
                    continue
                status, cycles, violated, _rounds = out
                if (len(status) != K or len(cycles) != K
                        or len(violated) != K):
                    raise ShardCorruption(
                        f"shard returned {len(status)} rows for a "
                        f"{K}-row chunk")
                return (np.asarray(status, np.int8),
                        np.asarray(cycles, np.int64),
                        np.asarray(violated, np.int64), "")
            except (_FutTimeout, TimeoutError):
                kind = "timeout"
                note = (f"shard timed out after "
                        f"{eff if eff is not None else 0:.3g}s")
            except (BrokenExecutor, _PoolBrokenFault) as exc:
                # every chunk whose future died with the pool lands here;
                # only the first one pays a respawn — later ones see the
                # new generation and simply relaunch on it
                if attempt.gen == self._pool_gen:
                    with self._cv:
                        ok = self._respawn_pool()
                    if not ok:
                        return fail(FAULTED,
                                    f"worker pool broke ({exc!r}) and the "
                                    f"respawn budget is spent")
                attempt = self._launch(entry, Db, pooled)
                continue               # a respawn is not a solve retry
            except CancelledError:
                # queued task cancelled by a pool respawn: relaunch
                attempt = self._launch(entry, Db, pooled)
                continue
            except Exception as exc:
                kind = "fault"
                note = f"shard solve faulted: {exc!r}"
            tries += 1
            if tries >= self.retry.max_attempts:
                note += f" (after {tries} attempts)"
                return fail(FAULTED if kind == "fault" else TIMED_OUT,
                            note)
            backoff = self.retry.backoff(tries - 1)
            if t_deadline is not None:
                backoff = min(backoff,
                              max(t_deadline - _time.perf_counter(), 0.0))
            if backoff > 0:
                _time.sleep(backoff)
            with self._cv:
                self.stats_retries += 1
            attempt = self._launch(entry, Db, pooled)

    def _solve_unique(self, entry: CacheEntry, Du: np.ndarray,
                      t_deadline: Optional[float] = None):
        """Solve the unique rows of a block, sharded across workers.

        Returns ``(status, cycles, violated, notes)`` where ``notes`` maps
        unique-row positions to fault detail strings for rows that ended
        FAULTED/TIMED_OUT instead of being solved.
        """
        U = len(Du)
        pooled = not (self._pool is None or U < self.min_shard_rows
                      or self.shards == 1)
        if pooled:
            idx_chunks = [c for c in
                          np.array_split(np.arange(U),
                                         min(self.shards, U)) if len(c)]
        else:
            idx_chunks = [np.arange(U)]
        status = np.empty(U, dtype=np.int8)
        cycles = np.full(U, -1, dtype=np.int64)
        violated = np.zeros(U, dtype=np.int64)
        notes: Dict[int, str] = {}
        attempts = [self._launch(entry, Du[c], pooled) for c in idx_chunks]
        for c, attempt in zip(idx_chunks, attempts):
            st, cy, vi, note = self._collect(entry, Du[c], attempt,
                                             pooled, t_deadline)
            status[c], cycles[c], violated[c] = st, cy, vi
            if note:
                for u in c:
                    notes[int(u)] = note
        return status, cycles, violated, notes

    # ------------------------------------------------------------ deliver
    def _deliver(self, blk: _Block) -> None:
        entry = blk.entry
        rows = np.stack([req.D[i] for (req, i) in blk.items])
        Du, inverse = np.unique(rows, axis=0, return_inverse=True)
        inverse = inverse.reshape(-1)
        with self._cv:
            self.stats_rows_unique += len(Du)
        deadlines = [req.t_deadline for (req, _i) in blk.items
                     if req.t_deadline is not None]
        t_deadline = min(deadlines) if deadlines else None
        # cross-block memo: identical (design, depth-row) pairs seen in any
        # earlier block are answered without a solver call — only the
        # residual rows reach _solve_unique
        U = len(Du)
        status_u = np.empty(U, dtype=np.int8)
        cycles_u = np.full(U, -1, dtype=np.int64)
        violated_u = np.zeros(U, dtype=np.int64)
        notes: Dict[int, str] = {}
        memo_hit = np.zeros(U, dtype=bool)
        if self.memo_capacity:
            with self._cv:
                for u in range(U):
                    mk = (entry.key, Du[u].tobytes())
                    got = self._memo.get(mk)
                    if got is not None:
                        self._memo.move_to_end(mk)
                        status_u[u], cycles_u[u], violated_u[u] = got
                        memo_hit[u] = True
                        self.stats_memo_hits += 1
        solve_idx = np.flatnonzero(~memo_hit)
        if len(solve_idx):
            st, cy, vi, sub_notes = self._solve_unique(
                entry, Du[solve_idx], t_deadline)
            status_u[solve_idx] = st
            cycles_u[solve_idx] = cy
            violated_u[solve_idx] = vi
            for su, note in sub_notes.items():
                notes[int(solve_idx[su])] = note
            if self.memo_capacity:
                with self._cv:
                    for su in range(len(solve_idx)):
                        s = int(st[su])
                        if s == FAULTED or s == TIMED_OUT:
                            continue
                        self._memo[(entry.key,
                                    Du[solve_idx[su]].tobytes())] = (
                            s, int(cy[su]), int(vi[su]))
                    while len(self._memo) > self.memo_capacity:
                        self._memo.popitem(last=False)

        # a failed unique row pays for its exact fallback only if a LIVE
        # request owning it asked for fallback (a cancelled or expired
        # tenant's rows must not cost engine re-simulations nobody will
        # receive)
        now = _time.perf_counter()
        fb_mask = np.zeros(len(Du), dtype=bool)
        for pos, (req, _i) in enumerate(blk.items):
            if (req.fallback and not req.cancelled.is_set()
                    and not req.expired(now)):
                fb_mask[inverse[pos]] = True
        # exact fallback needs the engine: once per unique row, under the
        # design's entry lock (depths are mutated + restored); the shared
        # dse helper keeps verdicts byte-identical to resimulate_batch's.
        # A faulting fallback (poisoned design) must not fail the block:
        # solver verdicts stand, only the engine-exact results are
        # withheld, and the design takes a quarantine strike.
        try:
            results_u, reasons_u = materialize_block(
                entry.result, Du, status_u, cycles_u, violated_u, fb_mask,
                engine_label="omnisim-sweep", lock=entry.lock,
                hybrid_cache=self.hybrid)
        except Exception as exc:
            note = f"fallback re-simulation faulted: {exc!r}"
            self.quarantine.strike(entry.key, note)
            results_u, reasons_u = materialize_block(
                entry.result, Du, status_u, cycles_u, violated_u,
                np.zeros(len(Du), dtype=bool),
                engine_label="omnisim-sweep", lock=entry.lock,
                hybrid_cache=self.hybrid)
            for u in range(len(Du)):
                if fb_mask[u] and status_u[u] != REUSED:
                    reasons_u[u] += f" [{note}]"
            fb_mask[:] = False
        for u, note in notes.items():
            reasons_u[u] = note
        n_fb = int((fb_mask & (status_u != REUSED)).sum())
        if n_fb:
            with self._cv:
                self.stats_fallbacks += n_fb

        now = _time.perf_counter()
        for pos, (req, i) in enumerate(blk.items):
            if req.cancelled.is_set():
                continue
            if req.expired(now):
                # end-to-end deadline: a result that arrives late is a
                # timeout, not a delivery
                req.out_q.put(ConfigResult(
                    request_id=req.rid, index=i,
                    depths=tuple(int(d) for d in req.D[i]),
                    ok=False, status=TIMED_OUT, cycles=-1, violated=0,
                    reason="deadline exceeded before this config was "
                           "delivered", result=None))
                with self._cv:
                    self.stats_timed_out_rows += 1
            else:
                u = int(inverse[pos])
                use_fb = req.fallback or status_u[u] == REUSED
                req.out_q.put(ConfigResult(
                    request_id=req.rid, index=i,
                    depths=tuple(int(d) for d in req.D[i]),
                    ok=bool(status_u[u] == REUSED),
                    status=int(status_u[u]),
                    cycles=int(cycles_u[u]) if use_fb else -1,
                    violated=int(violated_u[u]), reason=reasons_u[u],
                    result=results_u[u] if use_fb else None))
            req.delivered += 1
            if req.delivered >= req.K:
                self._finish(req)
        for req, _i in blk.items:
            if req.cancelled.is_set():
                self._finalize(req)

    # --------------------------------------------------------------- step
    def step(self) -> bool:
        """Assemble, solve and deliver ONE block; False when idle.

        The public unit of progress: the service's background thread calls
        it in a loop, and deterministic tests drive it directly.  Shard
        faults and timeouts are absorbed inside the block (FAULTED /
        TIMED_OUT rows); only a genuine scheduler bug reaches the except
        path, which fails exactly the block's requests (error + terminal
        sentinel, so no client stream hangs) and re-raises.
        """
        blk = self._assemble()
        if blk is None:
            return False
        try:
            self._deliver(blk)
        except Exception as exc:
            msg = f"sweep block failed: {exc!r}"
            self.quarantine.strike(blk.entry.key, msg)
            with self._cv:
                for req, _i in blk.items:
                    req.error = req.error or msg
                    self._finalize(req)
                    for lane in self._lanes.values():
                        if req in lane:          # rows beyond this block
                            lane.remove(req)
            raise
        return True

    def wait_for_work(self, timeout: float = 0.2) -> None:
        with self._cv:
            if self._pick_lane() is None:
                self._cv.wait(timeout)

    def has_work(self) -> bool:
        with self._cv:
            return self._pick_lane() is not None

    def stats(self) -> Dict[str, float]:
        with self._cv:
            solved = max(self.stats_rows_unique, 1)
            return {
                "requests": self.stats_requests,
                "blocks": self.stats_blocks,
                "blocks_interactive": self.stats_blocks_interactive,
                "blocks_bulk": self.stats_blocks_bulk,
                "rows": self.stats_rows,
                "rows_unique": self.stats_rows_unique,
                "dedup_ratio": (self.stats_rows / solved
                                if self.stats_rows else 1.0),
                "fallbacks": self.stats_fallbacks,
                "cancelled_rows": self.stats_cancelled_rows,
                "retries": self.stats_retries,
                "faulted_rows": self.stats_faulted_rows,
                "timed_out_rows": self.stats_timed_out_rows,
                "pool_respawns": self.stats_pool_respawns,
                "blob_reships": self.stats_blob_reships,
                "memo_hits": self.stats_memo_hits,
                "memo_size": len(self._memo),
                "shards": self.shards,
                "mode": self.mode,
            }

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None
