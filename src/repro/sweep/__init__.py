"""Served design-space exploration: sharded, streaming FIFO-depth sweeps
from a warm compiled-graph cache.

The subsystem that turns ``repro.core.resimulate_batch`` — a blocking,
single-host library call — into a multi-tenant workload (the ROADMAP's
"serve DSE requests against a warm CompiledGraph cache" item):

  * :mod:`repro.sweep.cache`     — content-addressed LRU of warm
    ``(SimResult, CompiledGraph, _BatchArrays)`` design entries;
  * :mod:`repro.sweep.scheduler` — continuous batching: cross-tenant
    block coalescing, in-block dedup, worker sharding, per-config
    streaming, cancellation, priority lanes, per-shard retry/timeout,
    pool respawn, end-to-end deadlines;
  * :mod:`repro.sweep.faults`    — deterministic fault injection
    (``FaultInjector``), ``RetryPolicy``, and the per-design
    ``DesignQuarantine`` circuit breaker;
  * :mod:`repro.sweep.admission` — per-tenant quotas and load shedding
    (``AdmissionController``);
  * :mod:`repro.sweep.service`   — the front door
    (``SweepService.submit/stream/sweep/stats``);
  * :mod:`repro.sweep.search`    — grid / random / successive-halving
    drivers producing (FIFO area, latency) Pareto frontiers.

See ``docs/sweep_guide.md`` for the walkthrough (including "Operating
under faults").
"""
from ..core.dse import CANCELLED, FAULTED, REJECTED, TIMED_OUT
from .admission import DEFAULT_TENANT, AdmissionController
from .cache import CacheEntry, GraphCache
from .faults import (DesignQuarantine, FaultInjector, InjectedFault,
                     RetryPolicy)
from .scheduler import BULK, INTERACTIVE, BlockScheduler, ConfigResult
from .search import (SearchOutcome, grid_search, pareto_front,
                     random_search, successive_halving)
from .service import SweepHandle, SweepService, SweepTimeoutError

__all__ = [
    "AdmissionController", "BlockScheduler", "BULK", "CacheEntry",
    "CANCELLED", "ConfigResult", "DEFAULT_TENANT", "DesignQuarantine",
    "FAULTED", "FaultInjector", "GraphCache", "grid_search",
    "InjectedFault", "INTERACTIVE", "pareto_front", "random_search",
    "REJECTED", "RetryPolicy", "SearchOutcome", "successive_halving",
    "SweepHandle", "SweepService", "SweepTimeoutError", "TIMED_OUT",
]
