"""Served design-space exploration: sharded, streaming FIFO-depth sweeps
from a warm compiled-graph cache.

The subsystem that turns ``repro.core.resimulate_batch`` — a blocking,
single-host library call — into a multi-tenant workload (the ROADMAP's
"serve DSE requests against a warm CompiledGraph cache" item):

  * :mod:`repro.sweep.cache`     — content-addressed LRU of warm
    ``(SimResult, CompiledGraph, _BatchArrays)`` design entries;
  * :mod:`repro.sweep.scheduler` — continuous batching: cross-tenant
    block coalescing, in-block dedup, worker sharding, per-config
    streaming, cancellation, priority lanes;
  * :mod:`repro.sweep.service`   — the front door
    (``SweepService.submit/stream/sweep/stats``);
  * :mod:`repro.sweep.search`    — grid / random / successive-halving
    drivers producing (FIFO area, latency) Pareto frontiers.

See ``docs/sweep_guide.md`` for the walkthrough.
"""
from .cache import CacheEntry, GraphCache
from .scheduler import (BULK, CANCELLED, INTERACTIVE, BlockScheduler,
                        ConfigResult)
from .search import (SearchOutcome, grid_search, pareto_front,
                     random_search, successive_halving)
from .service import SweepHandle, SweepService

__all__ = [
    "BlockScheduler", "BULK", "CacheEntry", "CANCELLED", "ConfigResult",
    "GraphCache", "grid_search", "INTERACTIVE", "pareto_front",
    "random_search", "SearchOutcome", "successive_halving", "SweepHandle",
    "SweepService",
]
