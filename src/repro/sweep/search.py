"""Search drivers: FIFO-area minimization over the sweep-service stream.

The service answers "what does this depth vector cost?"; these drivers
decide *which* vectors to ask about.  All three consume the same streaming
API (``service.sweep`` / ``service.stream``) and produce a
:class:`SearchOutcome` whose centerpiece is the Pareto frontier of
``(total FIFO depth, latency cycles)`` — the HLS designer's actual
decision surface: every point on it is a cheapest design at its speed.

  * :func:`grid_search` — uniform-depth grid, per-FIFO axis sweeps, or a
    (capped) full cartesian product;
  * :func:`random_search` — seeded uniform sampling of the depth box;
  * :func:`successive_halving` — rounds of evaluate → keep the best
    ``1/eta`` (latency-lexicographic: fastest first, cheapest among ties)
    → respawn shrink-mutated children, so the population drifts toward
    the low-area end of the frontier; survivors carry their verdicts
    forward (driver memo), so only never-seen configs are submitted.

Deadlocked / cancelled configurations are infeasible and never enter the
frontier; every feasible cycle count is exact (service conformance).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..core.dse import (CANCELLED, DEADLOCK, FAULTED, REJECTED, TIMED_OUT,
                        BatchOutcome)
from ..core.program import Program
from .scheduler import BULK
from .service import SweepService

# Statuses that can never enter the frontier.  DEADLOCK is a solver
# verdict (the config genuinely stalls); the other four are the sweep
# service's terminal statuses (PR 6) — the row was never exactly solved,
# so whatever its ``cycles`` field carries must not be trusted.  The
# remaining fallback statuses (CYCLE / VIOLATED) are refined by an exact
# engine re-simulation, so their feasibility is decided by the refined
# result (``cycles >= 0`` and ``not res.deadlock``), not the raw verdict.
_INFEASIBLE_STATUSES = (DEADLOCK, CANCELLED, FAULTED, TIMED_OUT, REJECTED)


@dataclass
class SearchOutcome:
    """Everything a driver evaluated, plus the decision surface."""

    depths: np.ndarray            # (N, F) every evaluated candidate
    cycles: np.ndarray            # (N,) exact latency; -1 = infeasible
    feasible: np.ndarray          # (N,) bool
    pareto: List[Tuple[Tuple[int, ...], int, int]]
    # ^ [(depth vector, total depth, cycles)] sorted by ascending area
    best: Optional[Tuple[Tuple[int, ...], int]]   # fastest (cheapest on tie)
    rounds: int = 1

    def summary(self) -> str:
        front = ", ".join(f"(area={a}, cyc={c})" for _d, a, c in self.pareto)
        return (f"{len(self.depths)} evaluated, "
                f"{int(self.feasible.sum())} feasible, "
                f"pareto: {front or 'empty'}")


def _feasible_mask(out: BatchOutcome) -> np.ndarray:
    feas = (np.asarray(out.cycles) >= 0)
    feas &= ~np.isin(np.asarray(out.status), _INFEASIBLE_STATUSES)
    for k, res in enumerate(out.results):
        if res is not None and res.deadlock:
            feas[k] = False
    return feas


def pareto_front(depths: np.ndarray, cycles: np.ndarray,
                 feasible: Optional[np.ndarray] = None
                 ) -> List[Tuple[Tuple[int, ...], int, int]]:
    """Non-dominated ``(depth vector, total depth, cycles)`` points,
    minimizing both coordinates, sorted by ascending total depth."""
    D = np.asarray(depths)
    C = np.asarray(cycles)
    if feasible is None:
        feasible = C >= 0
    idx = np.flatnonzero(np.asarray(feasible))
    if len(idx) == 0:
        return []
    area = D[idx].sum(axis=1)
    order = idx[np.lexsort((C[idx], area))]      # by area, then cycles
    front: List[Tuple[Tuple[int, ...], int, int]] = []
    best_c = None
    for k in order:
        a, c = int(D[k].sum()), int(C[k])
        if best_c is not None and c >= best_c:
            continue                              # dominated (or duplicate)
        front.append((tuple(int(x) for x in D[k]), a, c))
        best_c = c
    return front


def _outcome(service: SweepService, program: Program, D: np.ndarray,
             rounds: int = 1, **submit_kw) -> SearchOutcome:
    out = service.sweep(program, D, **submit_kw)
    feas = _feasible_mask(out)
    cycles = np.asarray(out.cycles)
    best = None
    if feas.any():
        f = np.flatnonzero(feas)
        k = f[np.lexsort((D[f].sum(axis=1), cycles[f]))[0]]
        best = (tuple(int(x) for x in D[k]), int(cycles[k]))
    return SearchOutcome(depths=D, cycles=cycles, feasible=feas,
                         pareto=pareto_front(D, cycles, feas), best=best,
                         rounds=rounds)


def grid_search(service: SweepService, program: Program,
                values: Sequence[int], mode: str = "uniform",
                base_depths: Optional[Sequence[int]] = None,
                limit: int = 4096, **submit_kw) -> SearchOutcome:
    """Grid sweep of the depth space.

    ``mode="uniform"``: every FIFO gets the same depth, one config per
    value.  ``mode="axes"``: vary one FIFO at a time around
    ``base_depths`` (defaults to the program's current depths) — the
    classic coordinate sweep, ``F * len(values)`` configs with heavy
    duplicate structure the scheduler dedups.  ``mode="product"``: the
    full cartesian product (guarded by ``limit``).
    """
    F = len(program.fifos)
    values = [int(v) for v in values]
    if mode == "uniform":
        D = np.asarray([[v] * F for v in values], dtype=np.int64)
    elif mode == "axes":
        base = np.asarray(base_depths if base_depths is not None
                          else program.depths(), dtype=np.int64)
        rows = [base.copy()]
        for f in range(F):
            for v in values:
                row = base.copy()
                row[f] = v
                rows.append(row)
        D = np.stack(rows)
    elif mode == "product":
        if len(values) ** F > limit:
            raise ValueError(
                f"product grid {len(values)}^{F} exceeds limit={limit}; "
                f"use mode='axes'/'uniform' or random_search")
        mesh = np.meshgrid(*([values] * F), indexing="ij")
        D = np.stack([m.reshape(-1) for m in mesh], axis=1).astype(np.int64)
    else:
        raise ValueError(f"unknown grid mode {mode!r}")
    return _outcome(service, program, D, **submit_kw)


def random_search(service: SweepService, program: Program, n: int,
                  lo: int = 1, hi: int = 16, seed: int = 0,
                  **submit_kw) -> SearchOutcome:
    """Seeded uniform sampling of ``[lo, hi]^F`` (``n`` configs)."""
    rng = np.random.default_rng(seed)
    D = rng.integers(lo, hi + 1, size=(n, len(program.fifos)),
                     dtype=np.int64)
    return _outcome(service, program, D, **submit_kw)


def successive_halving(service: SweepService, program: Program,
                       n0: int = 32, rounds: int = 3, eta: int = 2,
                       lo: int = 1, hi: int = 16, seed: int = 0,
                       **submit_kw) -> SearchOutcome:
    """Successive-halving FIFO-area minimization.

    Round 0 evaluates ``n0`` random configs; each later round keeps the
    best ``1/eta`` (fastest first, cheapest among equally fast) and
    refills the population with shrink-mutated children of the survivors
    (each child halves a random subset of its parent's depths, floored at
    ``lo``) — pushing along the frontier toward smaller FIFO area.
    Children that deadlock are simply infeasible and drop out at the next
    selection.  Survivors carry their known verdicts forward (a
    driver-level memo), so each round only submits the configs it has
    never evaluated; all evaluations feed one final Pareto frontier.
    """
    submit_kw.setdefault("priority", BULK)
    rng = np.random.default_rng(seed)
    F = len(program.fifos)
    pop = rng.integers(lo, hi + 1, size=(n0, F), dtype=np.int64)
    memo: dict = {}                     # depth tuple -> (cycles, feasible)
    all_D: List[np.ndarray] = []
    all_C: List[np.ndarray] = []
    all_feas: List[np.ndarray] = []
    rounds_run = 0
    for _r in range(rounds):
        if not len(pop):
            break
        rounds_run += 1
        fresh = [row for row in pop if tuple(row) not in memo]
        if fresh:
            Df = np.stack(fresh)
            out = service.sweep(program, Df, **submit_kw)
            ofeas = _feasible_mask(out)
            for k, row in enumerate(Df):
                memo[tuple(row)] = (int(out.cycles[k]), bool(ofeas[k]))
            all_D.append(Df)
            all_C.append(np.asarray(out.cycles))
            all_feas.append(ofeas)
        cycles = np.asarray([memo[tuple(row)][0] for row in pop])
        feas = np.asarray([memo[tuple(row)][1] for row in pop])
        keep = max(1, len(pop) // eta)
        f = np.flatnonzero(feas)
        if len(f) == 0:
            break                       # all-infeasible: nothing to mutate
        order = f[np.lexsort((pop[f].sum(axis=1), cycles[f]))][:keep]
        survivors = pop[order]
        children = survivors.repeat(max(eta - 1, 1), axis=0)
        shrink = rng.random(children.shape) < 0.5
        children = np.where(shrink, np.maximum(children // 2, lo), children)
        pop = np.concatenate([survivors, children])
    if not all_D:
        # n0 == 0, or every round-0 row was already memoized by the caller:
        # a well-formed empty outcome, not an np.concatenate crash
        empty_D = np.zeros((0, F), dtype=np.int64)
        empty = np.zeros(0, dtype=np.int64)
        return SearchOutcome(depths=empty_D, cycles=empty,
                             feasible=np.zeros(0, dtype=bool), pareto=[],
                             best=None, rounds=rounds_run)
    D = np.concatenate(all_D)
    C = np.concatenate(all_C)
    feas = np.concatenate(all_feas)
    best = None
    if feas.any():
        f = np.flatnonzero(feas)
        k = f[np.lexsort((D[f].sum(axis=1), C[f]))[0]]
        best = (tuple(int(x) for x in D[k]), int(C[k]))
    return SearchOutcome(depths=D, cycles=C, feasible=feas,
                         pareto=pareto_front(D, C, feas), best=best,
                         rounds=rounds_run)
