"""Fault machinery for the sweep service: deterministic injection,
bounded retry with backoff, and a per-design circuit breaker.

Production brings failures the happy path never sees: a shard worker
raises mid-solve, hangs past every deadline, the whole process pool dies,
or one *design* is poisoned (its solves or fallback re-simulations fault
every time) and would otherwise take the service down for every tenant
co-scheduled with it.  This module holds the three pieces the hardened
scheduler (``scheduler.py``) is built on:

  * :class:`FaultInjector` — deterministic, seedable fault injection at
    named sites in the scheduler/worker code (``shard.fault``,
    ``shard.hang``, ``shard.corrupt``, ``pool.broken``).  The test suite
    and the fault benchmark drive every recovery path through it — same
    seed, same plan ⇒ same faults, so recovery behavior is pinned by
    ordinary assertions instead of flaky sleeps.
  * :class:`RetryPolicy` — bounded attempts with exponential backoff,
    always clipped to the request's remaining deadline budget.
  * :class:`DesignQuarantine` — a circuit breaker keyed by
    ``program_fingerprint``: repeated solve faults for ONE design trip
    the breaker, after which that design's requests are rejected fast
    (and its queued rows failed with a definite status) while every
    other design keeps being served.  Reset manually or after an
    optional cooldown.

Nothing here touches verdict content: a fault path may *withhold* a row
(``FAULTED`` / ``TIMED_OUT``), never alter one — rows that are delivered
stay bit-identical to the generator engine (pinned by
``tests/test_golden.py``).
"""
from __future__ import annotations

import threading
import time as _time
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

# Injection sites the scheduler consults.  Named here so tests/docs and
# the scheduler cannot drift on spelling.
SHARD_FAULT = "shard.fault"       # shard solve raises
SHARD_HANG = "shard.hang"         # shard solve sleeps past its timeout
SHARD_CORRUPT = "shard.corrupt"   # shard returns malformed result arrays
POOL_BROKEN = "pool.broken"       # worker pool reports itself broken
SITES = (SHARD_FAULT, SHARD_HANG, SHARD_CORRUPT, POOL_BROKEN)


class InjectedFault(RuntimeError):
    """Raised (or simulated) by an armed :class:`FaultInjector` site."""

    def __init__(self, site: str, occurrence: int):
        super().__init__(f"injected fault at {site!r} (occurrence "
                         f"#{occurrence})")
        self.site = site
        self.occurrence = occurrence


class _PoolBrokenFault(InjectedFault):
    """Injected stand-in for ``concurrent.futures.BrokenExecutor`` — the
    scheduler's respawn path treats it exactly like the real thing."""


class _Arm:
    __slots__ = ("at", "rate", "rng", "key")

    def __init__(self, at, rate, rng, key):
        self.at = at
        self.rate = rate
        self.rng = rng
        self.key = key


class FaultInjector:
    """Deterministic, seedable fault injection at named sites.

    Each site is *armed* with either an explicit occurrence plan
    (``at=[0, 3]`` — fire on the 0th and 3rd draw at that site) or a
    seeded Bernoulli ``rate``; optionally scoped to one design via
    ``key`` (the design's content fingerprint) so one tenant's poisoned
    design can fault while co-scheduled designs stay clean.  Every
    random stream is derived from ``(seed, site)``, so the firing
    pattern of one site never depends on how often other sites are
    drawn — runs are reproducible under any interleaving.

        inj = FaultInjector(seed=7, hang_s=0.1)
        inj.arm("shard.fault", at=[0])          # first shard solve faults
        inj.arm("shard.hang", rate=0.1)         # 10% of shards hang
        inj.arm("shard.fault", rate=1.0, key=poisoned_key)

    The scheduler calls :meth:`draw` once per shard attempt per site; a
    ``True`` return makes it run the corresponding fault action.  An
    unarmed injector (or ``injector=None``, the production default) costs
    one ``None`` check per block.
    """

    def __init__(self, seed: int = 0, hang_s: float = 0.25):
        self.seed = int(seed)
        self.hang_s = float(hang_s)
        self._arms: Dict[str, List[_Arm]] = {}
        self._counts: Dict[str, int] = {}
        self._lock = threading.Lock()
        self.log: List[Tuple[str, int, Optional[str]]] = []

    def arm(self, site: str, at: Optional[Iterable[int]] = None,
            rate: float = 0.0, key: Optional[str] = None) -> "FaultInjector":
        """Arm ``site`` with an occurrence plan and/or a fault rate,
        optionally scoped to one design ``key``.  Returns ``self`` so
        arms chain."""
        assert site in SITES, f"unknown injection site {site!r}"
        rng = np.random.default_rng(
            abs(hash((self.seed, site, key))) % (1 << 63))
        with self._lock:
            self._arms.setdefault(site, []).append(
                _Arm(frozenset(int(i) for i in (at or ())), float(rate),
                     rng, key))
        return self

    def draw(self, site: str, key: Optional[str] = None) -> bool:
        """One deterministic decision: does ``site`` fault on this draw?

        Increments the site's occurrence counter whether or not any arm
        matches, so plans stay stable when arms are added or removed.
        """
        with self._lock:
            arms = self._arms.get(site)
            count = self._counts.get(site, 0)
            self._counts[site] = count + 1
            if not arms:
                return False
            fired = False
            for arm in arms:
                if arm.key is not None and arm.key != key:
                    continue
                if count in arm.at:
                    fired = True
                # the rate stream advances only for matching arms — a
                # per-(site, key) stream independent of other designs
                elif arm.rate and arm.rng.random() < arm.rate:
                    fired = True
            if fired:
                self.log.append((site, count, key))
            return fired

    def fire(self, site: str, key: Optional[str] = None) -> None:
        """Raise :class:`InjectedFault` if :meth:`draw` fires (the
        convenience form for raise-only sites)."""
        with self._lock:
            count = self._counts.get(site, 0)
        if self.draw(site, key=key):
            raise InjectedFault(site, count)

    def stats(self) -> Dict[str, Dict[str, int]]:
        with self._lock:
            fired: Dict[str, int] = {}
            for site, _cnt, _key in self.log:
                fired[site] = fired.get(site, 0) + 1
            return {"draws": dict(self._counts), "fired": fired}


@dataclass(frozen=True)
class RetryPolicy:
    """Bounded retries with exponential backoff under a deadline budget.

    ``max_attempts`` counts the first try: ``max_attempts=3`` is one
    attempt plus two retries.  ``backoff(i)`` is the sleep before the
    i-th retry (0-based), exponentially grown and capped; the scheduler
    additionally clips every backoff to the affected requests' remaining
    deadline budget, so retrying can never push a row past its deadline
    just to sleep.
    """

    max_attempts: int = 3
    backoff_s: float = 0.01
    backoff_mult: float = 2.0
    max_backoff_s: float = 0.25

    def backoff(self, retry_index: int) -> float:
        return min(self.backoff_s * (self.backoff_mult ** retry_index),
                   self.max_backoff_s)


class DesignQuarantine:
    """Circuit breaker keyed by design fingerprint.

    Every exhausted-retries solve fault (and every faulting fallback
    re-simulation or cache build) records a *strike* against the
    design's key; ``threshold`` strikes trip the breaker.  A tripped
    design's queued rows fail fast with ``FAULTED`` and new submissions
    are rejected by the service front door — co-scheduled tenants keep
    being served instead of burning the retry budget on a poisoned
    design over and over.  ``cooldown_s`` (optional) auto-resets a trip
    after that many seconds; :meth:`reset` clears one key or all.
    """

    def __init__(self, threshold: int = 3,
                 cooldown_s: Optional[float] = None):
        self.threshold = max(int(threshold), 1)
        self.cooldown_s = cooldown_s
        self._strikes: Dict[str, int] = {}
        self._tripped: Dict[str, Tuple[float, str]] = {}
        self._lock = threading.Lock()
        self.trips = 0

    def strike(self, key: str, reason: str = "") -> bool:
        """Record one solve fault against ``key``; True if this strike
        trips (or re-trips) the breaker."""
        with self._lock:
            n = self._strikes.get(key, 0) + 1
            self._strikes[key] = n
            if n >= self.threshold and key not in self._tripped:
                self._tripped[key] = (_time.perf_counter(), reason)
                self.trips += 1
                return True
            return False

    def is_quarantined(self, key: str) -> bool:
        with self._lock:
            hit = self._tripped.get(key)
            if hit is None:
                return False
            if (self.cooldown_s is not None
                    and _time.perf_counter() - hit[0] >= self.cooldown_s):
                # cooldown elapsed: give the design a fresh budget
                del self._tripped[key]
                self._strikes.pop(key, None)
                return False
            return True

    def reason(self, key: str) -> str:
        with self._lock:
            hit = self._tripped.get(key)
            return hit[1] if hit else ""

    def reset(self, key: Optional[str] = None) -> None:
        with self._lock:
            if key is None:
                self._strikes.clear()
                self._tripped.clear()
            else:
                self._strikes.pop(key, None)
                self._tripped.pop(key, None)

    def stats(self) -> Dict[str, float]:
        with self._lock:
            return {
                "strikes": int(sum(self._strikes.values())),
                "designs_struck": len(self._strikes),
                "quarantined": len(self._tripped),
                "trips": self.trips,
                "threshold": self.threshold,
            }
