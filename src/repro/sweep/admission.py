"""Admission control for the sweep service: quotas and load shedding.

A served system must refuse work it cannot absorb — *definitively*.  The
:class:`AdmissionController` sits in ``SweepService.submit`` and decides,
before a request touches the cache or the scheduler, whether to admit it:

  * **per-tenant in-flight row quotas** — one tenant's 10^6-row bulk
    sweep cannot monopolize the scheduler: each tenant may have at most
    ``max_inflight_rows_per_tenant`` rows admitted-but-unfinished at a
    time (reserved atomically at submit, released when the request's
    stream finishes for any reason — delivered, cancelled, faulted or
    timed out);
  * **queue-depth load shedding** — beyond ``max_queued_rows`` total
    in-flight rows the service is saturated and sheds load instead of
    queueing unboundedly.

A shed request never hangs and never raises from the scheduler: its
handle completes immediately with every row in the ``REJECTED`` status
(``repro.core.dse.REJECTED``) and a reason string — the client sees a
definite verdict it can retry against, not a stuck stream.  Both limits
default to ``None`` (unlimited), which keeps the library-use fast path
allocation-free.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional

DEFAULT_TENANT = "default"


class AdmissionController:
    """Atomic reserve/release of in-flight row budget per tenant."""

    def __init__(self,
                 max_inflight_rows_per_tenant: Optional[int] = None,
                 max_queued_rows: Optional[int] = None):
        self.max_inflight_rows_per_tenant = max_inflight_rows_per_tenant
        self.max_queued_rows = max_queued_rows
        self._inflight: Dict[str, int] = {}
        self._total = 0
        self._lock = threading.Lock()
        self.admitted_requests = 0
        self.admitted_rows = 0
        self.rejected_requests = 0
        self.rejected_rows = 0

    # ------------------------------------------------------------- decide
    def try_admit(self, tenant: str, rows: int) -> Optional[str]:
        """Reserve ``rows`` for ``tenant``; ``None`` on admission, else
        the rejection reason (nothing reserved)."""
        with self._lock:
            have = self._inflight.get(tenant, 0)
            cap = self.max_inflight_rows_per_tenant
            if cap is not None and have + rows > cap:
                self.rejected_requests += 1
                self.rejected_rows += rows
                return (f"tenant {tenant!r} quota exceeded: {have} rows "
                        f"in flight + {rows} requested > {cap} allowed")
            if (self.max_queued_rows is not None
                    and self._total + rows > self.max_queued_rows):
                self.rejected_requests += 1
                self.rejected_rows += rows
                return (f"service saturated: {self._total} rows queued "
                        f"+ {rows} requested > {self.max_queued_rows} "
                        f"allowed (load shed)")
            self._inflight[tenant] = have + rows
            self._total += rows
            self.admitted_requests += 1
            self.admitted_rows += rows
            return None

    def release(self, tenant: str, rows: int) -> None:
        """Return a finished (or failed-to-enqueue) reservation."""
        with self._lock:
            have = self._inflight.get(tenant, 0)
            left = max(have - rows, 0)
            if left:
                self._inflight[tenant] = left
            else:
                self._inflight.pop(tenant, None)
            self._total = max(self._total - rows, 0)

    # -------------------------------------------------------------- stats
    def inflight(self, tenant: str = DEFAULT_TENANT) -> int:
        with self._lock:
            return self._inflight.get(tenant, 0)

    def stats(self) -> Dict[str, object]:
        with self._lock:
            return {
                "inflight_rows": self._total,
                "tenants": dict(self._inflight),
                "admitted_requests": self.admitted_requests,
                "admitted_rows": self.admitted_rows,
                "rejected_requests": self.rejected_requests,
                "rejected_rows": self.rejected_rows,
                "max_inflight_rows_per_tenant":
                    self.max_inflight_rows_per_tenant,
                "max_queued_rows": self.max_queued_rows,
            }
