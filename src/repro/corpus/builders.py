"""Seeded random dataflow Programs: the macro interpreter + fuzz builders.

``build_case(seed)`` deterministically derives a *builder* — a zero-arg
callable returning a fresh :class:`~repro.core.program.Program` — plus a
feature summary.  Designs are assembled from macro scripts interpreted by a
shared generator body, so module bodies are pure and re-runnable (the DSL
contract) and every loop is statically bounded: a case either terminates or
blocks forever on FIFO waits, which the engines must *report* as deadlock,
never hang on.

Structure: a producer -> stage* -> sink pipeline over SPSC FIFOs (one
writer and one reader module per FIFO, by construction), randomly decorated
with the dynamic features the hybrid engine must preserve:

  * lossy producers (``WriteNB`` silent drop, ``Full``-probe-guarded
    writes) and bounded-retry NB polling readers (Type C material);
  * a watchdog module polling a done signal with bounded attempts;
  * a blocking ack/credit feedback FIFO (cyclic module graph, Type B);
  * an extra two-module ring that is live when primed and a true deadlock
    when not;
  * dead probes, delays, leftover (never consumed) writes.

Every received value, probe outcome and drop/poll count folds into each
module's emitted checksum, so any functional divergence between engines is
visible in ``SimResult.outputs``.

This module is the library home of what used to live in
``tests/fuzz_designs.py`` (which now re-exports from here); the corpus
generator (:mod:`repro.corpus.generator`) composes the same ``_interp``
macro language into much larger topologies, so the interpreter also carries
the structural macros the fuzz pipelines never needed: round-robin
split/merge (``SPLIT``/``MERGE``), broadcast (``BCAST``), k-token feedback
rings (``RINGK``), single-token bridges (``R1``) and AXI burst masters
(``AXIWR``).
"""
from __future__ import annotations

import random

from repro.core.program import (Delay, Emit, Empty, Full, Program, Read,
                                ReadNB, Write, WriteNB)

MOD = 1_000_003


def _interp(name: str, script, fifos):
    """Generator body interpreting an immutable macro script."""

    def body():
        acc, polls, drops = 0, 0, 0
        for ins in script:
            op = ins[0]
            if op == "SRC":
                (_, fid, n, style, ack_fid, ack_every, delay, deadp,
                 extra) = ins
                for i in range(n + extra):
                    if deadp and i % 3 == 0:
                        yield Full(fifos[fid], used=False)
                    v = (i * 7 + 11) % 251
                    if style == "B":
                        yield Write(fifos[fid], v)
                    elif style == "NB":
                        ok = yield WriteNB(fifos[fid], v)
                        if not ok:
                            drops += 1
                    else:                       # "FPW": probe-guarded write
                        full = yield Full(fifos[fid])
                        if not full:
                            yield Write(fifos[fid], v)
                        else:
                            drops += 1
                    if delay and i % 2 == 1:
                        yield Delay(delay)
                    if ack_every and i % ack_every == ack_every - 1:
                        a = yield Read(fifos[ack_fid])
                        acc = (acc * 31 + a + 7) % MOD
            elif op == "RELAY":
                _, fin, fout, n, tries, gap, lossy, delay = ins
                for i in range(n):
                    # lossy == 2: anchored — the first item is a blocking
                    # read, so the poll clock starts only once the cluster
                    # is actually live (bridged clusters start late)
                    if lossy and not (lossy == 2 and i == 0):
                        got = False
                        v = 0
                        for _ in range(tries):
                            ok, v = yield ReadNB(fifos[fin])
                            polls += 1
                            if ok:
                                got = True
                                break
                            if gap:
                                yield Delay(gap)
                        if not got:
                            acc = (acc * 17 + 3) % MOD
                            continue
                    else:
                        v = yield Read(fifos[fin])
                    acc = (acc * 31 + v + 7) % MOD
                    if delay and i % 3 == 2:
                        yield Delay(delay)
                    yield Write(fifos[fout], (v * 3 + 1) % 251)
            elif op == "SINK":
                _, fin, n, lossy, tries, gap, ack_fid, ack_every = ins
                for i in range(n):
                    if lossy and not (lossy == 2 and i == 0):
                        for _ in range(tries):
                            ok, v = yield ReadNB(fifos[fin])
                            polls += 1
                            if ok:
                                acc = (acc * 31 + v + 7) % MOD
                                break
                            if gap:
                                yield Delay(gap)
                    else:
                        v = yield Read(fifos[fin])
                        acc = (acc * 31 + v + 7) % MOD
                    if ack_every and i % ack_every == ack_every - 1:
                        yield Write(fifos[ack_fid], i % 97)
            elif op == "WATCH":
                _, fid, max_polls, gap = ins
                for _ in range(max_polls):
                    ok, _v = yield ReadNB(fifos[fid])
                    polls += 1
                    if ok:
                        acc = (acc * 13 + 1) % MOD
                        break
                    if gap:
                        yield Delay(gap)
            elif op == "RING":
                _, fin, fout, rounds, prime = ins
                if prime:
                    yield Write(fifos[fout], 1)
                for _ in range(rounds):
                    v = yield Read(fifos[fin])
                    acc = (acc * 31 + v + 7) % MOD
                    yield Write(fifos[fout], (v + 1) % 97)
            elif op == "PROBE":
                _, fid, kind, used = ins
                if kind == "E":
                    e = yield Empty(fifos[fid], used=used)
                    if used:
                        acc = (acc * 13 + (1 if e else 2)) % MOD
                else:
                    fl = yield Full(fifos[fid], used=used)
                    if used:
                        acc = (acc * 13 + (4 if fl else 5)) % MOD
            elif op == "POLLV":
                # poll loop with a (possibly non-uniform) gap pattern —
                # periodizer material: constant runs burst, gap changes and
                # the final success force the per-query fallback
                _, fid, max_polls, pattern = ins
                gi = 0
                for _ in range(max_polls):
                    ok, _v = yield ReadNB(fifos[fid])
                    polls += 1
                    if ok:
                        acc = (acc * 13 + 1) % MOD
                        break
                    g = pattern[gi % len(pattern)]
                    gi += 1
                    if g > 1:
                        yield Delay(g - 1)
            elif op == "PTR":
                # probe-then-read: a commit between queries breaks the
                # periodic pattern, so bursts must re-arm per probe run
                _, fid, n_items, tries, gap = ins
                got = 0
                for _ in range(tries):
                    if got >= n_items:
                        break
                    e = yield Empty(fifos[fid])
                    if not e:
                        v = yield Read(fifos[fid])
                        got += 1
                        acc = (acc * 31 + v + 7) % MOD
                    elif gap:
                        yield Delay(gap)
                acc = (acc * 7 + got) % MOD
            elif op == "NEST":
                # nested NB polling: two query sites alternate, so no
                # single-site streak forms unless the inner site is removed
                _, fid_done, fid_data, max_polls, gap = ins
                for _ in range(max_polls):
                    ok, _v = yield ReadNB(fifos[fid_done])
                    polls += 1
                    if ok:
                        acc = (acc * 13 + 1) % MOD
                        break
                    ok2, v2 = yield ReadNB(fifos[fid_data])
                    polls += 1
                    if ok2:
                        acc = (acc * 31 + v2 + 7) % MOD
                    if gap:
                        yield Delay(gap)
            elif op == "FEED":
                # uniform-rate producer: one write every `gap` cycles — the
                # steady clock the multi-site / NB-success periodizer
                # patterns are built against
                _, fid, n_items, fgap, salt = ins
                for i in range(n_items):
                    yield Write(fifos[fid], (i * salt + 1) % 251)
                    if fgap > 1:
                        yield Delay(fgap - 1)
            elif op == "MSPOLL":
                # multi-site round-robin NB poll: one watcher sweeps several
                # data FIFOs fed at different (commensurate) rates, so the
                # steady state is a repeating (site, gap, outcome) tuple no
                # single-site streak detector can see — the generalized
                # pattern periodizer's fuzz material.  Bounded by max_iters.
                _, fids_ms, total, max_iters, pause = ins
                got = 0
                for _ in range(max_iters):
                    for fid in fids_ms:
                        ok, v = yield ReadNB(fifos[fid])
                        polls += 1
                        if ok:
                            acc = (acc * 31 + v + 7) % MOD
                            got += 1
                    if got >= total:
                        break
                    if pause:
                        yield Delay(pause)
                acc = (acc * 7 + got) % MOD
            elif op == "NBDRAIN":
                # steady *successful* NB stream: drain a FIFO with ReadNB at
                # the producer's rate — after warmup every poll hits, which
                # a fail-streak detector never periodizes but the success-
                # pattern path commits in run-ahead-bounded windows
                _, fid, n_items, attempts, dgap = ins
                got = 0
                for _ in range(attempts):
                    ok, v = yield ReadNB(fifos[fid])
                    polls += 1
                    if ok:
                        acc = (acc * 31 + v + 7) % MOD
                        got += 1
                        if got >= n_items:
                            break
                    if dgap > 1:
                        yield Delay(dgap - 1)
                acc = (acc * 7 + got) % MOD
            elif op == "W1":
                yield Write(fifos[ins[1]], ins[2])
            elif op == "D":
                yield Delay(ins[1])
            elif op == "R1":
                # single-token bridge: block until an upstream cluster's
                # sink hands over its checksum, then fold it in — chains
                # otherwise-independent clusters into one dependency path
                v = yield Read(fifos[ins[1]])
                acc = (acc * 31 + v + 7) % MOD
            elif op == "SPLIT":
                # round-robin deal: n items from fin, item i to
                # fouts[i % len(fouts)] — the fan-out node of corpus trees
                _, fin, fouts, n, delay = ins
                for i in range(n):
                    v = yield Read(fifos[fin])
                    acc = (acc * 31 + v + 7) % MOD
                    if delay and i % 4 == 3:
                        yield Delay(delay)
                    yield Write(fifos[fouts[i % len(fouts)]], (v * 3 + 1) % 251)
            elif op == "MERGE":
                # round-robin collect: cycle over fins, reading until each
                # input's known count is exhausted — the fan-in node.  The
                # read order is fixed by construction, so every engine must
                # reproduce it exactly (blocking on an input whose producer
                # is slow is the interesting hybrid/trace stress).
                _, fins, counts, fout = ins
                rem = list(counts)
                i = 0
                for _ in range(sum(counts)):
                    while rem[i % len(fins)] <= 0:
                        i += 1
                    j = i % len(fins)
                    i += 1
                    v = yield Read(fifos[fins[j]])
                    rem[j] -= 1
                    acc = (acc * 31 + v + 7) % MOD
                    if fout >= 0:
                        yield Write(fifos[fout], (v * 5 + 2) % 251)
            elif op == "BCAST":
                # broadcast: n items from fin, each written to every fout
                _, fin, fouts, n = ins
                for _ in range(n):
                    v = yield Read(fifos[fin])
                    acc = (acc * 31 + v + 7) % MOD
                    for fo in fouts:
                        yield Write(fifos[fo], (v + 1) % 251)
            elif op == "RINGK":
                # k-token feedback ring node: prime k initial tokens, then
                # read/transform/forward for `rounds` iterations.  With the
                # primer doing R rounds and every other node R + k, the ring
                # terminates with exactly k leftover tokens parked on the
                # primer's input FIFO — live for any depths >= 1.
                _, fin, fout, rounds, prime_k = ins
                for t in range(prime_k):
                    yield Write(fifos[fout], (t * 11 + 5) % 97)
                for _ in range(rounds):
                    v = yield Read(fifos[fin])
                    acc = (acc * 31 + v + 7) % MOD
                    yield Write(fifos[fout], (v + 1) % 97)
            elif op == "AXIWR":
                # AXI burst master: read phase (AR requests, R beats) then
                # write phase (AW/W/B), phase-ordered to match
                # core.axi.make_memory's service order.  The write phase
                # stores back the values just read, unchanged — so the
                # memory's backing store is a fixpoint and the module stays
                # observably pure under re-execution (trace fallback,
                # resimulate fallback, classify probes all re-run bodies).
                (_, fid_ar, fid_r, fid_aw, fid_w, fid_b, n_bursts, burst,
                 base, fid_out) = ins
                vals = []
                for b in range(n_bursts):
                    yield Write(fifos[fid_ar], (base + b * burst, burst))
                    for _ in range(burst):
                        v = yield Read(fifos[fid_r])
                        vals.append(v)
                        acc = (acc * 31 + v + 7) % MOD
                        if fid_out >= 0:
                            yield Write(fifos[fid_out], (v * 3 + 1) % 251)
                for b in range(n_bursts):
                    yield Write(fifos[fid_aw], (base + b * burst, burst))
                    for i in range(burst):
                        yield Write(fifos[fid_w], vals[b * burst + i])
                    r = yield Read(fifos[fid_b])
                    acc = (acc * 13 + r + 4) % MOD
            else:
                raise AssertionError(f"unknown macro {op!r}")
        yield Emit(name, (acc, polls, drops))

    return body


def build_case(seed: int, scale: int = 1):
    """Derive (builder, meta) for ``seed``.  ``scale`` multiplies the item
    count (the slow-marked long tail runs bigger pipelines)."""
    rng = random.Random(seed * 0x9E3779B1 + 0x5EED)
    n_stages = rng.randint(0, 2)
    n = rng.randint(4, 18) * scale
    depths = [rng.randint(1, 6) for _ in range(n_stages + 1)]
    prod_style = rng.choice(["B", "B", "B", "NB", "FPW"])
    lossy = [prod_style != "B"]
    stage_tries = []
    for _ in range(n_stages):
        goes_lossy = lossy[-1] or rng.random() < 0.25
        lossy.append(goes_lossy)
        stage_tries.append(rng.randint(2, 5))
    sink_tries = rng.randint(2, 6)
    gap = rng.choice([0, 0, 1, 2])
    delay = rng.choice([0, 0, 0, 1, 2])
    extra = rng.choice([0, 0, 0, 1, 2])         # leftover writes
    deadp = rng.random() < 0.3
    feedback = prod_style == "B" and not any(lossy) and rng.random() < 0.3
    ack_every = rng.randint(2, 5) if feedback else 0
    ack_depth = rng.randint(1, 3)
    watchdog = rng.random() < 0.35
    max_polls = rng.randint(2, 40) * scale
    ring = rng.random() < 0.18
    ring_prime = rng.random() < 0.7
    ring_rounds = rng.randint(2, 6)
    ring_depth_xy = rng.randint(1, 3)
    ring_depth_yx = rng.randint(1, 3)
    probes_on_first = rng.random() < 0.25

    def builder() -> Program:
        prog = Program(f"fuzz_{seed}", declared_type=None)
        chain = [prog.fifo(f"c{i}", depths[i]) for i in range(n_stages + 1)]
        ack = prog.fifo("ack", ack_depth) if feedback else None
        done = prog.fifo("done", 1) if watchdog else None
        fifos = list(chain) + ([ack] if ack else []) + ([done] if done else [])
        fid_of = {f.name: i for i, f in enumerate(fifos)}

        src_script = [("SRC", 0, n, prod_style,
                       fid_of["ack"] if feedback else -1, ack_every,
                       delay, deadp, extra)]
        if probes_on_first:
            src_script.insert(0, ("PROBE", 0, "F", True))
        prog.add_module("src", _interp("src", src_script, fifos))

        for k in range(n_stages):
            sc = [("RELAY", k, k + 1, n, stage_tries[k], gap,
                   lossy[k], delay)]
            prog.add_module(f"st{k}", _interp(f"st{k}", sc, fifos))

        sink_script = [("SINK", n_stages, n, lossy[-1], sink_tries, gap,
                        fid_of["ack"] if feedback else -1,
                        ack_every if feedback else 0)]
        if watchdog:
            sink_script.append(("W1", fid_of["done"], 1))
        prog.add_module("sink", _interp("sink", sink_script, fifos))

        if watchdog:
            prog.add_module("watch", _interp(
                "watch", [("WATCH", fid_of["done"], max_polls, gap)], fifos))

        if ring:
            xy = prog.fifo("xy", ring_depth_xy)
            yx = prog.fifo("yx", ring_depth_yx)
            fifos2 = fifos + [xy, yx]
            i_xy, i_yx = len(fifos), len(fifos) + 1
            prog.add_module("rx", _interp(
                "rx", [("RING", i_yx, i_xy, ring_rounds, ring_prime)],
                fifos2))
            prog.add_module("ry", _interp(
                "ry", [("RING", i_xy, i_yx, ring_rounds, False)], fifos2))
        return prog

    meta = dict(n=n, stages=n_stages, prod=prod_style, lossy=any(lossy),
                feedback=feedback, watchdog=watchdog, ring=ring,
                ring_prime=ring_prime)
    return builder, meta


# ---------------------------------------------------------------------------
# Query-dominated poll-loop cases (ISSUE 4): exercise the hybrid engine's
# steady-state query periodizer — its burst fast path AND its divergence
# fallback — plus the provisional-times batch solver under parked writers.
# ---------------------------------------------------------------------------
_POLL_PATTERNS = (
    (1,),                      # tight uniform loop: one burst covers the run
    (2,), (3,), (5,),          # uniform with gap
    (1, 1, 1, 4),              # bursty: periodic runs + divergence per cycle
    (1, 1, 1, 1, 1, 2, 1, 7),  # long constant runs, two break points
    (1, 2, 3),                 # no run of >= 3 equal gaps: never bursts
)


def build_poll_case(seed: int, scale: int = 1):
    """Derive (builder, meta) for a poll-dominated design.

    A blocking source -> sink pipeline streams ``n`` items; the sink
    signals per-poller ``done`` FIFOs, and 1-3 pollers hammer them with
    seeded poll-loop shapes: uniform and bursty gap patterns (``POLLV``),
    probe-then-read consumption (``PTR``, commits between queries), nested
    NB reads (``NEST``, alternating query sites) — mid-run outcome
    divergence (the final successful poll, every gap-pattern change) comes
    with the territory.  A seeded subset additionally carries a multi-site
    round-robin watcher over two rate-commensurate feeds (``MSPOLL``, the
    repeating mixed-outcome (site, gap) tuple) and a matched-rate NB
    success drain (``FEED`` -> ``NBDRAIN``).  Bounded attempt budgets keep
    every module terminating, so under-drained pipelines surface as
    reported deadlocks, never hangs.
    """
    rng = random.Random(seed * 0x517CC1B7 + 0xB5EED)
    n = rng.randint(6, 24) * scale
    depth = rng.randint(1, 6)
    n_pollers = rng.randint(1, 3)
    sink_ptr = rng.random() < 0.35      # probe-then-read sink
    sink_tries = 4 * n + 16
    ptr_gap = rng.choice([0, 1, 2])
    nest = rng.random() < 0.4           # one poller also NB-reads a side FIFO
    side_extra = rng.randint(0, 3)
    patterns = [rng.choice(_POLL_PATTERNS) for _ in range(n_pollers)]
    max_polls = [rng.randint(4, 40) * scale for _ in range(n_pollers)]
    sink_delay = rng.choice([0, 0, 1, 2])
    # multi-site watcher: round-robin NB over two FIFOs fed at rates
    # period / 2*period, so the steady state is a repeating mixed-outcome
    # (site, gap) tuple — generalized-pattern periodizer material
    msite = rng.random() < 0.35
    ms_items = rng.randint(4, 12) * scale
    ms_pause = rng.choice([0, 1, 2])
    ms_depth = rng.randint(2, 8)
    # NB-success drain: a matched-rate FEED -> NBDRAIN pair where (after
    # warmup) every poll hits — the success-stream periodizer pattern
    nbdrain = rng.random() < 0.35
    nd_items = rng.randint(4, 16) * scale
    nd_gap = rng.choice([1, 2, 3])
    nd_depth = rng.randint(2, 8)

    def builder() -> Program:
        prog = Program(f"fuzz_poll_{seed}", declared_type=None)
        data = prog.fifo("data", depth)
        dones = [prog.fifo(f"done{i}", 1) for i in range(n_pollers)]
        side = prog.fifo("side", max(1, depth // 2)) if nest else None
        fifos = [data] + dones + ([side] if side else [])
        i_side = len(fifos) - 1
        if msite:
            fifos += [prog.fifo("ms_a", ms_depth), prog.fifo("ms_b", ms_depth)]
            i_ma, i_mb = len(fifos) - 2, len(fifos) - 1
        if nbdrain:
            fifos.append(prog.fifo("nd", nd_depth))
            i_nd = len(fifos) - 1

        # pollers first: trace="auto" aborts to the hybrid path immediately
        for i in range(n_pollers):
            if nest and i == 0:
                script = [("NEST", 1 + i, i_side, max_polls[i],
                           patterns[i][0] - 1)]
            else:
                script = [("POLLV", 1 + i, max_polls[i], patterns[i])]
            prog.add_module(f"poll{i}", _interp(f"poll{i}", script, fifos))

        if msite:
            ms_total = ms_items + ms_items // 2
            prog.add_module("watcher", _interp("watcher", [
                ("MSPOLL", (i_ma, i_mb), ms_total, 2 * ms_total + 16,
                 ms_pause)], fifos))
        if nbdrain:
            prog.add_module("drain", _interp("drain", [
                ("NBDRAIN", i_nd, nd_items, 3 * nd_items + 24, nd_gap)],
                fifos))

        src_script = [("SRC", 0, n, "B", -1, 0, 0, False, 0)]
        if nest:
            src_script.append(("SRC", i_side, side_extra + 1, "B",
                               -1, 0, 0, False, 0))
        prog.add_module("src", _interp("src", src_script, fifos))

        if msite:
            ms_period = ms_pause + 2    # cycles per watcher iteration
            prog.add_module("feed_a", _interp("feed_a", [
                ("FEED", i_ma, ms_items, ms_period, 7)], fifos))
            prog.add_module("feed_b", _interp("feed_b", [
                ("FEED", i_mb, ms_items // 2, 2 * ms_period, 13)], fifos))
        if nbdrain:
            prog.add_module("nd_feed", _interp("nd_feed", [
                ("FEED", i_nd, nd_items, nd_gap, 11)], fifos))

        if sink_ptr:
            sink_script = [("PTR", 0, n, sink_tries, ptr_gap)]
        else:
            sink_script = [("SINK", 0, n, False, 0, 0, -1, 0)]
        if sink_delay:
            sink_script.append(("D", sink_delay))
        sink_script += [("W1", 1 + i, 1) for i in range(n_pollers)]
        prog.add_module("sink", _interp("sink", sink_script, fifos))
        return prog

    meta = dict(n=n, depth=depth, pollers=n_pollers, patterns=patterns,
                sink_ptr=sink_ptr, nest=nest, msite=msite, nbdrain=nbdrain)
    return builder, meta
