"""Declarative constraint spec for the corpus generator.

Every parameter the generator samples — topology choice, FIFO depths, item
counts, fan-out arities, burst lengths, poll budgets, query density — is
drawn from a field of :class:`CorpusSpec` through one seeded
``random.Random``, in one fixed order.  That makes a corpus case a pure
function of ``(seed, scale, spec)``: re-running ``generate`` with the same
triple rebuilds a bit-identical Program (same fingerprint, same trace),
which is what lets the conformance suite pin digests by seed alone.

The spec is deliberately plain data (frozen dataclasses of ranges and
weighted choices, in the constrained-random style of SystemVerilog/zuspec
scenario solvers) rather than code: a test or benchmark that needs a
biased corpus — heavier AXI traffic, deeper trees, no dynamic modules —
passes a modified spec instead of forking the generator.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Tuple


@dataclass(frozen=True)
class IntRange:
    """Inclusive integer range; ``draw`` samples uniformly."""
    lo: int
    hi: int

    def __post_init__(self):
        if self.lo > self.hi:
            raise ValueError(f"empty IntRange({self.lo}, {self.hi})")

    def draw(self, rng) -> int:
        return rng.randint(self.lo, self.hi)


@dataclass(frozen=True)
class Choice:
    """Weighted finite choice; repeat an option to weight it (as the fuzz
    builders already do with ``rng.choice([0, 0, 1, 2])``)."""
    options: Tuple

    def draw(self, rng):
        return rng.choice(self.options)


@dataclass(frozen=True)
class CorpusSpec:
    # -- cluster mix: relative weights of each motif ---------------------
    # pipeline  : src -> relay* -> sink chain (optionally lossy/NB)
    # tree      : round-robin split tree -> leaf relays -> mirrored merge
    # diamond   : 1-level split/merge (a tree with levels=1)
    # ring      : cyclic feedback ring with k initial tokens
    # poll      : done-signal pollers (POLLV/PTR/NEST query loops)
    # axi       : AXI read-burst master + core.axi memory + sink
    motif_weights: Dict[str, int] = dataclasses.field(
        default_factory=lambda: dict(pipeline=4, tree=3, diamond=2,
                                     ring=2, poll=2, axi=2))

    # -- per-cluster shape parameters ------------------------------------
    items: IntRange = IntRange(4, 24)       # tokens emitted per source
    depth: IntRange = IntRange(1, 6)        # FIFO depths
    pipeline_stages: IntRange = IntRange(1, 6)
    fanout: IntRange = IntRange(2, 4)       # split/merge arity
    tree_levels: IntRange = IntRange(1, 3)
    ring_modules: IntRange = IntRange(2, 4)
    ring_rounds: IntRange = IntRange(2, 10)
    ring_tokens: IntRange = IntRange(1, 3)  # initial (primed) tokens
    n_pollers: IntRange = IntRange(1, 3)
    poll_budget: IntRange = IntRange(6, 48)
    burst_len: Choice = Choice((2, 4, 8))
    axi_bursts: IntRange = IntRange(2, 6)
    axi_read_latency: IntRange = IntRange(4, 16)
    delay: Choice = Choice((0, 0, 0, 1, 2))
    gap: Choice = Choice((0, 0, 1, 2))

    # -- dynamic-feature densities ---------------------------------------
    query_density: float = 0.25   # P(a pipeline relay/sink goes lossy/NB)
    bridge_prob: float = 0.4      # P(cluster chained to its predecessor)
    starve_prob: float = 0.0      # P(a pipeline source under-produces by
    #                               one item -> deterministic deadlock)

    def replace(self, **kw) -> "CorpusSpec":
        """Functional update (``dataclasses.replace`` sugar)."""
        return dataclasses.replace(self, **kw)


#: Default spec: mixed Type A/B/C corpus, every motif reachable.
DEFAULT_SPEC = CorpusSpec()

#: All-blocking variant: no NB/probe modules anywhere, so every design is
#: statically Type A/B and the straight-line trace path must engage.
BLOCKING_SPEC = CorpusSpec(
    motif_weights=dict(pipeline=4, tree=3, diamond=2, ring=2, poll=0,
                       axi=2),
    query_density=0.0,
)

#: Benchmark spec: like DEFAULT_SPEC but with a pinned item count so
#: per-engine throughput at different scales stays comparable.
BENCH_SPEC = CorpusSpec(items=IntRange(8, 8))
