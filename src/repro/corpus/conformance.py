"""Differential conformance runner for corpus designs.

``check_conformance(builder)`` runs one design through every engine path
the repo ships —

  * ``generator``         — ``simulate(trace="never")``, the reference;
  * ``auto``              — whatever ``trace="auto"`` selects;
  * ``hybrid``            — ``simulate_hybrid(periodize=False)``;
  * ``periodized``        — ``simulate_hybrid(periodize=True)``;
  * ``resimulate``        — incremental re-finalization at variant depths;
  * ``resimulate_batch``  — the batched solver over [variant, base] rows;
  * ``sweep``             — ``repro.sweep.SweepService`` over the same rows;
  * ``jax``               — the sparse Pallas solver lane
    (``backend="jax"``), bit-identical verdicts against numpy over
    [variant, base, all-ones] rows

— and demands a bit-identical record from each: cycles, deadlock verdict,
outputs, an order-insensitive digest of every FIFO table (commit times per
side + leftover payloads), constraint count and query/forced-false stats.
The record/digest layout deliberately matches ``tests/test_golden.py`` so
corpus seeds extend the same conformance contract to generated designs.

``rtl_crosscheck(builder)`` compares the default engine against the
cycle-stepped RTL oracle (``core.rtlsim.simulate_rtl``) — outputs AND
cycle counts must agree exactly; it is orders of magnitude slower, which
is why the corpus suite samples it instead of sweeping the full corpus.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from repro.core import resimulate, resimulate_batch, simulate, simulate_rtl
from repro.core.trace import TraceUnsupported, simulate_hybrid

#: every engine path the runner differential-checks, in check order
ENGINE_PATHS = ("generator", "auto", "hybrid", "periodized",
                "resimulate", "resimulate_batch", "sweep", "jax")


def normalize(obj):
    """JSON-stable view: tuples -> lists, recursively, sorted dict keys."""
    if isinstance(obj, dict):
        return {str(k): normalize(v) for k, v in sorted(obj.items())}
    if isinstance(obj, (list, tuple)):
        return [normalize(v) for v in obj]
    if isinstance(obj, (np.integer,)):
        return int(obj)
    return obj


def fifo_digest(result) -> str:
    """Order-insensitive digest of every FIFO table's end state (commit
    times per side + leftover payloads)."""
    h = hashlib.sha256()
    for tbl in result.graph.fifos:
        h.update(np.sort(np.asarray(tbl.write_times, np.int64)).tobytes())
        h.update(b"|")
        h.update(np.sort(np.asarray(tbl.read_times, np.int64)).tobytes())
        h.update(b"|")
        h.update(repr(list(tbl.values)).encode())
        h.update(b"#")
    return h.hexdigest()


def result_record(result) -> dict:
    """The conformance record every engine path must reproduce."""
    return {
        "cycles": int(result.cycles),
        "deadlock": bool(result.deadlock),
        "deadlock_cycle": int(result.deadlock_cycle),
        "outputs": normalize(result.outputs),
        "fifo_digest": fifo_digest(result),
        "n_constraints": len(result.constraints),
        "stats": {
            "nodes": int(result.stats.nodes),
            "edges": int(result.stats.edges),
            "queries": int(result.stats.queries),
            "queries_forced_false": int(result.stats.queries_forced_false),
            "skipped_probes": int(result.stats.skipped_probes),
        },
    }


def _diff(ref: dict, got: dict) -> str:
    keys = [k for k in ref if ref[k] != got.get(k)]
    parts = []
    for k in keys[:4]:
        r, g = ref[k], got.get(k)
        if isinstance(r, (dict, list)) and len(repr(r)) > 120:
            parts.append(f"{k} differs")
        else:
            parts.append(f"{k}: ref={r!r} got={g!r}")
    return "; ".join(parts) or "records differ"


@dataclass
class ConformanceReport:
    """Per-path verdicts plus the generator-engine reference record."""
    name: str
    reference: dict
    deadlock: bool
    hybrid_supported: bool
    paths: Dict[str, str] = field(default_factory=dict)  # path -> verdict

    @property
    def ok(self) -> bool:
        return not any(v.startswith("MISMATCH") for v in self.paths.values())

    def raise_on_mismatch(self) -> "ConformanceReport":
        bad = {p: v for p, v in self.paths.items()
               if v.startswith("MISMATCH")}
        if bad:
            detail = "; ".join(f"{p}: {v}" for p, v in bad.items())
            raise AssertionError(f"{self.name}: engine paths diverged — "
                                 f"{detail}")
        return self


def check_conformance(builder, *, name: str = "design",
                      service=None, paths=ENGINE_PATHS,
                      strict: bool = True) -> ConformanceReport:
    """Differential-check ``builder`` across the selected engine paths.

    ``service`` may be a live :class:`repro.sweep.SweepService` (reused
    across many designs to amortize worker startup); when omitted, the
    sweep path spins up an ephemeral in-process service.  With ``strict``
    (default) any divergence raises ``AssertionError``; otherwise the
    report carries per-path ``MISMATCH: ...`` verdicts for the caller.
    """
    g = simulate(builder(), trace="never")
    ref = result_record(g)
    report = ConformanceReport(name=name, reference=ref,
                               deadlock=bool(g.deadlock),
                               hybrid_supported=True)
    report.paths["generator"] = "ok"

    def check(path, result):
        got = result_record(result)
        report.paths[path] = ("ok" if got == ref
                              else "MISMATCH: " + _diff(ref, got))

    if "auto" in paths:
        check("auto", simulate(builder(), trace="auto"))

    if "hybrid" in paths or "periodized" in paths:
        try:
            hp = simulate_hybrid(builder(), periodize=True)
            if "periodized" in paths:
                check("periodized", hp)
            if "hybrid" in paths:
                check("hybrid", simulate_hybrid(builder(), periodize=False))
        except TraceUnsupported as e:
            report.hybrid_supported = False
            for p in ("hybrid", "periodized"):
                if p in paths:
                    report.paths[p] = f"skipped: TraceUnsupported ({e})"

    variant_paths = [p for p in ("resimulate", "resimulate_batch", "sweep",
                                 "jax") if p in paths]
    if variant_paths:
        if g.deadlock:
            for p in variant_paths:
                report.paths[p] = "skipped: base design deadlocks"
        else:
            dv = tuple(int(d) + 1 for d in g.depths)
            var = simulate(builder(), depths=dv, trace="never")
            vrec = (int(var.cycles), bool(var.deadlock),
                    normalize(var.outputs))

            if "resimulate" in paths:
                inc = resimulate(simulate(builder(), trace="auto"), dv)
                got = (int(inc.result.cycles), bool(inc.result.deadlock),
                       normalize(inc.result.outputs))
                report.paths["resimulate"] = (
                    "ok" if got == vrec else
                    f"MISMATCH: variant ref={vrec[:2]} got={got[:2]}")

            D = np.asarray([dv, [int(d) for d in g.depths]], dtype=np.int64)
            if "resimulate_batch" in paths:
                out = resimulate_batch(g, D)
                ok = (int(out.cycles[0]) == vrec[0]
                      and int(out.cycles[1]) == ref["cycles"])
                report.paths["resimulate_batch"] = (
                    "ok" if ok else
                    f"MISMATCH: cycles={out.cycles.tolist()} "
                    f"want=[{vrec[0]}, {ref['cycles']}]")

            if "jax" in paths:
                # sparse device-lane differential: the solver verdicts
                # (status / cycles / violated) must be bit-identical to
                # the numpy fixpoint — including a depth-1 row that may
                # starve writes (DEADLOCK) or invert event order (CYCLE)
                Dj = np.asarray([dv, [int(d) for d in g.depths],
                                 [1] * len(g.depths)], dtype=np.int64)
                o_np = resimulate_batch(g, Dj, backend="numpy",
                                        fallback=False)
                o_jx = resimulate_batch(g, Dj, backend="jax",
                                        fallback=False)
                ok = (np.array_equal(o_np.status, o_jx.status)
                      and np.array_equal(o_np.cycles, o_jx.cycles)
                      and np.array_equal(o_np.violated, o_jx.violated))
                report.paths["jax"] = (
                    "ok" if ok else
                    f"MISMATCH: jax status={o_jx.status.tolist()} "
                    f"cycles={o_jx.cycles.tolist()} vs numpy "
                    f"status={o_np.status.tolist()} "
                    f"cycles={o_np.cycles.tolist()}")

            if "sweep" in paths:
                D3 = np.asarray([dv, [int(d) for d in g.depths], dv],
                                dtype=np.int64)
                svc = service
                owned = svc is None
                if owned:
                    from repro.sweep import SweepService
                    svc = SweepService(block=8, shards=2, autostart=False)
                try:
                    s = svc.sweep(g, D3)
                    ok = (int(s.cycles[0]) == vrec[0]
                          and int(s.cycles[1]) == ref["cycles"]
                          and int(s.cycles[2]) == vrec[0]
                          and normalize(s.results[0].outputs) == vrec[2]
                          and bool(s.results[0].deadlock) == vrec[1])
                    report.paths["sweep"] = (
                        "ok" if ok else
                        f"MISMATCH: cycles={s.cycles.tolist()} "
                        f"want=[{vrec[0]}, {ref['cycles']}, {vrec[0]}]")
                finally:
                    if owned:
                        svc.close()

    if strict:
        report.raise_on_mismatch()
    return report


def rtl_crosscheck(builder, *, max_cycles: int = 2_000_000) -> dict:
    """Compare the default engine against the cycle-stepped RTL oracle.

    Returns a dict with ``agree`` (bool) and the per-engine verdicts.
    Agreement means: same deadlock verdict, and — for live designs — the
    same outputs and the exact same cycle count.  (Under deadlock the two
    report the blocked set differently, so only the verdict is compared.)
    """
    o = simulate(builder(), trace="auto")
    r = simulate_rtl(builder(), max_cycles=max_cycles)
    agree = bool(o.deadlock) == bool(r.deadlock)
    if agree and not o.deadlock:
        agree = (normalize(o.outputs) == normalize(r.outputs)
                 and int(o.cycles) == int(r.cycles))
    return dict(agree=agree, deadlock=bool(o.deadlock),
                cycles=int(o.cycles), rtl_cycles=int(r.cycles),
                engine=o.engine)
