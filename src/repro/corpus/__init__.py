"""repro.corpus — constrained-random design corpus + conformance sweep.

A seeded generator of task-parallel dataflow Programs (10-1000 modules,
reproducible from ``(seed, scale)``) and the differential conformance
runner that pins every engine path — plus a sampled RTL oracle
cross-check — on the generated designs.  See ``docs/architecture.md``
("Corpus & conformance") for the map.
"""
from .builders import MOD, build_case, build_poll_case  # noqa: F401
from .conformance import (ENGINE_PATHS, ConformanceReport,  # noqa: F401
                          check_conformance, fifo_digest, result_record,
                          rtl_crosscheck)
from .generator import (CorpusCase, EDIT_KINDS, EditPair,  # noqa: F401
                        PATCHABLE_KINDS, edit_pairs, generate)
from .spec import (BENCH_SPEC, BLOCKING_SPEC, Choice,  # noqa: F401
                   CorpusSpec, DEFAULT_SPEC, IntRange)

__all__ = [
    "generate", "CorpusCase",
    "edit_pairs", "EditPair", "EDIT_KINDS", "PATCHABLE_KINDS",
    "CorpusSpec", "IntRange", "Choice",
    "DEFAULT_SPEC", "BLOCKING_SPEC", "BENCH_SPEC",
    "build_case", "build_poll_case", "MOD",
    "check_conformance", "ConformanceReport", "ENGINE_PATHS",
    "result_record", "fifo_digest", "rtl_crosscheck",
]
