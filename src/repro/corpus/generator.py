"""Constrained-random corpus generator: 10-1000-module dataflow Programs.

``generate(seed, scale)`` derives a :class:`CorpusCase` — a zero-arg
Program builder plus structural metadata — by composing motif *clusters*
until the module budget ``scale`` is met:

  * ``pipeline`` — src -> relay* -> sink chains, optionally lossy/NB
    downstream of a randomly chosen stage (the fuzz-suite shape, scaled);
  * ``tree`` / ``diamond`` — round-robin SPLIT trees fanning a source out
    over ``b^L`` leaves, mirrored by MERGE fan-in back to one sink
    (multi-level fan-in/fan-out the hand corpus never reaches);
  * ``ring`` — an m-module feedback cycle primed with k initial tokens
    (live by token accounting: the primer runs R rounds, every other node
    R + k, leaving exactly k tokens parked at the end);
  * ``poll`` — done-signal pollers with POLLV/PTR/NEST query loops
    (the hybrid periodizer's diet);
  * ``axi`` — an AXIWR burst master against a ``core.axi.make_memory``
    model, streaming beats to a sink (request/data/response channels are
    ordinary SPSC FIFOs, so AXI timing rides the same engine paths).

Clusters are independent subgraphs except where a single-token *bridge*
chains one cluster's sink to the next cluster's source (R1/W1 macros),
building dependency paths as deep as the cluster count.  Every sampled
parameter comes from a :class:`~repro.corpus.spec.CorpusSpec` through one
seeded ``random.Random`` in one fixed draw order, so a case is
reproducible — bit-identical Program, fingerprint and trace — from
``(seed, scale, spec)`` alone.

The plan is built as plain data (FIFO name/depth rows + per-module macro
scripts) and only turned into a Program inside the builder, which keeps
module bodies pure/re-runnable and lets :meth:`CorpusCase.validate` check
SPSC and connectivity invariants statically, without running an engine.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Tuple

from repro.core.axi import AxiPort, make_memory
from repro.core.program import Program

from .builders import _interp
from .spec import BLOCKING_SPEC, DEFAULT_SPEC, CorpusSpec

# macro -> (positions read from, positions written to); a position is an
# index into the instruction tuple holding a fid (or tuple of fids);
# negative fids mean "unused" and are skipped.  PROBE/WATCH-style
# query-only macros count as readers: the engine registers the polling
# module as the FIFO's consumer endpoint.
_MACRO_ROLES = {
    "SRC":   (lambda ins: [ins[4]], lambda ins: [ins[1]]),
    "RELAY": (lambda ins: [ins[1]], lambda ins: [ins[2]]),
    "SINK":  (lambda ins: [ins[1]], lambda ins: [ins[6]] if ins[7] else []),
    "WATCH": (lambda ins: [ins[1]], lambda ins: []),
    "RING":  (lambda ins: [ins[1]], lambda ins: [ins[2]]),
    "RINGK": (lambda ins: [ins[1]], lambda ins: [ins[2]]),
    "POLLV": (lambda ins: [ins[1]], lambda ins: []),
    "PTR":   (lambda ins: [ins[1]], lambda ins: []),
    "NEST":  (lambda ins: [ins[1], ins[2]], lambda ins: []),
    "W1":    (lambda ins: [], lambda ins: [ins[1]]),
    "R1":    (lambda ins: [ins[1]], lambda ins: []),
    "D":     (lambda ins: [], lambda ins: []),
    "SPLIT": (lambda ins: [ins[1]], lambda ins: list(ins[2])),
    "MERGE": (lambda ins: list(ins[1]), lambda ins: [ins[3]]),
    "BCAST": (lambda ins: [ins[1]], lambda ins: list(ins[2])),
    "AXIWR": (lambda ins: [ins[2], ins[5]],
              lambda ins: [ins[1], ins[3], ins[4], ins[9]]),
}


class _Plan:
    """Mutable plan: FIFO rows + module entries, later frozen into a
    builder closure."""

    def __init__(self):
        self.fifo_rows: List[Tuple[str, int]] = []
        self.modules: List[list] = []   # ["interp", name, script-list] or
        #                                 ["aximem", name, fids, size, lat]

    def fifo(self, name: str, depth: int) -> int:
        self.fifo_rows.append((name, depth))
        return len(self.fifo_rows) - 1

    def interp(self, name: str, script: list) -> list:
        """Add a macro-script module; returns the (mutable) script so
        bridges can splice R1/W1 instructions in later."""
        entry = ["interp", name, script]
        self.modules.append(entry)
        return script

    def aximem(self, name: str, fids, size: int, lat: int, n_bursts: int):
        self.modules.append(["aximem", name, tuple(fids), size, lat,
                             n_bursts])

    @property
    def n_modules(self) -> int:
        return len(self.modules)


@dataclass
class CorpusCase:
    """A generated corpus design: builder + metadata + static plan."""
    name: str
    seed: int
    scale: int
    spec: CorpusSpec
    builder: Callable[[], Program]
    meta: Dict = field(default_factory=dict)
    _plan: _Plan = field(default=None, repr=False)

    def validate(self) -> None:
        """Static structural invariants: every FIFO has exactly one writer
        module and exactly one reader module (SPSC + full connectivity)."""
        writers: Dict[int, List[str]] = {}
        readers: Dict[int, List[str]] = {}

        def note(table, fid, mname):
            if fid is None or fid < 0:
                return
            table.setdefault(fid, []).append(mname)

        for entry in self._plan.modules:
            if entry[0] == "interp":
                _, mname, script = entry
                for ins in script:
                    rd, wr = _MACRO_ROLES[ins[0]]
                    for fid in rd(ins):
                        note(readers, fid, mname)
                    for fid in wr(ins):
                        note(writers, fid, mname)
            else:
                _, mname, fids, _size, _lat, _nb = entry
                ar, r, aw, w, b = fids
                for fid in (ar, aw, w):
                    note(readers, fid, mname)
                for fid in (r, b):
                    note(writers, fid, mname)

        for fid, (fname, _depth) in enumerate(self._plan.fifo_rows):
            ws = sorted(set(writers.get(fid, [])))
            rs = sorted(set(readers.get(fid, [])))
            if len(ws) != 1 or len(rs) != 1:
                raise AssertionError(
                    f"{self.name}: FIFO {fname} (fid {fid}) violates "
                    f"SPSC/connectivity: writers={ws} readers={rs}")


# ---------------------------------------------------------------------------
# cluster builders: each appends FIFOs + modules to the plan and returns
# (head_script, tail_script, cluster_meta) — head/tail are the mutable
# scripts bridges splice into.
# ---------------------------------------------------------------------------
def _pipeline_cluster(plan, rng, spec, pfx):
    n = spec.items.draw(rng)
    stages = spec.pipeline_stages.draw(rng)
    delay = spec.delay.draw(rng)
    gap = spec.gap.draw(rng)
    chain = [plan.fifo(f"{pfx}_c{i}", spec.depth.draw(rng))
             for i in range(stages + 1)]
    # once a stage goes lossy every downstream stage (and the sink) must be
    # lossy too, or dropped items deadlock a blocking reader
    # a starved pipeline under-produces by one item: every stage blocks on
    # the missing token, a deterministic deadlock the conformance runner
    # must verdict identically on every engine path
    starved = rng.random() < spec.starve_prob
    lossy = [False]
    for _ in range(stages):
        lossy.append((not starved)
                     and (lossy[-1] or rng.random() < spec.query_density))
    # Lossy stages need a real poll window (gap >= 1, generous tries):
    # with gap 0 every NB retry lands on the same cycle, the stage drops
    # nearly every item, and the blocking producer wedges on a full FIFO.
    # A wide window makes drops rare, so most designs stay live and the
    # occasional genuine drop-induced deadlock remains in the corpus.
    if any(lossy):
        gap = max(1, gap)
    tries = [rng.randint(8, 16) for _ in range(stages)]
    sink_tries = 4 * n + 16

    head = plan.interp(f"{pfx}_src",
                       [("SRC", chain[0], n - 1 if starved else n, "B",
                         -1, 0, delay, False, 0)])
    for k in range(stages):
        plan.interp(f"{pfx}_st{k}",
                    [("RELAY", chain[k], chain[k + 1], n, tries[k], gap,
                      2 if lossy[k] else False, delay)])
    tail = plan.interp(f"{pfx}_sink",
                       [("SINK", chain[stages], n,
                         2 if lossy[-1] else False, sink_tries,
                         gap, -1, 0)])
    return head, tail, dict(motif="pipeline", has_nb=any(lossy),
                            cyclic=False, starved=starved)


def _tree_cluster(plan, rng, spec, pfx, budget, levels=None):
    b = spec.fanout.draw(rng)
    levels_drawn = spec.tree_levels.draw(rng)
    L = levels if levels is not None else levels_drawn
    n = spec.items.draw(rng)
    delay = spec.delay.draw(rng)

    def est(b, L):
        return 2 * ((b ** L - 1) // (b - 1)) + b ** L + 2

    while est(b, L) > max(10, budget) and (L > 1 or b > 2):
        if L > 1:
            L -= 1
        elif b > 2:
            b -= 1

    root = plan.fifo(f"{pfx}_root", spec.depth.draw(rng))
    head = plan.interp(f"{pfx}_src",
                       [("SRC", root, n, "B", -1, 0, delay, False, 0)])
    nid = [0]

    def rec(fid_in, count, level):
        k = nid[0]
        nid[0] += 1
        if level == 0:
            out = plan.fifo(f"{pfx}_lf{k}", spec.depth.draw(rng))
            plan.interp(f"{pfx}_leaf{k}",
                        [("RELAY", fid_in, out, count, 0, 0, False, delay)])
            return out, count
        fouts = [plan.fifo(f"{pfx}_s{k}_{j}", spec.depth.draw(rng))
                 for j in range(b)]
        plan.interp(f"{pfx}_split{k}",
                    [("SPLIT", fid_in, tuple(fouts), count, delay)])
        child = [rec(fouts[j],
                     count // b + (1 if j < count % b else 0),
                     level - 1)
                 for j in range(b)]
        mout = plan.fifo(f"{pfx}_m{k}", spec.depth.draw(rng))
        plan.interp(f"{pfx}_merge{k}",
                    [("MERGE", tuple(c[0] for c in child),
                      tuple(c[1] for c in child), mout)])
        return mout, count

    mout, total = rec(root, n, L)
    tail = plan.interp(f"{pfx}_sink",
                       [("SINK", mout, total, False, 0, 0, -1, 0)])
    return head, tail, dict(motif="tree" if L > 1 else "diamond",
                            has_nb=False, cyclic=False,
                            fanout=b, levels=L)


def _ring_cluster(plan, rng, spec, pfx):
    m = spec.ring_modules.draw(rng)
    rounds = spec.ring_rounds.draw(rng)
    k = spec.ring_tokens.draw(rng)
    # fids[i]: module i -> module (i+1) % m.  The primer stops reading k
    # tokens before its upstream stops writing (the primed tokens retire
    # in the primer's input FIFO), so that edge needs depth >= k or the
    # last node wedges on its final writes.
    fids = [plan.fifo(f"{pfx}_r{i}",
                      max(spec.depth.draw(rng), k if i == m - 1 else 1))
            for i in range(m)]
    head = tail = plan.interp(
        f"{pfx}_n0", [("RINGK", fids[m - 1], fids[0], rounds, k)])
    for i in range(1, m):
        plan.interp(f"{pfx}_n{i}",
                    [("RINGK", fids[i - 1], fids[i], rounds + k, 0)])
    return head, tail, dict(motif="ring", has_nb=False, cyclic=True,
                            modules=m, tokens=k)


def _poll_cluster(plan, rng, spec, pfx):
    n = spec.items.draw(rng)
    depth = spec.depth.draw(rng)
    n_pollers = spec.n_pollers.draw(rng)
    gap = spec.gap.draw(rng)
    data = plan.fifo(f"{pfx}_data", depth)
    dones = [plan.fifo(f"{pfx}_done{i}", 1) for i in range(n_pollers)]
    side_caps = {}

    for i in range(n_pollers):
        budget = spec.poll_budget.draw(rng)
        kind = rng.choice(["POLLV", "POLLV", "PTR", "NEST"])
        if kind == "POLLV":
            pat = tuple(rng.choice([1, 1, 2, 3])
                        for _ in range(rng.randint(1, 4)))
            script = [("POLLV", dones[i], budget, pat)]
        elif kind == "PTR":
            script = [("PTR", dones[i], 1, budget, gap)]
        else:
            sdepth = max(1, depth // 2)
            side = plan.fifo(f"{pfx}_side{i}", sdepth)
            script = [("NEST", dones[i], side, budget, gap)]
            side_caps[side] = sdepth
        plan.interp(f"{pfx}_poll{i}", script)

    src_script = [("SRC", data, n, "B", -1, 0, 0, False, 0)]
    for ins in [m[2][0] for m in plan.modules[-n_pollers:]]:
        if ins[0] == "NEST":
            # a NEST poller may exit (done token seen) before draining its
            # side FIFO, so never write more side items than the FIFO
            # holds — the source must be able to finish unassisted
            src_script.append(("SRC", ins[2],
                               rng.randint(1, side_caps[ins[2]]), "B",
                               -1, 0, 0, False, 0))
    head = plan.interp(f"{pfx}_src", src_script)
    sink_script = [("SINK", data, n, False, 0, 0, -1, 0)]
    sink_script += [("W1", d, 1) for d in dones]
    tail = plan.interp(f"{pfx}_sink", sink_script)
    return head, tail, dict(motif="poll", has_nb=True, cyclic=False,
                            pollers=n_pollers)


def _axi_cluster(plan, rng, spec, pfx):
    burst = spec.burst_len.draw(rng)
    n_bursts = spec.axi_bursts.draw(rng)
    lat = spec.axi_read_latency.draw(rng)
    depth = spec.depth.draw(rng)
    ar = plan.fifo(f"{pfx}_ar", depth)
    r = plan.fifo(f"{pfx}_r", depth)
    aw = plan.fifo(f"{pfx}_aw", depth)
    w = plan.fifo(f"{pfx}_w", depth)
    b = plan.fifo(f"{pfx}_b", depth)
    out = plan.fifo(f"{pfx}_out", spec.depth.draw(rng))
    head = plan.interp(f"{pfx}_master",
                       [("AXIWR", ar, r, aw, w, b, n_bursts, burst, 0,
                         out)])
    plan.aximem(f"{pfx}_mem", (ar, r, aw, w, b), n_bursts * burst, lat,
                n_bursts)
    tail = plan.interp(f"{pfx}_sink",
                       [("SINK", out, n_bursts * burst, False, 0, 0, -1,
                         0)])
    return head, tail, dict(motif="axi", has_nb=False, cyclic=True,
                            burst=burst, bursts=n_bursts)


_CLUSTERS = {
    "pipeline": _pipeline_cluster,
    "tree": _tree_cluster,
    "diamond": lambda plan, rng, spec, pfx, budget:
        _tree_cluster(plan, rng, spec, pfx, budget, levels=1),
    "ring": _ring_cluster,
    "poll": _poll_cluster,
    "axi": _axi_cluster,
}


def _builder_from_rows(name: str, declared: str,
                       fifo_rows: tuple,
                       module_rows: tuple) -> Callable[[], Program]:
    """Freeze immutable (fifo, module) row tuples into a Program builder.

    Both :func:`generate` and :func:`edit_pairs` close over this one
    function, so a design and a row-level transformation of it hash their
    module bodies through identical bytecode — ``program_fingerprint`` and
    the per-module delta fingerprints differ only where the *rows* differ.
    """
    def builder() -> Program:
        prog = Program(name, declared_type=declared)
        fifos = [prog.fifo(nm, d) for nm, d in fifo_rows]
        for entry in module_rows:
            if entry[0] == "interp":
                _, mname, script = entry
                prog.add_module(mname, _interp(mname, script, fifos))
            else:
                _, mname, fids, size, lat, n_bursts = entry
                port = AxiPort(ar=fifos[fids[0]], r=fifos[fids[1]],
                               aw=fifos[fids[2]], w=fifos[fids[3]],
                               b=fifos[fids[4]])
                data = [(i * 7 + 3) % 97 for i in range(size)]
                make_memory(prog, port, data, read_latency=lat,
                            write_latency=8, name=mname,
                            n_reads=n_bursts, n_writes=n_bursts)
        return prog
    return builder


def generate(seed: int, scale: int = 32,
             spec: CorpusSpec = DEFAULT_SPEC) -> CorpusCase:
    """Generate a corpus design with roughly ``scale`` modules.

    Deterministic: the same ``(seed, scale, spec)`` triple always yields a
    bit-identical Program (same ``program_fingerprint``).  Module count is
    ``scale`` to ``scale + ~12`` — the last cluster may overshoot by its
    own size.
    """
    if scale < 1:
        raise ValueError(f"scale must be >= 1, got {scale}")
    rng = random.Random(seed * 1_000_003 + scale * 7_919 + 0x5EED)
    plan = _Plan()
    motif_bag = [m for m, wgt in sorted(spec.motif_weights.items())
                 for _ in range(wgt)]
    if not motif_bag:
        raise ValueError("spec.motif_weights selects no motifs")

    clusters = []
    prev_tail = None
    ci = 0
    while plan.n_modules < scale:
        motif = rng.choice(motif_bag)
        pfx = f"c{ci}"
        budget = scale - plan.n_modules
        if motif in ("tree", "diamond"):
            head, tail, cmeta = _CLUSTERS[motif](plan, rng, spec, pfx,
                                                 budget)
        else:
            head, tail, cmeta = _CLUSTERS[motif](plan, rng, spec, pfx)
        bridged = (prev_tail is not None
                   and rng.random() < spec.bridge_prob)
        if bridged:
            bfid = plan.fifo(f"{pfx}_bridge", 1)
            prev_tail.append(("W1", bfid, (ci * 13 + 7) % 97))
            head.insert(0, ("R1", bfid))
        cmeta["bridged"] = bridged
        clusters.append(cmeta)
        prev_tail = tail
        ci += 1

    has_nb = any(c["has_nb"] for c in clusters)
    cyclic = any(c["cyclic"] for c in clusters)
    declared = "C" if has_nb else ("B" if cyclic else "A")
    name = f"corpus_s{seed}_m{scale}"

    # freeze the plan into immutable closures for the builder: scripts
    # become tuples so program_fingerprint hashes pure content
    fifo_rows = tuple(plan.fifo_rows)
    module_rows = tuple(
        ("interp", e[1], tuple(e[2])) if e[0] == "interp"
        else ("aximem", e[1], e[2], e[3], e[4], e[5])
        for e in plan.modules)

    builder = _builder_from_rows(name, declared, fifo_rows, module_rows)

    meta = dict(modules=plan.n_modules, fifos=len(plan.fifo_rows),
                clusters=[c["motif"] for c in clusters],
                declared=declared, has_nb=has_nb, cyclic=cyclic,
                bridges=sum(1 for c in clusters if c["bridged"]))
    return CorpusCase(name=name, seed=seed, scale=scale, spec=spec,
                      builder=builder, meta=meta, _plan=plan)


# ---------------------------------------------------------------------------
# edit pairs: (base, edited) designs spanning every structural-delta class
# ---------------------------------------------------------------------------
@dataclass
class EditPair:
    """One corpus edit: a base design, an edited variant, and what the
    delta subsystem is expected to do with it.

    ``expect`` is ``"patched"`` for edits the trace patcher must serve by
    per-module splicing (pure timing/body edits, FIFO re-depths) and
    ``"cold"`` for edits it must reject to a cold rebuild (value changes,
    renames, interface/topology changes).  Either way the served result
    must be bit-identical to a from-scratch simulation of ``edited()``.
    """
    kind: str
    name: str
    base: Callable[[], Program]
    edited: Callable[[], Program]
    expect: str
    detail: str = ""


#: every delta class the corpus can exercise, in emission order
EDIT_KINDS = ("delay", "retype", "value", "rename", "interface",
              "added", "removed")

#: kinds the patch layer must serve without a cold rebuild
PATCHABLE_KINDS = ("delay", "retype")


def _edit_script(module_rows: tuple, mi: int, fn) -> tuple:
    """Return ``module_rows`` with module ``mi``'s script rewritten by
    ``fn(list(script)) -> list``."""
    rows = list(module_rows)
    _, mname, script = rows[mi]
    rows[mi] = ("interp", mname, tuple(fn(list(script))))
    return tuple(rows)


def _find_w1(module_rows: tuple):
    """Locate a literal single-write macro: (module index, script index)."""
    for mi, entry in enumerate(module_rows):
        if entry[0] != "interp":
            continue
        for si, ins in enumerate(entry[2]):
            if ins[0] == "W1":
                return mi, si
    return None


def edit_pairs(seed: int, scale: int = 32,
               spec: CorpusSpec = BLOCKING_SPEC,
               kinds: Tuple[str, ...] = EDIT_KINDS,
               max_probes: int = 64) -> List[EditPair]:
    """Derive (base, edited) design pairs covering the delta taxonomy.

    Probes seeds ``seed, seed+1, ...`` (up to ``max_probes``) for a live,
    trace-recordable base design that has at least one macro-script module
    and one literal ``W1`` write (a cluster bridge), then emits one
    :class:`EditPair` per requested kind as a pure row-level
    transformation of the frozen plan:

      * ``delay``     — insert a ``("D", k)`` stall into one module body
        (BODY_EDITED; must patch);
      * ``retype``    — one FIFO depth + 1 (fifo RETYPED; must patch —
        deepening a FIFO never removes behavior);
      * ``value``     — bump a bridge's written constant (functional edit;
        the write-stream gate must reject to cold);
      * ``rename``    — rename a FIFO (not patchable by contract);
      * ``interface`` — add a FIFO, a write of it to an existing module
        and a fresh reader module (INTERFACE_CHANGED + ADDED);
      * ``added`` / ``removed`` — a standalone writer/reader pair over a
        new FIFO appears / disappears.

    Base and edited builders share the Program name — only content
    distinguishes their fingerprints, exactly like a user edit.
    """
    from repro.core.trace import TraceUnsupported, record_trace

    unknown = set(kinds) - set(EDIT_KINDS)
    if unknown:
        raise ValueError(f"unknown edit kinds: {sorted(unknown)}")

    case = fifo_rows = module_rows = declared = w1 = None
    for off in range(max_probes):
        cand = generate(seed + off, scale=scale, spec=spec)
        rows = tuple(
            ("interp", e[1], tuple(e[2])) if e[0] == "interp"
            else ("aximem", e[1], e[2], e[3], e[4], e[5])
            for e in cand._plan.modules)
        w1_at = _find_w1(rows)
        if w1_at is None:
            continue
        try:
            record_trace(cand.builder())
        except TraceUnsupported:
            continue
        case, module_rows, w1 = cand, rows, w1_at
        fifo_rows = tuple(cand._plan.fifo_rows)
        declared = cand.meta["declared"]
        break
    if case is None:
        raise RuntimeError(
            f"no live editable base design within {max_probes} probes of "
            f"seed {seed} (scale {scale})")

    rng = random.Random(seed * 99_991 + scale * 101 + 0xED17)
    interp_idx = [i for i, e in enumerate(module_rows) if e[0] == "interp"]
    mk = lambda fr, mr: _builder_from_rows(case.name, declared, fr, mr)
    base = mk(fifo_rows, module_rows)
    pairs: List[EditPair] = []

    for kind in kinds:
        if kind == "delay":
            mi = rng.choice(interp_idx)
            k = 1 + rng.randrange(9)
            pos = rng.randrange(len(module_rows[mi][2]) + 1)
            edited_rows = _edit_script(
                module_rows, mi, lambda s: s[:pos] + [("D", k)] + s[pos:])
            pairs.append(EditPair(
                kind, case.name, base, mk(fifo_rows, edited_rows),
                "patched", f"+{k}-cycle stall in {module_rows[mi][1]}"))
        elif kind == "retype":
            fi = rng.randrange(len(fifo_rows))
            fr = list(fifo_rows)
            fr[fi] = (fr[fi][0], fr[fi][1] + 1)
            pairs.append(EditPair(
                kind, case.name, base, mk(tuple(fr), module_rows),
                "patched", f"FIFO {fifo_rows[fi][0]} depth +1"))
        elif kind == "value":
            mi, si = w1
            def bump(s, si=si):
                op, fid, v = s[si]
                s[si] = (op, fid, v + 1)
                return s
            edited_rows = _edit_script(module_rows, mi, bump)
            pairs.append(EditPair(
                kind, case.name, base, mk(fifo_rows, edited_rows),
                "cold", f"bridge value +1 in {module_rows[mi][1]}"))
        elif kind == "rename":
            fi = rng.randrange(len(fifo_rows))
            fr = list(fifo_rows)
            fr[fi] = (fr[fi][0] + "_rn", fr[fi][1])
            pairs.append(EditPair(
                kind, case.name, base, mk(tuple(fr), module_rows),
                "cold", f"FIFO {fifo_rows[fi][0]} renamed"))
        elif kind == "interface":
            nf = len(fifo_rows)
            fr = fifo_rows + (("xtra_if", 1),)
            mi = rng.choice(interp_idx)
            mr = _edit_script(module_rows, mi,
                              lambda s: s + [("W1", nf, 41)])
            mr = mr + (("interp", "xrd_if", (("R1", nf),)),)
            pairs.append(EditPair(
                kind, case.name, base, mk(fr, mr), "cold",
                f"new port on {module_rows[mi][1]} + reader module"))
        elif kind in ("added", "removed"):
            nf = len(fifo_rows)
            fr = fifo_rows + (("xtra_sb", 1),)
            mr = module_rows + (("interp", "xwr_sb", (("W1", nf, 9),)),
                                ("interp", "xrd_sb", (("R1", nf),)))
            big, small = mk(fr, mr), base
            if kind == "added":
                pairs.append(EditPair(kind, case.name, small, big, "cold",
                                      "standalone writer/reader pair added"))
            else:
                pairs.append(EditPair(kind, case.name, big, small, "cold",
                                      "standalone writer/reader pair removed"))
    return pairs
