"""Checkpointing + restart: the fault-tolerance substrate.

Design for thousands of nodes (DESIGN.md):
  * **atomic**: write to ``step_N.tmp/`` then rename — a checkpoint is either
    complete or absent; crashes mid-save never corrupt the latest.
  * **versioned**: ``step_N`` directories; ``latest()`` resolves the highest
    complete one; ``keep`` bounds disk usage.
  * **sharded**: each host writes only its local shards (here: single host
    writes the addressable shards of the global arrays); layout metadata is
    stored alongside so restore works under a *different* device count —
    the elastic-rescale path (distrib/elastic.py) re-shards on load.
  * **self-describing**: the tree structure is stored as flattened
    ``path -> array`` npz entries plus a JSON manifest (step, data-iterator
    state, mesh shape, config name).

Restart protocol (launch/train.py): on boot, resolve ``latest()``; if present
restore params/opt/data-state and continue; the scheduler can therefore kill
and reschedule any pod at will (preemption-safe).  Straggler mitigation: the
save path is async-friendly (arrays are fetched with ``jax.device_get``
outside the train step; hosts write independently, no barrier).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree, prefix="") -> Dict[str, np.ndarray]:
    out = {}
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.name == "bfloat16":     # npz has no native bf16
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


def _unflatten_into(template, arrays: Dict[str, np.ndarray]):
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = arrays[key]
        if hasattr(leaf, "dtype"):
            import jax.numpy as jnp
            arr = jnp.asarray(arr).astype(leaf.dtype)   # bf16-safe cast
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, params, opt_state=None,
             extra: Optional[Dict[str, Any]] = None) -> str:
        final = os.path.join(self.directory, f"step_{step:012d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "params.npz"), **_flatten(params))
        if opt_state is not None:
            np.savez(os.path.join(tmp, "opt_state.npz"), **_flatten(opt_state))
        manifest = {"step": step, "extra": extra or {},
                    "format": "repro-ckpt-v1"}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        os.rename(tmp, final)            # atomic publish
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:012d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.directory, name,
                                                 "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, params_template, opt_template=None
                ) -> Tuple[Any, Any, Dict[str, Any]]:
        d = os.path.join(self.directory, f"step_{step:012d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        arrays = dict(np.load(os.path.join(d, "params.npz")))
        params = _unflatten_into(params_template, arrays)
        opt_state = None
        if opt_template is not None:
            opt_arrays = dict(np.load(os.path.join(d, "opt_state.npz")))
            opt_state = _unflatten_into(opt_template, opt_arrays)
        return params, opt_state, manifest["extra"]
