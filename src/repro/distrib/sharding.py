"""Sharding rules: parameter and activation PartitionSpecs.

Path-based rules map every parameter leaf to a PartitionSpec over the
production mesh axes ('pod', 'data', 'model').  Leading stacked-layer axes
([L, ...] or [G, M, ...]) are padded with None automatically, so the same
rules serve scanned and unscanned layouts.

Policy (baseline — the §Perf hillclimb iterates on this):
  * tensor-parallel over 'model': attention heads / FFN hidden / vocab
  * experts sharded over 'model' (expert parallelism for MoE weights)
  * data-parallel batch over ('pod', 'data') — params replicated across pods
  * optimizer state mirrors param specs (ZeRO-style sharded moments)
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _base_spec(path: Tuple[str, ...], ndim: int) -> P:
    """Spec for the *unstacked* parameter at this path.

    Every large matrix is 2D-sharded: the tensor-parallel dim over 'model'
    and the other dim over 'data' (FSDP / ZeRO-3 — XLA all-gathers weight
    shards per scan step and reduce-scatters their grads).  Optimizer
    moments inherit the same specs, so state memory scales with the full
    chip count, not just the TP degree.
    """
    name = path[-1]
    in_moe = "moe" in path
    in_ssm = "ssm" in path or "mlstm" in path
    if name == "embed":
        return P("model", "data")
    if name == "lm_head":
        return P("data", "model")
    if name in ("wq", "wk", "wv"):
        return P("data", "model")
    if name == "wo":
        return P("model", "data")
    if name in ("bq", "bk", "bv"):
        return P("model")
    if in_moe and name in ("w_gate", "w_up"):
        return P("model", "data", None)        # experts over 'model', FSDP d
    if in_moe and name == "w_down":
        return P("model", None, "data")
    if in_moe and name == "router":
        return P("data", None)
    if name in ("w_gate", "w_up"):
        return P("data", "model")
    if name == "w_down":
        return P("model", "data")
    if in_ssm and name == "w_in":
        return P("data", "model")
    if in_ssm and name == "conv_w":
        return P(None, "model")
    if in_ssm and name == "w_bc":
        return P("model", "data")
    if in_ssm and name == "w_dt":
        return P("model", None)          # H may be < 16
    if in_ssm and name in ("w_q", "w_k"):
        return P("model", "data")
    if in_ssm and name == "d_skip":
        return P("model")
    if in_ssm and name == "w_out":
        return P("model", "data")
    if name == "w_if":
        return P("model", None)          # 2H may be < 16
    if name in ("w_gates",):                   # sLSTM input gates
        return P("data", "model")
    if name in ("r_gates",):
        return P(None, None, "model")
    if name == "w_out":
        return P("model", "data")
    return P()                                  # norms, biases: replicated


def param_spec(path: Tuple[str, ...], ndim: int) -> P:
    spec = _base_spec(path, ndim)
    pad = ndim - len(spec)
    if pad > 0:
        spec = P(*([None] * pad), *spec)
    elif pad < 0:
        # parameter is lower-rank than the rule (e.g. smoke configs): strip
        spec = P(*list(spec)[-ndim:]) if ndim else P()
    return spec


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for e in path:
        if hasattr(e, "key"):
            names.append(str(e.key))
        elif hasattr(e, "name"):
            names.append(str(e.name))
        else:
            names.append(str(e))
    return tuple(names)


FSDP_MIN_ELEMS = 4_000_000     # below this, replicating over 'data' is
                               # cheaper than per-layer weight all-gathers


def param_specs(params, fsdp_min_elems: int = FSDP_MIN_ELEMS) -> Any:
    """Pytree of PartitionSpecs matching ``params`` (works on shape structs).

    Size-adaptive FSDP (§Perf iteration B): small parameters drop the
    'data' axis — the all-gather traffic costs more than the memory saved.
    """
    def one(path, leaf):
        ndim = leaf.ndim if hasattr(leaf, "ndim") else np.ndim(leaf)
        spec = param_spec(_path_names(path), ndim)
        if _TP_DEGREE == 1:
            spec = _strip_model(spec)
        size = int(np.prod(leaf.shape)) if hasattr(leaf, "shape") else 0
        if size and size < fsdp_min_elems and "data" in spec:
            spec = P(*[None if a == "data" else a for a in spec])
        return spec

    return jax.tree_util.tree_map_with_path(one, params)


_TP_DEGREE = 16


def set_tp_degree(d: int) -> None:
    """Per-arch parallelism policy: tp=1 folds the mesh 'model' axis into
    the data-parallel axes and strips 'model' from every param spec."""
    global _TP_DEGREE
    _TP_DEGREE = d


def tp_degree() -> int:
    return _TP_DEGREE


def _strip_model(spec: P) -> P:
    return P(*[None if a == "model" else a for a in spec])


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    if _TP_DEGREE == 1 and "model" in mesh.axis_names:
        axes.append("model")
    return tuple(axes)


def batch_spec(mesh: Mesh, ndim: int, shard_batch: bool = True,
               batch_size: int = 0) -> P:
    """Tokens/targets [B, S] or frontend [B, F, D]: batch over DP axes.

    Greedy: use the longest DP-axis prefix whose product divides the batch
    (pure-DP folds 'model' into DP, which can exceed small serving batches).
    """
    dp = dp_axes(mesh)
    if batch_size:
        chosen = []
        prod = 1
        for a in dp:
            n = mesh.shape[a]
            if batch_size % (prod * n) == 0:
                chosen.append(a)
                prod *= n
        dp = tuple(chosen)
    lead = dp if shard_batch and dp else None
    return P(lead, *([None] * (ndim - 1)))


def cache_spec(mesh: Mesh, path: Tuple[str, ...], ndim: int,
               batch_one: bool = False) -> P:
    """Decode-cache leaves.

    KV caches [L, B, T, Hkv, hd]: batch over DP axes; for batch=1 long-context
    cells the *sequence* axis is sharded over 'data' instead.  SSM/xLSTM
    state tensors shard over batch when possible, else replicate.
    """
    name = path[-1]
    dp = dp_axes(mesh)
    if _TP_DEGREE == 1:
        if name in ("k", "v") and ndim == 5:
            if batch_one:
                return P(None, None, "data", None, None)
            return P(None, dp, None, None, None)
    if name in ("k", "v") and ndim == 5:
        # [L, B, T, Hkv, hd]: batch over DP; head_dim over 'model' (hd is
        # always a multiple of 16, unlike Hkv) — splits KV-read bandwidth.
        if batch_one:
            return P(None, None, "data", None, "model")
        return P(None, dp, None, None, "model")
    if name in ("k_scale", "v_scale") and ndim == 4:
        if batch_one:
            return P(None, None, "data", None)
        return P(None, dp, None, None)
    if name == "enc" and ndim == 3:
        return P(dp if not batch_one else None, None, None)
    if name == "pos":
        return P()
    # recurrent-state tensors: batch axis follows the stacked-layer axes —
    # [L, B, ...] for lm/hybrid caches, [G, M, B, ...] for mLSTM, [G, B, ...]
    # for sLSTM.
    if not batch_one and ndim >= 3:
        if "mlstm" in path:
            b_axis = 2
        else:                      # hybrid ssm cache / slstm: one stack axis
            b_axis = 1
        spec = [None] * ndim
        spec[b_axis] = dp
        return P(*spec)
    return P(*([None] * ndim))


def shardings_for(mesh: Mesh, specs) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))


# -------------------------------------------------------------- active mesh
# Launchers (train/serve/dryrun) register the mesh here so model code can
# place activation sharding constraints; smoke tests leave it unset and all
# constraints become no-ops.
_ACTIVE_MESH: Optional[Mesh] = None


def set_active_mesh(mesh: Optional[Mesh]) -> None:
    global _ACTIVE_MESH
    _ACTIVE_MESH = mesh


def active_mesh() -> Optional[Mesh]:
    return _ACTIVE_MESH


def constrain(x, *axes):
    """Sharding-constrain ``x`` if a mesh is active.

    ``axes`` entries: "dp" expands to the active DP axes; "model" as-is;
    None for unsharded dims.
    """
    mesh = _ACTIVE_MESH
    if mesh is None:
        return x
    spec = []
    for a in axes:
        if a == "dp":
            dp = dp_axes(mesh)
            spec.append(dp if dp else None)
        elif a == "model" and _TP_DEGREE == 1:
            spec.append(None)        # pure DP: 'model' already inside dp
        else:
            spec.append(a)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
