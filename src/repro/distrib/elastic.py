"""Elastic scaling + straggler mitigation.

**Elastic re-mesh**: on boot (and after any restart), the runtime builds the
largest mesh the *surviving* device set supports, preferring to shrink the
'data' axis (pure DP capacity) before touching 'model' (which would change
weight-shard layouts).  Checkpoints are layout-agnostic (full arrays +
specs), so restoring onto the new mesh is a plain sharded load.

**Straggler mitigation**: with synchronous data parallelism a straggling pod
slows every step.  The runtime tracks an EWMA of per-step wall time; when a
host exceeds ``straggler_factor`` x the fleet median for ``patience``
consecutive steps it is reported for eviction, after which the elastic
re-mesh path kicks in — shrink 'data', rebalance the global batch over the
remaining DP shards (the data pipeline reshards by host_id/num_hosts), and
continue from the in-memory params (no checkpoint rollback needed because
all survivors hold identical replicas along 'data').
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np


def best_mesh_shape(n_devices: int, model_parallel: int = 16,
                    pod_size: int = 256) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Largest usable (pod, data, model) shape for a surviving device count.

    'model' is pinned (changing it re-lays-out every weight shard); 'data'
    shrinks to the largest multiple that fits; full pods are preferred.
    """
    assert n_devices >= model_parallel, "fewer devices than model shards"
    pods = n_devices // pod_size
    if pods >= 2:
        data = pod_size // model_parallel
        return (pods, data, model_parallel), ("pod", "data", "model")
    data = n_devices // model_parallel
    return (data, model_parallel), ("data", "model")


def make_elastic_mesh(n_devices: Optional[int] = None,
                      model_parallel: int = 16):
    devs = jax.devices()
    n = n_devices or len(devs)
    shape, axes = best_mesh_shape(n, model_parallel)
    used = int(np.prod(shape))
    return jax.make_mesh(shape, axes, devices=devs[:used])


@dataclass
class StragglerMonitor:
    straggler_factor: float = 1.5
    patience: int = 5
    ewma: Dict[int, float] = field(default_factory=dict)
    strikes: Dict[int, int] = field(default_factory=dict)

    def record(self, host_id: int, step_time_s: float) -> None:
        prev = self.ewma.get(host_id, step_time_s)
        self.ewma[host_id] = 0.8 * prev + 0.2 * step_time_s

    def stragglers(self) -> List[int]:
        if len(self.ewma) < 2:
            return []
        median = float(np.median(list(self.ewma.values())))
        out = []
        for h, t in self.ewma.items():
            if t > self.straggler_factor * median:
                self.strikes[h] = self.strikes.get(h, 0) + 1
                if self.strikes[h] >= self.patience:
                    out.append(h)
            else:
                self.strikes[h] = 0
        return out
