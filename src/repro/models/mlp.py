"""Gated MLP (SwiGLU / GeGLU) feed-forward blocks."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import dense_init, gelu, silu


def init_mlp(key, d_model: int, d_ff: int):
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], d_model, d_ff),
        "w_up": dense_init(ks[1], d_model, d_ff),
        "w_down": dense_init(ks[2], d_ff, d_model),
    }


def mlp(p, x, activation: str = "silu"):
    act = silu if activation == "silu" else gelu
    h = act(x @ p["w_gate"].astype(x.dtype)) * (x @ p["w_up"].astype(x.dtype))
    return h @ p["w_down"].astype(x.dtype)
