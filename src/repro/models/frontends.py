"""Modality frontends — spec-compliant stubs.

Per the assignment: ``[vlm]``/``[audio]`` entries specify the transformer
BACKBONE only; the modality frontend is a STUB whose ``input_specs()``
provides precomputed frame/patch embeddings.  These helpers generate the
stand-in shapes (for the dry-run) and deterministic synthetic embeddings
(for smoke tests / the quickstart example).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig


def frontend_shape(cfg: ArchConfig, batch: int):
    """Shape of the precomputed patch/frame embeddings."""
    if cfg.frontend_tokens <= 0:
        return None
    return (batch, cfg.frontend_tokens, cfg.d_model)


def synthetic_frontend(cfg: ArchConfig, batch: int, seed: int = 0):
    shape = frontend_shape(cfg, batch)
    if shape is None:
        return None
    key = jax.random.PRNGKey(seed)
    return jax.random.normal(key, shape, jnp.float32) * 0.02
