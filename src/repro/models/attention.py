"""GQA attention: training (full-sequence), prefill, and decode-with-cache.

The jnp path below is what the dry-run lowers (XLA attention); the Pallas
flash-attention kernel (``repro.kernels.flash_attention``) is the TPU-target
hot-spot implementation, selected with ``cfg.use_pallas`` and validated in
interpret mode against ``kernels/flash_attention/ref.py``.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import apply_rope, causal_mask, dense_init, softcap

NEG_INF = -2.3819763e38          # bf16-safe large negative


def init_attn(key, cfg: ArchConfig):
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.num_heads * hd),
        "wk": dense_init(ks[1], cfg.d_model, cfg.num_kv_heads * hd),
        "wv": dense_init(ks[2], cfg.d_model, cfg.num_kv_heads * hd),
        "wo": dense_init(ks[3], cfg.num_heads * hd, cfg.d_model),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads * hd,))
        p["bk"] = jnp.zeros((cfg.num_kv_heads * hd,))
        p["bv"] = jnp.zeros((cfg.num_kv_heads * hd,))
    return p


def _project_qkv(p, x, cfg: ArchConfig, positions):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(B, S, cfg.num_heads, hd)
    k = k.reshape(B, S, cfg.num_kv_heads, hd)
    v = v.reshape(B, S, cfg.num_kv_heads, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, cfg: ArchConfig):
    """q: [B,Sq,H,hd]; k,v: [B,Sk,Hkv,hd]; mask: [B,Sq,Sk] or [Sq,Sk]."""
    B, Sq, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Sq, Hkv, G, hd)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k) / jnp.sqrt(hd).astype(q.dtype)
    if cfg.attn_softcap > 0:
        scores = softcap(scores.astype(jnp.float32), cfg.attn_softcap)
    scores = scores.astype(jnp.float32)
    m = mask[:, None, None, :, :] if mask.ndim == 3 else mask[None, None, None]
    scores = jnp.where(m, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(B, Sq, H * hd)


QCHUNK = 512          # query-block size for the chunked-attention path


def _sdpa_chunked(q, k, v, cfg: ArchConfig, positions, window,
                  chunk: int = QCHUNK):
    """Exact attention with O(chunk * S) score memory.

    Scans over query blocks; each block's softmax row is complete (full key
    range), so this is numerically identical to the direct path while never
    materializing the [S, S] score matrix — the XLA-level analogue of the
    flash-attention blocking the Pallas kernel performs in VMEM.
    """
    B, S, H, hd = q.shape
    nQ = S // chunk
    qb = q.reshape(B, nQ, chunk, H, hd).swapaxes(0, 1)       # [nQ,B,c,H,hd]
    pb = positions.reshape(B, nQ, chunk).swapaxes(0, 1)      # [nQ,B,c]

    def body(_, inp):
        qc, qpos = inp
        mask = causal_mask(qpos, positions, window)          # [B,c,S]
        return None, _sdpa(qc, k, v, mask, cfg)

    # checkpoint per chunk: the backward pass re-forms each chunk's scores
    # instead of stashing all nQ chunks' residuals (which would reconstitute
    # the full [S,S] matrix).
    body = jax.checkpoint(body)
    _, outs = jax.lax.scan(body, None, (qb, pb))             # [nQ,B,c,Hhd]
    return outs.swapaxes(0, 1).reshape(B, S, H * hd)


def attention(p, x, cfg: ArchConfig, positions, window=None,
              use_pallas: Optional[bool] = None):
    """Full-sequence causal attention (train / prefill)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, positions)
    if (cfg.use_pallas if use_pallas is None else use_pallas):
        from ..kernels.flash_attention.ops import flash_attention
        out = flash_attention(q, k, v, causal=True,
                              window=int(window) if window is not None else 0,
                              softcap=cfg.attn_softcap)
        out = out.reshape(B, S, -1)
    elif S > QCHUNK and S % QCHUNK == 0 and not cfg.cost_analysis_mode:
        out = _sdpa_chunked(q, k, v, cfg, positions, window)
    else:
        mask = causal_mask(positions, positions, window)
        out = _sdpa(q, k, v, mask, cfg)
    return out @ p["wo"].astype(x.dtype)


# --------------------------------------------------------------------- decode
def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int, layers: int,
                  dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim
    shape = (layers, batch, max_len, cfg.num_kv_heads, hd)
    if cfg.kv_quant:
        # int8 KV with per-(pos, head) scales: halves cache HBM — the
        # difference between fitting and not for MHA archs (minicpm).
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:-1], jnp.bfloat16),
                "v_scale": jnp.zeros(shape[:-1], jnp.bfloat16),
                "pos": jnp.zeros((batch,), jnp.int32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "pos": jnp.zeros((batch,), jnp.int32)}


def _quantize_row(x):
    """x: [..., hd] -> (int8 values, bf16 scale over the last dim)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale[..., 0].astype(jnp.bfloat16)


def decode_attention(p, x, cfg: ArchConfig, k_cache, v_cache, cache_pos,
                     window=None):
    """One-token decode: x [B,1,D]; k/v_cache [B,T,Hkv,hd]; cache_pos [B].

    Returns (out [B,1,D], new_k, new_v)."""
    B, _, _ = x.shape
    hd = cfg.resolved_head_dim
    T = k_cache.shape[1]
    positions = cache_pos[:, None]                       # [B,1]
    q, k_new, v_new = _project_qkv(p, x, cfg, positions)
    # in-place style KV insert: dynamic_update_slice touches one row per
    # sequence instead of the one-hot scatter-add's full-cache read+write
    # (§Perf decode iteration: halves per-layer cache traffic and lets XLA
    # alias the donated buffers).
    def _ins(row, new, pos):
        return jax.lax.dynamic_update_slice_in_dim(row, new, pos, axis=0)

    k_cache = jax.vmap(_ins)(k_cache, k_new.astype(k_cache.dtype), cache_pos)
    v_cache = jax.vmap(_ins)(v_cache, v_new.astype(v_cache.dtype), cache_pos)
    k_pos = jnp.arange(T, dtype=jnp.int32)[None, :].astype(jnp.int32)
    valid = k_pos <= cache_pos[:, None]                  # [B,T]
    if window is not None:
        w = jnp.asarray(window)
        local = k_pos > (cache_pos[:, None] - w)
        valid = jnp.where(w > 0, valid & local, valid)
    mask = valid[:, None, :]                             # [B,1,T]
    out = _sdpa(q, k_cache.astype(q.dtype), v_cache.astype(q.dtype), mask, cfg)
    out = out @ p["wo"].astype(x.dtype)
    return out, k_cache, v_cache


def decode_attention_quant(p, x, cfg: ArchConfig, k_cache, v_cache, k_scale,
                           v_scale, cache_pos, window=None):
    """int8-KV decode: caches are int8 with per-(pos, head) bf16 scales."""
    B, _, _ = x.shape
    T = k_cache.shape[1]
    positions = cache_pos[:, None]
    q, k_new, v_new = _project_qkv(p, x, cfg, positions)
    kq, ks_new = _quantize_row(k_new)                    # [B,1,H,hd],[B,1,H]
    vq, vs_new = _quantize_row(v_new)

    def _ins(row, new, pos):
        return jax.lax.dynamic_update_slice_in_dim(row, new, pos, axis=0)

    k_cache = jax.vmap(_ins)(k_cache, kq, cache_pos)
    v_cache = jax.vmap(_ins)(v_cache, vq, cache_pos)
    k_scale = jax.vmap(_ins)(k_scale, ks_new, cache_pos)
    v_scale = jax.vmap(_ins)(v_scale, vs_new, cache_pos)
    k = k_cache.astype(q.dtype) * k_scale.astype(q.dtype)[..., None]
    v = v_cache.astype(q.dtype) * v_scale.astype(q.dtype)[..., None]
    k_pos = jnp.arange(T, dtype=jnp.int32)[None, :]
    valid = k_pos <= cache_pos[:, None]
    if window is not None:
        w = jnp.asarray(window)
        local = k_pos > (cache_pos[:, None] - w)
        valid = jnp.where(w > 0, valid & local, valid)
    out = _sdpa(q, k, v, valid[:, None, :], cfg)
    out = out @ p["wo"].astype(x.dtype)
    return out, k_cache, v_cache, k_scale, v_scale
