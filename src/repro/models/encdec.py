"""Encoder-decoder transformer (seamless-m4t-medium backbone).

The speech/text frontend is a spec-compliant stub: ``input_specs`` provides
precomputed frame embeddings [B, F, D] for the encoder.  The decoder is a
standard causal transformer with cross-attention; decode uses a self-attn KV
cache plus cached encoder states.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..distrib.sharding import constrain
from .attention import (NEG_INF, _project_qkv, _sdpa, _sdpa_chunked,
                        attention, init_attn)
from .common import apply_rope, causal_mask, dense_init, dtype_of, \
    embed_init, mask_vocab_pad, padded_vocab, rms_norm
from .mlp import init_mlp, mlp

Params = Dict[str, Any]


def _init_cross_attn(key, cfg: ArchConfig):
    return init_attn(key, cfg)       # same projection structure


def init_enc_layer(key, cfg: ArchConfig):
    ks = jax.random.split(key, 2)
    return {
        "ln1": jnp.zeros((cfg.d_model,)),
        "attn": init_attn(ks[0], cfg),
        "ln2": jnp.zeros((cfg.d_model,)),
        "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff),
    }


def init_dec_layer(key, cfg: ArchConfig):
    ks = jax.random.split(key, 3)
    return {
        "ln1": jnp.zeros((cfg.d_model,)),
        "attn": init_attn(ks[0], cfg),
        "lnx": jnp.zeros((cfg.d_model,)),
        "xattn": _init_cross_attn(ks[1], cfg),
        "ln2": jnp.zeros((cfg.d_model,)),
        "mlp": init_mlp(ks[2], cfg.d_model, cfg.d_ff),
    }


def init_params(key, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 5)
    ek = jax.random.split(ks[0], cfg.encoder_layers)
    dk = jax.random.split(ks[1], cfg.num_layers)
    p = {
        "embed": embed_init(ks[2], cfg.vocab_size, cfg.d_model),
        "enc_layers": jax.vmap(lambda k: init_enc_layer(k, cfg))(ek),
        "dec_layers": jax.vmap(lambda k: init_dec_layer(k, cfg))(dk),
        "enc_norm": jnp.zeros((cfg.d_model,)),
        "final_norm": jnp.zeros((cfg.d_model,)),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[3], cfg.d_model,
                                  padded_vocab(cfg.vocab_size))
    return p


def _cross_attention(p, x, enc, cfg: ArchConfig):
    """x: [B,Sq,D] queries; enc: [B,Sk,D] encoder states (keys/values).
    Long decoder sequences scan over query blocks (chunked attention)."""
    B, Sq, _ = x.shape
    Sk = enc.shape[1]
    hd = cfg.resolved_head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, Sq, cfg.num_heads, hd)
    k = (enc @ p["wk"].astype(x.dtype)).reshape(B, Sk, cfg.num_kv_heads, hd)
    v = (enc @ p["wv"].astype(x.dtype)).reshape(B, Sk, cfg.num_kv_heads, hd)
    chunk = 512
    if Sq > chunk and Sq % chunk == 0:
        nQ = Sq // chunk
        qb = q.reshape(B, nQ, chunk, cfg.num_heads, hd).swapaxes(0, 1)

        def body(_, qc):
            mask = jnp.ones((chunk, Sk), bool)
            return None, _sdpa(qc, k, v, mask, cfg)

        _, outs = jax.lax.scan(jax.checkpoint(body), None, qb)
        out = outs.swapaxes(0, 1).reshape(B, Sq, cfg.num_heads * hd)
    else:
        mask = jnp.ones((Sq, Sk), bool)
        out = _sdpa(q, k, v, mask, cfg)
    return out @ p["wo"].astype(x.dtype)


def encode(params: Params, frames, cfg: ArchConfig):
    """frames: [B, F, D] stub embeddings -> encoder states [B, F, D]."""
    cdt = dtype_of(cfg.dtype)
    x = frames.astype(cdt)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(xc, lp):
        h = rms_norm(xc, lp["ln1"], cfg.norm_eps)
        # bidirectional self-attention
        q, k, v = _project_qkv(lp["attn"], h, cfg, positions)
        mask = jnp.ones((S, S), bool)
        a = _sdpa(q, k, v, mask, cfg) @ lp["attn"]["wo"].astype(xc.dtype)
        xc = xc + a
        h = rms_norm(xc, lp["ln2"], cfg.norm_eps)
        return xc + mlp(lp["mlp"], h), None

    body = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def forward(params: Params, tokens, cfg: ArchConfig,
            frontend: Optional[jnp.ndarray] = None):
    """Full enc-dec forward: frames -> encoder; tokens -> decoder logits."""
    enc = encode(params, frontend, cfg)
    cdt = dtype_of(cfg.dtype)
    x = params["embed"][tokens].astype(cdt)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(xc, lp):
        h = rms_norm(xc, lp["ln1"], cfg.norm_eps)
        a = attention(lp["attn"], h, cfg, positions)   # chunked-causal
        xc = xc + a
        h = rms_norm(xc, lp["lnx"], cfg.norm_eps)
        xc = xc + _cross_attention(lp["xattn"], h, enc, cfg)
        h = rms_norm(xc, lp["ln2"], cfg.norm_eps)
        xc = xc + mlp(lp["mlp"], h)
        if xc.shape[1] % 16 == 0:
            xc = constrain(xc, "dp", "model", None)    # sequence-parallel
        return xc, None

    body = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(x.dtype)
    logits = x @ head
    logits = constrain(logits, "dp", None, "model")
    return mask_vocab_pad(logits, cfg.vocab_size)


def loss_fn(params: Params, tokens, targets, cfg: ArchConfig,
            frontend: Optional[jnp.ndarray] = None):
    from .lm import cross_entropy
    logits = forward(params, tokens, cfg, frontend)
    return cross_entropy(logits, targets)


# --------------------------------------------------------------------- decode
def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> Params:
    hd = cfg.resolved_head_dim
    L = cfg.num_layers
    return {
        "k": jnp.zeros((L, batch, max_len, cfg.num_kv_heads, hd), jnp.bfloat16),
        "v": jnp.zeros((L, batch, max_len, cfg.num_kv_heads, hd), jnp.bfloat16),
        "enc": jnp.zeros((batch, cfg.frontend_tokens, cfg.d_model),
                         jnp.bfloat16),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def decode_step(params: Params, tokens, cache: Params, cfg: ArchConfig):
    """One decoder step with cached encoder states."""
    from .attention import decode_attention
    cdt = dtype_of(cfg.dtype)
    x = params["embed"][tokens].astype(cdt)
    pos = cache["pos"]
    enc = cache["enc"].astype(cdt)

    def body(xc, inp):
        lp, kc, vc = inp
        h = rms_norm(xc, lp["ln1"], cfg.norm_eps)
        a, k2, v2 = decode_attention(lp["attn"], h, cfg, kc, vc, pos)
        xc = xc + a
        h = rms_norm(xc, lp["lnx"], cfg.norm_eps)
        xc = xc + _cross_attention(lp["xattn"], h, enc, cfg)
        h = rms_norm(xc, lp["ln2"], cfg.norm_eps)
        return xc + mlp(lp["mlp"], h), (k2, v2)

    x, (k2, v2) = jax.lax.scan(body, x, (params["dec_layers"], cache["k"],
                                         cache["v"]))
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = k2, v2
    new_cache["pos"] = pos + 1
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(x.dtype)
    logits = constrain(x @ head, "dp", None, "model")
    return mask_vocab_pad(logits, cfg.vocab_size), new_cache
