"""xLSTM blocks: mLSTM (matrix memory, chunk-parallel) and sLSTM (scalar
memory, sequential recurrence).

The mLSTM recurrence C_t = f_t C_{t-1} + i_t v_t k_t^T with readout
y_t = (C_t^T q_t) / max(|n_t^T q_t|, 1) shares the SSD chunk structure of
``models/ssm.py``: we reuse ``ssd_scan`` with (C,B,u,dt) := (q,k,v,i) and a
second normalizer channel.  Deviation noted in DESIGN.md: the exponential
input gate is replaced by a bounded sigmoid gate so the chunked form needs
no running max-stabilizer; the dataflow (and therefore the roofline
character) is identical.

The sLSTM keeps per-head scalar state with a block-diagonal recurrent
matrix; its time loop is inherently sequential (lax.scan over steps).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, XLSTMConfig
from .common import dense_init, silu
from .ssm import _causal_conv, ssd_scan


# ---------------------------------------------------------------------- mLSTM
def init_mlstm(key, cfg: ArchConfig):
    xc = cfg.xlstm
    d = cfg.d_model
    d_inner = xc.mlstm_expand * d
    H = cfg.num_heads
    ks = jax.random.split(key, 8)
    return {
        "w_in": dense_init(ks[0], d, 2 * d_inner),            # u and gate z
        "conv_w": jax.random.normal(ks[1], (xc.conv_kernel, d_inner)) * 0.1,
        "w_q": dense_init(ks[2], d_inner, d_inner),
        "w_k": dense_init(ks[3], d_inner, d_inner),
        "w_if": dense_init(ks[4], d_inner, 2 * H),            # i and f gates
        "if_bias": jnp.zeros((2 * H,)),
        "w_out": dense_init(ks[5], d_inner, d),
    }


def mlstm_forward(p, x, cfg: ArchConfig):
    """x: [B,S,d_model] -> [B,S,d_model]."""
    xc = cfg.xlstm
    d_inner = xc.mlstm_expand * cfg.d_model
    H = cfg.num_heads
    P = d_inner // H
    B_, S, _ = x.shape
    xz = x @ p["w_in"].astype(x.dtype)
    u, z = jnp.split(xz, 2, axis=-1)
    u = silu(_causal_conv(u, p["conv_w"].astype(x.dtype)))
    q = (u @ p["w_q"].astype(x.dtype)).reshape(B_, S, H, P)
    k = (u @ p["w_k"].astype(x.dtype)).reshape(B_, S, H, P)
    v = u.reshape(B_, S, H, P)
    gif = (u @ p["w_if"].astype(x.dtype)).astype(jnp.float32) \
        + p["if_bias"][None, None]
    ig = jax.nn.sigmoid(gif[..., :H])                          # [B,S,H]
    la = jax.nn.log_sigmoid(gif[..., H:])                      # log f-gate <= 0

    # per-head chunked scan via the shared SSD machinery:
    #   decay log = la (per head), inputs scaled by ig.
    # normalizer: same scan with v replaced by ones (P+1 channels).
    kq_scale = 1.0 / jnp.sqrt(P).astype(jnp.float32)
    vv = jnp.concatenate(
        [v.astype(jnp.float32),
         jnp.ones((B_, S, H, 1), jnp.float32)], axis=-1)       # [B,S,H,P+1]
    num_den = _mlstm_chunk(q.astype(jnp.float32) * kq_scale,
                           k.astype(jnp.float32), vv, ig, la, xc.chunk, cfg)
    num, den = num_den[..., :P], num_den[..., P:]
    y = num / jnp.maximum(jnp.abs(den), 1.0)
    y = y.reshape(B_, S, d_inner).astype(x.dtype)
    y = y * silu(z)
    return y @ p["w_out"].astype(x.dtype)


def _mlstm_chunk(q, k, v, ig, la, chunk, cfg: ArchConfig):
    """Dispatch: Pallas kernel (TPU target) or ssd_scan-based reference.

    q,k: [B,S,H,P]; v: [B,S,H,Pv]; ig (input gate), la (log forget) [B,S,H].
    The mLSTM readout sum_{s<=t} exp(cum_t - cum_s) ig_s (q_t.k_s) v_s is the
    ssd_scan kernel with (C,B,u,dt) = (q,k,v,ig).
    """
    if cfg.use_pallas:
        from ..kernels.mlstm_chunk.ops import mlstm_chunk
        return mlstm_chunk(q, k, v, ig, la, chunk=chunk)
    Bb, S, H, P = q.shape
    # ssd_scan signature: u [B,S,H,P], dt [B,S,H], a [H], B,C [B,S,N] — here
    # decay varies per (b,s,h) and B/C are per-head, so call its generalized
    # sibling below (shared code path, per-head N=P).
    return _ssd_scan_perhead(q, k, v, ig, la, chunk)


def _ssd_scan_perhead(q, k, v, ig, la, chunk: int):
    """ssd_scan generalized to per-head (B,C) = (k,q) and data-dependent
    log-decay ``la`` [B,S,H].  Shapes: q,k [B,S,H,P]; v [B,S,H,Pv]."""
    Bb, S, H, P = q.shape
    Pv = v.shape[-1]
    c = min(chunk, S)
    nC = S // c
    assert nC * c == S
    q_ = q.reshape(Bb, nC, c, H, P)
    k_ = k.reshape(Bb, nC, c, H, P)
    v_ = v.reshape(Bb, nC, c, H, Pv)
    ig_ = ig.reshape(Bb, nC, c, H)
    la_ = la.reshape(Bb, nC, c, H)
    cum = jnp.cumsum(la_, axis=2)                              # [B,nC,c,H]

    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]       # [B,nC,c,c,H]
    causal = jnp.tril(jnp.ones((c, c), bool))
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bnthp,bnshp->bntsh", q_, k_)          # [B,nC,c,c,H]
    scores = scores * L
    iv = ig_[..., None] * v_                                   # [B,nC,c,H,Pv]
    y_local = jnp.einsum("bntsh,bnshp->bnthp", scores, iv)

    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)            # [B,nC,c,H]
    state_contrib = jnp.einsum("bnshk,bnshp->bnhkp",
                               k_, iv * decay_to_end[..., None])
    chunk_decay = jnp.exp(cum[:, :, -1])                       # [B,nC,H]

    def cross(carry, inp):
        st, dec = inp
        prev = carry
        new = prev * dec[:, :, None, None] + st
        return new, prev

    init = jnp.zeros((Bb, H, P, Pv), jnp.float32)
    _, prev_states = jax.lax.scan(
        cross, init, (state_contrib.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    prev_states = prev_states.swapaxes(0, 1)                   # [B,nC,H,P,Pv]
    y_carry = jnp.einsum("bnthp,bnhpw->bnthw", q_, prev_states)
    y = y_local + y_carry * jnp.exp(cum)[..., None]
    return y.reshape(Bb, S, H, Pv)


def init_mlstm_cache(cfg: ArchConfig, batch: int, n_mlstm: int):
    xc = cfg.xlstm
    d_inner = xc.mlstm_expand * cfg.d_model
    H = cfg.num_heads
    P = d_inner // H
    return {
        "state": jnp.zeros((n_mlstm, batch, H, P, P + 1), jnp.float32),
        "conv": jnp.zeros((n_mlstm, batch, xc.conv_kernel - 1, d_inner),
                          jnp.bfloat16),
    }


def mlstm_decode_step(p, x, cfg: ArchConfig, state, conv_buf):
    """x: [B,1,d]; state: [B,H,P,P+1]; conv_buf: [B,K-1,d_inner]."""
    xc = cfg.xlstm
    d_inner = xc.mlstm_expand * cfg.d_model
    H = cfg.num_heads
    P = d_inner // H
    xz = x @ p["w_in"].astype(x.dtype)
    u, z = jnp.split(xz, 2, axis=-1)
    window = jnp.concatenate([conv_buf.astype(u.dtype), u], axis=1)
    u_c = silu(jnp.einsum("bkd,kd->bd", window,
                          p["conv_w"].astype(u.dtype)))[:, None, :]
    new_conv = window[:, 1:, :].astype(conv_buf.dtype)
    q = (u_c @ p["w_q"].astype(x.dtype)).reshape(-1, H, P).astype(jnp.float32)
    k = (u_c @ p["w_k"].astype(x.dtype)).reshape(-1, H, P).astype(jnp.float32)
    v = u_c.reshape(-1, H, P).astype(jnp.float32)
    gif = (u_c @ p["w_if"].astype(x.dtype)).astype(jnp.float32)[:, 0] \
        + p["if_bias"][None]
    ig = jax.nn.sigmoid(gif[..., :H])
    fg = jax.nn.sigmoid(gif[..., H:])
    vv = jnp.concatenate([v, jnp.ones((v.shape[0], H, 1), jnp.float32)], -1)
    new_state = state * fg[:, :, None, None] \
        + ig[:, :, None, None] * jnp.einsum("bhp,bhw->bhpw", k, vv)
    scale = 1.0 / jnp.sqrt(P).astype(jnp.float32)
    out = jnp.einsum("bhp,bhpw->bhw", q * scale, new_state)
    num, den = out[..., :P], out[..., P:]
    y = num / jnp.maximum(jnp.abs(den), 1.0)
    y = y.reshape(-1, 1, d_inner).astype(x.dtype)
    y = y * silu(z)
    return y @ p["w_out"].astype(x.dtype), new_state, new_conv


# ---------------------------------------------------------------------- sLSTM
def init_slstm(key, cfg: ArchConfig):
    d = cfg.d_model
    H = cfg.num_heads
    P = d // H
    ks = jax.random.split(key, 3)
    return {
        "w_gates": dense_init(ks[0], d, 4 * d),                # i,f,z,o
        "r_gates": jax.random.normal(ks[1], (H, P, 4 * P)) * (1.0 / P ** 0.5),
        "b_gates": jnp.zeros((4 * d,)),
        "w_out": dense_init(ks[2], d, d),
    }


def slstm_forward(p, x, cfg: ArchConfig):
    """Sequential scalar-memory LSTM with block-diagonal recurrence."""
    B_, S, d = x.shape
    H = cfg.num_heads
    P = d // H
    wx = (x @ p["w_gates"].astype(x.dtype)).astype(jnp.float32) \
        + p["b_gates"][None, None]                              # [B,S,4d]

    def step(carry, wx_t):
        h, c, n = carry                                         # [B,H,P] each
        rec = jnp.einsum("bhp,hpq->bhq", h, p["r_gates"].astype(jnp.float32))
        g = wx_t.reshape(B_, H, 4 * P) + rec
        i = jax.nn.sigmoid(g[..., :P])
        f = jax.nn.sigmoid(g[..., P:2 * P])
        zin = jnp.tanh(g[..., 2 * P:3 * P])
        o = jax.nn.sigmoid(g[..., 3 * P:])
        c = f * c + i * zin
        n = f * n + i
        h = o * c / jnp.maximum(n, 1.0)
        return (h, c, n), h

    init = tuple(jnp.zeros((B_, H, P), jnp.float32) for _ in range(3))
    _, hs = jax.lax.scan(step, init, wx.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).reshape(B_, S, d).astype(x.dtype)
    return y @ p["w_out"].astype(x.dtype)


def init_slstm_cache(cfg: ArchConfig, batch: int, n_slstm: int):
    H = cfg.num_heads
    P = cfg.d_model // H
    z = jnp.zeros((n_slstm, batch, H, P), jnp.float32)
    return {"h": z, "c": z, "n": z}


def slstm_decode_step(p, x, cfg: ArchConfig, h, c, n):
    """x: [B,1,d]; h/c/n: [B,H,P]."""
    B_, _, d = x.shape
    H = cfg.num_heads
    P = d // H
    wx = (x @ p["w_gates"].astype(x.dtype)).astype(jnp.float32)[:, 0] \
        + p["b_gates"][None]
    rec = jnp.einsum("bhp,hpq->bhq", h, p["r_gates"].astype(jnp.float32))
    g = wx.reshape(B_, H, 4 * P) + rec
    i = jax.nn.sigmoid(g[..., :P])
    f = jax.nn.sigmoid(g[..., P:2 * P])
    zin = jnp.tanh(g[..., 2 * P:3 * P])
    o = jax.nn.sigmoid(g[..., 3 * P:])
    c = f * c + i * zin
    n = f * n + i
    h = o * c / jnp.maximum(n, 1.0)
    y = h.reshape(B_, 1, d).astype(x.dtype)
    return y @ p["w_out"].astype(x.dtype), h, c, n
