"""Mamba-2 (SSD)-style selective SSM block — used standalone and inside
hymba's parallel attention+SSM heads.

Hardware adaptation (DESIGN.md Sec. 2): the original Mamba CUDA kernel is a
warp-level scan — a GPU-specific mechanism.  The TPU-native analogue is the
SSD *chunked* formulation: within a chunk of length c the recurrence is a
decay-masked attention-like matmul (MXU-friendly [c,c] per head); chunk
boundary states propagate with a short ``lax.scan``.  All exponentials are
of non-positive arguments (pairwise cumulative-decay differences), so the
computation is overflow-safe by construction.

State layout per head: matrix state [N, P] (N = ssm.state_dim, P = head
channels), identical to the mLSTM matrix memory — the Pallas kernel
``repro.kernels.mlstm_chunk`` implements this same chunk pattern.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, SSMConfig
from .common import dense_init, silu


def _heads_for(d_inner: int) -> Tuple[int, int]:
    """Split d_inner into (H heads, P channels) with P a multiple of 8."""
    P = 64
    while d_inner % P and P > 8:
        P //= 2
    H = d_inner // P
    return H, P


def init_ssm(key, d_model: int, ssm: SSMConfig):
    d_inner = ssm.expand * d_model
    H, _ = _heads_for(d_inner)
    N = ssm.state_dim
    ks = jax.random.split(key, 6)
    return {
        "w_in": dense_init(ks[0], d_model, 2 * d_inner),       # u and gate z
        "conv_w": jax.random.normal(ks[1], (ssm.conv_kernel, d_inner)) * 0.1,
        "w_bc": dense_init(ks[2], d_inner, 2 * N),             # B, C (shared)
        "w_dt": dense_init(ks[3], d_inner, H),                 # per-head dt
        "dt_bias": jnp.zeros((H,)),
        "a_log": jnp.zeros((H,)),                              # A = -exp(a_log)
        "d_skip": jnp.ones((d_inner,)),
        "w_out": dense_init(ks[4], d_inner, d_model),
    }


def _causal_conv(x, w):
    """x: [B,S,D]; w: [K,D] depthwise causal conv."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for k in range(K):
        out = out + pad[:, k:k + x.shape[1], :] * w[k][None, None, :]
    return out


def ssd_scan(u, dt, a, B, C, chunk: int):
    """SSD chunked scan.

    u:  [Bb, S, H, P]   inputs per head
    dt: [Bb, S, H]      positive step sizes
    a:  [H]             negative per-head decay rates (A = -exp(a_log))
    B, C: [Bb, S, N]    shared input/output projections
    Returns y: [Bb, S, H, P].
    """
    Bb, S, H, P = u.shape
    N = B.shape[-1]
    c = min(chunk, S)
    nC = S // c
    assert nC * c == S, f"seq {S} must divide chunk {c}"

    u_ = u.reshape(Bb, nC, c, H, P)
    dt_ = dt.reshape(Bb, nC, c, H)
    B_ = B.reshape(Bb, nC, c, N)
    C_ = C.reshape(Bb, nC, c, N)

    la = dt_ * a[None, None, None, :]                  # log-decay per step (<=0)
    cum = jnp.cumsum(la, axis=2)                       # [Bb,nC,c,H]

    # ---- intra-chunk: decay-masked attention-like matmul ----
    # L[t,s] = exp(cum[t] - cum[s] + la[s]... ) for s <= t; standard SSD uses
    # decay from s (inclusive of step s's dt B u) to t: exp(cum[t]-cum[s]).
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]       # [Bb,nC,c,c,H]
    causal = jnp.tril(jnp.ones((c, c), bool))
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bntk,bnsk->bnts", C_, B_)              # [Bb,nC,c,c]
    scores = scores[..., None] * L                              # [Bb,nC,c,c,H]
    du = dt_[..., None] * u_                                    # [Bb,nC,c,H,P]
    y_local = jnp.einsum("bntsh,bnshp->bnthp", scores, du)

    # ---- chunk states and cross-chunk carry ----
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)             # [Bb,nC,c,H]
    state_contrib = jnp.einsum("bnsk,bnshp->bnkhp",
                               B_, du * decay_to_end[..., None])  # [Bb,nC,N,H,P]
    chunk_decay = jnp.exp(cum[:, :, -1])                        # [Bb,nC,H]

    def cross(carry, inp):
        st, dec = inp                                           # [Bb,N,H,P],[Bb,H]
        prev = carry
        new = prev * dec[:, None, :, None] + st
        return new, prev

    init = jnp.zeros((Bb, N, H, P), jnp.float32)
    _, prev_states = jax.lax.scan(
        cross, init,
        (state_contrib.swapaxes(0, 1).astype(jnp.float32),
         chunk_decay.swapaxes(0, 1).astype(jnp.float32)))
    prev_states = prev_states.swapaxes(0, 1)                    # [Bb,nC,N,H,P]

    carry_decay = jnp.exp(cum)                                  # decay from chunk start
    y_carry = jnp.einsum("bntk,bnkhp->bnthp",
                         C_, prev_states.astype(C_.dtype))
    y = y_local + y_carry * carry_decay[..., None]
    return y.reshape(Bb, S, H, P)


def ssm_forward(p, x, cfg: ArchConfig):
    """Full-sequence SSM block. x: [B,S,d_model] -> [B,S,d_model]."""
    ssm = cfg.ssm
    d_inner = ssm.expand * cfg.d_model
    H, P = _heads_for(d_inner)
    N = ssm.state_dim
    xz = x @ p["w_in"].astype(x.dtype)
    u, z = jnp.split(xz, 2, axis=-1)
    u = silu(_causal_conv(u, p["conv_w"].astype(x.dtype)))
    bc = u @ p["w_bc"].astype(x.dtype)
    B = bc[..., :N].astype(jnp.float32)
    C = bc[..., N:].astype(jnp.float32)
    dt = jax.nn.softplus((u @ p["w_dt"].astype(x.dtype)).astype(jnp.float32)
                         + p["dt_bias"][None, None])            # [B,S,H]
    a = -jnp.exp(p["a_log"])                                    # [H] < 0
    uh = u.reshape(*u.shape[:-1], H, P).astype(jnp.float32)
    y = ssd_scan(uh, dt, a, B, C, ssm.chunk)
    y = y.reshape(*x.shape[:-1], d_inner).astype(x.dtype)
    y = y + u * p["d_skip"].astype(x.dtype)[None, None]
    y = y * silu(z)
    return y @ p["w_out"].astype(x.dtype)


# ----------------------------------------------------------------- decode step
def init_ssm_cache(cfg: ArchConfig, batch: int, layers: int):
    ssm = cfg.ssm
    d_inner = ssm.expand * cfg.d_model
    H, P = _heads_for(d_inner)
    return {
        "state": jnp.zeros((layers, batch, ssm.state_dim, H, P), jnp.float32),
        "conv": jnp.zeros((layers, batch, ssm.conv_kernel - 1, d_inner),
                          jnp.bfloat16),
    }


def ssm_decode_step(p, x, cfg: ArchConfig, state, conv_buf):
    """One-token step.  x: [B,1,d_model]; state: [B,N,H,P];
    conv_buf: [B,K-1,d_inner].  Returns (y, new_state, new_conv)."""
    ssm = cfg.ssm
    d_inner = ssm.expand * cfg.d_model
    H, P = _heads_for(d_inner)
    N = ssm.state_dim
    xz = x @ p["w_in"].astype(x.dtype)
    u, z = jnp.split(xz, 2, axis=-1)                            # [B,1,d_inner]
    window = jnp.concatenate([conv_buf.astype(u.dtype), u], axis=1)
    u_c = silu(jnp.einsum("bkd,kd->bd", window,
                          p["conv_w"].astype(u.dtype)))[:, None, :]
    new_conv = window[:, 1:, :].astype(conv_buf.dtype)
    bc = u_c @ p["w_bc"].astype(x.dtype)
    B = bc[:, 0, :N].astype(jnp.float32)                        # [B,N]
    C = bc[:, 0, N:].astype(jnp.float32)
    dt = jax.nn.softplus((u_c @ p["w_dt"].astype(x.dtype)).astype(jnp.float32)
                         + p["dt_bias"][None, None])[:, 0]      # [B,H]
    a = -jnp.exp(p["a_log"])
    dec = jnp.exp(dt * a[None])                                 # [B,H]
    uh = u_c[:, 0].reshape(-1, H, P).astype(jnp.float32)        # [B,H,P]
    du = dt[..., None] * uh
    new_state = state * dec[:, None, :, None] \
        + jnp.einsum("bk,bhp->bkhp", B, du)
    y = jnp.einsum("bk,bkhp->bhp", C, new_state)                # [B,H,P]
    y = y.reshape(-1, 1, d_inner).astype(x.dtype)
    y = y + u_c * p["d_skip"].astype(x.dtype)[None, None]
    y = y * silu(z)
    return y @ p["w_out"].astype(x.dtype), new_state, new_conv
