"""Language-model assembly for every non-enc-dec family in the zoo.

Layers are stored stacked ([L, ...] leading axis) and consumed with
``jax.lax.scan`` so HLO size and compile time are depth-independent; per-layer
heterogeneity (gemma2's local/global alternation) is expressed with scanned
per-layer scalars (the sliding-window size), never with Python-level layer
loops.  ``jax.checkpoint`` around the scanned body implements activation
rematerialization for training.

Families:
  dense / vlm       — GQA attention + gated MLP (VLM: patch embeddings from
                      the frontend stub are prepended to the token stream)
  moe               — GQA attention + top-k MoE FFN
  hybrid (hymba)    — parallel attention ∥ SSM heads + gated MLP
  ssm (xlstm)       — mLSTM groups with interleaved sLSTM blocks, no FFN
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..distrib.sharding import constrain, tp_degree
from .attention import (attention, decode_attention, decode_attention_quant,
                        init_attn, init_kv_cache)
from .common import (dense_init, dtype_of, embed_init, mask_vocab_pad,
                     padded_vocab, rms_norm, softcap)
from .mlp import init_mlp, mlp
from .moe import init_moe, moe as moe_apply, moe_dense
from .ssm import init_ssm, init_ssm_cache, ssm_decode_step, ssm_forward
from .xlstm import (init_mlstm, init_mlstm_cache, init_slstm,
                    init_slstm_cache, mlstm_decode_step, mlstm_forward,
                    slstm_decode_step, slstm_forward)

Params = Dict[str, Any]


# ---------------------------------------------------------------- init
def _layer_keys(key, n):
    return jax.random.split(key, n)


def init_layer(key, cfg: ArchConfig) -> Params:
    """One block's parameters (unstacked)."""
    ks = jax.random.split(key, 6)
    p: Params = {"ln1": jnp.zeros((cfg.d_model,))}
    if cfg.family in ("dense", "vlm", "moe", "hybrid"):
        p["attn"] = init_attn(ks[0], cfg)
        p["ln2"] = jnp.zeros((cfg.d_model,))
        if cfg.family == "moe":
            p["moe"] = init_moe(ks[1], cfg)
        else:
            p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff)
        if cfg.family == "hybrid":
            p["ssm"] = init_ssm(ks[2], cfg.d_model, cfg.ssm)
        if cfg.post_norms:
            p["pn1"] = jnp.zeros((cfg.d_model,))
            p["pn2"] = jnp.zeros((cfg.d_model,))
    return p


def group_factor(L: int) -> int:
    """Outer-group count for two-level remat: the divisor of L minimizing
    saved-activation count (G outer group inputs + L/G inner layer inputs)."""
    best = 1
    best_cost = L + 1
    for g in range(1, L + 1):
        if L % g == 0:
            cost = g + L // g
            if cost < best_cost:
                best_cost = cost
                best = g
    return best


def layer_windows(cfg: ArchConfig) -> jnp.ndarray:
    """Per-layer sliding-window sizes (0 = global causal)."""
    L = cfg.num_layers
    if cfg.local_global_pattern and cfg.sliding_window:
        w = [cfg.sliding_window if i % 2 == 0 else 0 for i in range(L)]
    elif cfg.sliding_window:
        w = [cfg.sliding_window] * L
    else:
        w = [0] * L
    return jnp.asarray(w, jnp.int32)


def init_params(key, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 8)
    p: Params = {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model),
        "final_norm": jnp.zeros((cfg.d_model,)),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[1], cfg.d_model,
                                  padded_vocab(cfg.vocab_size))
    if cfg.family == "ssm":          # xlstm supergroups
        xc = cfg.xlstm
        G = cfg.num_layers // xc.slstm_every
        M = xc.slstm_every - 1       # mLSTM blocks per group
        mk = jax.random.split(ks[2], G * M).reshape(G, M, 2)
        p["mlstm"] = jax.vmap(jax.vmap(lambda k: init_mlstm(k, cfg)))(mk)
        p["ln_m"] = jnp.zeros((G, M, cfg.d_model))
        sk = jax.random.split(ks[3], G)
        p["slstm"] = jax.vmap(lambda k: init_slstm(k, cfg))(sk)
        p["ln_s"] = jnp.zeros((G, cfg.d_model))
    else:
        lk = _layer_keys(ks[2], cfg.num_layers)
        p["layers"] = jax.vmap(lambda k: init_layer(k, cfg))(lk)
    return p


# -------------------------------------------------------------- block bodies
SEQ_SHARD_MIN_BYTES = 0        # (§Perf iteration D — REFUTED: disabling SP
                               # for small models doubled the all-reduce
                               # traffic; AG+RS + small saves always won.)


def _seq_shard(x):
    """Megatron-style sequence parallelism: between blocks the residual
    stream lives S-sharded over the 'model' axis, so remat saves and
    norm/residual math are 1/TP-degree sized; XLA inserts the all-gather
    into the TP-sharded attention/FFN and the reduce-scatter back.

    Size-aware (§Perf iteration D): for small models the residual stream
    fits comfortably unsharded and the per-layer gather/scatter ping-pong
    dominates the collective term — skip SP below the threshold."""
    if tp_degree() == 1:              # pure DP: nothing to sequence-shard
        return x
    if x.shape[1] % 16 != 0:          # S must divide the TP degree
        return x
    per_dev_bytes = (x.size // 16) * x.dtype.itemsize   # batch already /16
    if per_dev_bytes < SEQ_SHARD_MIN_BYTES:
        return x
    return constrain(x, "dp", "model", None)


def _block(p, x, cfg: ArchConfig, positions, window):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    # (§Perf iteration C — REFUTED and reverted: explicitly pinning the
    # sequence-parallel gather here forced full activation gathers; XLA's
    # own propagation keeps Q sequence-sharded and gathers only K/V.)
    a = attention(p["attn"], h, cfg, positions, window=window)
    if cfg.family == "hybrid":
        s = ssm_forward(p["ssm"], h, cfg)
        a = 0.5 * (a + s)            # hymba: parallel attn+SSM head fusion
    if cfg.post_norms:
        a = rms_norm(a, p["pn1"], cfg.norm_eps)
    x = x + a
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        from ..distrib.sharding import active_mesh
        f = moe_apply(p["moe"], h, cfg, mesh=active_mesh())
    else:
        f = mlp(p["mlp"], h)
    if cfg.post_norms:
        f = rms_norm(f, p["pn2"], cfg.norm_eps)
    return _seq_shard(x + f)


def _xlstm_group(pm, ps, lnm, lns, x, cfg: ArchConfig):
    """One supergroup: M mLSTM blocks then one sLSTM block."""
    def m_body(xc, inp):
        lp, ln = inp
        y = mlstm_forward(lp, rms_norm(xc, ln, cfg.norm_eps), cfg)
        return xc + y, None

    if cfg.remat:
        m_body = jax.checkpoint(m_body)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(m_body, x, (pm, lnm))
    else:                                 # unrolled (cost-analysis variants)
        M = jax.tree.leaves(pm)[0].shape[0]
        for i in range(M):
            x, _ = m_body(x, (jax.tree.map(lambda a: a[i], pm), lnm[i]))
    y = slstm_forward(ps, rms_norm(x, lns, cfg.norm_eps), cfg)
    return x + y


# ------------------------------------------------------------------- forward
def hidden_forward(params: Params, tokens, cfg: ArchConfig,
                   frontend: Optional[jnp.ndarray] = None):
    """tokens: [B, S_tok] int32; frontend: [B, F, D] stub embeddings
    (vlm/audio) prepended to the token stream.  Returns final hidden states
    [B, S, D] (post final-norm) — the head is applied by the caller so the
    training loss can fuse projection + CE chunkwise."""
    cdt = dtype_of(cfg.dtype)
    x = params["embed"][tokens].astype(cdt)
    if cfg.family in ("vlm",) and frontend is not None:
        x = jnp.concatenate([frontend.astype(cdt), x], axis=1)
    x = constrain(x, "dp", None, None)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, cdt)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    if cfg.family == "ssm":
        def g_body(xc, inp):
            pm, ps, lnm, lns = inp
            return _xlstm_group(pm, ps, lnm, lns, xc, cfg), None

        body = jax.checkpoint(g_body) if cfg.remat else g_body
        tree = (params["mlstm"], params["slstm"], params["ln_m"],
                params["ln_s"])
        if cfg.scan_layers:
            x, _ = jax.lax.scan(body, x, tree)
        else:                             # unrolled (cost-analysis variants)
            G = params["ln_s"].shape[0]
            for g in range(G):
                x, _ = body(x, jax.tree.map(lambda a: a[g], tree))
    else:
        windows = layer_windows(cfg)

        def body(xc, inp):
            lp, w = inp
            return _block(lp, xc, cfg, positions, w), None

        if not cfg.scan_layers:
            body = jax.checkpoint(body) if cfg.remat else body
            for i in range(cfg.num_layers):
                lp = jax.tree.map(lambda a: a[i], params["layers"])
                x, _ = body(x, (lp, windows[i]))
        elif cfg.remat:
            # two-level (sqrt) remat: outer scan over G groups saves only
            # group inputs; the checkpointed inner scan over L/G layers
            # re-saves layer inputs during each group's backward replay.
            L = cfg.num_layers
            G = group_factor(L)
            grouped = jax.tree.map(
                lambda a: a.reshape(G, L // G, *a.shape[1:]),
                (params["layers"], windows))

            def group_body(xc, ginp):
                y, _ = jax.lax.scan(jax.checkpoint(body), xc, ginp)
                return y, None

            x, _ = jax.lax.scan(jax.checkpoint(group_body), x, grouped)
        else:
            x, _ = jax.lax.scan(body, x, (params["layers"], windows))

    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def _head(params: Params, cfg: ArchConfig, dtype):
    return (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(dtype)


def forward(params: Params, tokens, cfg: ArchConfig,
            frontend: Optional[jnp.ndarray] = None):
    """Returns logits [B, S, V] (serving / small-scale use)."""
    x = hidden_forward(params, tokens, cfg, frontend)
    logits = x @ _head(params, cfg, x.dtype)
    logits = constrain(logits, "dp", None, "model")   # vocab-sharded logits
    if cfg.logit_softcap > 0:
        logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    return mask_vocab_pad(logits, cfg.vocab_size)


def cross_entropy(logits, targets):
    """Vocab-sharding-friendly CE: logsumexp minus a one-hot contraction —
    never gathers across the sharded vocab axis (no all-gather of logits)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)                     # [B,S]
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=jnp.float32)
    tgt = jnp.sum(logits * onehot, axis=-1)                     # [B,S]
    return (lse - tgt).mean()


CE_CHUNK = 512


def chunked_head_ce(x, head, targets, cap: float, vocab: int,
                    chunk: int = CE_CHUNK):
    """Fused final-projection + CE over sequence chunks.

    Never materializes the full [B, S, V] logits: each scan step projects a
    [B, chunk, D] slice, softcaps, and reduces to a scalar; ``jax.checkpoint``
    makes the backward re-form each chunk's logits instead of storing them.
    """
    B, S, D = x.shape
    nQ = S // chunk
    xb = x.reshape(B, nQ, chunk, D).swapaxes(0, 1)
    tb = targets.reshape(B, nQ, chunk).swapaxes(0, 1)

    def body(acc, inp):
        xc, tc = inp
        logits = xc @ head
        logits = constrain(logits, "dp", None, "model")
        logits = logits.astype(jnp.float32)
        if cap > 0:
            logits = softcap(logits, cap)
        logits = mask_vocab_pad(logits, vocab)
        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(tc, logits.shape[-1], dtype=jnp.float32)
        tgt = jnp.sum(logits * onehot, axis=-1)
        return acc + jnp.sum(lse - tgt), None

    total, _ = jax.lax.scan(jax.checkpoint(body), jnp.zeros((), jnp.float32),
                            (xb, tb))
    return total / (B * S)


def loss_fn(params: Params, tokens, targets, cfg: ArchConfig,
            frontend: Optional[jnp.ndarray] = None):
    """Next-token cross-entropy averaged over target tokens."""
    x = hidden_forward(params, tokens, cfg, frontend)
    if frontend is not None and cfg.family == "vlm":
        x = x[:, frontend.shape[1]:, :]               # loss on text only
    head = _head(params, cfg, x.dtype)
    if x.shape[1] % CE_CHUNK == 0 and x.shape[1] > CE_CHUNK \
            and not cfg.cost_analysis_mode:
        return chunked_head_ce(x, head, targets, cfg.logit_softcap,
                               cfg.vocab_size)
    logits = x @ head
    logits = constrain(logits, "dp", None, "model")
    if cfg.logit_softcap > 0:
        logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    logits = mask_vocab_pad(logits, cfg.vocab_size)
    return cross_entropy(logits, targets)


# --------------------------------------------------------------------- decode
def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> Params:
    """Stacked per-layer decode state."""
    if cfg.family == "ssm":
        xc = cfg.xlstm
        G = cfg.num_layers // xc.slstm_every
        M = xc.slstm_every - 1
        m = init_mlstm_cache(cfg, batch, G * M)
        s = init_slstm_cache(cfg, batch, G)
        return {
            "mlstm": jax.tree.map(
                lambda a: a.reshape(G, M, *a.shape[1:]), m),
            "slstm": s,
            "pos": jnp.zeros((batch,), jnp.int32),
        }
    cache: Params = init_kv_cache(cfg, batch, max_len, cfg.num_layers)
    if cfg.family == "hybrid":
        cache["ssm"] = init_ssm_cache(cfg, batch, cfg.num_layers)
    return cache


def decode_step(params: Params, tokens, cache: Params, cfg: ArchConfig):
    """One decode step. tokens: [B,1] int32. Returns (logits [B,1,V], cache)."""
    cdt = dtype_of(cfg.dtype)
    x = params["embed"][tokens].astype(cdt)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, cdt)
    pos = cache["pos"]

    if cfg.family == "ssm":
        def g_body(xc, inp):
            pm, ps, lnm, lns, mc, sc = inp

            def m_body(xm, minp):
                lp, ln, st, cv = minp
                h = rms_norm(xm, ln, cfg.norm_eps)
                y, st2, cv2 = mlstm_decode_step(lp, h, cfg, st, cv)
                return xm + y, (st2, cv2)

            xc, mstates = jax.lax.scan(
                m_body, xc, (pm, lnm, mc["state"], mc["conv"]))
            h = rms_norm(xc, lns, cfg.norm_eps)
            y, hh, cc, nn = slstm_decode_step(ps, h, cfg,
                                              sc["h"], sc["c"], sc["n"])
            xc = xc + y
            return xc, ({"state": mstates[0], "conv": mstates[1]},
                        {"h": hh, "c": cc, "n": nn})

        x, (mc2, sc2) = jax.lax.scan(
            g_body, x, (params["mlstm"], params["slstm"], params["ln_m"],
                        params["ln_s"], cache["mlstm"], cache["slstm"]))
        new_cache = {"mlstm": mc2, "slstm": sc2, "pos": pos + 1}
    else:
        windows = layer_windows(cfg)

        def body(xc, inp):
            if cfg.kv_quant:
                lp, w, kc, vc, ksc, vsc, *rest = inp
            else:
                lp, w, kc, vc, *rest = inp
            h = rms_norm(xc, lp["ln1"], cfg.norm_eps)
            if cfg.kv_quant:
                a, k2, v2, ks2, vs2 = decode_attention_quant(
                    lp["attn"], h, cfg, kc, vc, ksc, vsc, pos, window=w)
                kv_out = (k2, v2, ks2, vs2)
            else:
                a, k2, v2 = decode_attention(lp["attn"], h, cfg, kc, vc, pos,
                                             window=w)
                kv_out = (k2, v2)
            extra = ()
            if cfg.family == "hybrid":
                st, cv = rest
                s, st2, cv2 = ssm_decode_step(lp["ssm"], h, cfg, st, cv)
                a = 0.5 * (a + s)
                extra = (st2, cv2)
            if cfg.post_norms:
                a = rms_norm(a, lp["pn1"], cfg.norm_eps)
            xc = xc + a
            h = rms_norm(xc, lp["ln2"], cfg.norm_eps)
            f = moe_dense(lp["moe"], h, cfg) if cfg.family == "moe" \
                else mlp(lp["mlp"], h)
            if cfg.post_norms:
                f = rms_norm(f, lp["pn2"], cfg.norm_eps)
            return xc + f, kv_out + extra

        ins = (params["layers"], windows, cache["k"], cache["v"])
        if cfg.kv_quant:
            ins = ins + (cache["k_scale"], cache["v_scale"])
        if cfg.family == "hybrid":
            ins = ins + (cache["ssm"]["state"], cache["ssm"]["conv"])
        # (unrolled decode with .at[i] updates was tried and REFUTED:
        # per-layer resharding collectives exploded — see EXPERIMENTS.md)
        x, outs = jax.lax.scan(body, x, ins)
        new_cache = dict(cache)
        new_cache["k"], new_cache["v"] = outs[0], outs[1]
        nxt = 2
        if cfg.kv_quant:
            new_cache["k_scale"], new_cache["v_scale"] = outs[2], outs[3]
            nxt = 4
        if cfg.family == "hybrid":
            new_cache["ssm"] = {"state": outs[nxt], "conv": outs[nxt + 1]}
        new_cache["pos"] = pos + 1

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(x.dtype)
    logits = x @ head
    logits = constrain(logits, "dp", None, "model")   # vocab-sharded logits
    if cfg.logit_softcap > 0:
        logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    return mask_vocab_pad(logits, cfg.vocab_size), new_cache
