"""Family-dispatching model API: one entry point for every architecture.

    init_params(key, cfg)                      -> params
    forward(params, tokens, cfg, frontend)     -> logits
    loss_fn(params, tokens, targets, cfg, ...) -> scalar
    init_cache(cfg, batch, max_len)            -> decode cache
    decode_step(params, tokens, cache, cfg)    -> (logits, cache)
"""
from __future__ import annotations

from typing import Any, Optional

from ..configs.base import ArchConfig
from . import encdec, lm


def _mod(cfg: ArchConfig):
    return encdec if cfg.family == "audio" else lm


def init_params(key, cfg: ArchConfig):
    return _mod(cfg).init_params(key, cfg)


def forward(params, tokens, cfg: ArchConfig, frontend=None):
    return _mod(cfg).forward(params, tokens, cfg, frontend)


def loss_fn(params, tokens, targets, cfg: ArchConfig, frontend=None):
    return _mod(cfg).loss_fn(params, tokens, targets, cfg, frontend)


def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    return _mod(cfg).init_cache(cfg, batch, max_len)


def decode_step(params, tokens, cache, cfg: ArchConfig):
    return _mod(cfg).decode_step(params, tokens, cache, cfg)
