"""Shared model components: initializers, norms, RoPE, projections.

Everything is functional JAX: params are nested dicts of arrays; layer
stacks are stored stacked along a leading axis and consumed with
``jax.lax.scan`` so compile time and HLO size are depth-independent
(critical for the 40-cell x 2-mesh dry-run matrix).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# ----------------------------------------------------------------- initializers
def dense_init(key, in_dim: int, out_dim: int, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(in_dim)
    return jax.random.uniform(key, (in_dim, out_dim), dtype, -scale, scale)


VOCAB_PAD_MULTIPLE = 256      # 16 (model) x 16 (data FSDP) shard grid


def padded_vocab(vocab: int) -> int:
    """Embedding tables are padded so both shard axes divide evenly; the
    padding ids are unreachable (tokens < vocab) and their logits are masked
    to -inf before softmax/argmax."""
    m = VOCAB_PAD_MULTIPLE
    return -(-vocab // m) * m


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32):
    return jax.random.normal(key, (padded_vocab(vocab), dim), dtype) * 0.02


def mask_vocab_pad(logits, vocab: int):
    """Mask padded vocab columns to a large negative (softmax/argmax-safe)."""
    Vp = logits.shape[-1]
    if Vp == vocab:
        return logits
    col = jnp.arange(Vp) >= vocab
    return jnp.where(col, jnp.asarray(-1e30, logits.dtype), logits)


def stacked(keys, fn, *args, **kw):
    """Initialize a [L, ...] stacked parameter from per-layer keys."""
    return jnp.stack([fn(k, *args, **kw) for k in keys])


# ------------------------------------------------------------------------ norms
def rms_norm(x, gamma, eps: float = 1e-6):
    # variance in f32 for stability, but the normalization itself applies in
    # the compute dtype: activation tensors (and their cotangents) then stay
    # bf16 end-to-end, halving every resharding collective's payload
    # (§Perf iteration E).
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * scale * (1.0 + gamma).astype(x.dtype)


def layer_norm(x, gamma, beta, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(dt)


def softcap(x, cap: float):
    """Gemma-2 style logit soft-capping: cap * tanh(x / cap)."""
    if cap <= 0.0:
        return x
    return cap * jnp.tanh(x / cap)


# ------------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., seq, heads, head_dim]; positions: [..., seq].

    Angles in f32; rotation applied in the compute dtype so q/k stay bf16
    (see rms_norm note — §Perf iteration E)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(angles)[..., :, None, :].astype(x.dtype)
    sin = jnp.sin(angles)[..., :, None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                           axis=-1)


# ---------------------------------------------------------------------- masking
def causal_mask(q_pos, k_pos, window: Optional[jnp.ndarray] = None):
    """Boolean [.., Sq, Sk] mask. ``window``: 0/neg = global causal; >0 =
    sliding-window causal (key within `window` of query).  ``window`` may be
    a traced scalar so local/global layer alternation stays scannable."""
    m = k_pos[..., None, :] <= q_pos[..., :, None]
    if window is not None:
        w = jnp.asarray(window)
        local = k_pos[..., None, :] > (q_pos[..., :, None] - w)
        m = jnp.where(w > 0, m & local, m)
    return m


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def silu(x):
    return jax.nn.silu(x)
