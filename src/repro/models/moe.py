"""Mixture-of-Experts layers: top-k routing with two execution strategies.

``impl="dense"`` — masked dense compute: every expert processes every token,
   masked by routing weights.  Trivially shardable by XLA SPMD (experts live
   on the 'model' axis), numerically exact, but computes E/K times the active
   FLOPs.  This is the *baseline* the §Perf hillclimb starts from.

``impl="ep"`` — expert parallelism: tokens are routed to expert shards with
   an all-to-all inside ``shard_map``; each shard computes only its local
   experts over the tokens routed to it (capacity-bounded, dropless up to the
   capacity factor).  Active-FLOPs-proportional compute at the price of two
   all-to-alls per MoE layer — the classic EP trade, surfaced in the roofline
   collective term.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig, MoEConfig
from .common import dense_init, silu
from .mlp import init_mlp, mlp


def init_moe(key, cfg: ArchConfig, expert_shards: int = 16):
    mo = cfg.moe
    ks = jax.random.split(key, 5)
    d, f = cfg.d_model, mo.d_expert
    E = padded_experts(mo, expert_shards)
    p = {
        "router": dense_init(ks[0], d, E),
        # stacked expert weights [E, ...]
        "w_gate": jax.vmap(lambda k: dense_init(k, d, f))(
            jax.random.split(ks[1], E)),
        "w_up": jax.vmap(lambda k: dense_init(k, d, f))(
            jax.random.split(ks[2], E)),
        "w_down": jax.vmap(lambda k: dense_init(k, f, d))(
            jax.random.split(ks[3], E)),
    }
    if mo.num_shared_experts:
        p["shared"] = init_mlp(ks[4], d, mo.d_shared or mo.d_expert)
    return p


def padded_experts(mo: MoEConfig, expert_shards: int = 16) -> int:
    """Experts padded up to a multiple of the expert-shard count so both the
    dense-masked einsums and EP all-to-alls shard evenly (granite: 40->48)."""
    E = mo.num_experts
    return -(-E // expert_shards) * expert_shards


def _route(p, x, mo: MoEConfig):
    """Returns (weights [B,S,K] fp32 normalized, idx [B,S,K] int32)."""
    logits = x.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    E = p["router"].shape[-1]
    if E > mo.num_experts:      # padding experts can never be routed to
        pad_mask = jnp.arange(E) >= mo.num_experts
        logits = jnp.where(pad_mask, -1e30, logits)
    weights, idx = jax.lax.top_k(logits, mo.top_k)
    weights = jax.nn.softmax(weights, axis=-1)
    return weights, idx.astype(jnp.int32)


# ------------------------------------------------------------------ dense path
def moe_dense(p, x, cfg: ArchConfig):
    """Masked dense MoE: out = sum_e gate_e(x) * FFN_e(x).

    Computes every (padded) expert for every token — E/K x the active FLOPs;
    the §Perf hillclimb replaces this with the EP path.  The down-projection
    is fused with the combine weights so no [B,S,E,D] intermediate exists.
    """
    mo = cfg.moe
    E = p["router"].shape[-1]
    weights, idx = _route(p, x, mo)
    combine = jax.nn.one_hot(idx, E, dtype=jnp.float32)          # [B,S,K,E]
    combine = jnp.einsum("bske,bsk->bse", combine, weights).astype(x.dtype)
    h = jnp.einsum("bsd,edf->bsef", x, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,edf->bsef", x, p["w_up"].astype(x.dtype))
    h = silu(h) * u
    h = h * combine[..., None]
    out = jnp.einsum("bsef,efd->bsd", h, p["w_down"].astype(x.dtype))
    if mo.num_shared_experts:
        out = out + mlp(p["shared"], x)
    return out


# --------------------------------------------------------------------- EP path
def moe_ep(p, x, cfg: ArchConfig, mesh, expert_axis: str = "model",
           capacity_factor: float = 1.25):
    """Expert-parallel MoE: shard_map + all-to-all with PER-EXPERT capacity
    buffers (§Perf hillclimb for the MoE cells).

    Layout: tokens enter [B, S, D] with B over the DP axes and S over the
    'model' axis (the sequence-parallel residual layout); experts are
    sharded over 'model'.  Per shard:

      1. route its T_loc tokens, build a send buffer [E, C, D] with slot
         rank computed per EXPERT (not per shard);
      2. tiled all_to_all over 'model' exchanges expert blocks: each shard
         ends up holding [n_shards, E_local, C, D] for ITS experts;
      3. grouped per-expert batched matmuls — active-FLOPs proportional
         (E_local x (n*C) x 4df ~= K/E-fraction of dense-masked compute);
      4. reverse all_to_all + weighted combine into token slots.

    Dropless up to ``capacity_factor``; overflow tokens fall back to zero
    contribution for that expert choice (standard capacity semantics).
    """
    # jax >= 0.5 exposes shard_map at top level (check_vma kwarg); older
    # releases only have the experimental module (check_rep kwarg).
    try:
        shard_map = jax.shard_map
        smap_kwargs = {"check_vma": False}
    except AttributeError:
        from jax.experimental.shard_map import shard_map
        smap_kwargs = {"check_rep": False}

    mo = cfg.moe
    n = mesh.shape[expert_axis]
    E_pad = p["router"].shape[-1]
    E_local = E_pad // n
    assert E_local * n == E_pad
    dp = [a for a in ("pod", "data") if a in mesh.axis_names]

    def local_fn(router, w_gate, w_up, w_down, xs):
        b, s_loc, D = xs.shape
        T = b * s_loc
        xt = xs.reshape(T, D)
        logits = xt.astype(jnp.float32) @ router.astype(jnp.float32)
        if E_pad > mo.num_experts:
            pad_mask = jnp.arange(E_pad) >= mo.num_experts
            logits = jnp.where(pad_mask, -1e30, logits)
        weights, idx = jax.lax.top_k(logits, mo.top_k)        # [T, K]
        weights = jax.nn.softmax(weights, axis=-1)
        # per-expert capacity
        C = int(capacity_factor * mo.top_k * T / E_pad)
        C = max(4, -(-C // 4) * 4)
        flat_e = idx.reshape(-1)                              # [T*K]
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        seg_start = jnp.concatenate([
            jnp.zeros((1,), jnp.int32),
            jnp.cumsum(jnp.bincount(sorted_e, length=E_pad))[:-1]
            .astype(jnp.int32)])
        pos = jnp.arange(T * mo.top_k, dtype=jnp.int32)
        rank = jnp.zeros_like(pos).at[order].set(pos - seg_start[sorted_e])
        keep = rank < C
        e_sel = jnp.where(keep, flat_e, 0)
        r_sel = jnp.where(keep, rank, C - 1)
        tok_of = jnp.repeat(jnp.arange(T, dtype=jnp.int32), mo.top_k)
        send = jnp.zeros((E_pad, C, D), xs.dtype)
        send = send.at[e_sel, r_sel].add(
            jnp.where(keep[:, None], xt[tok_of], 0).astype(xs.dtype))
        # exchange expert blocks: shard j receives block j from every peer
        recv = jax.lax.all_to_all(send, expert_axis, 0, 0, tiled=True)
        # [n * E_local, C, D] -> [E_local, n*C, D] (peer-major slots)
        recv = recv.reshape(n, E_local, C, D).transpose(1, 0, 2, 3) \
            .reshape(E_local, n * C, D)
        h = jnp.einsum("ecd,edf->ecf", recv, w_gate.astype(recv.dtype))
        u = jnp.einsum("ecd,edf->ecf", recv, w_up.astype(recv.dtype))
        y = jnp.einsum("ecf,efd->ecd", silu(h) * u,
                       w_down.astype(recv.dtype))
        y = y.reshape(E_local, n, C, D).transpose(1, 0, 2, 3) \
            .reshape(E_pad, C, D)
        back = jax.lax.all_to_all(y, expert_axis, 0, 0, tiled=True)
        flat = back.reshape(E_pad * C, D)                     # [E*C, D]
        per_k = jnp.where(keep[:, None], flat[e_sel * C + r_sel], 0)
        per_k = per_k.reshape(T, mo.top_k, D).astype(jnp.float32)
        out = jnp.einsum("tkd,tk->td", per_k, weights).astype(xs.dtype)
        return out.reshape(b, s_loc, D)

    espec = P(expert_axis)
    token_spec = P(tuple(dp) if dp else None, expert_axis, None)
    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(), espec, espec, espec, token_spec),
        out_specs=token_spec,
        **smap_kwargs)
    out = fn(p["router"], p["w_gate"], p["w_up"], p["w_down"], x)
    if mo.num_shared_experts:
        out = out + mlp(p["shared"], x)
    return out


def moe(p, x, cfg: ArchConfig, mesh=None):
    mo = cfg.moe
    if mo.impl == "ep" and mesh is not None:
        return moe_ep(p, x, cfg, mesh)
    return moe_dense(p, x, cfg)
