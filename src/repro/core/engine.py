"""The OmniSim engine: coupled functionality + performance simulation.

Faithful realization of paper Sec. 6.2 with the JAX/TPU-era adaptation of
DESIGN.md Sec. 2: Func Sim *threads* become deterministic coroutines, the
Perf Sim *thread* becomes the orchestrator below.  The protocol is kept
exactly:

  ❶ invoke one Func Sim task per dataflow module (plus the orchestrator);
  ❷ tasks emit requests; informative ones update the partial simulation
    graph and FIFO read/write tables immediately;
  ❸ a task pauses when it issues a *query* (NB access / status probe whose
    target is unknown) or blocks on a B access; the task tracker counts
    active tasks;
  ❹ at quiescence (task tracker == 0) the orchestrator resolves queries
    earliest-cycle-first against the FIFO tables (paper Table 2); if nothing
    is resolvable it applies the earliest-query rule — the earliest pending
    query is resolved *false*, which is sound because every uncommitted
    event must eventually commit at or after that query's cycle (paper
    Sec. 7.1, our proof in core/engine.py::_force_earliest);
  ❺ resolved tasks resume; on global completion the eagerly maintained node
    times are the finalized result (``verify_finalization=True`` re-derives
    them from the graph by longest path and asserts equality — opt-in since
    the PR 1 hot-path overhaul; tests enable it).

Deadlock: quiescence with no pending queries and no satisfiable blocked
access ⇒ true design-level deadlock, reported immediately with the stall
cycle (paper Sec. 7.1).

Determinism: the ready list is serviced in module order by default;
``shuffle_seed`` randomizes servicing order to demonstrate that results are
schedule-independent — the property the paper fights OS scheduling for.

Trace compilation (paper Sec. 5.1, PR 2): for blocking-only runs the
per-op generator dispatch below is the dominant cost of the *initial*
simulation, so :func:`simulate` first tries ``core/trace.py`` — record each
module's op stream once, compile it to flat numpy op arrays, and replay by
array-level dispatch (chain cummax + cross-edge fixpoint) instead of
resuming generators.  Designs with live NB accesses / status probes, true
deadlocks, or SPSC violations raise ``TraceUnsupported`` and fall back to
the generator loop in this file, which remains the semantics reference for
every design class (Type A/B/C).
"""
from __future__ import annotations

import heapq
import random
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .events import (Constraint, DeadlockError, NodeKind, Query, RequestType,
                     SimStats)
from .fifo import FifoTable
from .graph import SimGraph, longest_path_numpy
from .program import (Delay, Emit, Empty, Full, Op, Program, Read, ReadNB,
                      SimResult, Write, WriteNB)


class TaskState(Enum):
    """Lifecycle of a Func Sim task (paper Sec. 6.2 ❸: a task pauses on an
    unresolvable query or a blocked blocking access)."""

    READY = 0
    PAUSED_QUERY = 1
    PAUSED_READ = 2
    PAUSED_WRITE = 3
    DONE = 4


@dataclass
class _Task:
    mid: int
    name: str
    gen: Any
    clock: int = 1                     # next available hardware cycle (1-based)
    state: TaskState = TaskState.READY
    send_value: Any = None             # value to send into the generator
    last_node: int = -1                # idx of last graph node (for seq edges)
    last_node_time: int = 0
    pending_op: Optional[Op] = None    # blocked B op or queried NB op
    pending_query: Optional[Query] = None
    started: bool = False


# Edge kinds on the simulation graph (stored as weight-tagged preds):
# we tag WAR edges so incremental re-finalization can strip/regenerate them.
SEQ, RAW, WAR = 0, 1, 2


class OmniSim:
    """Coupled Func/Perf simulation engine (paper Sec. 6.2).

    One instance = one run: module generators drive FIFO accesses, each
    committed access becomes a simulation-graph node stamped with its
    hardware **cycle**, and per-FIFO :class:`~repro.core.fifo.FifoTable`\\ s
    answer the Table-2 resolution questions.  The finished instance is
    carried on ``SimResult.graph`` and is the substrate for incremental
    (``core/incremental.py``) and batched (``core/dse.py``)
    re-simulation — the trace replay (``core/trace.py``) populates an
    identical end state without running this event loop.
    """

    def __init__(self, program: Program, shuffle_seed: Optional[int] = None,
                 max_steps: int = 50_000_000, verify_finalization: bool = False,
                 _fifo_shells: bool = False):
        self.program = program
        self.graph = SimGraph()
        # the trace replay (core/trace.py) installs every table's event
        # arrays wholesale right after construction — _fifo_shells skips
        # the per-table buffer allocations it would immediately discard
        if _fifo_shells:
            self.fifos = [FifoTable._shell(f.fid, f.name, f.depth)
                          for f in program.fifos]
        else:
            self.fifos = [FifoTable(f.fid, f.name, f.depth)
                          for f in program.fifos]
        self.tasks = [_Task(m.mid, m.name, None) for m in program.modules]
        self.outputs: Dict[str, Any] = {}
        self.stats = SimStats()
        self.constraints: List[Constraint] = []
        # min-heap of (source_time, qid, Query): earliest-query-first access
        # is O(log n) instead of the repeated full sorts of earlier revisions
        self.query_pool: List[Tuple[int, int, Query]] = []
        self._qid = 0
        self._rng = random.Random(shuffle_seed) if shuffle_seed is not None else None
        self._verify_finalization = verify_finalization
        # wake lists: O(1) unblocking instead of all-task scans (perf iter 2)
        self._waiting_reader: Dict[int, _Task] = {}
        self._waiting_writer: Dict[int, _Task] = {}
        self._wakeups: List[_Task] = []
        # tasks made READY by quiescence-time resumption/resolution; drained
        # by run() instead of rescanning every task per round (perf iter 4:
        # the corpus suite's 1000-module designs made the O(tasks) scans per
        # quiescence round the dominant generator-engine cost)
        self._ready_now: List[_Task] = []
        self._n_done = 0
        self._max_steps = max_steps
        self._steps = 0
        self._war_edges: List = []       # (dst_node, src_node, fifo, w_seq)
        self.deadlock = False
        self.deadlock_cycle = -1
        # edge-kind bookkeeping for incremental re-sim
        self._edge_kinds: Dict = {}      # (dst, src) -> kind
        # SPSC endpoint enforcement: FIFO tables and query sequencing assume
        # one writer module and one reader module per FIFO (HLS semantics).
        self._writer_of: Dict[int, int] = {}
        self._reader_of: Dict[int, int] = {}

    def _check_endpoint(self, fid: int, mid: int, side: str) -> None:
        table = self._writer_of if side == "w" else self._reader_of
        prev = table.setdefault(fid, mid)
        if prev != mid:
            raise AssertionError(
                f"FIFO '{self.fifos[fid].name}' has two {side}-side modules "
                f"({self.program.modules[prev].name}, "
                f"{self.program.modules[mid].name}); FIFOs are SPSC")

    # ------------------------------------------------------------------ utils
    def _new_node(self, task: _Task, kind: NodeKind, time: int,
                  fifo: int = -1, seq: int = -1, issue: Optional[int] = None):
        """Add a node committing at ``time``.

        The SEQ edge carries only the *static-schedule* gap (issue - prev),
        never the stall component — stalls are expressed by RAW/WAR edges so
        incremental re-finalization under new FIFO depths recomputes them
        instead of baking them in.
        """
        node = self.graph.add_node(task.mid, kind, time, fifo, seq)
        if task.last_node >= 0:
            gap = (issue if issue is not None else time) - task.last_node_time
            node.add_edge(task.last_node, gap)
            self._edge_kinds[(node.idx, task.last_node)] = SEQ
        task.last_node = node.idx
        task.last_node_time = time
        self.stats.nodes += 1
        return node

    def _add_raw_edge(self, node, src_idx: int, weight: int) -> None:
        node.add_edge(src_idx, weight)
        self._edge_kinds[(node.idx, src_idx)] = RAW
        self.stats.edges += 1

    def _add_war_edge(self, node, src_idx: int, weight: int) -> None:
        node.add_edge(src_idx, weight)
        self._edge_kinds[(node.idx, src_idx)] = WAR
        self.stats.edges += 1

    # ------------------------------------------------------------------- run
    def run(self) -> SimResult:
        """Execute the protocol ❶-❺ of the module docstring to completion
        (or deadlock) and return the finalized :class:`SimResult`, whose
        ``cycles`` is the max node commit cycle."""
        # ❶ invoke all tasks
        for task, mod in zip(self.tasks, self.program.modules):
            task.gen = mod.fn()
            start = self.graph.add_node(task.mid, NodeKind.START, 0)
            task.last_node = start.idx
            task.last_node_time = 0

        live = len(self.tasks)
        ready: List[_Task] = list(self.tasks)
        while True:
            if ready:
                if self._rng is not None:
                    self._rng.shuffle(ready)
                for task in ready:
                    if task.state is TaskState.READY:
                        self._run_until_pause(task)
                ready = []
            # collect O(1) wakeups of blocked B-ops before quiescence logic
            if self._wakeups:
                for task in self._wakeups:
                    if task.state in (TaskState.PAUSED_READ,
                                      TaskState.PAUSED_WRITE):
                        op = task.pending_op
                        task.pending_op = None
                        task.state = TaskState.READY
                        okk = (self._exec_read(task, op)
                               if isinstance(op, Read)
                               else self._exec_write(task, op))
                        assert okk
                        ready.append(task)
                self._wakeups = []
                if ready:
                    continue
            # ---- quiescence ----
            self.stats.quiescence_rounds += 1
            if self._n_done == len(self.tasks):
                break
            progressed = self._resume_blocked()
            progressed |= self._resolve_queries()
            if not progressed and self.query_pool:
                self._force_earliest()
                progressed = True
            if progressed:
                # every task made READY since the last drain was appended to
                # _ready_now by _resume_blocked/_resolve_one — no task scan
                ready = self._ready_now
                self._ready_now = []
                continue
            # true design-level deadlock
            self.deadlock = True
            self.deadlock_cycle = self._current_horizon()
            blocked = [t.name for t in self.tasks if t.state is not TaskState.DONE]
            result = self._finish()
            result.deadlock = True
            result.deadlock_cycle = self.deadlock_cycle
            result.outputs["__deadlock__"] = blocked
            return result

        return self._finish()

    def _current_horizon(self) -> int:
        """Latest known cycle (committed nodes + live task clocks) — the
        stall cycle reported on deadlock (paper Sec. 7.1)."""
        h = 0
        for n in self.graph.nodes:
            if n.time > h:
                h = n.time
        for t in self.tasks:
            if t.state is not TaskState.DONE and t.clock > h:
                h = t.clock
        return h

    # ----------------------------------------------------------- task driving
    def _run_until_pause(self, task: _Task) -> None:
        """Resume ``task``'s generator and execute ops until it pauses
        (query/blocked access) or terminates.  This per-op dispatch is the
        generator path's hot loop — the cost the trace-compiled replay
        (``core/trace.py``) eliminates for blocking-only designs."""
        self.stats.resumes += 1
        while True:
            self._steps += 1
            if self._steps > self._max_steps:
                raise RuntimeError(
                    f"step budget exceeded ({self._max_steps}); possible "
                    f"livelock — neither OmniSim nor co-sim detects livelock")
            try:
                if not task.started:
                    task.started = True
                    op = next(task.gen)
                else:
                    op = task.gen.send(task.send_value)
                task.send_value = None
            except StopIteration:
                self._new_node(task, NodeKind.END, task.clock)
                task.state = TaskState.DONE
                self._n_done += 1
                return
            if not self._exec_op(task, op):
                return  # paused

    def _exec_op(self, task: _Task, op: Op) -> bool:
        """Execute one op; returns True if the task may continue."""
        if isinstance(op, Delay):
            task.clock += op.cycles
            task.send_value = None
            return True
        if isinstance(op, Emit):
            self.outputs[op.key] = op.value
            task.send_value = None
            return True
        if isinstance(op, Read):
            return self._exec_read(task, op)
        if isinstance(op, Write):
            return self._exec_write(task, op)
        if isinstance(op, (ReadNB, WriteNB, Empty, Full)):
            return self._exec_query_op(task, op)
        raise TypeError(f"unknown op {op!r}")

    def _exec_read(self, task: _Task, op: Read) -> bool:
        tbl = self.fifos[op.fifo.fid]
        self._check_endpoint(op.fifo.fid, task.mid, "r")
        r = tbl.n_reads + 1
        wt = tbl.earliest_write_time(r)
        if wt is None:
            task.state = TaskState.PAUSED_READ
            task.pending_op = op
            self._waiting_reader[op.fifo.fid] = task
            return False
        u = max(task.clock, wt + 1)
        node = self._new_node(task, NodeKind.FIFO_READ, u, op.fifo.fid, r,
                              issue=task.clock)
        self._add_raw_edge(node, int(tbl.writes[r - 1]), 1)
        task.send_value = tbl.commit_read(node.idx, u)
        task.clock = u + 1
        self._wake(self._waiting_writer, op.fifo.fid)
        return True

    def _exec_write(self, task: _Task, op: Write) -> bool:
        tbl = self.fifos[op.fifo.fid]
        self._check_endpoint(op.fifo.fid, task.mid, "w")
        w = tbl.n_writes + 1
        tgt = tbl.write_target_read(w)
        if tgt is None:
            u = task.clock
            node = self._new_node(task, NodeKind.FIFO_WRITE, u, op.fifo.fid, w)
            tbl.commit_write(node.idx, u, op.value)
        else:
            rt = tbl.earliest_read_time(tgt)
            if rt is None:
                task.state = TaskState.PAUSED_WRITE
                task.pending_op = op
                self._waiting_writer[op.fifo.fid] = task
                return False
            u = max(task.clock, rt + 1)
            node = self._new_node(task, NodeKind.FIFO_WRITE, u, op.fifo.fid, w,
                                  issue=task.clock)
            src = int(tbl.reads[tgt])
            self._add_war_edge(node, src, 1)
            self._war_edges.append((node.idx, src, op.fifo.fid, w))
            tbl.commit_write(node.idx, u, op.value)
        task.send_value = None
        task.clock = u + 1
        self._maybe_wake_readers(op.fifo.fid)
        return True

    # ------------------------------------------------------------ NB / probes
    def _exec_query_op(self, task: _Task, op: Op) -> bool:
        tbl = self.fifos[op.fifo.fid]
        t = task.clock
        # dead-query elimination (paper Sec. 7.3.2): probe result unused.
        if isinstance(op, (Empty, Full)) and not op.used:
            self.stats.skipped_probes += 1
            task.clock = t + 1
            task.send_value = None
            return True
        if isinstance(op, (ReadNB, Empty)):
            rtype = (RequestType.FIFO_NB_READ if isinstance(op, ReadNB)
                     else RequestType.FIFO_CAN_READ)
            self._check_endpoint(op.fifo.fid, task.mid, "r")
            seq = tbl.n_reads + 1
            verdict = tbl.can_read_at(seq, t)
        else:
            rtype = (RequestType.FIFO_NB_WRITE if isinstance(op, WriteNB)
                     else RequestType.FIFO_CAN_WRITE)
            self._check_endpoint(op.fifo.fid, task.mid, "w")
            seq = tbl.n_writes + 1
            verdict = tbl.can_write_at(seq, t)
        self.stats.queries += 1
        if verdict is None:
            # ❸ pause on an unresolvable query
            self._qid += 1
            q = Query(self._qid, task.mid, rtype, op.fifo.fid, seq, t,
                      payload=getattr(op, "value", None))
            task.state = TaskState.PAUSED_QUERY
            task.pending_op = op
            task.pending_query = q
            heapq.heappush(self.query_pool, (q.source_time, q.qid, q))
            return False
        self._apply_query_result(task, op, rtype, seq, t, bool(verdict))
        return True

    def _apply_query_result(self, task: _Task, op: Op, rtype: RequestType,
                            seq: int, t: int, ok: bool) -> None:
        tbl = self.fifos[op.fifo.fid]
        if isinstance(op, ReadNB):
            if ok:
                node = self._new_node(task, NodeKind.FIFO_READ, t, op.fifo.fid, seq)
                # constraint edge only — NB ops never stall (DESIGN.md Sec. 2)
                value = tbl.commit_read(node.idx, t)
                task.send_value = (True, value)
                src_node = node.idx
                self._wake(self._waiting_writer, op.fifo.fid)
            else:
                node = self._new_node(task, NodeKind.NB_FAIL, t, op.fifo.fid, seq)
                task.send_value = (False, None)
                src_node = node.idx
        elif isinstance(op, WriteNB):
            if ok:
                node = self._new_node(task, NodeKind.FIFO_WRITE, t, op.fifo.fid, seq)
                tbl.commit_write(node.idx, t, op.value)
                self._maybe_wake_readers(op.fifo.fid)
                task.send_value = True
                src_node = node.idx
            else:
                node = self._new_node(task, NodeKind.NB_FAIL, t, op.fifo.fid, seq)
                task.send_value = False
                src_node = node.idx
        else:  # Empty / Full probes
            node = self._new_node(task, NodeKind.PROBE, t, op.fifo.fid, seq)
            src_node = node.idx
            if isinstance(op, Empty):
                task.send_value = not ok       # can_read == not empty
            else:
                task.send_value = not ok       # can_write == not full
        self.constraints.append(
            Constraint(rtype, op.fifo.fid, seq, src_node, ok))
        task.clock = t + 1
        task.pending_op = None
        task.pending_query = None

    # --------------------------------------------------------- quiescence ops
    def _resume_blocked(self) -> bool:
        """At quiescence, retry every blocked blocking access whose target
        event has since committed; True if any task progressed.

        Iterates the waiting tables, not all tasks: every PAUSED_READ /
        PAUSED_WRITE task registers itself in ``_waiting_reader`` /
        ``_waiting_writer`` when it blocks, and ``_wake`` pops entries it
        hands to the wakeup queue — so the tables are exactly the blocked
        set, keyed by FIFO (unique per side under SPSC).  At 1000 modules
        this turns the per-round cost from O(tasks) into O(blocked)."""
        progressed = False
        for fid, task in list(self._waiting_reader.items()):
            if task.state is not TaskState.PAUSED_READ:
                continue                     # already queued by _wake
            tbl = self.fifos[fid]
            if tbl.earliest_write_time(tbl.n_reads + 1) is not None:
                self._waiting_reader.pop(fid, None)
                op = task.pending_op
                task.pending_op = None
                task.state = TaskState.READY
                ok = self._exec_read(task, op)
                assert ok
                self._ready_now.append(task)
                progressed = True
        for fid, task in list(self._waiting_writer.items()):
            if (task.state is not TaskState.PAUSED_WRITE
                    or self._waiting_writer.get(fid) is not task):
                continue
            tbl = self.fifos[fid]
            tgt = tbl.write_target_read(tbl.n_writes + 1)
            if tgt is None or tbl.earliest_read_time(tgt) is not None:
                self._waiting_writer.pop(fid, None)
                op = task.pending_op
                task.pending_op = None
                task.state = TaskState.READY
                ok = self._exec_write(task, op)
                assert ok
                self._ready_now.append(task)
                progressed = True
        return progressed

    def _wake(self, table: Dict[int, "_Task"], fid: int) -> None:
        task = table.pop(fid, None)
        if task is not None:
            self._wakeups.append(task)

    def _maybe_wake_readers(self, fid: int) -> None:
        self._wake(self._waiting_reader, fid)

    def _resolve_queries(self) -> bool:
        """❹ resolve all currently-definitive queries, earliest-first."""
        progressed = False
        remaining: List[Tuple[int, int, Query]] = []
        while self.query_pool:
            entry = heapq.heappop(self.query_pool)
            q = entry[2]
            tbl = self.fifos[q.fifo]
            if q.rtype in (RequestType.FIFO_NB_READ, RequestType.FIFO_CAN_READ):
                verdict = tbl.can_read_at(q.source_seq, q.source_time)
            else:
                verdict = tbl.can_write_at(q.source_seq, q.source_time)
            if verdict is None:
                remaining.append(entry)
                continue
            self._resolve_one(q, bool(verdict))
            progressed = True
        # drained in heap order, so ``remaining`` is sorted — already a valid
        # min-heap, no heapify needed
        self.query_pool = remaining
        return progressed

    def _force_earliest(self) -> None:
        """Earliest-query rule (paper Sec. 7.1, second challenge).

        Soundness: at this point every task is paused and no query/blocked
        access is definitive.  Any still-uncommitted event can only commit
        after some paused task resumes; resumptions (including this forced
        one) happen at cycles >= the earliest query's cycle t_q, hence every
        future commit has cycle >= t_q and cannot satisfy a strictly-before
        t_q comparison — the earliest query resolves *false*.
        """
        q = heapq.heappop(self.query_pool)[2]
        self.stats.queries_forced_false += 1
        self._resolve_one(q, False)

    def _resolve_one(self, q: Query, ok: bool) -> None:
        task = self.tasks[q.module]
        assert task.state is TaskState.PAUSED_QUERY and task.pending_query is q
        op = task.pending_op
        task.state = TaskState.READY
        self._apply_query_result(task, op, q.rtype, q.source_seq,
                                 q.source_time, ok)
        self._ready_now.append(task)

    # ------------------------------------------------------------- finalize
    def _finish(self) -> SimResult:
        # Finalization. The from-scratch longest-path verification is opt-in
        # (tests enable it); production runs trust the eagerly maintained
        # times — rebuilding CSR per run dominated small-design wall time
        # (engine perf iteration 3, see EXPERIMENTS.md §Perf).
        if self._verify_finalization and not self.deadlock:
            indptr, src, wgt, base = self.graph.to_csr()
            times = longest_path_numpy(indptr, src, wgt, base)
            eager = self.graph.times()
            if not np.array_equal(times, eager):
                bad = int(np.flatnonzero(times != eager)[0])
                raise AssertionError(
                    f"finalization mismatch at node {bad}: "
                    f"recomputed {times[bad]} vs eager {eager[bad]}")
        cycles = 0
        for node in self.graph.nodes:
            if node.time > cycles:
                cycles = node.time
        self.stats.edges = self.graph.n_edges
        return SimResult(
            program=self.program.name,
            outputs=dict(self.outputs),
            cycles=cycles,
            engine="omnisim",
            stats=self.stats,
            graph=self,
            constraints=list(self.constraints),
            depths=self.program.depths(),
        )


def simulate(program: Program, depths=None, shuffle_seed: Optional[int] = None,
             max_steps: int = 50_000_000, trace: str = "auto",
             hybrid_cache=None, periodize: bool = True) -> SimResult:
    """Run the OmniSim engine on ``program`` (optionally overriding depths).

    ``trace`` selects the initial-simulation strategy:

      * ``"auto"`` (default) — try the straight-line trace-compiled replay
        (``core/trace.py``: generators entered once, op arrays replayed by
        vectorized dispatch); when the design's control flow is
        cycle-dependent (live NB accesses / status probes), drop to the
        *hybrid* segmented replay (``trace.simulate_hybrid``: blocking
        segments compiled to flat arrays, generator protocol only at the
        query points); fall back to the generator engine only when even the
        hybrid path must defer (true deadlocks, SPSC violations — the
        generator engine produces the paper-exact report).  Results are
        identical on every path (tests pin equality).
      * ``"always"`` — compiled replay (straight-line or hybrid) or raise
        :class:`~repro.core.trace.TraceUnsupported`.
      * ``"never"`` — generator engine only (the semantics reference; also
        used with ``shuffle_seed`` to exercise scheduling independence).

    ``hybrid_cache`` (a :class:`~repro.core.trace.HybridCache`) memoizes
    module yield streams across repeated simulations of the same design
    shape — ``classify_dynamic`` threads one through its perturbed-depth
    probe runs so unchanged modules replay without re-running their
    generators (validated cached segments replay array-at-a-time, so the
    probe runs are near-free).  ``periodize`` (default True) enables the
    hybrid path's steady-state query periodization — fixed poll loops
    resolve their definitively-false outcomes in bulk against the
    committed FIFO tables (``SimStats.queries_periodized`` counts them) —
    and only affects speed, never results.

    A non-``None`` ``shuffle_seed`` implies the generator path: the point
    of shuffling is to randomize actual task servicing order, which the
    schedule-free replay has no analogue of (``trace="always"`` plus a
    seed is contradictory and raises ``ValueError``).

    Module bodies must be *re-runnable*: ``mod.fn()`` may be invoked more
    than once per Program (an aborted trace recording falls back to the
    hybrid/generator paths, and the incremental/DSE fallbacks re-simulate
    from scratch), so bodies must not mutate shared closure state or
    perform external side effects — the same purity the DSL has always
    required of ``resimulate``'s fallback path.
    """
    if trace not in ("auto", "always", "never"):
        raise ValueError(f"trace must be 'auto'|'always'|'never', got {trace!r}")
    if trace == "always" and shuffle_seed is not None:
        raise ValueError("trace='always' is incompatible with shuffle_seed: "
                         "the schedule-free replay has no servicing order "
                         "to shuffle")
    if depths is not None:
        program.with_depths(depths)
    if trace != "never" and shuffle_seed is None:
        from . import trace as _trace
        try:
            return _trace.simulate_traced(program, max_steps=max_steps)
        except _trace.TraceUnsupported as exc:
            if exc.dynamic:
                try:
                    return _trace.simulate_hybrid(program, max_steps=max_steps,
                                                  cache=hybrid_cache,
                                                  periodize=periodize)
                except _trace.TraceUnsupported:
                    if trace == "always":
                        raise        # the hybrid verdict is the precise one
            elif trace == "always":
                raise
    return OmniSim(program, shuffle_seed=shuffle_seed, max_steps=max_steps).run()
