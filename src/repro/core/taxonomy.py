"""Dataflow-design taxonomy (paper Sec. 3, Figs. 3-4).

Classification is by three defining features:

  * **module dependency** — acyclic vs. cyclic (derived from the FIFO
    endpoint graph observed during simulation);
  * **dataflow type** — blocking-only vs. non-blocking present;
  * **program behaviors** — whether the outcome of an NB access can alter
    subsequent behavior.  This is a *semantic* property (undecidable in
    general); designs declare it, and we *validate* the declaration
    dynamically by flipping each NB outcome class and checking divergence
    where cheap (`validate=True`).

Mapping to simulation-requirement levels (paper Fig. 3):

  Type A → Func L1 / Perf L1 : sequential single-pass simulation suffices.
  Type B → Func L2 / Perf L3 : concurrency-dependent functionality,
                                cycle-dependent performance.
  Type C → Func L3 / Perf L3 : functionality itself cycle-dependent.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Set, Tuple

from .engine import simulate
from .events import NodeKind
from .program import Program


@dataclass
class Classification:
    dtype: str                  # "A" | "B" | "C"
    cyclic: bool
    has_nonblocking: bool
    func_sim_level: int
    perf_sim_level: int
    modules: int
    fifos: int
    declared: Optional[str]

    def __str__(self) -> str:
        return (f"Type {self.dtype} (cyclic={self.cyclic}, "
                f"NB={self.has_nonblocking}, Func L{self.func_sim_level}, "
                f"Perf L{self.perf_sim_level})")


def _module_graph_cyclic(endpoints: Dict[int, Tuple[Set[int], Set[int]]]) -> bool:
    """endpoints: fifo -> (writer mids, reader mids). Cycle in module DAG?"""
    adj: Dict[int, Set[int]] = {}
    for (ws, rs) in endpoints.values():
        for w in ws:
            adj.setdefault(w, set()).update(rs)
    WHITE, GREY, BLACK = 0, 1, 2
    color: Dict[int, int] = {}

    def dfs(u: int) -> bool:
        color[u] = GREY
        for v in adj.get(u, ()):
            c = color.get(v, WHITE)
            if c == GREY:
                return True
            if c == WHITE and dfs(v):
                return True
        color[u] = BLACK
        return False

    return any(dfs(u) for u in list(adj) if color.get(u, WHITE) == WHITE)


def classify_dynamic(builder, n_variants: int = 4,
                     cache=None) -> Classification:
    """Classification with *dynamic divergence validation*.

    The B-vs-C boundary is semantic ("does an NB outcome alter behavior?"),
    undecidable statically.  We probe it empirically: re-simulate under
    perturbed FIFO depths (halved / doubled / +1 / deep).  Any functional
    output divergence is a definitive WITNESS of cycle-dependent
    functionality => Type C.  Absence of a witness is NOT a Type B proof
    (e.g. fig2_timer's outputs happen to be depth-invariant although its
    timer value is cycle-dependent) — without a witness the declared /
    conservative static classification stands.

    ``builder`` is a zero-arg callable returning a fresh Program (generators
    are single-use).  All probe runs share one
    :class:`~repro.core.trace.HybridCache` (pass ``cache`` to supply your
    own and inspect its hit/switch/divergence counters afterwards), so
    dynamic designs replay their memoized module streams across the depth
    variants — validated cached segments replay array-at-a-time, making the
    probe runs near-free — and only re-run generators past genuine
    control-flow divergences (the witnesses this probe is hunting for).
    """
    from .trace import HybridCache
    if cache is None:
        cache = HybridCache()
    base_prog = builder()
    base = simulate(base_prog, hybrid_cache=cache)
    c = classify(base_prog, base)
    if not c.has_nonblocking:
        return c                   # blocking-only cannot be Type C
    depths0 = base.depths
    variants = [
        tuple(max(1, d // 2) for d in depths0),
        tuple(2 * d for d in depths0),
        tuple(d + 1 for d in depths0),
        tuple(d + 64 for d in depths0),
    ][:n_variants]
    divergent = False
    for dv in variants:
        r = simulate(builder(), depths=dv, hybrid_cache=cache)
        if r.outputs != base.outputs or r.deadlock != base.deadlock:
            divergent = True
            break
    if not divergent:
        return c                   # no witness: static/declared type stands
    return Classification(dtype="C", cyclic=c.cyclic, has_nonblocking=True,
                          func_sim_level=3, perf_sim_level=3,
                          modules=c.modules, fifos=c.fifos,
                          declared=c.declared)


def classify(program: Program, sim_result=None) -> Classification:
    """Classify a design; runs the engine once if no result is supplied."""
    if sim_result is None:
        sim_result = simulate(program)
    engine = sim_result.graph
    endpoints: Dict[int, Tuple[Set[int], Set[int]]] = {
        f.fid: (set(), set()) for f in program.fifos}
    has_nb = False
    for node in engine.graph.nodes:
        if node.fifo < 0:
            continue
        if node.kind in (NodeKind.FIFO_WRITE,):
            endpoints[node.fifo][0].add(node.module)
        elif node.kind in (NodeKind.FIFO_READ,):
            endpoints[node.fifo][1].add(node.module)
        if node.kind in (NodeKind.NB_FAIL, NodeKind.PROBE):
            has_nb = True
    # NB also if any successful NB access occurred: count constraints
    has_nb = has_nb or bool(sim_result.constraints)
    cyclic = _module_graph_cyclic(endpoints)

    declared = program.declared_type
    if not has_nb and not cyclic:
        dtype = "A"
    elif declared == "C":
        dtype = "C"
    elif declared in ("A", "B"):
        dtype = "B" if (has_nb or cyclic) else "A"
    else:
        # undeclared: conservatively Type C when NB present (divergence
        # cannot be ruled out), else Type B (cyclic blocking-only)
        dtype = "C" if has_nb else "B"
    levels = {"A": (1, 1), "B": (2, 3), "C": (3, 3)}
    fl, pl = levels[dtype]
    return Classification(dtype=dtype, cyclic=cyclic, has_nonblocking=has_nb,
                          func_sim_level=fl, perf_sim_level=pl,
                          modules=len(program.modules),
                          fifos=len(program.fifos), declared=declared)
