"""The (partial) simulation graph and its finalization pass.

Construction uses an adjacency list with edges stored *alongside* each node
(paper Sec. 7.3.1) so the orchestrator can traverse the incomplete graph
zero-copy while resolving queries.  Finalization — computing every node's
hardware cycle as the longest path from the virtual start — exploits the
invariant that **node creation order is a topological order** (a node's
predecessors always exist before it; see DESIGN.md Sec. 2), so a single
forward pass suffices.

Three longest-path backends:

  * ``longest_path_numpy`` — vectorized CSR forward pass over levels
    (production path on CPU; reference for the others).
  * ``repro.kernels.maxplus`` — Pallas TPU kernel: blocked dense max-plus
    relaxation with VMEM tiling (the TPU analogue of LightningSimV2's
    compiled CSR graph).  Used for device-resident incremental re-sim.
  * ``longest_path_python`` — straight-line oracle used in tests.
"""
from __future__ import annotations

from typing import List, NamedTuple, Sequence, Tuple

import numpy as np

from .events import Node, NodeKind


class SimGraph:
    """Append-only adjacency-list simulation graph."""

    def __init__(self) -> None:
        self.nodes: List[Node] = []

    # -- construction ----------------------------------------------------------
    def add_node(self, module: int, kind: NodeKind, time: int,
                 fifo: int = -1, seq: int = -1) -> Node:
        n = Node(idx=len(self.nodes), module=module, kind=kind, time=time,
                 fifo=fifo, seq=seq)
        self.nodes.append(n)
        return n

    @property
    def n_nodes(self) -> int:
        return len(self.nodes)

    @property
    def n_edges(self) -> int:
        return sum(len(n.preds) for n in self.nodes)

    # -- export -----------------------------------------------------------------
    def to_csr(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """CSR by *destination*: (indptr, src, weight, base).

        ``base[i]`` is the node's schedule-intrinsic earliest time (its
        recorded time is max(base, preds)); for reconstruction we only need
        edges + base because times were computed eagerly: base is derived as
        the recorded time when the node has no preds, else 0 (edges carry the
        stall structure; intra-module sequencing is itself an edge).
        """
        n = len(self.nodes)
        indptr = np.zeros(n + 1, dtype=np.int64)
        for i, node in enumerate(self.nodes):
            indptr[i + 1] = indptr[i] + len(node.preds)
        m = int(indptr[-1])
        src = np.zeros(m, dtype=np.int64)
        wgt = np.zeros(m, dtype=np.int64)
        base = np.zeros(n, dtype=np.int64)
        k = 0
        for i, node in enumerate(self.nodes):
            if not node.preds:
                base[i] = node.time
            for (s, w) in node.preds:
                src[k] = s
                wgt[k] = w
                k += 1
        return indptr, src, wgt, base

    def times(self) -> np.ndarray:
        return np.array([n.time for n in self.nodes], dtype=np.int64)


# ------------------------------------------------------------------------------
# Longest-path backends
# ------------------------------------------------------------------------------
def longest_path_python(indptr: np.ndarray, src: np.ndarray, wgt: np.ndarray,
                        base: np.ndarray) -> np.ndarray:
    """O(V+E) forward pass in creation (= topological) order."""
    n = len(base)
    t = base.astype(np.int64).copy()
    for i in range(n):
        lo, hi = indptr[i], indptr[i + 1]
        for k in range(lo, hi):
            cand = t[src[k]] + wgt[k]
            if cand > t[i]:
                t[i] = cand
    return t


def level_schedule(indptr: np.ndarray, src: np.ndarray) -> Tuple[np.ndarray, List[np.ndarray]]:
    """Group nodes into levels where level(i) = 1 + max(level(preds)).

    Nodes within a level have no edges among themselves, so each level can be
    relaxed fully in parallel (level-synchronous max-plus) — this is the
    parallel structure the Pallas kernel and the vectorized numpy backend use.

    Node numbering need NOT be topological (the decoupled baseline's traces
    are not); a Kahn pass computes levels for any DAG and raises on cycles.
    """
    n = len(indptr) - 1
    if n == 0:
        return np.zeros(0, dtype=np.int64), []
    indeg = np.diff(indptr).astype(np.int64)
    # out-adjacency (CSR by source) — fully vectorized Kahn below: each wave
    # gathers all frontier out-edges with the offset trick, bumps target
    # levels with maximum.at, and decrements indegrees with bincount.
    dst = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    order = np.argsort(src, kind="stable")
    out_dst = dst[order]
    out_counts = np.bincount(src, minlength=n)
    out_indptr = np.concatenate([[0], np.cumsum(out_counts)]).astype(np.int64)

    level = np.zeros(n, dtype=np.int64)
    frontier = np.flatnonzero(indeg == 0)
    levels: List[np.ndarray] = []
    done = 0
    while len(frontier):
        levels.append(frontier)
        done += len(frontier)
        starts = out_indptr[frontier]
        counts = (out_indptr[frontier + 1] - starts)
        total = int(counts.sum())
        if total == 0:
            break
        offs = np.repeat(starts - np.concatenate(
            [[0], np.cumsum(counts)[:-1]]), counts)
        idx = np.arange(total, dtype=np.int64) + offs
        targets = out_dst[idx]
        lvl_edge = np.repeat(level[frontier] + 1, counts)
        np.maximum.at(level, targets, lvl_edge)
        dec = np.bincount(targets, minlength=n)
        indeg -= dec
        frontier = np.flatnonzero((indeg == 0) & (dec > 0))
    if done != n:
        raise ValueError("simulation graph contains a cycle")
    return level, levels


def longest_path_numpy(indptr: np.ndarray, src: np.ndarray, wgt: np.ndarray,
                       base: np.ndarray,
                       levels: Sequence[np.ndarray] = None) -> np.ndarray:
    """Vectorized level-synchronous forward pass."""
    n = len(base)
    t = base.astype(np.int64).copy()
    if levels is None:
        _, levels = level_schedule(indptr, src)
    for nodes in levels:
        # gather all incoming edges of this level's nodes at once
        starts = indptr[nodes]
        counts = (indptr[nodes + 1] - starts).astype(np.int64)
        total = int(counts.sum())
        if total == 0:
            continue
        offs = np.repeat(starts - np.concatenate(
            [[0], np.cumsum(counts)[:-1]]), counts)
        edge_idx = np.arange(total, dtype=np.int64) + offs
        owner = np.repeat(np.arange(len(nodes)), counts)
        cand = t[src[edge_idx]] + wgt[edge_idx]
        upd = t[nodes].copy()
        np.maximum.at(upd, owner, cand)
        t[nodes] = upd
    return t


def longest_path_chains(chains, seq_w, base, cross_dst, cross_src, cross_w,
                        max_iters: int = 0):
    """Chain-decomposed longest path (vectorized fixpoint).

    The simulation graph is a set of per-module *chains* (SEQ edges with
    additive weights) plus sparse cross-module edges (RAW/WAR).  Within a
    chain, t[i] = CW[i] + cummax(c[i] - CW[i]) where CW is the cumulative
    SEQ weight and c[i] the best cross/base contribution — a single
    ``np.maximum.accumulate``.  Cross contributions are a vectorized
    segment-max.  Iterating the two to fixpoint needs only as many rounds
    as the longest cross-edge chain (module hops), not the graph diameter —
    the decisive speedup for incremental re-simulation on deep pipelines.

    chains: list of node-id arrays in chain order; seq_w[i]: SEQ weight into
    node i (0 for chain heads); base[i]: source contribution.
    """
    n = len(base)
    NEGI = np.int64(-(1 << 60))
    c = base.astype(np.int64).copy()
    # precompute per-chain cumulative weights
    cws = [np.cumsum(seq_w[ch]) for ch in chains]
    t = np.full(n, NEGI, dtype=np.int64)
    iters = max_iters or (n + 2)
    for _ in range(iters):
        for ch, cw in zip(chains, cws):
            t[ch] = cw + np.maximum.accumulate(c[ch] - cw)
        if len(cross_dst):
            cand = t[cross_src] + cross_w
            c_new = c.copy()
            np.maximum.at(c_new, cross_dst, cand)
        else:
            c_new = c
        if np.array_equal(c_new, c):
            break
        c = c_new
    else:
        raise ValueError("longest_path_chains did not converge (cycle?)")
    return t


def longest_path_chains_batched(chain_slices, cw, base, cross_dst, cross_src,
                                cross_w, dyn_dst, dyn_src_idx, dyn_valid,
                                bound: int, max_iters: int = 0):
    """Batched chain-decomposed longest path: K configs in one fixpoint.

    The depth-batched analogue of :func:`longest_path_chains` — node columns
    are permuted chain-major (``chain_slices`` index contiguous column
    ranges), so the per-chain pass is one ``np.maximum.accumulate`` over a
    ``(K, len)`` contiguous view per chain, for ALL K configs at once.

    Cross edges split into two groups:

      * static (config-independent, e.g. RAW): ``cross_dst/src/w`` — 1-D
        arrays shared across the batch;
      * dynamic (config-dependent, e.g. regenerated WAR): ``dyn_dst`` (m,)
        destination columns with per-config gather indices ``dyn_src_idx``
        (K, m) and mask ``dyn_valid`` (K, m); weight is 1 (FIFO hold time).

    Destination columns must be UNIQUE within and across the two groups
    (each read node has exactly one RAW in-edge, each write node at most one
    WAR in-edge per config), so the scatter-max is a plain fancy-indexed
    ``np.maximum`` — no ``np.maximum.at`` buffering.

    ``base`` is the (K, n) initial contribution matrix (consumed in place).
    Rows converge independently: converged rows are retired from the working
    set each round, so one pathological config (a WAR cycle grows its times
    past ``bound``) does not tax the others.  Returns ``(times, converged,
    rounds)`` — times (K, n); ``converged[k]`` False means config k's
    regenerated edges formed a cycle (times for that row are meaningless).
    """
    K, n = base.shape
    times = np.empty_like(base)
    converged = np.zeros(K, dtype=bool)
    if n == 0 or K == 0:
        converged[:] = True
        return times, converged, 0
    iters = max_iters or (n + 2)
    act = np.arange(K)                      # rows still iterating
    c = base                                # (K_act, n) working contributions
    t = np.empty_like(c)
    have_dyn = len(dyn_dst) > 0
    dyn_src_act = dyn_src_idx if have_dyn else None
    dyn_valid_act = dyn_valid if have_dyn else None
    rounds = 0
    while len(act):
        rounds += 1
        # ---- chain pass: t = cw + cummax(c - cw) per contiguous chain ----
        for (lo, hi) in chain_slices:
            seg = c[:, lo:hi] - cw[lo:hi]
            np.maximum.accumulate(seg, axis=1, out=seg)
            seg += cw[lo:hi]
            t[:, lo:hi] = seg
        if rounds > iters:
            break                           # leftover rows: cycle
        # ---- cross pass: unique-dst scatter-max into c ----
        changed = np.zeros(len(act), dtype=bool)
        if len(cross_dst):
            cand = t[:, cross_src] + cross_w
            old = c[:, cross_dst]
            np.maximum(cand, old, out=cand)
            changed |= (cand != old).any(axis=1)
            c[:, cross_dst] = cand
        if have_dyn:
            cand = np.take_along_axis(t, dyn_src_act, axis=1)
            cand += 1
            old = c[:, dyn_dst]
            # masked candidates: invalid (w <= S, NB, or no target) entries
            # must not contribute
            cand = np.where(dyn_valid_act, cand, old)
            np.maximum(cand, old, out=cand)
            changed |= (cand != old).any(axis=1)
            c[:, dyn_dst] = cand
        # ---- retire rows: fixpoint reached or blown past the DAG bound ----
        over = (t > bound).any(axis=1)      # positive cycle: early exit
        done = ~changed | over
        if done.any():
            rows = act[done]
            times[rows] = t[done]
            converged[rows] = ~over[done]
            keep = ~done
            act = act[keep]
            c = c[keep]
            t = t[keep]
            if have_dyn:
                dyn_src_act = dyn_src_act[keep]
                dyn_valid_act = dyn_valid_act[keep]
    if len(act):                            # hit the iteration cap: cycles
        times[act] = t
    return times, converged, rounds


class ChainFlatArrays(NamedTuple):
    """Flat chain-major export of the batched solver's graph view.

    The device-side (sparse Pallas) analogue of the argument list of
    :func:`longest_path_chains_batched`: every array is chain-major and
    ``int32`` (the transfer format of ``repro.kernels.maxplus.sparse``),
    padded on the node axis to a ``lanes`` multiple so VPU tiles are
    hardware-aligned.  Columns ``n..npad`` are inert: each is its own
    one-element segment seeded at the -INF sentinel, so the segmented
    cummax never leaks across them and no edge targets them.

    The WAR tables are the *config-independent* half of WAR regeneration:
    one row per blocking write of every FIFO that has at least one read
    (a blocking overflow with no reads is a structural deadlock, masked
    before solving).  The config-dependent half — which read each write
    waits on under depth ``S`` (``tgt = wseq - S - 1``) — is computed
    on-device from these tables plus the depth block.
    """

    n: int                    # real node count (columns 0..n are live)
    npad: int                 # padded node-axis length (lanes multiple)
    cw: np.ndarray            # (npad,) cumulative SEQ weights, 0 in padding
    seg_start: np.ndarray     # (npad,) chain-start column of each column
    c_seed: np.ndarray        # (npad,) seed contribution (NEG sentinel pad)
    raw_dst: np.ndarray       # (E,) static RAW edges, chain-major columns
    raw_src: np.ndarray       # (E,)
    raw_w: np.ndarray         # (E,)
    war_dst: np.ndarray       # (m,) blocking-write columns (unique)
    war_wseq: np.ndarray      # (m,) 1-based write sequence numbers
    war_fid: np.ndarray       # (m,) owning FIFO (column of the depth row)
    war_nr: np.ndarray        # (m,) reads of that FIFO
    war_roff: np.ndarray      # (m,) offset of that FIFO's reads in war_rcols
    war_rcols: np.ndarray     # (R,) concatenated read columns, FIFO-major
    bound: int                # upper bound on any acyclic path length
    max_seg: int = 1          # longest chain (caps the scan's doubling steps)


def export_chain_flat(chain_slices, cw, c_seed, raw_dst, raw_src, raw_w,
                      fifo_w_cols, fifo_r_cols, fifo_blocking, bound: int,
                      neg: int, lanes: int = 128) -> ChainFlatArrays:
    """Build the :class:`ChainFlatArrays` transfer view of a chain-major
    graph (``neg`` is the int32 -INF sentinel everything is clipped to)."""
    n = len(cw)
    npad = max(((n + lanes - 1) // lanes) * lanes, lanes)
    seg = np.arange(npad, dtype=np.int32)      # padding: isolated segments
    for (lo, hi) in chain_slices:
        seg[lo:hi] = lo
    cwp = np.zeros(npad, np.int32)
    cwp[:n] = np.minimum(cw, np.iinfo(np.int32).max)
    cs = np.full(npad, neg, np.int32)
    cs[:n] = np.maximum(c_seed, neg)
    wd, ws, wf, wnr, wro, rc = [], [], [], [], [], []
    roff = 0
    for fid, wcols in enumerate(fifo_w_cols):
        rcols = fifo_r_cols[fid]
        blk = fifo_blocking[fid]
        if len(wcols) == 0 or len(rcols) == 0 or not blk.any():
            continue
        keep = np.flatnonzero(blk)             # only blocking writes can WAR
        wd.append(wcols[keep])
        ws.append(keep + 1)                    # 1-based write sequence
        wf.append(np.full(len(keep), fid, np.int64))
        wnr.append(np.full(len(keep), len(rcols), np.int64))
        wro.append(np.full(len(keep), roff, np.int64))
        rc.append(rcols)
        roff += len(rcols)

    def cat(parts):
        return (np.concatenate(parts).astype(np.int32) if parts
                else np.zeros(0, np.int32))

    def pad(a, m, fill):
        """Bucket array lengths to powers of two (floor 16) so solves of
        different designs reuse the device solver's jit cache; padding
        entries are inert (see the per-array fill values below)."""
        if len(a) == 0 or len(a) == m:
            return a.astype(np.int32)
        out = np.full(m, fill, np.int32)
        out[:len(a)] = a
        return out

    def bucket(k):
        m = 16
        while m < k:
            m *= 2
        return m

    E = bucket(len(raw_dst)) if len(raw_dst) else 0
    war_dst_c = cat(wd)
    m = bucket(len(war_dst_c)) if len(war_dst_c) else 0
    R = bucket(roff) if roff else 0
    return ChainFlatArrays(
        n=n, npad=npad, cw=cwp, seg_start=seg, c_seed=cs,
        # padding edges: weight = -INF (a max-identity), src/dst = 0
        raw_dst=pad(np.asarray(raw_dst), E, 0),
        raw_src=pad(np.asarray(raw_src), E, 0),
        raw_w=pad(np.maximum(raw_w, neg), E, neg),
        # padding WAR rows: wseq = 0 makes every target negative (masked);
        # nr = 1 / roff = 0 keep the clipped gather in bounds
        war_dst=pad(war_dst_c, m, 0), war_wseq=pad(cat(ws), m, 0),
        war_fid=pad(cat(wf), m, 0), war_nr=pad(cat(wnr), m, 1),
        war_roff=pad(cat(wro), m, 0), war_rcols=pad(cat(rc), R, 0),
        bound=int(bound),
        max_seg=max([hi - lo for (lo, hi) in chain_slices] or [1]))


def to_dense_blocks(indptr: np.ndarray, src: np.ndarray, wgt: np.ndarray,
                    base: np.ndarray, pad_to: int = 128):
    """Dense max-plus adjacency for the Pallas kernel (small graphs).

    Returns (A, b) with A[i, j] = weight of edge j->i or -INF, padded to a
    multiple of ``pad_to`` so MXU/VPU tiles are hardware-aligned.
    """
    n = len(base)
    npad = ((n + pad_to - 1) // pad_to) * pad_to if n else pad_to
    NEG = np.int64(-(1 << 40))
    A = np.full((npad, npad), NEG, dtype=np.int64)
    b = np.full((npad,), NEG, dtype=np.int64)
    b[:n] = base
    for i in range(n):
        lo, hi = indptr[i], indptr[i + 1]
        for k in range(lo, hi):
            A[i, src[k]] = max(A[i, src[k]], wgt[k])
    return A, b
