"""The (partial) simulation graph and its finalization pass.

Construction uses an adjacency list with edges stored *alongside* each node
(paper Sec. 7.3.1) so the orchestrator can traverse the incomplete graph
zero-copy while resolving queries.  Finalization — computing every node's
hardware cycle as the longest path from the virtual start — exploits the
invariant that **node creation order is a topological order** (a node's
predecessors always exist before it; see DESIGN.md Sec. 2), so a single
forward pass suffices.

Three longest-path backends:

  * ``longest_path_numpy`` — vectorized CSR forward pass over levels
    (production path on CPU; reference for the others).
  * ``repro.kernels.maxplus`` — Pallas TPU kernel: blocked dense max-plus
    relaxation with VMEM tiling (the TPU analogue of LightningSimV2's
    compiled CSR graph).  Used for device-resident incremental re-sim.
  * ``longest_path_python`` — straight-line oracle used in tests.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

from .events import Node, NodeKind


class SimGraph:
    """Append-only adjacency-list simulation graph."""

    def __init__(self) -> None:
        self.nodes: List[Node] = []

    # -- construction ----------------------------------------------------------
    def add_node(self, module: int, kind: NodeKind, time: int,
                 fifo: int = -1, seq: int = -1) -> Node:
        n = Node(idx=len(self.nodes), module=module, kind=kind, time=time,
                 fifo=fifo, seq=seq)
        self.nodes.append(n)
        return n

    @property
    def n_nodes(self) -> int:
        return len(self.nodes)

    @property
    def n_edges(self) -> int:
        return sum(len(n.preds) for n in self.nodes)

    # -- export -----------------------------------------------------------------
    def to_csr(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """CSR by *destination*: (indptr, src, weight, base).

        ``base[i]`` is the node's schedule-intrinsic earliest time (its
        recorded time is max(base, preds)); for reconstruction we only need
        edges + base because times were computed eagerly: base is derived as
        the recorded time when the node has no preds, else 0 (edges carry the
        stall structure; intra-module sequencing is itself an edge).
        """
        n = len(self.nodes)
        indptr = np.zeros(n + 1, dtype=np.int64)
        for i, node in enumerate(self.nodes):
            indptr[i + 1] = indptr[i] + len(node.preds)
        m = int(indptr[-1])
        src = np.zeros(m, dtype=np.int64)
        wgt = np.zeros(m, dtype=np.int64)
        base = np.zeros(n, dtype=np.int64)
        k = 0
        for i, node in enumerate(self.nodes):
            if not node.preds:
                base[i] = node.time
            for (s, w) in node.preds:
                src[k] = s
                wgt[k] = w
                k += 1
        return indptr, src, wgt, base

    def times(self) -> np.ndarray:
        return np.array([n.time for n in self.nodes], dtype=np.int64)


# ------------------------------------------------------------------------------
# Longest-path backends
# ------------------------------------------------------------------------------
def longest_path_python(indptr: np.ndarray, src: np.ndarray, wgt: np.ndarray,
                        base: np.ndarray) -> np.ndarray:
    """O(V+E) forward pass in creation (= topological) order."""
    n = len(base)
    t = base.astype(np.int64).copy()
    for i in range(n):
        lo, hi = indptr[i], indptr[i + 1]
        for k in range(lo, hi):
            cand = t[src[k]] + wgt[k]
            if cand > t[i]:
                t[i] = cand
    return t


def level_schedule(indptr: np.ndarray, src: np.ndarray) -> Tuple[np.ndarray, List[np.ndarray]]:
    """Group nodes into levels where level(i) = 1 + max(level(preds)).

    Nodes within a level have no edges among themselves, so each level can be
    relaxed fully in parallel (level-synchronous max-plus) — this is the
    parallel structure the Pallas kernel and the vectorized numpy backend use.

    Node numbering need NOT be topological (the decoupled baseline's traces
    are not); a Kahn pass computes levels for any DAG and raises on cycles.
    """
    n = len(indptr) - 1
    if n == 0:
        return np.zeros(0, dtype=np.int64), []
    indeg = np.diff(indptr).astype(np.int64)
    # out-adjacency (CSR by source) — fully vectorized Kahn below: each wave
    # gathers all frontier out-edges with the offset trick, bumps target
    # levels with maximum.at, and decrements indegrees with bincount.
    dst = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    order = np.argsort(src, kind="stable")
    out_dst = dst[order]
    out_counts = np.bincount(src, minlength=n)
    out_indptr = np.concatenate([[0], np.cumsum(out_counts)]).astype(np.int64)

    level = np.zeros(n, dtype=np.int64)
    frontier = np.flatnonzero(indeg == 0)
    levels: List[np.ndarray] = []
    done = 0
    while len(frontier):
        levels.append(frontier)
        done += len(frontier)
        starts = out_indptr[frontier]
        counts = (out_indptr[frontier + 1] - starts)
        total = int(counts.sum())
        if total == 0:
            break
        offs = np.repeat(starts - np.concatenate(
            [[0], np.cumsum(counts)[:-1]]), counts)
        idx = np.arange(total, dtype=np.int64) + offs
        targets = out_dst[idx]
        lvl_edge = np.repeat(level[frontier] + 1, counts)
        np.maximum.at(level, targets, lvl_edge)
        dec = np.bincount(targets, minlength=n)
        indeg -= dec
        frontier = np.flatnonzero((indeg == 0) & (dec > 0))
    if done != n:
        raise ValueError("simulation graph contains a cycle")
    return level, levels


def longest_path_numpy(indptr: np.ndarray, src: np.ndarray, wgt: np.ndarray,
                       base: np.ndarray,
                       levels: Sequence[np.ndarray] = None) -> np.ndarray:
    """Vectorized level-synchronous forward pass."""
    n = len(base)
    t = base.astype(np.int64).copy()
    if levels is None:
        _, levels = level_schedule(indptr, src)
    for nodes in levels:
        # gather all incoming edges of this level's nodes at once
        starts = indptr[nodes]
        counts = (indptr[nodes + 1] - starts).astype(np.int64)
        total = int(counts.sum())
        if total == 0:
            continue
        offs = np.repeat(starts - np.concatenate(
            [[0], np.cumsum(counts)[:-1]]), counts)
        edge_idx = np.arange(total, dtype=np.int64) + offs
        owner = np.repeat(np.arange(len(nodes)), counts)
        cand = t[src[edge_idx]] + wgt[edge_idx]
        upd = t[nodes].copy()
        np.maximum.at(upd, owner, cand)
        t[nodes] = upd
    return t


def longest_path_chains(chains, seq_w, base, cross_dst, cross_src, cross_w,
                        max_iters: int = 0):
    """Chain-decomposed longest path (vectorized fixpoint).

    The simulation graph is a set of per-module *chains* (SEQ edges with
    additive weights) plus sparse cross-module edges (RAW/WAR).  Within a
    chain, t[i] = CW[i] + cummax(c[i] - CW[i]) where CW is the cumulative
    SEQ weight and c[i] the best cross/base contribution — a single
    ``np.maximum.accumulate``.  Cross contributions are a vectorized
    segment-max.  Iterating the two to fixpoint needs only as many rounds
    as the longest cross-edge chain (module hops), not the graph diameter —
    the decisive speedup for incremental re-simulation on deep pipelines.

    chains: list of node-id arrays in chain order; seq_w[i]: SEQ weight into
    node i (0 for chain heads); base[i]: source contribution.
    """
    n = len(base)
    NEGI = np.int64(-(1 << 60))
    c = base.astype(np.int64).copy()
    # precompute per-chain cumulative weights
    cws = [np.cumsum(seq_w[ch]) for ch in chains]
    t = np.full(n, NEGI, dtype=np.int64)
    iters = max_iters or (n + 2)
    for _ in range(iters):
        for ch, cw in zip(chains, cws):
            t[ch] = cw + np.maximum.accumulate(c[ch] - cw)
        if len(cross_dst):
            cand = t[cross_src] + cross_w
            c_new = c.copy()
            np.maximum.at(c_new, cross_dst, cand)
        else:
            c_new = c
        if np.array_equal(c_new, c):
            break
        c = c_new
    else:
        raise ValueError("longest_path_chains did not converge (cycle?)")
    return t


def longest_path_chains_batched(chain_slices, cw, base, cross_dst, cross_src,
                                cross_w, dyn_dst, dyn_src_idx, dyn_valid,
                                bound: int, max_iters: int = 0):
    """Batched chain-decomposed longest path: K configs in one fixpoint.

    The depth-batched analogue of :func:`longest_path_chains` — node columns
    are permuted chain-major (``chain_slices`` index contiguous column
    ranges), so the per-chain pass is one ``np.maximum.accumulate`` over a
    ``(K, len)`` contiguous view per chain, for ALL K configs at once.

    Cross edges split into two groups:

      * static (config-independent, e.g. RAW): ``cross_dst/src/w`` — 1-D
        arrays shared across the batch;
      * dynamic (config-dependent, e.g. regenerated WAR): ``dyn_dst`` (m,)
        destination columns with per-config gather indices ``dyn_src_idx``
        (K, m) and mask ``dyn_valid`` (K, m); weight is 1 (FIFO hold time).

    Destination columns must be UNIQUE within and across the two groups
    (each read node has exactly one RAW in-edge, each write node at most one
    WAR in-edge per config), so the scatter-max is a plain fancy-indexed
    ``np.maximum`` — no ``np.maximum.at`` buffering.

    ``base`` is the (K, n) initial contribution matrix (consumed in place).
    Rows converge independently: converged rows are retired from the working
    set each round, so one pathological config (a WAR cycle grows its times
    past ``bound``) does not tax the others.  Returns ``(times, converged,
    rounds)`` — times (K, n); ``converged[k]`` False means config k's
    regenerated edges formed a cycle (times for that row are meaningless).
    """
    K, n = base.shape
    times = np.empty_like(base)
    converged = np.zeros(K, dtype=bool)
    if n == 0 or K == 0:
        converged[:] = True
        return times, converged, 0
    iters = max_iters or (n + 2)
    act = np.arange(K)                      # rows still iterating
    c = base                                # (K_act, n) working contributions
    t = np.empty_like(c)
    have_dyn = len(dyn_dst) > 0
    dyn_src_act = dyn_src_idx if have_dyn else None
    dyn_valid_act = dyn_valid if have_dyn else None
    rounds = 0
    while len(act):
        rounds += 1
        # ---- chain pass: t = cw + cummax(c - cw) per contiguous chain ----
        for (lo, hi) in chain_slices:
            seg = c[:, lo:hi] - cw[lo:hi]
            np.maximum.accumulate(seg, axis=1, out=seg)
            seg += cw[lo:hi]
            t[:, lo:hi] = seg
        if rounds > iters:
            break                           # leftover rows: cycle
        # ---- cross pass: unique-dst scatter-max into c ----
        changed = np.zeros(len(act), dtype=bool)
        if len(cross_dst):
            cand = t[:, cross_src] + cross_w
            old = c[:, cross_dst]
            np.maximum(cand, old, out=cand)
            changed |= (cand != old).any(axis=1)
            c[:, cross_dst] = cand
        if have_dyn:
            cand = np.take_along_axis(t, dyn_src_act, axis=1)
            cand += 1
            old = c[:, dyn_dst]
            # masked candidates: invalid (w <= S, NB, or no target) entries
            # must not contribute
            cand = np.where(dyn_valid_act, cand, old)
            np.maximum(cand, old, out=cand)
            changed |= (cand != old).any(axis=1)
            c[:, dyn_dst] = cand
        # ---- retire rows: fixpoint reached or blown past the DAG bound ----
        over = (t > bound).any(axis=1)      # positive cycle: early exit
        done = ~changed | over
        if done.any():
            rows = act[done]
            times[rows] = t[done]
            converged[rows] = ~over[done]
            keep = ~done
            act = act[keep]
            c = c[keep]
            t = t[keep]
            if have_dyn:
                dyn_src_act = dyn_src_act[keep]
                dyn_valid_act = dyn_valid_act[keep]
    if len(act):                            # hit the iteration cap: cycles
        times[act] = t
    return times, converged, rounds


def to_dense_blocks(indptr: np.ndarray, src: np.ndarray, wgt: np.ndarray,
                    base: np.ndarray, pad_to: int = 128):
    """Dense max-plus adjacency for the Pallas kernel (small graphs).

    Returns (A, b) with A[i, j] = weight of edge j->i or -INF, padded to a
    multiple of ``pad_to`` so MXU/VPU tiles are hardware-aligned.
    """
    n = len(base)
    npad = ((n + pad_to - 1) // pad_to) * pad_to if n else pad_to
    NEG = np.int64(-(1 << 40))
    A = np.full((npad, npad), NEG, dtype=np.int64)
    b = np.full((npad,), NEG, dtype=np.int64)
    b[:n] = base
    for i in range(n):
        lo, hi = indptr[i], indptr[i + 1]
        for k in range(lo, hi):
            A[i, src[k]] = max(A[i, src[k]], wgt[k])
    return A, b
