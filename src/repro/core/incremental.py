"""Incremental re-simulation on FIFO-depth changes (paper Sec. 7.2).

Unlike the decoupled baseline — whose simulation graph is depth-independent
for Type A designs — the OmniSim graph is built *under* specific depths, so
reuse must be validated.  The paper's mechanism, reproduced here:

  1. strip the depth-dependent write-after-read (WAR) edges and regenerate
     them from the FIFO tables for the new depths;
  2. re-run Finalization (longest path) to get new node times;
  3. re-evaluate every stored *constraint* (the recorded outcome of each NB
     query / status probe, Table 2 semantics) against the new times;
  4. all constraints hold → the graph is reusable: report the new cycle
     count in microseconds;  any constraint flips → control/data flow would
     diverge → a full re-simulation is required.

Infeasibility is also detected structurally: a committed blocking write
whose (w - S')-th target read never occurred can never commit under the new
depths (deadlock), and regenerated WAR edges that create a cycle mean the
old event order cannot be replayed; both force a full re-sim.

The engine-side compiled-graph cache (:class:`CompiledGraph`, built once by
:func:`compile_graph` and stored on the engine) is the analogue of
LightningSimV2's compile-once/re-solve-many design: every later
``resimulate``/``resimulate_batch`` call over the same base run shares it —
only the WAR regeneration and the fixpoint depend on the candidate depths.
When the base run came from the trace-compiled replay (``core/trace.py``),
the cache is pre-built directly from the op trace at initial-simulation
time (``trace.to_compiled_graph``), so even the *first* incremental call
never re-interprets the Python node objects.

Units: all times are hardware cycles; ``elapsed_s`` fields are wall-clock
seconds; sequence numbers are 1-based per-FIFO event counts (Table 2).
"""
from __future__ import annotations

import time as _time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .engine import OmniSim, SEQ, RAW, WAR, simulate
from .events import RequestType
from .graph import longest_path_chains, longest_path_numpy
from .program import SimResult

NEGI = np.int64(-(1 << 60))


@dataclass
class IncrementalOutcome:
    """Verdict of one :func:`resimulate` call (paper Sec. 7.2 / Table 6).

    ``ok`` means every recorded constraint held under the new depths and
    the graph was reused; otherwise ``reason`` explains the violation and
    ``result`` is the fallback full re-simulation (or None with
    ``fallback=False``).  ``elapsed_s`` is wall-clock seconds.
    """

    ok: bool                       # constraints satisfied → graph reused
    reason: str
    elapsed_s: float
    result: Optional[SimResult]    # reused (ok) or fallback result
    violated: int = 0              # number of flipped constraint outcomes


@dataclass
class CompiledGraph:
    """Depth-independent numpy snapshot of a finished OmniSim run.

    Holds the base (SEQ + RAW) edge structure in chain-decomposed form,
    per-FIFO committed-event arrays, and the recorded constraint outcomes —
    everything incremental and batched re-simulation need, so repeated calls
    never touch the Python-object graph again.  ``batch`` is the lazily
    built chain-major-permuted view used by ``core/dse.py``.
    """

    n: int
    raw_dst: np.ndarray            # RAW cross edges (depth-independent)
    raw_src: np.ndarray
    raw_w: np.ndarray
    base: np.ndarray               # source contribution (NEGI = none)
    chains: List[np.ndarray]       # per-module node id sequences
    seq_w: np.ndarray              # SEQ weight into each node (0 at heads)
    fifos: List[Tuple[np.ndarray, np.ndarray, np.ndarray]]
    # ^ per FIFO: (write nodes, read nodes, blocking-write mask)
    c_kind: np.ndarray             # 0 = can-read, 1 = can-write
    c_fifo: np.ndarray
    c_seq: np.ndarray
    c_src: np.ndarray
    c_out: np.ndarray
    batch: Any = field(default=None, repr=False)   # built by core/dse.py


def compile_graph(engine: OmniSim) -> CompiledGraph:
    """Build (once) and return the engine's compiled-graph cache.

    Chain decomposition: per-module node sequences (SEQ edges) plus
    cross-module RAW edges; WAR edges are depth-dependent and regenerated
    per candidate depth vector.  Subsequent incremental/batched calls are
    fully vectorized against these arrays (the engine-side analogue of
    LightningSimV2's compiled-graph reuse).

    Trace-compiled runs (``core/trace.py``) install a cache built straight
    from the op arrays at initial-simulation time, so this walk over the
    Python node objects only ever happens for generator-path runs.
    """
    cached = getattr(engine, "_incr_cache", None)
    if cached is not None:
        return cached
    nodes = engine.graph.nodes
    n = len(nodes)
    dsts, srcs, wgts = [], [], []
    base_c = np.full(n, NEGI, dtype=np.int64)
    seq_w = np.zeros(n, dtype=np.int64)
    chains_map: Dict[int, List[int]] = {}
    for node in nodes:
        chains_map.setdefault(node.module, []).append(node.idx)
        if not node.preds:
            base_c[node.idx] = node.time
        for (s, w) in node.preds:
            kind = engine._edge_kinds.get((node.idx, s), SEQ)
            if kind == WAR:
                continue
            if kind == SEQ:
                seq_w[node.idx] = w
                continue
            dsts.append(node.idx)       # RAW cross edge
            srcs.append(s)
            wgts.append(w)
    chains = [np.asarray(v, np.int64) for v in chains_map.values()]
    # NB-committed writes never stall: regenerated WAR edges must attach
    # only to blocking writes (NB depth-dependence is a CONSTRAINT).
    nb_write_nodes = {
        int(c.source_node) for c in engine.constraints
        if c.rtype in (RequestType.FIFO_NB_WRITE, RequestType.FIFO_CAN_WRITE)
        and c.outcome}
    fifo_np = []
    for tbl in engine.fifos:
        w_nodes = np.asarray(tbl.writes, np.int64).copy()
        blocking = np.asarray([int(w) not in nb_write_nodes
                               for w in w_nodes], bool)
        fifo_np.append((w_nodes, np.asarray(tbl.reads, np.int64).copy(),
                        blocking))
    # constraint arrays: kind 0 = can-read (target = seq-th write),
    # kind 1 = can-write (target depends on depth)
    c_kind, c_fifo, c_seq, c_src, c_out = [], [], [], [], []
    for c in engine.constraints:
        is_read = c.rtype in (RequestType.FIFO_NB_READ,
                              RequestType.FIFO_CAN_READ)
        c_kind.append(0 if is_read else 1)
        c_fifo.append(c.fifo)
        c_seq.append(c.source_seq)
        c_src.append(c.source_node)
        c_out.append(c.outcome)
    cg = CompiledGraph(
        n=n,
        raw_dst=np.asarray(dsts, np.int64),
        raw_src=np.asarray(srcs, np.int64),
        raw_w=np.asarray(wgts, np.int64),
        base=base_c,
        chains=chains,
        seq_w=seq_w,
        fifos=fifo_np,
        c_kind=np.asarray(c_kind, np.int64),
        c_fifo=np.asarray(c_fifo, np.int64),
        c_seq=np.asarray(c_seq, np.int64),
        c_src=np.asarray(c_src, np.int64),
        c_out=np.asarray(c_out, bool),
    )
    engine._incr_cache = cg
    return cg


# backward-compatible alias (pre-CompiledGraph name)
_cache_base_arrays = compile_graph


def _cross_edges(engine: OmniSim, depths: Sequence[int]):
    """RAW cross edges (cached) + WAR edges regenerated for ``depths`` —
    fully vectorized."""
    cache = compile_graph(engine)
    dst_parts = [cache.raw_dst]
    src_parts = [cache.raw_src]
    wgt_parts = [cache.raw_w]
    for tbl, (w_nodes, r_nodes, blocking) in zip(engine.fifos, cache.fifos):
        S = depths[tbl.fid]
        nw = len(w_nodes)
        if nw <= S:
            continue
        w_seq = np.arange(S + 1, nw + 1, dtype=np.int64)      # writes > S
        tgt = w_seq - S - 1
        blk = blocking[S:]
        # a BLOCKING write whose target read never happened can never
        # commit (deadlock); an NB write in that situation simply fails —
        # which its constraint re-evaluation reports as a flip.
        if np.any(blk & (tgt >= len(r_nodes))):
            bad = int(w_seq[blk & (tgt >= len(r_nodes))][0])
            return None, None, None, (
                f"write #{bad} on '{tbl.name}' can never commit with "
                f"depth {S} (would deadlock)")
        sel = blk & (tgt < len(r_nodes))
        dst_parts.append(w_nodes[S:][sel])
        src_parts.append(r_nodes[tgt[sel]])
        wgt_parts.append(np.ones(int(sel.sum()), np.int64))
    return (np.concatenate(dst_parts), np.concatenate(src_parts),
            np.concatenate(wgt_parts), None)


def check_constraints(cache: CompiledGraph, times: np.ndarray,
                      depths: Sequence[int]) -> int:
    """Re-evaluate every stored constraint against ``times`` (paper
    Sec. 7.2); returns the number of flipped outcomes."""
    if not len(cache.c_kind):
        return 0
    new_ok = np.zeros(len(cache.c_kind), bool)
    src_t = times[cache.c_src]
    for fid, (w_nodes, r_nodes, _blk) in enumerate(cache.fifos):
        S = depths[fid]
        sel = cache.c_fifo == fid
        if not sel.any():
            continue
        seq = cache.c_seq[sel]
        kind = cache.c_kind[sel]
        st = src_t[sel]
        ok = np.zeros(len(seq), bool)
        # reads: target = seq-th write
        rd = kind == 0
        tgt = np.minimum(seq[rd] - 1, max(len(w_nodes) - 1, 0))
        exists = (seq[rd] - 1) < len(w_nodes)
        t_tgt = times[w_nodes[tgt]] if len(w_nodes) else \
            np.zeros(len(tgt), np.int64)
        ok[rd] = exists & (t_tgt < st[rd])
        # writes: trivially true if seq <= S, else target read
        wr = kind == 1
        seq_w = seq[wr]
        triv = seq_w <= S
        tgt_w = np.clip(seq_w - S - 1, 0, max(len(r_nodes) - 1, 0))
        exists_w = (seq_w - S - 1) < len(r_nodes)
        t_tgt_w = times[r_nodes[tgt_w]] if len(r_nodes) else \
            np.zeros(len(tgt_w), np.int64)
        ok[wr] = triv | (exists_w & (t_tgt_w < st[wr]))
        new_ok[sel] = ok
    return int((new_ok != cache.c_out).sum())


def verify_times(graph: CompiledGraph, times: np.ndarray,
                 depths: Sequence[int]) -> Optional[str]:
    """Pointwise max-plus + Table-2 re-verification of a claimed solution.

    The PR 9 ``_FullRun`` verifier pattern, lifted to a
    :class:`CompiledGraph`: re-derive every node's contribution vector
    (base + RAW + WAR regenerated for ``depths``) and check that ``times``
    satisfies the chain recurrence ``t[i] == max(t[i-1] + seq_w[i], c[i])``
    *pointwise*, then re-evaluate every stored Table-2 constraint outcome.
    The dependency graph of a completed run is acyclic, so pointwise
    equality pins the unique fixpoint — a verified solution IS the
    solution, no matter how it was produced.  ``repro.delta.patch`` runs
    this over every spliced re-record before serving it: any stale reuse
    fails here and is rejected to a cold rebuild, never served.

    Returns ``None`` when verified, else a human-readable reason.
    """
    n = graph.n
    times = np.asarray(times, dtype=np.int64)
    if len(times) != n:
        return f"times length {len(times)} != graph nodes {n}"
    c = graph.base.astype(np.int64, copy=True)
    if len(graph.raw_dst):
        np.maximum.at(c, graph.raw_dst, times[graph.raw_src] + graph.raw_w)
    for fid, (w_nodes, r_nodes, blocking) in enumerate(graph.fifos):
        S = int(depths[fid])
        nw = len(w_nodes)
        if nw <= S:
            continue
        tgt = np.arange(nw - S, dtype=np.int64)          # writes > S
        blk = blocking[S:]
        if np.any(blk & (tgt >= len(r_nodes))):
            return (f"blocking write beyond depth {S} of FIFO {fid} has no "
                    f"matching read (structural deadlock)")
        sel = blk & (tgt < len(r_nodes))
        np.maximum.at(c, w_nodes[S:][sel], times[r_nodes[tgt[sel]]] + 1)
    prev = np.full(n, NEGI, dtype=np.int64)
    for ch in graph.chains:
        if len(ch) > 1:
            prev[ch[1:]] = times[ch[:-1]]
    expect = np.maximum(np.where(prev == NEGI, NEGI, prev + graph.seq_w), c)
    if not np.array_equal(expect, times):
        bad = int(np.flatnonzero(expect != times)[0])
        return (f"pointwise max-plus mismatch at node {bad}: "
                f"expected {int(expect[bad])}, claimed {int(times[bad])}")
    flips = check_constraints(graph, times, depths)
    if flips:
        return f"{flips} Table-2 constraint outcome(s) flipped"
    return None


def resimulate(result: SimResult, new_depths: Sequence[int],
               fallback: bool = True) -> IncrementalOutcome:
    """Attempt incremental re-simulation of an OmniSim result.

    With ``fallback=True`` a constraint violation triggers a full re-sim
    (reusing the compiled program — the paper's Table 6 second row).
    """
    t0 = _time.perf_counter()
    engine: OmniSim = result.graph
    assert isinstance(engine, OmniSim), "incremental re-sim needs an OmniSim result"
    new_depths = tuple(int(d) for d in new_depths)

    cache = compile_graph(engine)
    cross_dst, cross_src, cross_w, err = _cross_edges(engine, new_depths)
    if err is None:
        try:
            times = longest_path_chains(cache.chains, cache.seq_w,
                                        cache.base, cross_dst, cross_src,
                                        cross_w)
        except ValueError:           # WAR edges formed a cycle
            err = "regenerated WAR edges create a cycle (event order invalid)"
    if err is None:
        # re-evaluate constraints (paper Sec. 7.2) — vectorized
        violated = check_constraints(cache, times, new_depths)
        if violated == 0:
            cycles = int(times.max()) if len(times) else 0
            elapsed = _time.perf_counter() - t0
            new_res = SimResult(program=result.program,
                                outputs=dict(result.outputs), cycles=cycles,
                                engine="omnisim-incr", stats=result.stats,
                                graph=engine, constraints=result.constraints,
                                depths=new_depths)
            return IncrementalOutcome(True, "constraints satisfied", elapsed,
                                      new_res)
        err = f"{violated} constraint(s) violated — control/data flow diverges"
    elapsed = _time.perf_counter() - t0
    if not fallback:
        return IncrementalOutcome(False, err, elapsed, None)
    full = simulate(engine.program, depths=new_depths)
    elapsed = _time.perf_counter() - t0
    out = IncrementalOutcome(False, err, elapsed, full)
    return out
