"""OmniSim core: coupled functionality + performance simulation of dataflow
hardware designs (Sarkar & Hao, MICRO'25), adapted to a JAX/TPU stack.

Public API:

    from repro.core import (Program, Read, Write, ReadNB, WriteNB, Empty,
                            Full, Delay, Emit, simulate, simulate_rtl,
                            simulate_traced, LightningSim, csim, resimulate,
                            resimulate_batch, classify)

See docs/architecture.md for the module map (which paper section each file
implements) and docs/api.md for the full public-API reference.
"""
from .engine import OmniSim, simulate
from .events import (Constraint, DeadlockError, NodeKind, Query, RequestType,
                     SimStats, UnsupportedDesignError)
from .graph import (SimGraph, level_schedule, longest_path_numpy,
                    longest_path_python, to_dense_blocks)
from .dse import BatchOutcome, resimulate_batch
from .incremental import (CompiledGraph, IncrementalOutcome, compile_graph,
                          resimulate)
from .lightningsim import CSimCrash, LightningSim, csim
from .program import (Delay, Emit, Empty, Fifo, Full, Module, Op, Program,
                      Read, ReadNB, SimResult, Write, WriteNB)
from .rtlsim import simulate_rtl
from .taxonomy import Classification, classify, classify_dynamic
from .trace import (CompiledTrace, HybridCache, HybridSim, ModuleTrace,
                    RecordedTrace, TraceSimGraph, TraceUnsupported,
                    compile_trace, program_fingerprint, record_trace,
                    simulate_hybrid, simulate_traced)

__all__ = [
    "OmniSim", "simulate", "simulate_rtl", "LightningSim", "csim",
    "resimulate", "resimulate_batch", "BatchOutcome", "CompiledGraph",
    "compile_graph", "classify", "Classification", "IncrementalOutcome",
    "Program", "Fifo", "Module", "Op", "Read", "Write", "ReadNB", "WriteNB",
    "Empty", "Full", "Delay", "Emit", "SimResult", "SimGraph",
    "longest_path_numpy", "longest_path_python", "level_schedule",
    "to_dense_blocks", "Constraint", "DeadlockError", "Query", "RequestType",
    "NodeKind", "SimStats", "UnsupportedDesignError", "CSimCrash",
    "classify_dynamic",
    "TraceUnsupported", "RecordedTrace", "ModuleTrace", "CompiledTrace",
    "TraceSimGraph", "record_trace", "compile_trace", "simulate_traced",
    "HybridCache", "HybridSim", "simulate_hybrid", "program_fingerprint",
]
