"""AXI interface modeling (paper Table 1: AxiReadReq/WriteReq, AxiRead/
Write, AxiWriteResp).

The paper's runtime library intercepts AXI intrinsics the same way it
intercepts FIFO accesses; each AXI channel *is* a FIFO with hardware timing.
We model an AXI master <-> memory subsystem as a module factory over the
existing DSL primitives — request/data/response channels are ordinary SPSC
FIFOs, so the engine's FIFO tables give AXI transactions exact hardware
timing with zero engine changes (the same observation the paper exploits).

Channels per port (AXI4 semantics, ID-less in-order per port):

    ar  : read-address requests  (burst_len encoded in the request)
    r   : read-data beats        (memory -> master)
    aw  : write-address requests
    w   : write-data beats       (master -> memory)
    b   : write responses        (memory -> master)

``make_memory`` spawns the memory model module: it services AR/AW queues
with a configurable first-beat latency and per-beat II of 1 — the standard
DDR/HBM abstraction used by HLS co-simulation testbenches.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

from .program import Delay, Emit, Program, Read, ReadNB, Write


@dataclass
class AxiPort:
    ar: "Fifo"
    r: "Fifo"
    aw: "Fifo"
    w: "Fifo"
    b: "Fifo"


def make_axi_port(prog: Program, name: str, depth: int = 4) -> AxiPort:
    return AxiPort(
        ar=prog.fifo(f"{name}_ar", depth),
        r=prog.fifo(f"{name}_r", depth),
        aw=prog.fifo(f"{name}_aw", depth),
        w=prog.fifo(f"{name}_w", depth),
        b=prog.fifo(f"{name}_b", depth),
    )


def make_memory(prog: Program, port: AxiPort, data: List[int],
                read_latency: int = 12, write_latency: int = 8,
                name: str = "memory", n_reads: Optional[int] = None,
                n_writes: Optional[int] = None) -> None:
    """Memory model: services `n_reads` AR bursts then `n_writes` AW bursts.

    (A fully reactive memory would poll both queues with NB reads — that
    variant is `make_reactive_memory` below and is Type B.)
    """
    mem = list(data)

    def memory():
        for _ in range(n_reads if n_reads is not None else 0):
            addr, burst = yield Read(port.ar)        # AxiReadReq
            yield Delay(read_latency - 1)            # row activate / CAS
            for i in range(burst):                   # AxiRead beats, II=1
                yield Write(port.r, mem[addr + i])
        for _ in range(n_writes if n_writes is not None else 0):
            addr, burst = yield Read(port.aw)        # AxiWriteReq
            yield Delay(write_latency - 1)
            for i in range(burst):                   # AxiWrite beats
                mem[addr + i] = yield Read(port.w)
            yield Write(port.b, 0)                   # AxiWriteResp (OKAY)
        yield Emit(f"{name}_final", tuple(mem))

    prog.add_module(name, memory)


def make_reactive_memory(prog: Program, port: AxiPort, data: List[int],
                         read_latency: int = 12, write_latency: int = 8,
                         name: str = "memory") -> None:
    """Reactive memory: NB-polls AR and AW until a shutdown write lands at
    address 0 — a Type B module (infinite loop + NB accesses)."""
    mem = list(data)

    def memory():
        while True:
            ok, req = yield ReadNB(port.ar)
            if ok:
                addr, burst = req
                yield Delay(read_latency - 1)
                for i in range(burst):
                    yield Write(port.r, mem[addr + i])
                continue
            ok, req = yield ReadNB(port.aw)
            if ok:
                addr, burst = req
                yield Delay(write_latency - 1)
                for i in range(burst):
                    mem[addr + i] = yield Read(port.w)
                yield Write(port.b, 0)
                if addr == 0:                        # shutdown doorbell
                    break
        yield Emit(f"{name}_final", tuple(mem))

    prog.add_module(name, memory)


# --------------------------------------------------------------- demo design
def axi_master_design(n: int = 64, burst: int = 16,
                      read_latency: int = 12) -> Program:
    """The Vitis 'AXI4 master' pattern: burst-read n words, scale, burst-
    write them back, wait for the response.  Type A end to end."""
    prog = Program("axi_master", declared_type="A")
    port = make_axi_port(prog, "gmem")
    data = [(i * 7 + 3) % 97 for i in range(n)]
    n_bursts = n // burst

    @prog.module("master")
    def master():
        total = 0
        # read phase: issue AR per burst, consume R beats
        for b in range(n_bursts):
            yield Write(port.ar, (b * burst, burst))     # AxiReadReq
            vals = []
            for _ in range(burst):
                v = yield Read(port.r)                   # AxiRead
                vals.append(v)
                total += v
            # write phase for this burst: scale by 2
            yield Write(port.aw, (b * burst, burst))     # AxiWriteReq
            for v in vals:
                yield Write(port.w, 2 * v)               # AxiWrite
            yield Read(port.b)                           # AxiWriteResp
        yield Emit("checksum", total)

    mem = list(data)

    def memory():
        for _ in range(n_bursts):
            addr, bl = yield Read(port.ar)
            yield Delay(read_latency - 1)
            for i in range(bl):
                yield Write(port.r, mem[addr + i])
            addr, bl = yield Read(port.aw)
            yield Delay(7)
            for i in range(bl):
                mem[addr + i] = yield Read(port.w)
            yield Write(port.b, 0)
        yield Emit("memory_final", tuple(mem))

    prog.add_module("memory", memory)
    return prog


def axi_prefetch_design(n: int = 64, burst: int = 8) -> Program:
    """Type C: a prefetcher speculatively issues the next AR while compute
    drains the current burst; on backpressure (full AR queue, checked with a
    NB write) the prefetch is skipped and counted."""
    prog = Program("axi_prefetch", declared_type="C")
    port = make_axi_port(prog, "gmem", depth=2)
    data = [(i * 5 + 1) % 83 for i in range(2 * n)]
    n_bursts = n // burst
    from .program import WriteNB

    @prog.module("prefetcher")
    def prefetcher():
        issued = 0
        skipped = 0
        b = 0
        while issued < n_bursts:
            ok = yield WriteNB(port.ar, (b * burst, burst))
            if ok:
                issued += 1
                b += 1
            else:
                skipped += 1
                yield Delay(3)
        yield Emit("prefetch_skipped", skipped)

    @prog.module("compute")
    def compute():
        total = 0
        for _ in range(n_bursts * burst):
            v = yield Read(port.r)
            total += v
            yield Delay(1)                               # 2 cycles/beat
        yield Emit("checksum", total)

    make_reactive_memory(prog, port, data, name="memory")

    @prog.module("shutdown")
    def shutdown():
        # waits for compute's checksum? modeled as a fixed-time doorbell:
        # issue the shutdown write after draining is guaranteed.
        yield Delay(16 * n)
        yield Write(port.aw, (0, 1))
        yield Write(port.w, 0)
        yield Read(port.b)

    return prog
