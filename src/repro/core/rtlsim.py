"""Cycle-stepped discrete-event reference simulator ("co-sim" stand-in).

We cannot run Vivado/Vitis RTL co-simulation in this environment; this module
is the ground-truth oracle instead: it advances a *global* clock one cycle at
a time and evaluates every module against exact registered-FIFO semantics:

  * a value written in cycle t becomes readable in cycle t+1 (strictly-after
    visibility — the same rule the OmniSim engine's FIFO tables encode);
  * occupancy observed in cycle t counts writes/reads committed in cycles < t;
  * a blocking access retries every cycle until feasible; NB accesses and
    probes sample the pre-cycle state exactly once.

Because it steps every cycle (including long idle stretches) it is orders of
magnitude slower than the event-driven OmniSim engine on the same design —
this is the honest speed baseline for the Fig. 8(b) reproduction, and its
outputs/cycle counts are the accuracy baseline for Table 3 / Fig. 8(a).
"""
from __future__ import annotations

from collections import deque
from typing import Any, Dict, List, Optional

from .program import (Delay, Emit, Empty, Full, Op, Program, Read, ReadNB,
                      SimResult, Write, WriteNB)


class _RtlFifo:
    """Registered FIFO with *staged* same-cycle accesses.

    All modules evaluated within cycle t observe the identical pre-cycle
    state (writes/reads committed in cycles < t); this makes module
    iteration order irrelevant — the property OmniSim's FIFO tables provide
    by comparing hardware cycles.
    """

    __slots__ = ("depth", "values", "writes_this_cycle", "reads_this_cycle")

    def __init__(self, depth: int):
        self.depth = depth
        self.values: deque = deque()       # visible (committed < current cycle)
        self.writes_this_cycle: List[Any] = []
        self.reads_this_cycle = 0

    # -- pre-cycle state queries ------------------------------------------
    def can_read(self) -> bool:
        return self.reads_this_cycle < len(self.values)

    def occupancy_for_write(self) -> int:
        # writers see pre-cycle occupancy: writes < t minus reads < t
        return len(self.values)

    # -- staged accesses -----------------------------------------------------
    def do_read(self) -> Any:
        v = self.values[self.reads_this_cycle]
        self.reads_this_cycle += 1
        return v

    def do_write(self, v: Any) -> None:
        self.writes_this_cycle.append(v)

    def end_cycle(self) -> None:
        for _ in range(self.reads_this_cycle):
            self.values.popleft()
        self.reads_this_cycle = 0
        self.values.extend(self.writes_this_cycle)
        self.writes_this_cycle.clear()


class _RtlTask:
    __slots__ = ("name", "gen", "ready_at", "pending", "done", "started",
                 "send_value", "end_time")

    def __init__(self, name: str, gen):
        self.name = name
        self.gen = gen
        self.ready_at = 1
        self.pending: Optional[Op] = None
        self.done = False
        self.started = False
        self.send_value: Any = None
        self.end_time = 1      # module end = cycle after last op (+ delays)


def simulate_rtl(program: Program, depths=None,
                 max_cycles: int = 5_000_000) -> SimResult:
    """Run the cycle-stepped oracle."""
    if depths is not None:
        program.with_depths(depths)
    fifos = {f: _RtlFifo(f.depth) for f in program.fifos}
    tasks = [_RtlTask(m.name, m.fn()) for m in program.modules]
    outputs: Dict[str, Any] = {}

    def fetch(task: _RtlTask) -> None:
        """Advance the generator to its next cycle-consuming op."""
        while True:
            try:
                if not task.started:
                    task.started = True
                    op = next(task.gen)
                else:
                    op = task.gen.send(task.send_value)
                task.send_value = None
            except StopIteration:
                task.done = True
                task.pending = None
                # module end = next-ready cycle (includes trailing delays),
                # matching the engine's END-node convention.
                task.end_time = task.ready_at
                return
            if isinstance(op, Emit):
                outputs[op.key] = op.value
                task.send_value = None
                continue
            if isinstance(op, Delay):
                task.ready_at += op.cycles
                task.send_value = None
                continue
            task.pending = op
            return

    for task in tasks:
        fetch(task)

    t = 0
    while True:
        t += 1
        if t > max_cycles:
            raise RuntimeError(f"cycle budget exceeded ({max_cycles})")
        if all(task.done for task in tasks):
            t -= 1
            break
        progress = False
        any_waiting = False
        for task in tasks:
            if task.done or task.ready_at > t:
                any_waiting |= (not task.done)
                progress |= (not task.done)   # delayed task will act later
                continue
            op = task.pending
            f = fifos[op.fifo]
            if isinstance(op, Read):
                if f.can_read():
                    task.send_value = f.do_read()
                    task.ready_at = t + 1
                    fetch(task)
                    progress = True
            elif isinstance(op, Write):
                if f.occupancy_for_write() < f.depth:
                    f.do_write(op.value)
                    task.ready_at = t + 1
                    fetch(task)
                    progress = True
            elif isinstance(op, ReadNB):
                if f.can_read():
                    task.send_value = (True, f.do_read())
                else:
                    task.send_value = (False, None)
                task.ready_at = t + 1
                fetch(task)
                progress = True
            elif isinstance(op, WriteNB):
                if f.occupancy_for_write() < f.depth:
                    f.do_write(op.value)
                    task.send_value = True
                else:
                    task.send_value = False
                task.ready_at = t + 1
                fetch(task)
                progress = True
            elif isinstance(op, Empty):
                task.send_value = not f.can_read()
                task.ready_at = t + 1
                fetch(task)
                progress = True
            elif isinstance(op, Full):
                task.send_value = f.occupancy_for_write() >= f.depth
                task.ready_at = t + 1
                fetch(task)
                progress = True
            else:  # pragma: no cover
                raise TypeError(f"unknown op {op!r}")
        for f in fifos.values():
            f.end_cycle()
        if not progress:
            # every live task is blocked on an infeasible B access and no
            # commit happened: the state is a fixpoint -> true deadlock.
            blocked = [task.name for task in tasks if not task.done]
            res = SimResult(program=program.name, outputs=dict(outputs),
                            cycles=t, engine="rtlsim",
                            depths=program.depths(), deadlock=True,
                            deadlock_cycle=t)
            res.outputs["__deadlock__"] = blocked
            return res

    total = max((task.end_time for task in tasks), default=0)
    return SimResult(program=program.name, outputs=dict(outputs), cycles=total,
                     engine="rtlsim", depths=program.depths())
