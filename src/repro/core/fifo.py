"""FIFO read/write timing tables — data structure (D) of paper Fig. 7.

Each FIFO keeps the ordered list of committed write/read events (node
indices into the simulation graph) plus the value payloads in flight.  The
tables answer the Perf Sim orchestrator's resolution questions of Table 2:

  * NB write, w-th write, FIFO size S:  succeeds iff  w <= S  or the
    (w-S)-th read committed *strictly before* the write's cycle.
  * NB read, r-th read: succeeds iff the r-th write committed strictly
    before the read's cycle.

The strict-before rule is what makes functionality cycle-dependent for
Type C designs: comparing *hardware* cycles recorded here — not executor
scheduling order — is the paper's core correctness mechanism.
"""
from __future__ import annotations

from collections import deque
from typing import Any, List, Optional


class FifoTable:
    __slots__ = ("fid", "name", "depth", "writes", "reads", "values",
                 "write_times", "read_times")

    def __init__(self, fid: int, name: str, depth: int):
        self.fid = fid
        self.name = name
        self.depth = depth
        self.writes: List[int] = []       # node idx of each committed write
        self.reads: List[int] = []        # node idx of each committed read
        self.write_times: List[int] = []  # cycle of each committed write
        self.read_times: List[int] = []   # cycle of each committed read
        self.values: deque = deque()      # payloads not yet consumed

    # -- commits -------------------------------------------------------------
    def commit_write(self, node_idx: int, time: int, value: Any) -> int:
        """Returns the 1-based write sequence number."""
        self.writes.append(node_idx)
        self.write_times.append(time)
        self.values.append(value)
        return len(self.writes)

    def commit_read(self, node_idx: int, time: int) -> Any:
        self.reads.append(node_idx)
        self.read_times.append(time)
        return self.values.popleft()

    # -- counters --------------------------------------------------------------
    @property
    def n_writes(self) -> int:
        return len(self.writes)

    @property
    def n_reads(self) -> int:
        return len(self.reads)

    # -- Table 2 resolution ----------------------------------------------------
    def write_target_read(self, w: int) -> Optional[int]:
        """Index (0-based into reads) of the read the w-th write must follow,
        or None if the write trivially fits (w <= S)."""
        if w <= self.depth:
            return None
        return w - self.depth - 1  # (w-S)-th read, 0-based

    def can_write_at(self, w: int, t: int) -> Optional[bool]:
        """Can the w-th write commit at cycle t?  None = target still unknown."""
        tgt = self.write_target_read(w)
        if tgt is None:
            return True
        if tgt >= len(self.read_times):
            return None                      # target read not yet simulated
        return self.read_times[tgt] < t      # strictly after the target

    def can_read_at(self, r: int, t: int) -> Optional[bool]:
        """Can the r-th read commit at cycle t?  None = target still unknown."""
        tgt = r - 1                          # r-th write, 0-based
        if tgt >= len(self.write_times):
            return None
        return self.write_times[tgt] < t

    def occupancy_at(self, t: int) -> Optional[int]:
        """Number of elements present at cycle t, or None if not yet decidable.

        Decidable when we know all writes/reads with time < t have been
        simulated — conservatively, the orchestrator only calls this at
        quiescence where the earliest-query rule guarantees decidability.
        """
        w = sum(1 for x in self.write_times if x < t)
        r = sum(1 for x in self.read_times if x < t)
        return w - r

    def earliest_write_time(self, r: int) -> Optional[int]:
        """Commit cycle of the r-th write (0-based tgt = r-1), if known."""
        if r - 1 < len(self.write_times):
            return self.write_times[r - 1]
        return None

    def earliest_read_time(self, idx0: int) -> Optional[int]:
        if idx0 < len(self.read_times):
            return self.read_times[idx0]
        return None
