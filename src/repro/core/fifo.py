"""FIFO read/write timing tables — data structure (D) of paper Fig. 7.

Each FIFO keeps the ordered sequence of committed write/read events (node
indices into the simulation graph) plus the value payloads in flight.  The
tables answer the Perf Sim orchestrator's resolution questions of Table 2:

  * NB write, w-th write, FIFO size S:  succeeds iff  w <= S  or the
    (w-S)-th read committed *strictly before* the write's cycle.
  * NB read, r-th read: succeeds iff the r-th write committed strictly
    before the read's cycle.

The strict-before rule is what makes functionality cycle-dependent for
Type C designs: comparing *hardware* cycles recorded here — not executor
scheduling order — is the paper's core correctness mechanism.

Storage is growable numpy arrays (amortized-doubling append) rather than
Python lists: commit times per FIFO side are nondecreasing (each side is
driven by a single module whose clock only advances), so occupancy queries
are ``searchsorted`` binary searches, and incremental/batched re-simulation
(``core/incremental.py``, ``core/dse.py``) reads the tables as numpy views
without per-element conversion.  The views are only valid until the next
commit (growth reallocates the buffer) — copy them to hold past one.
"""
from __future__ import annotations

from collections import deque
from typing import Any, Optional

import numpy as np

_EMPTY_I64 = np.empty(0, dtype=np.int64)   # shared placeholder for shells


class FifoTable:
    """One FIFO's committed read/write event tables (paper Fig. 7, (D)).

    Units: ``*_times`` are hardware **cycles** (1-based commit cycles);
    sequence numbers (``w``/``r`` arguments) are 1-based **event** counts on
    this FIFO's side.  Node indices refer to the simulation graph.  Filled
    one commit at a time by the generator engine, or wholesale (vectorized)
    by the trace replay (``core/trace.py``) — both end states are
    identical.
    """

    __slots__ = ("fid", "name", "depth", "values",
                 "_w_nodes", "_w_times", "_r_nodes", "_r_times",
                 "_nw", "_nr")

    _INIT_CAP = 16

    def __init__(self, fid: int, name: str, depth: int):
        self.fid = fid
        self.name = name
        self.depth = depth
        self._w_nodes = np.empty(self._INIT_CAP, dtype=np.int64)
        self._w_times = np.empty(self._INIT_CAP, dtype=np.int64)
        self._r_nodes = np.empty(self._INIT_CAP, dtype=np.int64)
        self._r_times = np.empty(self._INIT_CAP, dtype=np.int64)
        self._nw = 0
        self._nr = 0
        self.values: deque = deque()      # payloads not yet consumed

    @classmethod
    def _shell(cls, fid: int, name: str, depth: int) -> "FifoTable":
        """Table whose event arrays are about to be installed wholesale.

        The trace replay (``core/trace.py``) assigns ``_w_nodes`` /
        ``_w_times`` / ``_r_nodes`` / ``_r_times`` for every FIFO right
        after construction, so the per-table ``_INIT_CAP`` allocations of
        ``__init__`` would be garbage on arrival — at corpus scale that
        is thousands of throwaway numpy buffers per delta patch.  The
        shared empty placeholder keeps the views well-defined (``_nw ==
        _nr == 0``) if anything peeks before installation.
        """
        t = cls.__new__(cls)
        t.fid = fid
        t.name = name
        t.depth = depth
        t._w_nodes = t._w_times = t._r_nodes = t._r_times = _EMPTY_I64
        t._nw = 0
        t._nr = 0
        t.values = deque()
        return t

    # -- committed-event views (zero-copy numpy slices) ------------------------
    @property
    def writes(self) -> np.ndarray:
        """Node idx of each committed write, in commit order."""
        return self._w_nodes[:self._nw]

    @property
    def reads(self) -> np.ndarray:
        """Node idx of each committed read, in commit order."""
        return self._r_nodes[:self._nr]

    @property
    def write_times(self) -> np.ndarray:
        """Commit cycle of each write (nondecreasing: single writer module)."""
        return self._w_times[:self._nw]

    @property
    def read_times(self) -> np.ndarray:
        """Commit cycle of each read (nondecreasing: single reader module)."""
        return self._r_times[:self._nr]

    # -- commits -------------------------------------------------------------
    def commit_write(self, node_idx: int, time: int, value: Any) -> int:
        """Returns the 1-based write sequence number."""
        n = self._nw
        if n == len(self._w_nodes):
            if n == 0:                    # _shell() table: no capacity yet
                self._w_nodes = np.empty(self._INIT_CAP, dtype=np.int64)
                self._w_times = np.empty(self._INIT_CAP, dtype=np.int64)
            else:
                self._w_nodes = np.concatenate([self._w_nodes, self._w_nodes])
                self._w_times = np.concatenate([self._w_times, self._w_times])
        self._w_nodes[n] = node_idx
        self._w_times[n] = time
        self._nw = n + 1
        self.values.append(value)
        return self._nw

    def commit_read(self, node_idx: int, time: int) -> Any:
        """Record the next read committing at cycle ``time``; returns the
        payload popped from the in-flight value queue."""
        n = self._nr
        if n == len(self._r_nodes):
            if n == 0:                    # _shell() table: no capacity yet
                self._r_nodes = np.empty(self._INIT_CAP, dtype=np.int64)
                self._r_times = np.empty(self._INIT_CAP, dtype=np.int64)
            else:
                self._r_nodes = np.concatenate([self._r_nodes, self._r_nodes])
                self._r_times = np.concatenate([self._r_times, self._r_times])
        self._r_nodes[n] = node_idx
        self._r_times[n] = time
        self._nr = n + 1
        return self.values.popleft()

    # -- counters --------------------------------------------------------------
    @property
    def n_writes(self) -> int:
        """Committed write count (events so far; the next write is #n+1)."""
        return self._nw

    @property
    def n_reads(self) -> int:
        """Committed read count (events so far; the next read is #n+1)."""
        return self._nr

    # -- Table 2 resolution ----------------------------------------------------
    def write_target_read(self, w: int) -> Optional[int]:
        """Index (0-based into reads) of the read the w-th write must follow,
        or None if the write trivially fits (w <= S)."""
        if w <= self.depth:
            return None
        return w - self.depth - 1  # (w-S)-th read, 0-based

    def can_write_at(self, w: int, t: int) -> Optional[bool]:
        """Can the w-th write commit at cycle t?  None = target still unknown."""
        tgt = self.write_target_read(w)
        if tgt is None:
            return True
        if tgt >= self._nr:
            return None                      # target read not yet simulated
        return bool(self._r_times[tgt] < t)  # strictly after the target

    def can_read_at(self, r: int, t: int) -> Optional[bool]:
        """Can the r-th read commit at cycle t?  None = target still unknown."""
        tgt = r - 1                          # r-th write, 0-based
        if tgt >= self._nw:
            return None
        return bool(self._w_times[tgt] < t)

    def occupancy_at(self, t: int) -> Optional[int]:
        """Number of elements present at cycle t, or None if not yet decidable.

        Decidable when we know all writes/reads with time < t have been
        simulated — conservatively, the orchestrator only calls this at
        quiescence where the earliest-query rule guarantees decidability.
        Commit times are nondecreasing, so both counts are binary searches.
        """
        w = int(np.searchsorted(self._w_times[:self._nw], t, side="left"))
        r = int(np.searchsorted(self._r_times[:self._nr], t, side="left"))
        return w - r

    def earliest_write_time(self, r: int) -> Optional[int]:
        """Commit cycle of the r-th write (0-based tgt = r-1), if known."""
        if r - 1 < self._nw:
            return int(self._w_times[r - 1])
        return None

    def earliest_read_time(self, idx0: int) -> Optional[int]:
        """Commit cycle of the read at 0-based index ``idx0``, if known —
        the WAR target lookup of paper Table 2 (w-th write waits on the
        (w-S)-th read)."""
        if idx0 < self._nr:
            return int(self._r_times[idx0])
        return None
