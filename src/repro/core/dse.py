"""Depth-batched design-space exploration: K re-simulations in one pass.

The paper's Table 6 capability — re-evaluating a finished run under new
FIFO depths in microseconds — turned into a *throughput* engine.  FIFO
sizing spaces are 10^3–10^5 configurations; evaluating them one
``resimulate()`` call at a time serializes Python and numpy call overhead.
``resimulate_batch`` instead treats the K candidate depth vectors as a
leading batch axis over the whole incremental pipeline (the
compile-once/re-solve-many structure of LightningSimV2, arXiv 2404.09471,
lifted to a batch of solves):

  1. regenerate the depth-dependent WAR edges for ALL K configs as stacked
     index/mask arrays (the static SEQ+RAW skeleton is shared via
     :class:`~repro.core.incremental.CompiledGraph` — for trace-compiled
     base runs it was built directly from the op trace at initial-sim
     time, so no Python graph object is ever walked — and per-(FIFO,
     depth) columns are cached: depth values repeat heavily across a
     sweep);
  2. run the chain-decomposed longest-path fixpoint with a leading batch
     axis — one ``np.maximum.accumulate`` per module chain over the whole
     batch instead of K Python loops.  The production solver seeds every
     config with the depth-INDEPENDENT no-WAR fixpoint (computed once at
     compile time) and Gauss-Seidel-sweeps chains in module order with
     dirty tracking, so a config only pays for the part of the pipeline its
     WAR constraints actually move — slack configs converge with zero
     sweeps;
  3. re-check every stored NB/probe constraint for all K configs in one
     vectorized pass;
  4. mask out structurally-infeasible configs (a committed blocking write
     whose target read never occurred ⇒ deadlock), cyclic configs (the
     regenerated event order is invalid) and constraint-violating configs,
     and fall back to a full re-simulation for exactly that subset.

Backends: ``"numpy"`` (default, above), ``"reference"`` (the synchronous
Jacobi :func:`~repro.core.graph.longest_path_chains_batched` — the oracle
the production solver is tested against), ``"jax"`` — the sparse
chain-structured Pallas max-plus solver (``repro.kernels.maxplus.sparse``:
segmented cummax over the chain-major flat arrays, on-device WAR
regeneration, O(K·n + K·edges) memory) for device-resident sweeps of any
graph size — and ``"jax_dense"``, the historical ``jax.vmap`` lowering of
the dense O(n²)-per-config max-plus fixpoint, kept for tiny graphs and as
a second device oracle.
"""
from __future__ import annotations

import copy
import threading
import time as _time
from contextlib import nullcontext
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from .engine import OmniSim, simulate
from .graph import (export_chain_flat, longest_path_chains,
                    longest_path_chains_batched)
from .incremental import NEGI, CompiledGraph, compile_graph
from .program import SimResult

# per-config status codes.  The first four are solver verdicts (what
# ``solve_block_status`` classifies); the last four are *service-level*
# terminal statuses used by the sweep subsystem (``repro/sweep``) so that
# every submitted row ends in a definite state even when it was never
# solved: cancelled by the client, failed by a faulting shard after
# retries, expired past its deadline, or shed by admission control.
REUSED, DEADLOCK, CYCLE, VIOLATED = 0, 1, 2, 3
CANCELLED, FAULTED, TIMED_OUT, REJECTED = 4, 5, 6, 7

# Per-Program re-entrant locks serializing every transient in-place
# mutation (the fallback re-simulation sets FIFO depths and restores
# them) against readers of that state on other threads — notably the
# sweep cache's fingerprint-and-build path.  Per Program, not global:
# unrelated designs must not stall behind one design's engine re-sims.
_LOCK_CREATE = threading.Lock()


def program_mutation_lock(program) -> threading.RLock:
    lock = getattr(program, "_mutation_lock", None)
    if lock is None:
        with _LOCK_CREATE:
            lock = getattr(program, "_mutation_lock", None)
            if lock is None:
                lock = threading.RLock()
                program._mutation_lock = lock
    return lock

_STATUS_REASON = {
    REUSED: "constraints satisfied",
    CYCLE: "regenerated WAR edges create a cycle (event order invalid)",
    CANCELLED: "request cancelled before this config was scheduled",
    FAULTED: "shard solve faulted repeatedly (retries exhausted)",
    TIMED_OUT: "deadline exceeded before this config was solved",
    REJECTED: "rejected by admission control",
}

# statuses the exact engine fallback applies to: solver verdicts that a
# full re-simulation can refine.  Service-level terminal statuses
# (CANCELLED/FAULTED/TIMED_OUT/REJECTED) must never pay for engine work.
FALLBACK_STATUSES = (DEADLOCK, CYCLE, VIOLATED)


@dataclass
class _BatchArrays:
    """Chain-major-permuted view of a CompiledGraph for batched solving."""

    perm: np.ndarray               # new pos -> original node idx
    inv: np.ndarray                # original node idx -> new pos
    slices: List[tuple]            # contiguous (lo, hi) per module chain
    starts: np.ndarray             # chain start offsets (for chain-of-node)
    cw: np.ndarray                 # cumulative SEQ weight, chain-major
    base_p: np.ndarray             # base contribution, chain-major (NEGI=none)
    raw_dst: np.ndarray            # RAW edges, chain-major columns
    raw_src: np.ndarray
    raw_w: np.ndarray
    raw_buckets: dict              # src chain -> [(dst chain, src, dst, w)]
    fifo_w_cols: List[np.ndarray]  # per FIFO: write node columns
    fifo_r_cols: List[np.ndarray]  # per FIFO: read node columns
    fifo_blocking: List[np.ndarray]
    fifo_need: np.ndarray          # min depth to avoid structural deadlock
    fifo_rchain: np.ndarray        # per FIFO: reader module chain (-1 = none)
    fifo_wchain: np.ndarray        # per FIFO: writer module chain (-1 = none)
    c_src_p: np.ndarray            # constraint source nodes, chain-major
    bound: int                     # upper bound on any acyclic path length
    t_inf: np.ndarray = None       # no-WAR (infinite-depth) fixpoint times
    c_inf: np.ndarray = None       # ... and its contribution vector
    war_cache: Dict[tuple, tuple] = field(default_factory=dict)
    sparse: object = None          # lazy ChainFlatArrays (jax sparse lane)


def _chain_of(starts: np.ndarray, col: int) -> int:
    return int(np.searchsorted(starts, col, side="right") - 1)


def _batch_arrays(cache: CompiledGraph) -> _BatchArrays:
    if cache.batch is not None:
        return cache.batch
    n = cache.n
    perm = (np.concatenate(cache.chains) if cache.chains
            else np.zeros(0, np.int64))
    assert len(perm) == n, "every node must belong to exactly one chain"
    inv = np.empty(n, dtype=np.int64)
    inv[perm] = np.arange(n, dtype=np.int64)
    slices, cw_parts, off = [], [], 0
    for ch in cache.chains:
        slices.append((off, off + len(ch)))
        cw_parts.append(np.cumsum(cache.seq_w[ch]))
        off += len(ch)
    cw = np.concatenate(cw_parts) if cw_parts else np.zeros(0, np.int64)
    starts = np.asarray([lo for (lo, _) in slices] or [0], np.int64)
    raw_dst = inv[cache.raw_dst]
    raw_src = inv[cache.raw_src]
    # the unique-destination invariant the batched scatter-max relies on:
    # one RAW in-edge per read node, one WAR in-edge per write node, and
    # read/write node sets are disjoint (engine construction guarantees it)
    assert len(np.unique(raw_dst)) == len(raw_dst), \
        "RAW destinations must be unique for the batched fixpoint"
    # bucket RAW edges by (src chain, dst chain) for the Gauss-Seidel sweep
    raw_buckets: dict = {}
    if len(raw_dst):
        sc = np.searchsorted(starts, raw_src, side="right") - 1
        dc = np.searchsorted(starts, raw_dst, side="right") - 1
        order = np.lexsort((dc, sc))
        s_s, d_s = sc[order], dc[order]
        cut = np.flatnonzero(np.diff(s_s) | np.diff(d_s))
        bounds = np.concatenate([[0], cut + 1, [len(order)]])
        for a, b in zip(bounds[:-1], bounds[1:]):
            idx = order[a:b]
            raw_buckets.setdefault(int(s_s[a]), []).append(
                (int(d_s[a]), raw_src[idx], raw_dst[idx], cache.raw_w[idx]))
    w_cols, r_cols, blocking, need, rchain, wchain = [], [], [], [], [], []
    for (w_nodes, r_nodes, blk) in cache.fifos:
        wc = inv[w_nodes] if len(w_nodes) else w_nodes
        rc = inv[r_nodes] if len(r_nodes) else r_nodes
        w_cols.append(wc)
        r_cols.append(rc)
        blocking.append(blk)
        rchain.append(_chain_of(starts, rc[0]) if len(rc) else -1)
        wchain.append(_chain_of(starts, wc[0]) if len(wc) else -1)
        if blk.any():
            w_seq = np.arange(1, len(w_nodes) + 1, dtype=np.int64)
            need.append(int(w_seq[blk].max()) - len(r_nodes))
        else:
            need.append(-(1 << 30))
    finite_base = cache.base[cache.base != NEGI]
    bound = int((finite_base.max() if len(finite_base) else 0)
                + cache.seq_w.sum() + cache.raw_w.sum()
                + sum(len(w) for (w, _, _) in cache.fifos) + 1)
    ba = _BatchArrays(
        perm=perm, inv=inv, slices=slices, starts=starts, cw=cw,
        base_p=cache.base[perm] if n else cache.base,
        raw_dst=raw_dst, raw_src=raw_src, raw_w=cache.raw_w,
        raw_buckets=raw_buckets,
        fifo_w_cols=w_cols, fifo_r_cols=r_cols, fifo_blocking=blocking,
        fifo_need=np.asarray(need, np.int64),
        fifo_rchain=np.asarray(rchain, np.int64),
        fifo_wchain=np.asarray(wchain, np.int64),
        c_src_p=(inv[cache.c_src] if len(cache.c_src) else cache.c_src),
        bound=bound)
    # depth-independent seed: the no-WAR (infinite-depth) fixpoint is a
    # lower bound of every config's fixpoint (WAR edges only delay), so the
    # per-config solve starts from it and pays only for the WAR impact
    if n:
        t_inf = longest_path_chains(cache.chains, cache.seq_w, cache.base,
                                    cache.raw_dst, cache.raw_src,
                                    cache.raw_w)[perm]
        c_inf = ba.base_p.copy()
        if len(raw_dst):
            c_inf[raw_dst] = np.maximum(c_inf[raw_dst],
                                        t_inf[raw_src] + cache.raw_w)
    else:
        t_inf = np.zeros(0, np.int64)
        c_inf = np.zeros(0, np.int64)
    ba.t_inf = t_inf
    ba.c_inf = c_inf
    cache.batch = ba
    return ba


def _war_cols(ba: _BatchArrays, fid: int, S: int):
    """Cached per-(FIFO, depth) regenerated-WAR columns.

    Returns (src_col, valid_col, cand_inf): for each of the FIFO's writes,
    the chain-major column of its (w-S)-th read, whether the edge exists
    under depth S (blocking, target read committed), and the edge's
    candidate contribution under the no-WAR seed times (NEGI = none).
    """
    key = (fid, S)
    hit = ba.war_cache.get(key)
    if hit is not None:
        return hit
    w_cols = ba.fifo_w_cols[fid]
    r_cols = ba.fifo_r_cols[fid]
    nw, nr = len(w_cols), len(r_cols)
    w_seq = np.arange(1, nw + 1, dtype=np.int64)
    tgt = w_seq - S - 1
    valid = ba.fifo_blocking[fid] & (tgt >= 0) & (tgt < nr)
    src = (r_cols[np.clip(tgt, 0, nr - 1)] if nr
           else np.zeros(nw, np.int64))
    cand = np.where(valid, ba.t_inf[src] + 1, NEGI)
    # a depth whose candidates cannot move the no-WAR fixpoint needs no
    # seed push at all (slack WAR — the common case when depths grow)
    effective = bool((cand > ba.c_inf[w_cols]).any())
    entry = (src, valid, cand, effective)
    ba.war_cache[key] = entry
    return entry


@dataclass
class BatchOutcome:
    """Result of :func:`resimulate_batch` over K depth configurations."""

    ok: np.ndarray                 # (K,) bool: graph reused for this config
    cycles: np.ndarray             # (K,) int64: cycle count (-1 = no result)
    status: np.ndarray             # (K,) int8: REUSED/DEADLOCK/CYCLE/VIOLATED
    violated: np.ndarray           # (K,) int64: # of flipped constraints
    reasons: List[str]
    results: List[Optional[SimResult]]
    elapsed_s: float
    fixpoint_rounds: int = 0
    n_unique: int = 0              # distinct depth rows actually solved

    @property
    def n_reused(self) -> int:
        return int(self.ok.sum())

    @property
    def n_fallback(self) -> int:
        return len(self.ok) - self.n_reused

    def us_per_config(self) -> float:
        return self.elapsed_s / max(len(self.ok), 1) * 1e6


def _regen_war_stacked(ba: _BatchArrays, Db: np.ndarray):
    """Stacked WAR regeneration for the reference (Jacobi) backend.

    Returns (dyn_dst (m,), dyn_src (B, m), dyn_valid (B, m)) covering every
    FIFO that can overflow for at least one config in the block; entry
    (k, j) is the regenerated WAR edge of the j-th write under config k
    (masked False where w <= S_k, the write is non-blocking, or the target
    read does not exist).
    """
    B = len(Db)
    dst_parts, src_parts, valid_parts = [], [], []
    for fid, w_cols in enumerate(ba.fifo_w_cols):
        nw = len(w_cols)
        if nw == 0 or int(Db[:, fid].min()) >= nw:
            continue                       # no config overflows this FIFO
        r_cols = ba.fifo_r_cols[fid]
        nr = len(r_cols)
        w_seq = np.arange(1, nw + 1, dtype=np.int64)
        tgt = w_seq[None, :] - Db[:, fid][:, None] - 1        # (B, nw)
        valid = ba.fifo_blocking[fid][None, :] & (tgt >= 0) & (tgt < nr)
        if nr:
            src = r_cols[np.clip(tgt, 0, nr - 1)]
        else:
            src = np.zeros((B, nw), np.int64)
        dst_parts.append(w_cols)
        src_parts.append(src)
        valid_parts.append(valid)
    if not dst_parts:
        z = np.zeros(0, np.int64)
        return z, np.zeros((B, 0), np.int64), np.zeros((B, 0), bool)
    return (np.concatenate(dst_parts),
            np.concatenate(src_parts, axis=1),
            np.concatenate(valid_parts, axis=1))


def _check_constraints_stacked(cache: CompiledGraph, ba: _BatchArrays,
                               t: np.ndarray, Db: np.ndarray):
    """Vectorized Table-2 re-check of all constraints for a block of configs.

    ``t``: (n, B) node times in chain-major (node-major) layout.  Returns
    the (B,) count of flipped constraint outcomes (0 ⇒ reusable).
    """
    nC = len(cache.c_kind)
    B = len(Db)
    if nC == 0:
        return np.zeros(B, np.int64)
    ok = np.zeros((nC, B), bool)
    st = t[ba.c_src_p]                                        # (nC, B)
    for fid in range(len(cache.fifos)):
        sel = cache.c_fifo == fid
        if not sel.any():
            continue
        w_cols, r_cols = ba.fifo_w_cols[fid], ba.fifo_r_cols[fid]
        nw, nr = len(w_cols), len(r_cols)
        seq = cache.c_seq[sel]
        kind = cache.c_kind[sel]
        stf = st[sel]                                         # (m, B)
        okf = np.zeros((len(seq), B), bool)
        # reads: target = seq-th write (config-independent)
        rd = kind == 0
        if rd.any():
            tgt = np.minimum(seq[rd] - 1, max(nw - 1, 0))
            exists = (seq[rd] - 1) < nw
            t_tgt = (t[w_cols[tgt]] if nw
                     else np.zeros((int(rd.sum()), B), t.dtype))
            okf[rd] = exists[:, None] & (t_tgt < stf[rd])
        # writes: trivially true if seq <= S, else target read (per config)
        wr = kind == 1
        if wr.any():
            seq_w = seq[wr][:, None]                          # (m, 1)
            S = Db[None, :, fid]                              # (1, B)
            triv = seq_w <= S
            tgt_w = seq_w - S - 1                             # (m, B)
            exists_w = tgt_w < nr
            if nr:
                idx = r_cols[np.clip(tgt_w, 0, nr - 1)]
                t_tgt_w = np.take_along_axis(t, idx, axis=0)
            else:
                t_tgt_w = np.zeros(tgt_w.shape, t.dtype)
            okf[wr] = triv | (exists_w & (t_tgt_w < stf[wr]))
        ok[sel] = okf
    return (ok != cache.c_out[:, None]).sum(axis=0).astype(np.int64)


def _solve_block_reference(ba: _BatchArrays, Db: np.ndarray):
    """Jacobi reference solve via :func:`longest_path_chains_batched`
    (one synchronized cross pass per round; the testing oracle)."""
    B = len(Db)
    n = len(ba.perm)
    if ba.bound < (1 << 28):
        dtype, NEG = np.int32, -(1 << 29)
    else:
        dtype, NEG = np.int64, int(NEGI)
    base = np.where(ba.base_p == NEGI, NEG, ba.base_p).astype(dtype)
    base = np.broadcast_to(base, (B, n)).copy()
    dyn_dst, dyn_src, dyn_valid = _regen_war_stacked(ba, Db)
    times_p, conv, rounds = longest_path_chains_batched(
        ba.slices, ba.cw.astype(dtype), base,
        ba.raw_dst, ba.raw_src, ba.raw_w.astype(dtype),
        dyn_dst, dyn_src, dyn_valid, bound=ba.bound)
    return np.ascontiguousarray(times_p.T), conv, rounds


def _solve_block_numpy(ba: _BatchArrays, Db: np.ndarray):
    """Batched seeded Gauss-Seidel fixpoint for one block of configs.

    Node-major ``(n, K)`` layout (cross-edge gathers/scatters hit
    contiguous K-wide rows; the per-chain cummax streams contiguous
    slabs).  Every config starts AT the no-WAR fixpoint, its regenerated
    WAR candidates (per-(FIFO, depth) cached columns) are applied once,
    and then chains are swept in module order with per-(chain, config)
    dirty tracking — so a sweep recomputes only the chains some config's
    WAR constraints actually moved, and slack configs converge with zero
    sweeps.  int32 when the path-length bound allows (halves the traffic).

    Returns (times (n, K) in solve dtype, converged (K,), sweeps).
    Non-converged configs (WAR cycle: times grow past the acyclic bound,
    or the sweep cap is hit) report False and undefined times.
    """
    K = len(Db)
    n = len(ba.perm)
    if ba.bound < (1 << 28):
        dtype, NEG = np.int32, -(1 << 29)
    else:
        dtype, NEG = np.int64, int(NEGI)
    conv_out = np.ones(K, dtype=bool)
    if n == 0 or K == 0:
        return np.zeros((n, K), dtype), conv_out, 0
    cw = ba.cw.astype(dtype)
    t_seed = np.maximum(ba.t_inf, NEG).astype(dtype)
    c_seed = np.maximum(ba.c_inf, NEG).astype(dtype)
    c = np.empty((n, K), dtype=dtype)
    c[:] = c_seed[:, None]
    t = np.empty((n, K), dtype=dtype)
    t[:] = t_seed[:, None]
    nch = len(ba.slices)
    dirty = np.zeros((nch, K), dtype=bool)
    # ---- seed pass: apply each config's WAR candidates over t_inf ----
    war_entries = []        # [rchain, wchain, dcols, src_mat, val_mat, inv]
    for fid, w_cols in enumerate(ba.fifo_w_cols):
        nw = len(w_cols)
        if nw == 0 or int(Db[:, fid].min()) >= nw:
            continue                       # no config overflows this FIFO
        if len(ba.fifo_r_cols[fid]) == 0:
            continue       # blocking overflow ⇒ already masked as deadlock
        uniq, invq = np.unique(Db[:, fid], return_inverse=True)
        cols = [_war_cols(ba, fid, int(S)) for S in uniq]
        src_mat = np.stack([cc[0] for cc in cols], axis=1)    # (nw, u)
        val_mat = np.stack([cc[1] for cc in cols], axis=1)
        if any(cc[3] for cc in cols):      # some depth's WAR binds at seed
            cand_mat = np.maximum(np.stack([cc[2] for cc in cols], axis=1),
                                  NEG).astype(dtype)
            cand = cand_mat[:, invq]                          # (nw, K)
            old = c[w_cols]
            np.maximum(cand, old, out=cand)
            chm = cand != old
            if chm.any():
                c[w_cols] = cand
                dirty[int(ba.fifo_wchain[fid])] |= chm.any(axis=0)
        war_entries.append([int(ba.fifo_rchain[fid]),
                            int(ba.fifo_wchain[fid]), w_cols,
                            src_mat, val_mat, invq])
    war_by_reader: dict = {}
    for e in war_entries:
        war_by_reader.setdefault(e[0], []).append(e)

    times_out = None
    act = np.arange(K)
    sweeps = 0
    max_sweeps = n + 2
    while True:
        # ---- retire configs with no pending chains (or diverged) ----
        pend = dirty.any(axis=0)
        if sweeps >= 8 or not pend.any():
            over = (t > ba.bound).any(axis=0)
        else:
            over = np.zeros(len(act), dtype=bool)
        done = ~pend | over
        if done.any():
            if done.all() and len(act) == K:
                # fast path: the whole block settles at once — hand the
                # working matrix back without the (n, K) copy
                conv_out[act] = ~over
                return t, conv_out, sweeps
            if times_out is None:
                times_out = np.empty((n, K), dtype=dtype)
            rows = act[done]
            times_out[:, rows] = t[:, done]
            conv_out[rows] = ~over[done]
            if done.all():
                break
            keep = ~done
            act = act[keep]
            c = np.ascontiguousarray(c[:, keep])
            t = np.ascontiguousarray(t[:, keep])
            dirty = np.ascontiguousarray(dirty[:, keep])
            for e in war_entries:
                e[5] = e[5][keep]
        if sweeps >= max_sweeps:
            if times_out is None:
                times_out = np.empty((n, K), dtype=dtype)
            times_out[:, act] = t                  # cap hit: cyclic leftovers
            conv_out[act] = False
            break
        sweeps += 1
        # ---- one Gauss-Seidel sweep over dirty chains, module order ----
        for ci in range(nch):
            if not dirty[ci].any():
                continue
            dirty[ci] = False
            lo, hi = ba.slices[ci]
            seg = c[lo:hi] - cw[lo:hi, None]
            np.maximum.accumulate(seg, axis=0, out=seg)
            seg += cw[lo:hi, None]
            if np.array_equal(seg, t[lo:hi]):
                continue                   # no new times ⇒ pushes stand
            t[lo:hi] = seg
            for (dc, scols, dcols, w) in ba.raw_buckets.get(ci, ()):
                cand = t[scols] + w[:, None].astype(dtype)
                old = c[dcols]
                np.maximum(cand, old, out=cand)
                chm = cand != old
                if chm.any():
                    c[dcols] = cand
                    dirty[dc] |= chm.any(axis=0)
            for e in war_by_reader.get(ci, ()):
                wc, dcols, src_mat, val_mat, invq = \
                    e[1], e[2], e[3], e[4], e[5]
                src_idx = src_mat[:, invq]                    # (nw, K_act)
                cand = np.take_along_axis(t, src_idx, axis=0)
                cand += 1
                old = c[dcols]
                cand = np.where(val_mat[:, invq], cand, old)
                np.maximum(cand, old, out=cand)
                chm = cand != old
                if chm.any():
                    c[dcols] = cand
                    dirty[wc] |= chm.any(axis=0)
    return times_out, conv_out, sweeps


def solve_block_status(cache: CompiledGraph, depth_block,
                       backend: str = "numpy", block: int = 128,
                       jax_interpret: bool = True):
    """Engine-free solve phase of :func:`resimulate_batch`.

    Classifies a block of depth vectors against ``cache`` alone — no
    ``OmniSim`` engine, no Python generators, no fallback re-simulation —
    which makes it the unit of work the sweep service (``repro/sweep``)
    ships to shard workers: a :class:`~repro.core.incremental.CompiledGraph`
    pickles cleanly (numpy arrays + the lazily rebuilt ``_BatchArrays``
    view), so a worker process holding only the compiled graph can solve
    any depth block of that design.

    Returns ``(status, cycles, violated, fixpoint_rounds)`` — per config:
    REUSED with its exact cycle count, or DEADLOCK / CYCLE / VIOLATED with
    ``cycles = -1`` (the caller decides whether to pay for the exact
    fallback re-simulation, which *does* need the engine).
    """
    ba = _batch_arrays(cache)
    D = np.asarray(depth_block, dtype=np.int64)
    if D.ndim == 1:
        D = D[None, :]
    K = len(D)
    status = np.zeros(K, dtype=np.int8)
    cycles = np.full(K, -1, dtype=np.int64)
    violated = np.zeros(K, dtype=np.int64)
    # ① structural infeasibility: committed blocking write whose target
    # read never occurred can never commit — deadlock under these depths
    dead = (D < ba.fifo_need[None, :]).any(axis=1)
    status[dead] = DEADLOCK
    alive = np.flatnonzero(~dead)
    total_rounds = 0

    if len(alive):
        if backend == "jax_dense":
            blocks = [(np.arange(len(alive)),
                       *_solve_dense_jax(cache, ba, D[alive],
                                         interpret=jax_interpret,
                                         block=block))]
        elif backend in ("numpy", "reference", "jax"):
            if backend == "numpy":
                solve = _solve_block_numpy
            elif backend == "reference":
                solve = _solve_block_reference
            else:           # sparse chain-structured Pallas max-plus lane
                solve = (lambda ba_, Db_: _solve_sparse_jax(
                    cache, ba_, Db_, interpret=jax_interpret))
            blocks = []
            for lo in range(0, len(alive), max(block, 1)):
                sl = np.arange(lo, min(lo + max(block, 1), len(alive)))
                t_nm, conv, rounds = solve(ba, D[alive[sl]])
                total_rounds = max(total_rounds, rounds)
                blocks.append((sl, t_nm, conv))
        else:
            raise ValueError(f"unknown backend {backend!r}")
        for sl, t_nm, conv in blocks:
            rows = alive[sl]
            status[rows[~conv]] = CYCLE                       # ② event order
            if conv.any():
                # ③ constraint re-check, all configs at once
                viol = _check_constraints_stacked(cache, ba, t_nm,
                                                  D[rows])
                violated[rows[conv]] = viol[conv]
                status[rows[conv & (viol > 0)]] = VIOLATED
                good = conv & (viol == 0)
                if good.any():
                    cyc = (t_nm.max(axis=0) if t_nm.shape[0]
                           else np.zeros(len(rows), np.int64))
                    cycles[rows[good]] = cyc[good]
    return status, cycles, violated, total_rounds


def status_reason(cache: CompiledGraph, status_k: int, violated_k: int,
                  depths_row: np.ndarray,
                  fifo_names: Optional[List[str]] = None) -> str:
    """Human-readable verdict for one config of :func:`solve_block_status`
    (exactly the strings :func:`resimulate_batch` reports)."""
    if status_k in _STATUS_REASON:
        return _STATUS_REASON[status_k]
    if status_k == DEADLOCK:
        ba = _batch_arrays(cache)
        fid = int(np.flatnonzero(depths_row < ba.fifo_need)[0])
        name = fifo_names[fid] if fifo_names else f"fifo{fid}"
        return (f"a committed write on '{name}' can never commit "
                f"with depth {int(depths_row[fid])} (would deadlock)")
    return (f"{int(violated_k)} constraint(s) violated — "
            f"control/data flow diverges")


def materialize_block(result: SimResult, Du: np.ndarray,
                      status_u: np.ndarray, cycles_u: np.ndarray,
                      violated_u: np.ndarray, fallback_mask: np.ndarray,
                      engine_label: str = "omnisim-batch", lock=None,
                      hybrid_cache=None):
    """Post-solve verdict assembly shared by :func:`resimulate_batch` and
    the sweep scheduler (``repro/sweep/scheduler.py``).

    For each unique depth row: the human-readable reason string, a
    lightweight REUSED :class:`SimResult` shell carrying the solved cycle
    count, or — where ``fallback_mask`` allows — the exact fallback full
    re-simulation (``cycles_u`` is updated in place with its result).
    ``lock`` serializes the fallback (it temporarily mutates Program FIFO
    depths); the sweep scheduler passes the design's entry lock, direct
    library calls need none.  ``hybrid_cache`` threads a shared
    :class:`~repro.core.trace.HybridCache` into the fallback simulations,
    so a dynamic design's repeat fallbacks (same depths, any tenant)
    replay the verified whole-run entry instead of re-interpreting.
    Returns ``(results_u, reasons_u)``.
    """
    engine: OmniSim = result.graph
    cache = compile_graph(engine)
    fifo_names = [f.name for f in engine.fifos]
    U = len(Du)
    results_u: List[Optional[SimResult]] = [None] * U
    reasons_u: List[str] = [""] * U
    for u in range(U):
        reasons_u[u] = status_reason(cache, int(status_u[u]),
                                     int(violated_u[u]), Du[u], fifo_names)
        if status_u[u] == REUSED:
            # per-shell copies: SimStats is a mutable dataclass and
            # constraints a mutable list — sharing them would let a caller
            # mutating one sweep result corrupt its siblings AND the cached
            # base run (the graph stays shared by design: it IS the cache)
            results_u[u] = SimResult(
                program=result.program, outputs=dict(result.outputs),
                cycles=int(cycles_u[u]), engine=engine_label,
                stats=copy.copy(result.stats), graph=engine,
                constraints=list(result.constraints),
                depths=tuple(int(d) for d in Du[u]))
        elif fallback_mask[u] and status_u[u] in FALLBACK_STATUSES:
            with (lock if lock is not None else nullcontext()), \
                    program_mutation_lock(engine.program):
                saved = engine.program.depths()
                try:
                    full = simulate(engine.program,
                                    depths=tuple(int(d) for d in Du[u]),
                                    hybrid_cache=hybrid_cache)
                finally:
                    engine.program.with_depths(saved)
            results_u[u] = full
            cycles_u[u] = full.cycles
    return results_u, reasons_u


def resimulate_batch(result: SimResult, depth_matrix,
                     fallback: bool = True, backend: str = "numpy",
                     block: int = 128,
                     jax_interpret: bool = True,
                     dedup: bool = True) -> BatchOutcome:
    """Incrementally re-simulate ``result`` under K depth vectors at once.

    ``depth_matrix``: (K, n_fifos) array-like of candidate depths.  Returns
    a :class:`BatchOutcome` whose k-th entry is exactly what
    ``resimulate(result, depth_matrix[k])`` would report — reusable configs
    get their cycle count from the shared batched fixpoint; deadlocked,
    cyclic or constraint-violating configs fall back to a full
    re-simulation (``fallback=True``) of just that config.

    ``dedup`` (default True) collapses identical depth rows before solving:
    only the unique rows pay for the fixpoint, the constraint re-check AND
    any fallback re-simulation — duplicate rows share one result object.
    Sweep drivers routinely re-propose configurations (grids revisit corner
    points, halving rounds re-evaluate survivors), so this keeps solver
    work proportional to the number of *distinct* configs
    (``BatchOutcome.n_unique``).

    ``backend="jax"`` lowers the fixpoint onto the sparse chain-structured
    Pallas max-plus kernel (``repro.kernels.maxplus.sparse``) — O(K·n +
    K·edges) memory, device-resident sweeps; ``backend="jax_dense"`` keeps
    the legacy dense O(n^2)-per-config vmap lowering for tiny graphs;
    ``backend="reference"`` runs the synchronous Jacobi oracle.  ``block``
    bounds the per-slab working set for every backend.
    """
    t0 = _time.perf_counter()
    engine: OmniSim = result.graph
    assert isinstance(engine, OmniSim), "batched re-sim needs an OmniSim result"
    D = np.asarray(depth_matrix, dtype=np.int64)
    if D.ndim == 1:
        D = D[None, :]
    K, F = D.shape
    if F != len(engine.fifos):
        raise ValueError(f"depth_matrix has {F} columns for "
                         f"{len(engine.fifos)} FIFOs")
    cache = compile_graph(engine)

    if dedup and K > 1:
        Du, inverse = np.unique(D, axis=0, return_inverse=True)
        inverse = inverse.reshape(-1)
    else:
        Du, inverse = D, np.arange(K)
    U = len(Du)
    status_u, cycles_u, violated_u, total_rounds = solve_block_status(
        cache, Du, backend=backend, block=block, jax_interpret=jax_interpret)

    # ④ fall back to full re-simulation for exactly the failed subset —
    # once per unique config; duplicate rows share the result object
    results_u, reasons_u = materialize_block(
        result, Du, status_u, cycles_u, violated_u,
        np.full(U, bool(fallback)))

    status = status_u[inverse]
    return BatchOutcome(ok=status == REUSED, cycles=cycles_u[inverse],
                        status=status, violated=violated_u[inverse],
                        reasons=[reasons_u[i] for i in inverse],
                        results=[results_u[i] for i in inverse],
                        elapsed_s=_time.perf_counter() - t0,
                        fixpoint_rounds=total_rounds, n_unique=U)


# ---------------------------------------------------------------------------
# jax backends: sparse chain-structured kernel + legacy dense vmap
# ---------------------------------------------------------------------------
# Working-set ceiling for the dense lowering: K * npad^2 int32 entries per
# slab.  A module constant so regression tests can shrink it and exercise
# the chunking/error paths without gigabyte batches.
_DENSE_CAP = 1 << 27


def _int32_saturation_guard(ba: _BatchArrays, backend: str) -> None:
    """Refuse int32 device transfer when finite times could exceed int32.

    ``ba.bound`` bounds every finite (acyclic) node time and the numpy
    path switches to int64 at ``2^28``; the jax lanes are int32-only, so
    past that point a silently wrapped time could flip a constraint
    comparison.  Raise instead of wrapping.
    """
    if ba.bound >= (1 << 28):
        raise ValueError(
            f"backend={backend!r} solves in int32 but the graph's "
            f"path-length bound {ba.bound} >= 2^28 risks overflow; "
            f"use backend='numpy' (int64) for this design")


def _sparse_arrays(cache: CompiledGraph, ba: _BatchArrays):
    """Lazily built (and cached on ``ba``) chain-flat device transfer
    arrays for the sparse jax lane."""
    if ba.sparse is None:
        from ..kernels.maxplus.sparse import NEG
        ba.sparse = export_chain_flat(
            ba.slices, ba.cw, ba.c_inf, ba.raw_dst, ba.raw_src, ba.raw_w,
            ba.fifo_w_cols, ba.fifo_r_cols, ba.fifo_blocking,
            bound=ba.bound, neg=int(NEG))
    return ba.sparse


def _solve_sparse_jax(cache: CompiledGraph, ba: _BatchArrays,
                      Db: np.ndarray, interpret: bool = True):
    """Sparse chain-structured Pallas solve for one block of configs.

    Seeds every config at the no-WAR fixpoint contribution (``c_inf``, a
    lower bound of every least fixpoint) and iterates the Jacobi
    chain-pass/cross-pass to the same unique least fixpoint the numpy
    Gauss-Seidel reaches — times, and hence statuses/cycles/violations,
    are bit-identical for converged rows.  O(K·n + K·edges) memory.
    """
    from ..kernels.maxplus import sparse as sp

    _int32_saturation_guard(ba, "jax")
    arr = _sparse_arrays(cache, ba)
    return sp.solve_chains(arr, Db, use_pallas=True, interpret=interpret)


def _solve_dense_jax(cache: CompiledGraph, ba: _BatchArrays, Db: np.ndarray,
                     interpret: bool = True, block: int = 128):
    """Batched node times via ``jax.vmap`` over the dense Pallas max-plus
    kernel (``repro.kernels.maxplus``) — the legacy O(n^2)-per-config
    lowering, kept as ``backend="jax_dense"`` for tiny graphs.

    Builds dense ``(slab, npad, npad)`` max-plus adjacencies (shared
    SEQ+RAW skeleton broadcast, per-config WAR entries scattered in) and
    vmaps the jitted fixpoint, chunking the batch so one slab never
    exceeds ``_DENSE_CAP`` int32 entries (a *single* config past the cap
    is a hard error).  Convergence is certified by one extra sweep:
    non-converged rows (WAR cycles) report False.
    """
    import jax
    import jax.numpy as jnp

    from ..kernels.maxplus.kernel import BLK, NEG as NEG32, maxplus_sweep
    from ..kernels.maxplus.ops import longest_path

    n = cache.n
    npad = ((n + BLK - 1) // BLK) * BLK if n else BLK
    K = len(Db)
    if npad * npad > _DENSE_CAP:
        raise ValueError(
            f"dense jax backend needs npad^2 <= {_DENSE_CAP} per config "
            f"(got {npad}^2); use backend='numpy' (or the sparse "
            f"backend='jax') for large graphs")
    _int32_saturation_guard(ba, "jax_dense")
    slab = max(1, min(max(block, 1), _DENSE_CAP // (npad * npad)))
    # clip int64 weights against the kernel's -INF before the int32 cast —
    # a bare .astype would wrap NEGI into a huge positive phantom edge
    # (the hazard ops.finalize_times documents for a/base)
    b = np.full((npad,), int(NEG32), dtype=np.int32)
    b[:n] = np.maximum(cache.base, int(NEG32)).astype(np.int32)
    A = np.full((npad, npad), int(NEG32), dtype=np.int32)
    for ch in cache.chains:                      # SEQ skeleton
        if len(ch) > 1:
            A[ch[1:], ch[:-1]] = np.maximum(
                cache.seq_w[ch[1:]], int(NEG32)).astype(np.int32)
    A[cache.raw_dst, cache.raw_src] = np.maximum(
        cache.raw_w, int(NEG32)).astype(np.int32)
    bK = jnp.asarray(b)
    solve = jax.vmap(lambda a: longest_path(a, bK, use_pallas=True,
                                            interpret=interpret))
    sweep = jax.vmap(lambda a, t: maxplus_sweep(a, t, bK,
                                                interpret=interpret))
    times_parts, conv_parts = [], []
    for lo in range(0, K, slab):
        Ds = Db[lo:lo + slab]
        AK = np.broadcast_to(A, (len(Ds), npad, npad)).copy()
        for fid, (w_nodes, r_nodes, blk) in enumerate(cache.fifos):
            nw, nr = len(w_nodes), len(r_nodes)
            if nw == 0 or int(Ds[:, fid].min()) >= nw:
                continue
            w_seq = np.arange(1, nw + 1, dtype=np.int64)
            tgt = w_seq[None, :] - Ds[:, fid][:, None] - 1
            valid = blk[None, :] & (tgt >= 0) & (tgt < nr)
            kk, jj = np.nonzero(valid)
            AK[kk, w_nodes[jj], r_nodes[tgt[kk, jj]]] = 1
        aK = jnp.asarray(AK)
        tK = solve(aK)
        # certify fixpoint: one more sweep must be a no-op (cycles diverge)
        conv_parts.append(np.asarray((sweep(aK, tK) == tK).all(axis=1)))
        times_parts.append(np.asarray(tK)[:, :n].astype(np.int64))
    times = np.concatenate(times_parts) if times_parts else \
        np.zeros((0, n), np.int64)
    conv = np.concatenate(conv_parts) if conv_parts else np.zeros(0, bool)
    times_nm = (np.ascontiguousarray(times[:, ba.perm].T) if n
                else times.T)
    return times_nm, conv
