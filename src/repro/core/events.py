"""Event, request and query records for the OmniSim engine.

Mirrors the paper's Table 1 (requests emitted by Func Sim threads) and the
node/edge records of the partial simulation graph (Sec. 5/6).  Every FIFO
access becomes a *node* in the simulation graph; the node's ``time`` is the
hardware cycle at which the access commits.  Node creation order is a
topological order of the graph (see DESIGN.md Sec. 2), which the finalization
pass (``core/graph.py``) and the Pallas max-plus kernel rely on.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Any, NamedTuple, Optional


class NodeKind(enum.Enum):
    """Kinds of simulation-graph nodes (events)."""

    START = "start"            # module start
    END = "end"                # module end
    FIFO_WRITE = "fifo_write"  # committed (blocking or successful NB) write
    FIFO_READ = "fifo_read"    # committed (blocking or successful NB) read
    NB_FAIL = "nb_fail"        # failed non-blocking access (occupies a cycle)
    PROBE = "probe"            # empty()/full() status check
    DELAY = "delay"            # explicit latency from the static schedule


class RequestType(enum.Enum):
    """Requests a Func Sim task can make — paper Table 1.

    The first group is informative (updates graph state); the last group are
    *queries* that must be resolved by the Perf Sim orchestrator against the
    FIFO tables before the task may resume.
    """

    TRACE_BLOCK = "TraceBlock"
    START_TASK = "StartTask"
    FIFO_READ = "FifoRead"          # blocking read
    FIFO_WRITE = "FifoWrite"        # blocking write
    AXI_READ = "AxiRead"            # modeled as FIFO pair; kept for parity
    AXI_WRITE = "AxiWrite"
    # ---- queries ----
    FIFO_CAN_READ = "FifoCanRead"   # empty() probe
    FIFO_CAN_WRITE = "FifoCanWrite" # full() probe
    FIFO_NB_READ = "FifoNbRead"
    FIFO_NB_WRITE = "FifoNbWrite"

    @property
    def is_query(self) -> bool:
        return self in (
            RequestType.FIFO_CAN_READ,
            RequestType.FIFO_CAN_WRITE,
            RequestType.FIFO_NB_READ,
            RequestType.FIFO_NB_WRITE,
        )


@dataclass
class Node:
    """A node of the (partial) simulation graph."""

    idx: int
    module: int                 # module index
    kind: NodeKind
    time: int                   # hardware cycle at which the event commits
    fifo: int = -1              # FIFO id (or -1)
    seq: int = -1               # 1-based sequence number of this access on its FIFO
    # incoming edges: list of (src node idx, weight).  src < idx holds for
    # engine-built graphs (creation order is topological); trace-replayed
    # graphs (core/trace.py) are chain-major, so use order-insensitive
    # longest-path backends (level-scheduled or fixpoint) on them.
    preds: list = field(default_factory=list)

    def add_edge(self, src: int, weight: int) -> None:
        self.preds.append((src, weight))


@dataclass
class Query:
    """A pending non-blocking query — paper Table 2.

    ``source_time`` is the hardware cycle of the NB access being queried.
    ``target`` identifies the committed access the source is compared against:
    for the w-th NB write with FIFO size S it is the (w-S)-th read; for the
    r-th NB read it is the r-th write.  ``None`` target means the access
    trivially succeeds (w <= S).
    """

    qid: int
    module: int
    rtype: RequestType
    fifo: int
    source_seq: int            # w for writes, r for reads (1-based, prospective)
    source_time: int
    payload: Any = None        # value being written, for NB writes

    def target_seq(self, depth: int) -> Optional[int]:
        if self.rtype in (RequestType.FIFO_NB_WRITE, RequestType.FIFO_CAN_WRITE):
            if self.source_seq <= depth:
                return None
            return self.source_seq - depth
        return self.source_seq


class Constraint(NamedTuple):
    """Outcome of a resolved query, recorded for incremental re-simulation.

    On a FIFO-depth change, finalization is re-run and every constraint is
    re-evaluated against the new node times; if any query would now resolve
    differently, the simulation graph is invalid and a full re-sim is needed
    (paper Sec. 7.2).  A NamedTuple rather than a dataclass: query-dominated
    designs materialize one record per query, and construction cost is on
    the hot path of both the generator engine and the hybrid replay.
    """

    rtype: RequestType
    fifo: int
    source_seq: int
    source_node: int            # node idx of the probe/NB event
    outcome: bool


@dataclass
class SimStats:
    """Bookkeeping counters, reported by benchmarks."""

    nodes: int = 0
    edges: int = 0
    queries: int = 0
    queries_forced_false: int = 0   # resolved by the earliest-query rule
    queries_periodized: int = 0     # resolved in bulk by query periodization
                                    # (hybrid engine poll-loop bursts; the
                                    # generator engine always reports 0)
    quiescence_rounds: int = 0
    resumes: int = 0
    skipped_probes: int = 0         # dead-query elimination (paper Sec. 7.3.2)


class DeadlockError(RuntimeError):
    """Raised when a true design-level deadlock is detected (paper Sec. 7.1)."""

    def __init__(self, blocked: list, cycle: int):
        self.blocked = blocked
        self.cycle = cycle
        super().__init__(
            f"unresolvable deadlock detected at cycle {cycle}: "
            f"all tasks blocked: {blocked}"
        )


class UnsupportedDesignError(RuntimeError):
    """Raised by the decoupled (LightningSim-style) baseline on Type B/C designs."""
