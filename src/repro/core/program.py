"""Dataflow-design DSL.

The paper consumes Vitis HLS LLVM bitcode plus the C-synthesis static
schedule.  We have no Vitis front-end, so designs are authored in this small
Python DSL carrying the *same information*: modules (dataflow tasks), FIFO
channels with depths, blocking / non-blocking accesses, status probes, and
explicit static-schedule latencies (``Delay``).  Every yielded op costs one
hardware cycle unless stated otherwise — i.e. loops have II=1 per op by
default, and extra latency is expressed with ``Delay`` (this mirrors the
dynamic-stage unrolling of the paper's Sec. 5.1).

A module body is a Python *generator function*; it yields ops and receives
results (read values, NB success flags) via ``send``.  Bodies must be
**pure and re-runnable**: the framework may invoke ``fn()`` more than once
per Program (trace recording with generator fallback, incremental/DSE
fallback re-simulation, the RTL oracle), so a body must not mutate state
shared across invocations (e.g. popping from a closure list) or perform
external side effects.  Example::

    prog = Program("producer_consumer")
    data = prog.fifo("data", depth=2)

    @prog.module("producer")
    def producer():
        for i in range(N):
            yield Write(data, i)

    @prog.module("consumer")
    def consumer():
        total = 0
        for _ in range(N):
            v = yield Read(data)
            total += v
        yield Emit("sum", total)

Cycle-cost model (shared by the OmniSim engine, the cycle-stepped RTL oracle
and the decoupled baseline so that accuracy comparisons are apples-to-apples):

==============  =========================================================
op              cost
==============  =========================================================
Read            commits at u = max(t, time(matching write) + 1); next op
                at u+1.  Pauses while the matching write is unknown.
Write           commits at u = max(t, time((w-S)-th read) + 1); next op at
                u+1.  Pauses while the FIFO is full.
ReadNB          samples at t; success iff time(r-th write) < t. 1 cycle.
WriteNB         samples at t; success iff w <= S or time((w-S)-th read) < t.
                1 cycle.
Empty/Full      samples occupancy at t, 1 cycle.  ``used=False`` marks a
                probe whose result is dead (paper Sec. 7.3.2) — skipped.
Delay(n)        advances the local clock by n cycles.
Emit            records a functional output; zero cycles.
==============  =========================================================
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Generator, List, Optional, Tuple


# --------------------------------------------------------------------------
# Ops — slotted plain classes, not dataclasses: one op object is constructed
# per yielded operation, so __init__ is on the hot path of every engine
# (frozen-dataclass construction costs an object.__setattr__ per field).
# --------------------------------------------------------------------------
class Op:
    __slots__ = ()

    def __repr__(self) -> str:
        args = ", ".join(f"{s}={getattr(self, s)!r}" for s in self.__slots__)
        return f"{self.__class__.__name__}({args})"


class Read(Op):
    __slots__ = ("fifo",)

    def __init__(self, fifo: "Fifo"):
        self.fifo = fifo


class Write(Op):
    __slots__ = ("fifo", "value")

    def __init__(self, fifo: "Fifo", value: Any):
        self.fifo = fifo
        self.value = value


class ReadNB(Op):
    __slots__ = ("fifo",)

    def __init__(self, fifo: "Fifo"):
        self.fifo = fifo


class WriteNB(Op):
    __slots__ = ("fifo", "value")

    def __init__(self, fifo: "Fifo", value: Any):
        self.fifo = fifo
        self.value = value


class Empty(Op):
    __slots__ = ("fifo", "used")

    def __init__(self, fifo: "Fifo", used: bool = True):
        self.fifo = fifo
        self.used = used    # False → dead probe, eliminated (paper Sec. 7.3.2)


class Full(Op):
    __slots__ = ("fifo", "used")

    def __init__(self, fifo: "Fifo", used: bool = True):
        self.fifo = fifo
        self.used = used


class Delay(Op):
    __slots__ = ("cycles",)

    def __init__(self, cycles: int):
        self.cycles = cycles


class Emit(Op):
    __slots__ = ("key", "value")

    def __init__(self, key: str, value: Any):
        self.key = key
        self.value = value


# --------------------------------------------------------------------------
# Program structure
# --------------------------------------------------------------------------
@dataclass
class Fifo:
    name: str
    depth: int
    fid: int = -1

    def __hash__(self) -> int:
        return id(self)


@dataclass
class Module:
    name: str
    fn: Callable[[], Generator]
    mid: int = -1


GenFn = Callable[[], Generator]


class Program:
    """A dataflow design: FIFOs + modules, analogous to an HLS dataflow region."""

    def __init__(self, name: str, declared_type: Optional[str] = None):
        self.name = name
        self.fifos: List[Fifo] = []
        self.modules: List[Module] = []
        # Optional author-declared taxonomy type ("A" | "B" | "C"); the
        # classifier cross-checks the statically detectable features.
        self.declared_type = declared_type

    # -- construction ------------------------------------------------------
    def fifo(self, name: str, depth: int) -> Fifo:
        f = Fifo(name=name, depth=depth, fid=len(self.fifos))
        self.fifos.append(f)
        return f

    def module(self, name: str) -> Callable[[GenFn], GenFn]:
        def deco(fn: GenFn) -> GenFn:
            m = Module(name=name, fn=fn, mid=len(self.modules))
            self.modules.append(m)
            return fn

        return deco

    def add_module(self, name: str, fn: GenFn) -> Module:
        m = Module(name=name, fn=fn, mid=len(self.modules))
        self.modules.append(m)
        return m

    # -- depth overrides (for incremental re-simulation) --------------------
    def depths(self) -> Tuple[int, ...]:
        return tuple(f.depth for f in self.fifos)

    def with_depths(self, depths) -> "Program":
        assert len(depths) == len(self.fifos)
        for f, d in zip(self.fifos, depths):
            f.depth = int(d)
        return self

    # -- static structure for taxonomy ---------------------------------------
    def static_trace(self, max_ops_per_module: int = 100_000) -> Dict[str, Any]:
        """Dry-inspect module generators is impossible without running them;
        static features here are derived from a bounded functional probe run
        by the classifier (see core/taxonomy.py)."""
        raise NotImplementedError("use core.taxonomy.classify(program)")


@dataclass
class SimResult:
    """Result of a simulation run (any engine)."""

    program: str
    outputs: Dict[str, Any]
    cycles: int
    engine: str
    stats: Any = None
    graph: Any = None            # SimGraph for the OmniSim engine
    constraints: list = field(default_factory=list)
    depths: Tuple[int, ...] = ()
    deadlock: bool = False
    deadlock_cycle: int = -1

    def summary(self) -> str:
        out = ", ".join(f"{k}={v}" for k, v in sorted(self.outputs.items()))
        dl = f" DEADLOCK@{self.deadlock_cycle}" if self.deadlock else ""
        return f"[{self.engine}] {self.program}: cycles={self.cycles}{dl} {out}"
