"""Decoupled two-phase baseline simulator (LightningSim/V2-style), and a
Vitis-C-sim emulation used to reproduce the paper's Table 3 comparison.

Phase 1 (untimed): modules execute *sequentially* in declaration order with
infinite FIFO depths, recording a trace (the paper's event lists).  This is
exactly the regime in which LightningSim is sound: Type A designs only.  A
non-blocking access, a status probe, or a read from an empty FIFO under
sequential execution means the design is Type B/C → ``UnsupportedDesignError``
(LightningSim "supports only a limited subset of HLS designs").

Phase 2 (timed): the trace is compiled into a simulation graph — sequential
edges with static-schedule gaps, read-after-write edges, and depth-dependent
write-after-read edges — and the cycle count is the longest path.  Phase 2
alone re-runs in microseconds for new FIFO depths (LightningSim's incremental
strength on Type A designs, Table 6 baseline).

``csim`` emulates what Vitis C simulation does to Type B/C designs (paper
Table 3, first column): sequential execution where ``write_nb`` always
succeeds, streams are infinitely deep, reads from empty streams warn and
return 0, and leftover data warns — i.e. functionally wrong results.
"""
from __future__ import annotations

import time as _time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .events import UnsupportedDesignError
from .graph import longest_path_numpy
from .program import (Delay, Emit, Empty, Full, Program, Read, ReadNB,
                      SimResult, Write, WriteNB)


@dataclass
class _TraceEvent:
    module: int
    kind: str          # "read" | "write"
    fifo: int
    seq: int           # 1-based per fifo per kind
    gap: int           # schedule cycles since previous event of this module


@dataclass
class Phase1Trace:
    events: List[_TraceEvent] = field(default_factory=list)
    end_gap: List[int] = field(default_factory=list)   # per module
    outputs: Dict[str, Any] = field(default_factory=dict)


class LightningSim:
    """Two-phase decoupled simulator. Type A designs only."""

    def __init__(self, program: Program):
        self.program = program
        self.trace: Optional[Phase1Trace] = None
        # phase-2 cache
        self._csr = None

    # ---------------------------------------------------------------- phase 1
    def phase1(self, max_ops: int = 10_000_000) -> Phase1Trace:
        trace = Phase1Trace()
        buffers: Dict[int, deque] = {f.fid: deque() for f in self.program.fifos}
        w_seq = {f.fid: 0 for f in self.program.fifos}
        r_seq = {f.fid: 0 for f in self.program.fifos}
        ops = 0
        for mod in self.program.modules:
            gen = mod.fn()
            clock_gap = 1          # schedule distance since previous event
            send = None
            started = False
            while True:
                ops += 1
                if ops > max_ops:
                    raise UnsupportedDesignError(
                        f"{self.program.name}: module '{mod.name}' does not "
                        f"terminate under sequential execution (Type B/C)")
                try:
                    op = next(gen) if not started else gen.send(send)
                    started = True
                    send = None
                except StopIteration:
                    break
                if isinstance(op, Emit):
                    trace.outputs[op.key] = op.value
                    continue
                if isinstance(op, Delay):
                    clock_gap += op.cycles
                    continue
                if isinstance(op, (ReadNB, WriteNB, Empty, Full)):
                    raise UnsupportedDesignError(
                        f"{self.program.name}: non-blocking access in module "
                        f"'{mod.name}' — Type B/C design, not supported by the "
                        f"decoupled two-phase simulator")
                if isinstance(op, Read):
                    fid = op.fifo.fid
                    if not buffers[fid]:
                        raise UnsupportedDesignError(
                            f"{self.program.name}: module '{mod.name}' reads "
                            f"from empty FIFO '{op.fifo.name}' under "
                            f"sequential execution — cyclic dependency "
                            f"(Type B/C), not supported")
                    send = buffers[fid].popleft()
                    r_seq[fid] += 1
                    trace.events.append(_TraceEvent(mod.mid, "read", fid,
                                                    r_seq[fid], clock_gap))
                    clock_gap = 1
                elif isinstance(op, Write):
                    fid = op.fifo.fid
                    buffers[fid].append(op.value)
                    w_seq[fid] += 1
                    trace.events.append(_TraceEvent(mod.mid, "write", fid,
                                                    w_seq[fid], clock_gap))
                    clock_gap = 1
                else:  # pragma: no cover
                    raise TypeError(f"unknown op {op!r}")
            trace.end_gap.append(clock_gap)
        self.trace = trace
        self._build_static_graph()
        return trace

    # ---------------------------------------------------------------- phase 2
    def _build_static_graph(self) -> None:
        """Compile the trace into CSR parts that do not depend on depths."""
        tr = self.trace
        n_mod = len(self.program.modules)
        n = len(tr.events) + 2 * n_mod   # + START/END per module
        start_idx = {m: len(tr.events) + 2 * m for m in range(n_mod)}
        end_idx = {m: len(tr.events) + 2 * m + 1 for m in range(n_mod)}
        edges: List[Tuple[int, int, int]] = []   # (dst, src, weight)
        last_of_mod = dict(start_idx)
        # per-fifo event node ids, in seq order
        self.fifo_writes: Dict[int, List[int]] = {f.fid: [] for f in self.program.fifos}
        self.fifo_reads: Dict[int, List[int]] = {f.fid: [] for f in self.program.fifos}
        for i, ev in enumerate(tr.events):
            edges.append((i, last_of_mod[ev.module], ev.gap))
            last_of_mod[ev.module] = i
            if ev.kind == "write":
                self.fifo_writes[ev.fifo].append(i)
            else:
                self.fifo_reads[ev.fifo].append(i)
        for m in range(n_mod):
            edges.append((end_idx[m], last_of_mod[m], tr.end_gap[m]))
        # RAW edges: write#k -> read#k, weight 1
        for fid in self.fifo_writes:
            for wn, rn in zip(self.fifo_writes[fid], self.fifo_reads[fid]):
                edges.append((rn, wn, 1))
        self._static = (n, edges, {m: start_idx[m] for m in range(n_mod)},
                        {m: end_idx[m] for m in range(n_mod)})

    def phase2(self, depths=None) -> Tuple[int, np.ndarray]:
        """Stall analysis with concrete FIFO depths → cycle count."""
        assert self.trace is not None, "run phase1 first"
        if depths is None:
            depths = self.program.depths()
        n, base_edges, start_idx, _ = self._static
        edges = list(base_edges)
        # WAR edges: read#(w-S) -> write#w, weight 1
        for f in self.program.fifos:
            S = depths[f.fid]
            writes = self.fifo_writes[f.fid]
            reads = self.fifo_reads[f.fid]
            for w0, wn in enumerate(writes):       # w0 is 0-based (w = w0+1)
                if w0 + 1 > S:
                    tgt = w0 + 1 - S - 1
                    if tgt >= len(reads):
                        raise UnsupportedDesignError(
                            f"write #{w0+1} on '{f.name}' can never commit "
                            f"with depth {S} (deadlock)")
                    edges.append((wn, reads[tgt], 1))
        indptr = np.zeros(n + 1, dtype=np.int64)
        for dst, _, _ in edges:
            indptr[dst + 1] += 1
        indptr = np.cumsum(indptr)
        src = np.zeros(len(edges), dtype=np.int64)
        wgt = np.zeros(len(edges), dtype=np.int64)
        fill = indptr[:-1].copy()
        for dst, s, w in edges:
            src[fill[dst]] = s
            wgt[fill[dst]] = w
            fill[dst] += 1
        base = np.zeros(n, dtype=np.int64)   # START nodes at 0; rest from edges
        times = longest_path_numpy(indptr, src, wgt, base)
        return int(times.max()), times

    # ------------------------------------------------------------------- API
    def run(self, depths=None) -> SimResult:
        t0 = _time.perf_counter()
        self.phase1()
        t1 = _time.perf_counter()
        cycles, _ = self.phase2(depths)
        t2 = _time.perf_counter()
        res = SimResult(program=self.program.name,
                        outputs=dict(self.trace.outputs), cycles=cycles,
                        engine="lightningsim", depths=self.program.depths())
        res.stats = {"phase1_s": t1 - t0, "phase2_s": t2 - t1}
        return res

    def resimulate(self, depths) -> SimResult:
        """Incremental: phase 2 only (the baseline's Table 6 capability)."""
        t0 = _time.perf_counter()
        cycles, _ = self.phase2(depths)
        dt = _time.perf_counter() - t0
        res = SimResult(program=self.program.name,
                        outputs=dict(self.trace.outputs), cycles=cycles,
                        engine="lightningsim-incr", depths=tuple(depths))
        res.stats = {"phase2_s": dt}
        return res


# ------------------------------------------------------------------------
# Vitis C-sim emulation (paper Table 3, "C-sim" column)
# ------------------------------------------------------------------------
class CSimCrash(RuntimeError):
    """Emulates '@E Simulation failed: SIGSEGV.'"""


def csim(program: Program, max_ops: int = 10_000_000) -> SimResult:
    """Sequential C-semantics run: what Vitis C simulation would print.

    Streams are infinitely deep; ``write_nb`` always succeeds; ``read_nb``
    and ``empty``/``full`` see the instantaneous software state; reads from
    empty streams warn and return 0.  Infinite producer loops guarded by a
    done-signal never see the signal and crash (array overrun → SIGSEGV),
    exactly the failure modes of Table 3.
    """
    buffers: Dict[int, deque] = {f.fid: deque() for f in program.fifos}
    outputs: Dict[str, Any] = {}
    warnings: List[str] = []
    ops = 0
    try:
        for mod in program.modules:
            gen = mod.fn()
            send = None
            started = False
            while True:
                ops += 1
                if ops > max_ops:
                    raise CSimCrash("SIGSEGV")   # runaway loop → crash
                try:
                    op = next(gen) if not started else gen.send(send)
                    started = True
                    send = None
                except StopIteration:
                    break
                if isinstance(op, Emit):
                    outputs[op.key] = op.value
                elif isinstance(op, Delay):
                    pass
                elif isinstance(op, Read):
                    buf = buffers[op.fifo.fid]
                    if buf:
                        send = buf.popleft()
                    else:
                        warnings.append(
                            f"WARNING: Hls::stream '{op.fifo.name}' is read "
                            f"while empty, returning zero")
                        send = 0
                elif isinstance(op, Write):
                    buffers[op.fifo.fid].append(op.value)
                elif isinstance(op, ReadNB):
                    buf = buffers[op.fifo.fid]
                    send = (True, buf.popleft()) if buf else (False, None)
                elif isinstance(op, WriteNB):
                    buffers[op.fifo.fid].append(op.value)  # always "succeeds"
                    send = True
                elif isinstance(op, Empty):
                    send = not buffers[op.fifo.fid]
                elif isinstance(op, Full):
                    send = False                           # infinite stream
                else:  # pragma: no cover
                    raise TypeError(f"unknown op {op!r}")
    except (CSimCrash, IndexError):   # array overrun in an unterminated loop
        res = SimResult(program=program.name,
                        outputs={"__crash__": "@E Simulation failed: SIGSEGV."},
                        cycles=-1, engine="csim", depths=program.depths())
        return res
    for f in program.fifos:
        if buffers[f.fid]:
            warnings.append(
                f"WARNING: Hls::stream '{f.name}' contains leftover data")
    if warnings:
        outputs["__warnings__"] = warnings
    return SimResult(program=program.name, outputs=outputs, cycles=-1,
                     engine="csim", depths=program.depths())
