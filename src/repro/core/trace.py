"""Trace compilation: replay the *initial* simulation at array speed.

The paper's Sec. 5.1 observation — once a design's FIFO-access trace is
known, simulation collapses from interpreting module bodies to replaying a
compiled trace — applied to the DSL engine.  This is the same move
LightningSimV2 (arXiv:2404.09471) makes over LightningSim's interpreted
traces (arXiv:2304.11219), lifted from *re*-simulation to the very first
simulation of a design.

Pipeline (``simulate_traced``):

  1. **Record** (:func:`record_trace`): every module generator is entered
     exactly once and driven to completion under *untimed* Kahn-process-
     network semantics (unbounded FIFOs, block only on an empty read, round
     robin between modules).  Blocking dataflow designs are deterministic
     KPNs, so the recorded op stream, FIFO values and ``Emit`` outputs are
     identical to what the timed engine would produce — per module we keep
     flat op arrays (opcode, fifo id, inter-op gap in cycles).  A live
     non-blocking access or status probe makes control flow potentially
     cycle-dependent: recording aborts with :class:`TraceUnsupported` and
     the engine falls back to the generator path (``core/engine.py``).

  2. **Compile** (:func:`compile_trace`): the op arrays are turned into the
     simulation-graph skeleton *without running anything*: per-module chains
     (SEQ weights = 1 + accumulated ``Delay``), RAW edges (r-th read <- r-th
     write, weight 1) and, per depth vector, WAR edges (w-th write <-
     (w-S)-th read, weight 1) — exactly the edges the engine's
     ``_exec_read``/``_exec_write`` would have created one Python object at
     a time.  Compilation works on the expanded arrays (graph, times and
     FIFO tables are inherently O(events)); after the run, steady-state
     loops are periodized — the trace *retained* on the engine is
     re-rolled to ``lead + body x reps`` (:meth:`ModuleTrace.periodize`),
     so a million-event pipeline keeps O(period) trace metadata around.

  3. **Replay** (:func:`simulate_traced`): node commit times are the
     longest path over that graph, computed by a per-chain ``cummax``
     Gauss-Seidel fixpoint with dirty-chain tracking — array-level dispatch
     instead of per-op generator resumption.  The result is bit-identical
     to the generator engine (tests pin ``SimResult`` equality across the
     taxonomy designs): same cycles, outputs, FIFO tables and graph, plus a
     pre-built :class:`~repro.core.incremental.CompiledGraph` so the first
     ``resimulate``/``resimulate_batch`` call skips graph re-interpretation
     entirely.

Structural deadlocks (a blocking write whose target read never occurs, or
regenerated WAR edges forming a cycle) and untimed-KPN deadlocks (cyclic
blocking waits) raise :class:`TraceUnsupported`; the generator engine then
reproduces the paper-exact deadlock report (stall cycle, blocked modules).

All times are hardware **cycles** (1-based commit cycles, START nodes at
cycle 0); all per-FIFO sequence numbers are 1-based **event** counts, as in
paper Table 2.
"""
from __future__ import annotations

import hashlib
import heapq
import types
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from itertools import repeat
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .events import Constraint, Node, NodeKind, RequestType, SimStats
from .program import (Delay, Emit, Empty, Fifo, Full, Program, Read, ReadNB,
                      SimResult, Write, WriteNB)

NEGI = np.int64(-(1 << 60))

# ---------------------------------------------------------------------------
# Flat op encoding (one row per recorded op).  OP_READ/OP_WRITE are the
# blocking accesses that survive into the straight-line compiled arrays —
# delays fold into the gap column, dead probes into a 1-cycle gap, Emits
# into the outputs dict.  The hybrid engine additionally records committed
# NB accesses (OP_READ_NB/OP_WRITE_NB), failed NB accesses (OP_NB_FAIL) and
# used status probes (OP_PROBE) as chain rows, so its segmented op streams
# share this encoding end to end.
# ---------------------------------------------------------------------------
OP_READ, OP_WRITE, OP_READ_NB, OP_WRITE_NB = 0, 1, 2, 3
OP_EMPTY, OP_FULL, OP_DELAY, OP_EMIT = 4, 5, 6, 7
OP_NB_FAIL, OP_PROBE, OP_PROBE_DEAD = 8, 9, 10

# node-kind codes of the compiled graph (map to events.NodeKind)
_NK_START, _NK_END, _NK_READ, _NK_WRITE = 0, 1, 2, 3
_NK_NB_FAIL, _NK_PROBE = 4, 5
_NK_TO_NODEKIND = {_NK_START: NodeKind.START, _NK_END: NodeKind.END,
                   _NK_READ: NodeKind.FIFO_READ, _NK_WRITE: NodeKind.FIFO_WRITE,
                   _NK_NB_FAIL: NodeKind.NB_FAIL, _NK_PROBE: NodeKind.PROBE}

# row opcode -> node-kind code (committed NB accesses become ordinary
# FIFO_READ/FIFO_WRITE nodes, exactly as in the generator engine)
_ROW_TO_NK = np.full(11, -1, dtype=np.int8)
_ROW_TO_NK[OP_READ] = _NK_READ
_ROW_TO_NK[OP_READ_NB] = _NK_READ
_ROW_TO_NK[OP_WRITE] = _NK_WRITE
_ROW_TO_NK[OP_WRITE_NB] = _NK_WRITE
_ROW_TO_NK[OP_NB_FAIL] = _NK_NB_FAIL
_ROW_TO_NK[OP_PROBE] = _NK_PROBE


class TraceUnsupported(Exception):
    """The design (or this run of it) cannot be trace-compiled.

    Raised on live non-blocking accesses / status probes (cycle-dependent
    control flow), untimed-KPN deadlock, SPSC violations, and depth-induced
    structural deadlocks or WAR cycles.  ``simulate(..., trace="auto")``
    catches it and falls back to the hybrid segmented replay
    (:func:`simulate_hybrid`) when ``dynamic`` is set — i.e. the only
    obstacle was cycle-dependent NB/probe control flow — and otherwise to
    the generator engine, which handles every design class (paper Fig. 3,
    Type A/B/C).
    """

    def __init__(self, msg: str, dynamic: bool = False):
        super().__init__(msg)
        self.dynamic = dynamic


# ---------------------------------------------------------------------------
# Recorded per-module op streams
# ---------------------------------------------------------------------------
@dataclass
class ModuleTrace:
    """One module's recorded op stream as flat arrays.

    ``kind[i]``/``fifo[i]`` identify the i-th FIFO access (OP_READ or
    OP_WRITE); ``gap[i]`` is the static-schedule distance in cycles from the
    previous access (1 + accumulated ``Delay``/dead-probe cycles — the SEQ
    edge weight of paper Sec. 7.3.1).  ``end_gap`` is the distance from the
    last access to the module END event.

    Periodized form (``reps > 1``): the stored arrays are the first ``lead``
    ops followed by one period of the steady-state loop body; the full
    stream is ``lead + body x reps`` (:meth:`expand`).
    """

    mid: int
    name: str
    kind: np.ndarray                # (L,) int8
    fifo: np.ndarray                # (L,) int64
    gap: np.ndarray                 # (L,) int64 — cycles
    end_gap: int
    lead: int = 0
    reps: int = 1
    # set by periodize() when the search found nothing, so re-periodizing
    # a trace (the delta patch path periodizes spliced recordings whose
    # unchanged modules were already scanned) skips the O(L^2) re-search
    no_period: bool = False

    @property
    def n_ops(self) -> int:
        """Number of FIFO accesses in the *expanded* stream (events)."""
        return self.lead + (len(self.kind) - self.lead) * self.reps

    @property
    def n_stored(self) -> int:
        """Number of op rows actually stored (lead + one body period)."""
        return len(self.kind)

    def expand(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Materialize the full (kind, fifo, gap) arrays via ``np.tile``."""
        if self.reps == 1:
            return self.kind, self.fifo, self.gap
        lead = self.lead
        return (
            np.concatenate([self.kind[:lead], np.tile(self.kind[lead:], self.reps)]),
            np.concatenate([self.fifo[:lead], np.tile(self.fifo[lead:], self.reps)]),
            np.concatenate([self.gap[:lead], np.tile(self.gap[lead:], self.reps)]),
        )

    def periodize(self, min_body: int = 4) -> "ModuleTrace":
        """Detect a steady-state loop and return the compressed trace.

        Finds the smallest period ``p`` (after a short lead of 0-2 warm-up
        ops) such that the remaining stream is an integer number of exact
        (kind, fifo, gap) repetitions, mirroring the paper's dynamic-stage
        unrolling of Sec. 5.1 in reverse: we *re-roll* the unrolled steady
        state.  Returns ``self`` unchanged when no period is found (and
        marks ``no_period`` so repeat calls are O(1)).
        """
        if self.no_period or self.reps != 1 or len(self.kind) < 2 * min_body:
            return self
        L = len(self.kind)
        key = self.fifo * 8 + self.kind          # one comparable op id
        for lead in range(0, min(3, L)):
            T = L - lead
            for p in range(1, T // 2 + 1):
                if T % p:
                    continue
                # cheap reject: first period vs second period
                if not np.array_equal(key[lead:lead + p],
                                      key[lead + p:lead + 2 * p]):
                    continue
                if not np.array_equal(self.gap[lead:lead + p],
                                      self.gap[lead + p:lead + 2 * p]):
                    continue
                # full verify: stream is periodic with period p after lead
                if (np.array_equal(key[lead:L - p], key[lead + p:])
                        and np.array_equal(self.gap[lead:L - p],
                                           self.gap[lead + p:])):
                    return ModuleTrace(
                        mid=self.mid, name=self.name,
                        kind=self.kind[:lead + p].copy(),
                        fifo=self.fifo[:lead + p].copy(),
                        gap=self.gap[:lead + p].copy(),
                        end_gap=self.end_gap, lead=lead, reps=T // p)
        self.no_period = True
        return self


@dataclass
class RecordedTrace:
    """A whole design's recorded op streams + functional results.

    ``outputs`` are the design's ``Emit`` records (complete — recording runs
    every module to termination); ``leftovers[fid]`` are payloads written
    but never consumed (they become the FIFO tables' end-of-run residue).
    ``steps`` counts per-op generator ``send`` calls; ``activations``
    counts module (re)activations by the recording scheduler — the
    analogue of the generator engine's task-resume counter.
    """

    program: str
    modules: List[ModuleTrace]
    outputs: Dict[str, Any]
    leftovers: List[list]
    skipped_probes: int = 0
    steps: int = 0
    activations: int = 0
    # --- optional functional capture (record_trace(keep_values=True)) ---
    # the delta layer (repro.delta) needs the *values* that flowed, not
    # just the op skeleton: per-FIFO written-value streams (complete, in
    # write order — SPSC means one writer per FIFO so this is also that
    # writer's per-FIFO write stream), per-module Emit records in emit
    # order, and per-module dead-probe counts.  None unless captured.
    values: Optional[List[list]] = None          # [fid] -> written values
    module_emits: Optional[List[list]] = None    # [mid] -> [(key, value)]
    module_skips: Optional[List[int]] = None     # [mid] -> dead probes

    @property
    def n_ops(self) -> int:
        return sum(m.n_ops for m in self.modules)

    @property
    def n_stored(self) -> int:
        return sum(m.n_stored for m in self.modules)

    def periodize(self) -> "RecordedTrace":
        """Compress every module stream in place; returns self."""
        self.modules = [m.periodize() for m in self.modules]
        return self


# ---------------------------------------------------------------------------
# Pass 1: record — generators entered at most once per module
# ---------------------------------------------------------------------------
_REC_QUANTUM = 256     # ops per activation before the recorder rotates


def record_trace(program: Program, max_steps: int = 50_000_000,
                 keep_values: bool = False) -> RecordedTrace:
    """Run every module generator once, untimed, and record its op stream.

    Untimed KPN semantics: FIFOs are unbounded, a ``Read`` from an empty
    FIFO parks the module until its (single) writer produces, modules are
    scheduled round-robin.  For blocking-only designs this yields exactly
    the functional behavior of the timed engine (KPN determinism); any live
    NB access/probe, a parked module that never wakes (cyclic blocking
    wait — a true design deadlock), or a second reader racing a parked one
    raises :class:`TraceUnsupported`.

    Each activation is bounded to ``_REC_QUANTUM`` ops before the scheduler
    rotates (legal under KPN determinism — any schedule records the same
    streams), so probing a *dynamic* design under ``trace="auto"`` aborts
    to the hybrid path after O(modules x quantum) ops instead of first
    recording some module's entire multi-thousand-op stream.

    Raises ``RuntimeError`` when ``max_steps`` generator resumptions are
    exceeded (possible livelock), matching the generator engine's budget.

    ``keep_values=True`` additionally captures the functional side of the
    run — per-FIFO written-value streams, per-module Emit lists and
    per-module dead-probe counts — which is what ``repro.delta`` needs to
    re-record a single edited module in isolation and verify its writes
    against the original streams.
    """
    modules = program.modules
    n_mod = len(modules)
    buffers: List[deque] = [deque() for _ in program.fifos]
    wvals: Optional[List[list]] = (
        [[] for _ in program.fifos] if keep_values else None)
    memits: Optional[List[list]] = (
        [[] for _ in range(n_mod)] if keep_values else None)
    mskips: Optional[List[int]] = [0] * n_mod if keep_values else None
    kinds: List[list] = [[] for _ in range(n_mod)]
    fids: List[list] = [[] for _ in range(n_mod)]
    gaps: List[list] = [[] for _ in range(n_mod)]
    end_gap = [1] * n_mod
    outputs: Dict[str, Any] = {}
    gens = [m.fn() for m in modules]
    done = [False] * n_mod
    parked: List[Optional[Read]] = [None] * n_mod
    gap_acc = [1] * n_mod
    waiting_reader: Dict[int, int] = {}
    skipped_probes = 0
    steps = 0
    activations = 0
    runq: deque = deque(range(n_mod))
    while runq:
        mid = runq.popleft()
        activations += 1
        gen_send = gens[mid].send
        kapp, fapp, gapp = kinds[mid].append, fids[mid].append, gaps[mid].append
        gap = gap_acc[mid]
        op = parked[mid]
        if op is not None:                 # woken: re-execute the parked Read
            parked[mid] = None
            fid = op.fifo.fid
            buf = buffers[fid]
            if not buf:                    # a second reader drained the FIFO
                raise TraceUnsupported(
                    f"{program.name}: FIFO '{op.fifo.name}' drained by "
                    f"another reader while '{modules[mid].name}' was parked "
                    f"— SPSC violation; deferring to the generator engine's "
                    f"endpoint check")
            send = buf.popleft()
            kapp(OP_READ)
            fapp(fid)
            gapp(gap)
            gap = 1
        else:
            send = None
        quantum = steps + _REC_QUANTUM
        while True:
            steps += 1
            if steps > max_steps:
                raise RuntimeError(
                    f"step budget exceeded ({max_steps}); possible livelock "
                    f"— neither OmniSim nor co-sim detects livelock")
            if steps > quantum and send is None and runq:
                runq.append(mid)        # rotate: bounded activation quantum
                break
            try:
                op = gen_send(send)
            except StopIteration:
                done[mid] = True
                end_gap[mid] = gap
                break
            send = None
            cls = op.__class__
            if cls is Read:
                fid = op.fifo.fid
                buf = buffers[fid]
                if buf:
                    send = buf.popleft()
                    kapp(OP_READ)
                    fapp(fid)
                    gapp(gap)
                    gap = 1
                else:
                    prev = waiting_reader.get(fid)
                    if prev is not None and prev != mid:
                        raise TraceUnsupported(
                            f"{program.name}: two modules read FIFO "
                            f"'{op.fifo.name}' — SPSC violation; deferring "
                            f"to the generator engine's endpoint check")
                    waiting_reader[fid] = mid
                    parked[mid] = op
                    break
            elif cls is Write:
                fid = op.fifo.fid
                buffers[fid].append(op.value)
                if wvals is not None:
                    wvals[fid].append(op.value)
                kapp(OP_WRITE)
                fapp(fid)
                gapp(gap)
                gap = 1
                if waiting_reader:
                    w = waiting_reader.pop(fid, None)
                    if w is not None:
                        runq.append(w)
            elif cls is Delay:
                gap += op.cycles
            elif cls is Emit:
                outputs[op.key] = op.value
                if memits is not None:
                    memits[mid].append((op.key, op.value))
            elif (cls is Empty or cls is Full) and not op.used:
                # dead probe (paper Sec. 7.3.2): costs 1 cycle, no query
                skipped_probes += 1
                if mskips is not None:
                    mskips[mid] += 1
                gap += 1
            elif cls in (ReadNB, WriteNB, Empty, Full):
                raise TraceUnsupported(
                    f"{program.name}: module '{modules[mid].name}' issues "
                    f"{cls.__name__} — outcome is cycle-dependent, control "
                    f"flow may diverge; using the hybrid segmented replay",
                    dynamic=True)
            else:
                raise TypeError(f"unknown op {op!r}")
        gap_acc[mid] = gap
    if not all(done):
        blocked = [modules[m].name for m in range(n_mod) if not done[m]]
        raise TraceUnsupported(
            f"{program.name}: cyclic blocking wait (untimed KPN deadlock) — "
            f"modules {blocked} never terminate; the generator engine will "
            f"report the exact stall cycle")
    mtraces = [
        ModuleTrace(mid=m, name=modules[m].name,
                    kind=np.asarray(kinds[m], dtype=np.int8),
                    fifo=np.asarray(fids[m], dtype=np.int64),
                    gap=np.asarray(gaps[m], dtype=np.int64),
                    end_gap=end_gap[m])
        for m in range(n_mod)
    ]
    return RecordedTrace(program=program.name, modules=mtraces,
                         outputs=outputs,
                         leftovers=[list(b) for b in buffers],
                         skipped_probes=skipped_probes, steps=steps,
                         activations=activations,
                         values=wvals, module_emits=memits,
                         module_skips=mskips)


# ---------------------------------------------------------------------------
# Pass 2: compile — op arrays -> simulation-graph skeleton
# ---------------------------------------------------------------------------
@dataclass
class CompiledTrace:
    """Depth-independent graph skeleton compiled from a RecordedTrace.

    Node ids are chain-major: module ``m`` owns the contiguous id range
    ``slices[m]`` as ``[START, op_0 .. op_{k-1}, END]``.  ``seq_w[i]`` is
    the SEQ-edge weight into node ``i`` (0 at chain heads); RAW edges are
    depth-independent; WAR edges are generated per depth vector by
    :meth:`war_edges`.  Everything is in cycles / 1-based event counts.
    """

    n: int
    n_modules: int
    slices: List[Tuple[int, int]]       # per-module (lo, hi) node id range
    seq_w: np.ndarray                   # (n,) int64 — SEQ weight into node
    base: np.ndarray                    # (n,) int64 — START time 0, else NEGI
    node_kind: np.ndarray               # (n,) int8 — _NK_* codes
    node_fifo: np.ndarray               # (n,) int64 — FIFO id or -1
    node_seq: np.ndarray                # (n,) int64 — 1-based fifo seq or -1
    fifo_w_nodes: List[np.ndarray]      # per FIFO: write node ids, seq order
    fifo_r_nodes: List[np.ndarray]      # per FIFO: read node ids, seq order
    fifo_wmod: np.ndarray               # per FIFO: writer module (-1 = none)
    fifo_rmod: np.ndarray               # per FIFO: reader module (-1 = none)
    raw_dst: np.ndarray                 # RAW edges (read <- write, w=1)
    raw_src: np.ndarray
    trace: RecordedTrace = field(repr=False, default=None)

    def war_edges(self, depths) -> Tuple[np.ndarray, np.ndarray]:
        """Regenerate the depth-dependent WAR edges for ``depths``.

        The w-th write of a FIFO with depth S waits on the (w-S)-th read
        (paper Table 2).  A write whose target read never occurs can never
        commit — a structural deadlock under these depths — which raises
        :class:`TraceUnsupported` so the generator engine can produce the
        paper-exact deadlock report.
        """
        dst_parts, src_parts = [], []
        for fid, w_nodes in enumerate(self.fifo_w_nodes):
            S = int(depths[fid])
            nw = len(w_nodes)
            if nw <= S:
                continue
            r_nodes = self.fifo_r_nodes[fid]
            if nw - len(r_nodes) > S:
                raise TraceUnsupported(
                    f"write #{len(r_nodes) + S + 1} on fifo {fid} can never "
                    f"commit with depth {S} (structural deadlock)")
            dst_parts.append(w_nodes[S:])
            src_parts.append(r_nodes[:nw - S])
        if not dst_parts:
            z = np.zeros(0, np.int64)
            return z, z
        return np.concatenate(dst_parts), np.concatenate(src_parts)


def compile_trace(rec: RecordedTrace, n_fifos: int) -> CompiledTrace:
    """Lower a RecordedTrace into the chain/edge arrays of CompiledTrace.

    Purely array work — no generator is resumed.  Enforces the engine's
    SPSC endpoint rule (one writer module and one reader module per FIFO)
    on the recorded streams; violations raise :class:`TraceUnsupported` so
    the generator engine surfaces its own AssertionError.
    """
    n_mod = len(rec.modules)
    expanded = [m.expand() for m in rec.modules]
    counts = [len(k) for (k, _, _) in expanded]
    n = sum(counts) + 2 * n_mod
    seq_w = np.zeros(n, dtype=np.int64)
    node_kind = np.empty(n, dtype=np.int8)
    node_fifo = np.full(n, -1, dtype=np.int64)
    node_seq = np.full(n, -1, dtype=np.int64)
    base = np.full(n, NEGI, dtype=np.int64)
    slices: List[Tuple[int, int]] = []
    all_fifo, all_kind, all_node, all_mod = [], [], [], []
    off = 0
    for m, (k, f, g) in enumerate(expanded):
        L = counts[m]
        hi = off + L + 2
        slices.append((off, hi))
        node_kind[off] = _NK_START
        base[off] = 0                       # START commits at cycle 0
        node_kind[off + 1:hi - 1] = np.where(k == OP_WRITE, _NK_WRITE, _NK_READ)
        node_kind[hi - 1] = _NK_END
        node_fifo[off + 1:hi - 1] = f
        seq_w[off + 1:hi - 1] = g
        seq_w[hi - 1] = rec.modules[m].end_gap
        all_fifo.append(f)
        all_kind.append(k)
        all_node.append(np.arange(off + 1, hi - 1, dtype=np.int64))
        all_mod.append(np.full(L, m, dtype=np.int64))
        off = hi
    fifo_all = (np.concatenate(all_fifo) if all_fifo
                else np.zeros(0, np.int64))
    kind_all = (np.concatenate(all_kind).astype(np.int64) if all_kind
                else np.zeros(0, np.int64))
    node_all = (np.concatenate(all_node) if all_node
                else np.zeros(0, np.int64))
    mod_all = (np.concatenate(all_mod) if all_mod
               else np.zeros(0, np.int64))
    # group events by (fifo, kind); stable sort keeps each side's per-module
    # issue order, which IS commit/seq order because FIFOs are SPSC
    order = np.lexsort((kind_all, fifo_all))
    f_s, k_s, n_s, m_s = (fifo_all[order], kind_all[order], node_all[order],
                          mod_all[order])
    fifo_w_nodes: List[np.ndarray] = []
    fifo_r_nodes: List[np.ndarray] = []
    fifo_wmod = np.full(n_fifos, -1, dtype=np.int64)
    fifo_rmod = np.full(n_fifos, -1, dtype=np.int64)
    raw_dst_parts, raw_src_parts = [], []
    for fid in range(n_fifos):
        lo = int(np.searchsorted(f_s, fid, side="left"))
        hi = int(np.searchsorted(f_s, fid, side="right"))
        mid_split = lo + int(np.searchsorted(k_s[lo:hi], OP_WRITE))
        r_nodes = n_s[lo:mid_split]
        w_nodes = n_s[mid_split:hi]
        for side_nodes, side_mods, table in (
                (r_nodes, m_s[lo:mid_split], fifo_rmod),
                (w_nodes, m_s[mid_split:hi], fifo_wmod)):
            if len(side_nodes):
                mods = np.unique(side_mods)
                if len(mods) > 1:
                    raise TraceUnsupported(
                        f"fifo {fid} has {len(mods)} endpoint modules on one "
                        f"side — SPSC violation; deferring to the generator "
                        f"engine's endpoint check")
                table[fid] = int(mods[0])
        fifo_w_nodes.append(np.ascontiguousarray(w_nodes))
        fifo_r_nodes.append(np.ascontiguousarray(r_nodes))
        node_seq[w_nodes] = np.arange(1, len(w_nodes) + 1)
        node_seq[r_nodes] = np.arange(1, len(r_nodes) + 1)
        nr = len(r_nodes)
        if nr:                              # r-th read <- r-th write, w=1
            raw_dst_parts.append(r_nodes)
            raw_src_parts.append(w_nodes[:nr])
    raw_dst = (np.concatenate(raw_dst_parts) if raw_dst_parts
               else np.zeros(0, np.int64))
    raw_src = (np.concatenate(raw_src_parts) if raw_src_parts
               else np.zeros(0, np.int64))
    return CompiledTrace(n=n, n_modules=n_mod, slices=slices, seq_w=seq_w,
                         base=base, node_kind=node_kind, node_fifo=node_fifo,
                         node_seq=node_seq, fifo_w_nodes=fifo_w_nodes,
                         fifo_r_nodes=fifo_r_nodes, fifo_wmod=fifo_wmod,
                         fifo_rmod=fifo_rmod, raw_dst=raw_dst,
                         raw_src=raw_src, trace=rec)


# ---------------------------------------------------------------------------
# Pass 3: replay — Gauss-Seidel chain fixpoint (array-level dispatch)
# ---------------------------------------------------------------------------
def _cross_buckets(ct: CompiledTrace, war_dst: np.ndarray,
                   war_src: np.ndarray, starts: np.ndarray) -> Dict:
    """Bucket cross edges by source chain (RAW: writer -> reader module;
    WAR: reader -> writer module) — no sort needed, FIFO sides are SPSC.

    Pure function of the trace skeleton + WAR edge set: the delta patch
    path caches the result per :class:`~repro.delta.patch.DeltaState` and
    reuses it whenever the skeleton and depth vector are unchanged.
    """
    out_buckets: Dict[int, List[Tuple[int, np.ndarray, np.ndarray]]] = {}
    for dst, src in ((ct.raw_dst, ct.raw_src), (war_dst, war_src)):
        if not len(dst):
            continue
        # split by fifo-contiguous runs: each concatenated part came from
        # one fifo, i.e. one (src chain, dst chain) pair
        sch = np.searchsorted(starts, src, "right") - 1
        dch = np.searchsorted(starts, dst, "right") - 1
        cut = np.flatnonzero(np.diff(sch) | np.diff(dch))
        bounds = np.concatenate([[0], cut + 1, [len(dst)]])
        run_sc, run_dc = sch[bounds[:-1]], dch[bounds[:-1]]
        for i, (a, b) in enumerate(zip(bounds[:-1], bounds[1:])):
            out_buckets.setdefault(int(run_sc[i]), []).append(
                (int(run_dc[i]), src[a:b], dst[a:b]))
    return out_buckets


def _solve_times(ct: CompiledTrace, war_dst: np.ndarray,
                 war_src: np.ndarray,
                 warm: Optional[Tuple[np.ndarray, List[int]]] = None,
                 buckets: Optional[Dict] = None,
                 ) -> Tuple[np.ndarray, int]:
    """Longest-path node times over SEQ chains + RAW/WAR cross edges.

    Within a chain, ``t = cw + cummax(c - cw)`` (cw = cumulative SEQ
    weight) resolves all sequential propagation in one vectorized pass;
    cross edges are bucketed by (source module, destination module) — one
    bucket per FIFO side, since FIFOs are SPSC — and swept Gauss-Seidel in
    module order with dirty-chain tracking, so each sweep only recomputes
    chains some cross edge actually moved.  Converges in O(module-graph
    hops), not O(events).  A WAR cycle makes times grow past the acyclic
    bound: raises :class:`TraceUnsupported` (the timed engine would
    deadlock; the generator path reports it exactly).

    ``warm = (old_times, dirty_chains)`` seeds the fixpoint from a prior
    solution of the *same* graph with only ``dirty_chains`` marked dirty —
    the edit-and-resimulate fast path (``repro.delta.patch``).  Sound when
    every weight change is an increase (the old solution is then a lower
    bound of the new least fixpoint, and ascending Gauss-Seidel converges
    to the least fixpoint from any lower bound); if weights *decreased*,
    the result can land above the true fixpoint, so warm callers MUST
    check the result (``verify_times``) and re-solve cold on mismatch.

    ``buckets`` optionally supplies a prebuilt :func:`_cross_buckets`
    table (it must match ``ct`` + the WAR edge *content* exactly — the
    patch path reuses the snapshot's table when skeleton and depths are
    unchanged).

    Returns ``(times, sweeps)`` — times in cycles.
    """
    n = ct.n
    n_ch = ct.n_modules
    cw = np.concatenate([np.cumsum(ct.seq_w[lo:hi]) for (lo, hi) in ct.slices]) \
        if n else np.zeros(0, np.int64)
    c = ct.base.copy()
    t = np.full(n, NEGI, dtype=np.int64)
    starts = np.asarray([lo for (lo, _) in ct.slices] or [0], np.int64)
    out_buckets = buckets if buckets is not None \
        else _cross_buckets(ct, war_dst, war_src, starts)

    bound = int(ct.seq_w.sum() + len(ct.raw_dst) + len(war_dst) + 1)
    if warm is not None:
        old_t, dirty_chains = warm
        t = old_t.astype(np.int64, copy=True)
        # re-derive cross contributions from the old solution (one
        # vectorized pass), then only the edited chains start dirty
        for dst, src in ((ct.raw_dst, ct.raw_src), (war_dst, war_src)):
            if len(dst):
                np.maximum.at(c, dst, t[src] + 1)
        dirty = np.zeros(n_ch, dtype=bool)
        dirty[list(dirty_chains)] = True
    else:
        dirty = np.ones(n_ch, dtype=bool)
    sweeps = 0
    max_sweeps = n + 2
    while dirty.any():
        sweeps += 1
        if sweeps > max_sweeps or (sweeps > n_ch + 4 and t.max() > bound):
            raise TraceUnsupported(
                "WAR edges form a cycle — the recorded event order is "
                "invalid under these depths (the design deadlocks)")
        for ci in range(n_ch):
            if not dirty[ci]:
                continue
            dirty[ci] = False
            lo, hi = ct.slices[ci]
            seg = c[lo:hi] - cw[lo:hi]
            np.maximum.accumulate(seg, out=seg)
            seg += cw[lo:hi]
            if np.array_equal(seg, t[lo:hi]):
                continue
            t[lo:hi] = seg
            for (dc, s_ids, d_ids) in out_buckets.get(ci, ()):
                cand = t[s_ids] + 1
                old = c[d_ids]
                moved = cand > old
                if moved.any():
                    c[d_ids] = np.maximum(old, cand)
                    dirty[dc] = True
    return t, sweeps


# ---------------------------------------------------------------------------
# Array-backed simulation graph (API-compatible with graph.SimGraph reads)
# ---------------------------------------------------------------------------
class TraceSimGraph:
    """The replayed simulation graph, stored as numpy arrays.

    Drop-in for :class:`~repro.core.graph.SimGraph` consumers that *read*
    a finished graph — ``nodes`` (materialized lazily as
    :class:`~repro.core.events.Node` objects for e.g. the taxonomy
    classifier), ``times()``, ``to_csr()``, ``n_nodes``/``n_edges`` — while
    the hot path never touches per-node Python objects.  Node times are in
    cycles; node ids are chain-major (see :class:`CompiledTrace`), which is
    *not* a topological order — use level-scheduled or fixpoint longest-path
    backends, not ``longest_path_python``.
    """

    def __init__(self, ct: CompiledTrace, times: np.ndarray,
                 war_dst: np.ndarray, war_src: np.ndarray,
                 module_arr: np.ndarray):
        self._ct = ct
        self._times = times
        self._module = module_arr
        self._cross_dst = (np.concatenate([ct.raw_dst, war_dst])
                           if len(ct.raw_dst) or len(war_dst)
                           else np.zeros(0, np.int64))
        self._cross_src = (np.concatenate([ct.raw_src, war_src])
                           if len(ct.raw_src) or len(war_src)
                           else np.zeros(0, np.int64))
        self._nodes: Optional[List[Node]] = None

    # -- SimGraph read API ---------------------------------------------------
    @property
    def n_nodes(self) -> int:
        return self._ct.n

    @property
    def n_edges(self) -> int:
        # SEQ edges into every non-head node + RAW/WAR cross edges
        return (self._ct.n - self._ct.n_modules) + len(self._cross_dst)

    def times(self) -> np.ndarray:
        """Commit cycle of every node (same as SimGraph.times())."""
        return self._times.copy()

    @property
    def nodes(self) -> List[Node]:
        """Materialize Node objects (lazily, once) for object-level readers."""
        if self._nodes is None:
            ct = self._ct
            nodes = []
            heads = {lo for (lo, _) in ct.slices}
            for i in range(ct.n):
                node = Node(idx=i, module=int(self._module[i]),
                            kind=_NK_TO_NODEKIND[int(ct.node_kind[i])],
                            time=int(self._times[i]),
                            fifo=int(ct.node_fifo[i]),
                            seq=int(ct.node_seq[i]))
                if i not in heads:
                    node.preds.append((i - 1, int(ct.seq_w[i])))
                nodes.append(node)
            for dst, src in zip(self._cross_dst, self._cross_src):
                nodes[int(dst)].preds.append((int(src), 1))
            self._nodes = nodes
        return self._nodes

    def to_csr(self):
        """CSR by destination — same convention as SimGraph.to_csr()."""
        ct = self._ct
        n = ct.n
        head_mask = np.zeros(n, dtype=bool)
        for (lo, _) in ct.slices:
            head_mask[lo] = True
        seq_dst = np.flatnonzero(~head_mask)
        dsts = np.concatenate([seq_dst, self._cross_dst])
        srcs = np.concatenate([seq_dst - 1, self._cross_src])
        wgts = np.concatenate([ct.seq_w[seq_dst],
                               np.ones(len(self._cross_dst), np.int64)])
        order = np.argsort(dsts, kind="stable")
        counts = np.bincount(dsts, minlength=n)
        indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        base = np.where(indptr[1:] == indptr[:-1], self._times, 0)
        return indptr, srcs[order], wgts[order], base.astype(np.int64)


class _LazyConstraints(list):
    """Constraint records materialized on first access.

    The same trick as :attr:`TraceSimGraph.nodes`: query-dominated runs
    carry one :class:`~repro.core.events.Constraint` per query, but the
    incremental/DSE consumers read the *compiled* constraint arrays of the
    pre-built CompiledGraph — the object records exist for object-level
    readers (tests, reporting) and are built on the first access.  Every
    reader *and* mutator of the list API forces materialization first (see
    the wrapper loop below), so a partially-initialized view can never
    leak; being a list subclass, reflected comparisons against plain lists
    dispatch here first, so those force too.
    """

    __slots__ = ("_thunk",)

    def __init__(self, thunk):
        super().__init__()
        self._thunk = thunk

    def _force(self) -> None:
        thunk, self._thunk = self._thunk, None
        if thunk is not None:
            list.extend(self, thunk())

    __hash__ = None


def _lazy_forcing(name):
    base = getattr(list, name)

    def method(self, *args, **kwargs):
        self._force()
        for a in args:
            if type(a) is _LazyConstraints:
                a._force()
        return base(self, *args, **kwargs)

    method.__name__ = name
    return method


for _name in ("__len__", "__iter__", "__getitem__", "__eq__", "__ne__",
              "__lt__", "__le__", "__gt__", "__ge__", "__contains__",
              "__repr__", "__reversed__", "__add__", "__mul__", "__rmul__",
              "__iadd__", "__imul__", "__setitem__", "__delitem__",
              "count", "index", "copy", "append", "extend", "insert",
              "remove", "pop", "sort", "reverse", "clear"):
    setattr(_LazyConstraints, _name, _lazy_forcing(_name))
del _name


# ---------------------------------------------------------------------------
# Content-addressed design keys: warm-cache reuse of the pre-built graph
# ---------------------------------------------------------------------------
_FP_PRIM = (str, int, float, bool, bytes, complex, type(None))


def _fp_plain(obj, depth: int = 0) -> bool:
    """True when ``obj`` is pure primitive data (possibly nested in plain
    lists/tuples): its ``repr`` is then deterministic content, so the
    fingerprint walk can hash it in one C-level call instead of recursing
    per element.  Exact-type checks keep subclasses (enums, numpy scalars,
    repr-overriding wrappers) on the structural path.
    """
    t = type(obj)
    if t in _FP_PRIM:
        return True
    if (t is tuple or t is list) and depth <= 8:
        for x in obj:                    # plain loop: no genexpr frames —
            if not _fp_plain(x, depth + 1):   # this predicate runs per
                return False             # element of every macro script
        return True
    return False


def _fp_update(h, obj, depth: int = 0, fifo_depth: bool = True,
               memo: Optional[dict] = None) -> None:
    """Feed ``obj`` into hash ``h`` by *content*, not identity.

    Function objects are fingerprinted by bytecode + consts + defaults +
    closure contents (recursively), FIFOs by name/depth, arrays by bytes —
    so two Programs built by the same builder with the same arguments hash
    equal even though every call allocates fresh function/Fifo objects,
    while changing any captured argument (``items=512`` vs ``1024``)
    changes the key.  ``fifo_depth=False`` hashes captured FIFOs by name
    only — the depth-insensitive flavor the hybrid segment cache keys on,
    where depth perturbations are the intended reuse.

    Failure direction matters: unknown values must never make two
    *different* designs collide.  Past the recursion bound, and for
    objects with no content-based handling, we hash ``repr`` — plain
    containers stay content-addressed, and an object whose repr embeds
    its address merely produces an unstable key (a safe cache miss, never
    a false hit).  Default-``__repr__`` instances are recursed through
    ``vars()`` so ordinary config objects captured by closures still hash
    by content.

    Containers (list/tuple/dict) hash *Merkle-style*: the parent stream
    receives the sha256 digest of the container's own content stream.
    That makes ``memo`` — an optional per-top-level-call ``{(id, depth):
    digest}`` dict — sound: an object shared between modules (generated
    designs capture one FIFO list in every module closure) is walked once
    per design instead of once per module, turning whole-design
    fingerprinting from quadratic (~300 ms at 300 modules) to linear.
    Memoized and memo-less calls produce identical bytes; memo entries
    must not outlive the hashed objects (callers build a fresh memo per
    design).
    """
    if depth > 8:                        # defensive bound on weird closures
        h.update(b"<deep>")
        h.update(repr(obj).encode())     # still content-based for data
        return
    if type(obj) in _FP_PRIM:
        # exact-type primitive leaf: same bytes the final ``repr`` branch
        # would produce, without walking the isinstance chain — closure
        # cells are mostly ints/strs, so this is the hottest exit
        h.update(repr(obj).encode())
        return
    if isinstance(obj, types.FunctionType):
        def all_names(code):             # incl. nested lambdas/inner defs
            names = set(code.co_names)
            for c in code.co_consts:
                if isinstance(c, types.CodeType):
                    names |= all_names(c)
            return names

        code = obj.__code__
        h.update(b"fn(")
        h.update(code.co_code)
        _fp_update(h, code.co_consts, depth + 1, fifo_depth, memo)
        # every module a factory stamps out shares one code object, so the
        # names repr (like the consts tuple above, which memo-hits by id)
        # is worth caching across the design walk
        nkey = (id(code), "conames") if memo is not None else None
        names_b = memo.get(nkey) if nkey is not None else None
        if names_b is None:
            names_b = repr(code.co_names).encode()
            if nkey is not None:
                memo[nkey] = names_b
        h.update(names_b)
        _fp_update(h, obj.__defaults__, depth + 1, fifo_depth, memo)
        _fp_update(h, obj.__kwdefaults__, depth + 1, fifo_depth, memo)
        if obj.__closure__:
            for cell in obj.__closure__:
                try:
                    _fp_update(h, cell.cell_contents, depth + 1, fifo_depth,
                               memo)
                except ValueError:
                    h.update(b"<empty>")
        # module-level state the body reads is design content too (a
        # global `N` changing between builds changes the trace) — also
        # when the read happens inside a nested lambda/inner def; modules
        # hash by name only — importing numpy is not design identity
        g = obj.__globals__
        # the referenced-global name list depends only on (code, globals)
        # — shared by every module a generator factory stamps out — so
        # memoize it alongside the capture digests
        gkey = (id(code), id(g), "gnames") if memo is not None else None
        if gkey is not None and gkey in memo:
            gnames = memo[gkey]
        else:
            gnames = sorted(all_names(code) & set(g))
            if gkey is not None:
                memo[gkey] = gnames
        # Merkle-wrap the whole globals contribution unconditionally (so
        # the bytes don't depend on whether a memo is in use) and memoize
        # it per (code, globals, depth): every module a factory stamps out
        # references the same helpers through the same dict, so after the
        # first module this entire section is one dict get + one update
        gdkey = (id(code), id(g), depth, "gdig") if memo is not None else None
        gdig = memo.get(gdkey) if gdkey is not None else None
        if gdig is None:
            gh = hashlib.sha256()
            for name in gnames:
                gh.update(name.encode())
                v = g[name]
                if isinstance(v, types.ModuleType):
                    gh.update(v.__name__.encode())
                else:
                    # per-value digests memo too: distinct code objects
                    # (different factories) still share helper values
                    vkey = (id(v), depth, "g") if memo is not None else None
                    digest = memo.get(vkey) if vkey is not None else None
                    if digest is None:
                        sub = hashlib.sha256()
                        _fp_update(sub, v, depth + 1, fifo_depth, memo)
                        digest = sub.digest()
                        if vkey is not None:
                            memo[vkey] = digest
                    gh.update(digest)
            gdig = gh.digest()
            if gdkey is not None:
                memo[gdkey] = gdig
        h.update(gdig)
        h.update(b")")
    elif isinstance(obj, types.CodeType):
        h.update(b"code(")
        h.update(obj.co_code)
        _fp_update(h, obj.co_consts, depth + 1, fifo_depth, memo)
        h.update(repr(obj.co_names).encode())
        h.update(b")")
    elif isinstance(obj, Fifo):
        if fifo_depth == "blind":
            # position-free placeholder: the delta layer's *body* hash must
            # not change when a FIFO is renamed or re-depthed — only the
            # bytecode/constants matter there (``repro.delta.fingerprint``)
            h.update(b"Fifo(_)")
        elif fifo_depth:
            h.update(f"Fifo({obj.name},{obj.depth})".encode())
        else:
            h.update(f"Fifo({obj.name})".encode())
    elif isinstance(obj, np.ndarray):
        h.update(obj.tobytes())
    elif isinstance(obj, (list, tuple)):
        key = (id(obj), depth) if memo is not None else None
        if key is not None and key in memo:
            h.update(memo[key])
            return
        if _fp_plain(obj, depth):
            # pure primitive data (e.g. generated macro scripts): one repr
            # is deterministic content — same bytes with or without memo
            data = repr(obj).encode()
            if key is not None:
                memo[key] = data
            h.update(data)
            return
        sub = hashlib.sha256()
        sub.update(b"(" if isinstance(obj, tuple) else b"[")
        for x in obj:
            _fp_update(sub, x, depth + 1, fifo_depth, memo)
            sub.update(b",")
        sub.update(b"]")
        digest = sub.digest()
        if key is not None:
            memo[key] = digest
        h.update(digest)
    elif isinstance(obj, dict):
        key = (id(obj), depth) if memo is not None else None
        if key is not None and key in memo:
            h.update(memo[key])
            return
        sub = hashlib.sha256()
        sub.update(b"{")
        for k in obj:
            _fp_update(sub, k, depth + 1, fifo_depth, memo)
            sub.update(b":")
            _fp_update(sub, obj[k], depth + 1, fifo_depth, memo)
        sub.update(b"}")
        digest = sub.digest()
        if key is not None:
            memo[key] = digest
        h.update(digest)
    elif type(obj).__repr__ is object.__repr__:
        # default repr would embed the instance address (a new key every
        # builder call — the cache would never hit): hash the class plus
        # the attribute dict by content instead
        h.update(type(obj).__qualname__.encode())
        try:
            _fp_update(h, vars(obj), depth + 1, fifo_depth, memo)
        except TypeError:                # __slots__ etc.: accept misses
            h.update(repr(obj).encode())
    else:
        h.update(repr(obj).encode())


def module_content_hash(fn, fifo_depth=True,
                        memo: Optional[dict] = None) -> str:
    """Content hash of one module generator function (sha256 hex digest).

    Hashes bytecode + constants + defaults + closure contents + referenced
    globals via :func:`_fp_update`.  ``fifo_depth`` selects how captured
    FIFOs enter the hash: ``True`` by name+depth (the exact-key flavor),
    ``False`` by name only (the hybrid cache's depth-insensitive flavor),
    ``"blind"`` as a position-free placeholder (the delta layer's *body*
    hash — invariant under FIFO renames and re-depthing).  ``memo`` is a
    per-design shared-capture digest cache (see :func:`_fp_update`); all
    modules of one design must share one memo *per flavor*.
    """
    h = hashlib.sha256()
    _fp_update(h, fn, fifo_depth=fifo_depth, memo=memo)
    return h.hexdigest()


def program_fingerprint(program: Program) -> str:
    """Stable content-addressed key of a design (sha256 hex digest).

    Module bodies are pure and re-runnable by the :class:`Program`
    contract, so the recorded trace — and therefore the compiled graph,
    the base simulation and every ``resimulate``/``resimulate_batch``
    verdict derived from it — is a pure function of what this fingerprint
    hashes: FIFO names/depths plus each module generator's bytecode,
    constants, defaults and captured closure values.  Equal fingerprints ⇒
    interchangeable base runs, which is exactly the guarantee the sweep
    service's warm cache (``repro.sweep.cache.GraphCache``) needs to serve
    repeat requests for a design without re-recording or re-hoisting
    anything.

    The key composes per-FIFO ``(name, depth)`` rows with per-module
    *depth-insensitive* content digests (:func:`module_content_hash` with
    ``fifo_depth=False``): the depth vector is design-level state and is
    hashed exactly once via the FIFO rows, not once per capturing module.
    That keeps the key depth-sensitive while letting
    ``repro.delta.fingerprint`` reconstruct the same key from its
    :class:`ModuleFingerprint` table with a single hash walk per module —
    an exact-key hit in the delta-aware cache lookup is literally this
    digest matching.
    """
    h = hashlib.sha256()
    h.update(program.name.encode())
    for f in program.fifos:
        h.update(b"|F")
        _fp_update(h, f)
    memo: dict = {}      # shared captures (e.g. one FIFO list) hash once
    for m in program.modules:
        h.update(b"|M")
        h.update(m.name.encode())
        h.update(module_content_hash(m.fn, fifo_depth=False,
                                     memo=memo).encode())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# CompiledGraph bridge: incremental/DSE reuse without graph re-interpretation
# ---------------------------------------------------------------------------
def to_compiled_graph(ct: CompiledTrace):
    """Build the incremental-resimulation cache directly from the trace.

    The returned :class:`~repro.core.incremental.CompiledGraph` is what
    ``compile_graph(engine)`` would have extracted by walking the Python
    node objects of a generator-path run — chains, SEQ weights, RAW edges,
    per-FIFO event arrays (all writes blocking: the compiled path carries
    no NB accesses) and an empty constraint set.  ``simulate_traced``
    installs it as the engine's ``_incr_cache``, so the first
    ``resimulate``/``resimulate_batch`` call skips re-interpretation.
    """
    from .incremental import CompiledGraph
    # CompiledGraph arrays are immutable by contract (consumers — the
    # solvers, _batch_arrays, graph_blob — only read or build permuted
    # copies), so the graph *shares* the trace's arrays rather than
    # copying: at corpus scale the per-FIFO copies alone were >1 ms per
    # delta patch.  Chains are slices of one arange for the same reason.
    fifos = [(w, r, np.ones(len(w), dtype=bool))
             for w, r in zip(ct.fifo_w_nodes, ct.fifo_r_nodes)]
    ids = np.arange(ct.n, dtype=np.int64)
    z = np.zeros(0, np.int64)
    return CompiledGraph(
        n=ct.n,
        raw_dst=ct.raw_dst,
        raw_src=ct.raw_src,
        raw_w=np.ones(len(ct.raw_dst), np.int64),
        base=ct.base,
        chains=[ids[lo:hi] for (lo, hi) in ct.slices],
        seq_w=ct.seq_w,
        fifos=fifos,
        c_kind=z, c_fifo=z, c_seq=z, c_src=z,
        c_out=np.zeros(0, dtype=bool),
    )


# ---------------------------------------------------------------------------
# Front door
# ---------------------------------------------------------------------------
def simulate_traced(program: Program,
                    max_steps: int = 50_000_000) -> SimResult:
    """Record, compile and replay ``program`` — the trace-compiled initial
    simulation (paper Sec. 5.1).

    Returns a :class:`~repro.core.program.SimResult` interchangeable with
    the generator engine's (same outputs, cycles, FIFO tables, graph and
    incremental-resimulation behavior) with ``engine="omnisim-trace"``.
    Raises :class:`TraceUnsupported` when the design needs the generator
    path (live NB accesses/probes, deadlocks, SPSC violations); callers
    normally go through ``repro.core.simulate(..., trace="auto")`` which
    handles the fallback.
    """
    rec = record_trace(program, max_steps)
    ct = compile_trace(rec, len(program.fifos))
    depths = program.depths()
    war_dst, war_src = ct.war_edges(depths)
    times, sweeps = _solve_times(ct, war_dst, war_src)
    return build_traced_result(program, rec, ct, times, war_dst, war_src,
                               sweeps)


def build_traced_result(program: Program, rec: RecordedTrace,
                        ct: CompiledTrace, times: np.ndarray,
                        war_dst: np.ndarray, war_src: np.ndarray,
                        sweeps: int, graph=None) -> SimResult:
    """Assemble the trace path's :class:`SimResult` + engine shell.

    Shared by :func:`simulate_traced` (cold record) and
    ``repro.delta.patch`` (spliced re-record): given a solved trace, build
    an engine shell so downstream consumers (incremental, DSE, taxonomy,
    ``kernels.finalize_times``) see exactly the generator engine's end
    state.  ``graph`` optionally supplies an already-built
    ``to_compiled_graph(ct)`` (the patch path builds one for verification
    anyway) so it isn't rebuilt here.
    """
    depths = program.depths()
    cycles = int(times.max()) if ct.n else 0
    from .engine import OmniSim
    engine = OmniSim(program, _fifo_shells=True)
    engine.outputs = dict(rec.outputs)
    module_arr = np.empty(ct.n, dtype=np.int64)
    for m, (lo, hi) in enumerate(ct.slices):
        module_arr[lo:hi] = m
    engine.graph = TraceSimGraph(ct, times, war_dst, war_src, module_arr)
    for f in program.fifos:
        tbl = engine.fifos[f.fid]
        w_nodes = ct.fifo_w_nodes[f.fid]
        r_nodes = ct.fifo_r_nodes[f.fid]
        # share the trace's node arrays: the tables never write below
        # ``_nw``/``_nr`` (growth reallocates), so no copy is needed
        tbl._w_nodes = np.asarray(w_nodes, dtype=np.int64)
        tbl._w_times = times[w_nodes]
        tbl._nw = len(w_nodes)
        tbl._r_nodes = np.asarray(r_nodes, dtype=np.int64)
        tbl._r_times = times[r_nodes]
        tbl._nr = len(r_nodes)
        tbl.values.extend(rec.leftovers[f.fid])
        if len(w_nodes):
            engine._writer_of[f.fid] = int(ct.fifo_wmod[f.fid])
        if len(r_nodes):
            engine._reader_of[f.fid] = int(ct.fifo_rmod[f.fid])
    stats = engine.stats
    # the generator engine counts nodes in _new_node, which START bypasses
    stats.nodes = ct.n - ct.n_modules
    stats.edges = engine.graph.n_edges
    stats.resumes = rec.activations          # scheduler (re)activations
    stats.skipped_probes = rec.skipped_probes
    stats.quiescence_rounds = sweeps
    engine._incr_cache = graph if graph is not None else to_compiled_graph(ct)
    engine._trace = rec.periodize()          # compact steady-state storage
    return SimResult(
        program=program.name,
        outputs=dict(rec.outputs),
        cycles=cycles,
        engine="omnisim-trace",
        stats=stats,
        graph=engine,
        constraints=[],
        depths=depths,
    )


# ===========================================================================
# Hybrid trace compilation for dynamic (NB/probe) designs — paper Sec. 5.1
# ===========================================================================
# The straight-line replay above bails out the moment a module issues a live
# non-blocking access or status probe, because the op stream past that point
# is cycle-dependent.  The hybrid engine below keeps the same flat-array
# machinery but segments each module's op stream at its *query points*:
#
#   * **blocking segments** (the ops between two queries) are recorded as
#     flat (kind, fifo, gap, seq) rows exactly like :func:`record_trace` and
#     timed array-at-a-time;
#   * **query points** drop to the generator protocol of ``core/engine.py``:
#     the query's source cycle is the (now solved) chain time, the verdict
#     comes from the committed per-FIFO time tables (paper Table 2), and an
#     unresolvable stuck state applies the earliest-query forced-false rule
#     (paper Sec. 7.1) — sound here too, because every event that is still
#     untimed at a stuck state transitively waits on some pending query and
#     therefore commits strictly after the earliest priced query's cycle.
#
# Three solvers cooperate on the timing side:
#
#   * **Scalar/windowed frontier** (:meth:`HybridSim._advance_frontier`):
#     advances one module's maximal ready prefix, row by row or in
#     geometrically growing numpy windows.  It stops at the first row whose
#     RAW/WAR source is not yet *timed*, so tightly-coupled pipelines make
#     it ping-pong between modules in FIFO-depth-sized hops.
#   * **Provisional-times batch solver** (:meth:`HybridSim._solve_batch`):
#     when enough rows are pending, every module's pending window is solved
#     *simultaneously* — chains are truncated at rows whose source event is
#     not even recorded yet (the writer/reader is parked at a query), cross
#     edges between the provisional windows are materialized, and the same
#     per-chain ``t = cw + cummax(c - cw)`` Gauss-Seidel sweep as
#     :func:`_solve_times` runs to fixpoint over the whole window.  The
#     truncation is what validates the committed prefix: a row inside it
#     depends only on committed times or on rows of the same window, so the
#     fixpoint times are final.  Non-convergence (times growing past the
#     acyclic bound — a WAR cycle, i.e. a genuine deadlock under these
#     depths) commits nothing and defers to the scalar frontier, which
#     stalls and lets ``run()`` raise :class:`TraceUnsupported` so the
#     generator engine reports the paper-exact stall cycle.
#   * **Query periodization** (:meth:`HybridSim._burst_polls`): a steady-
#     state poll loop — the same query site failing with the same period and
#     no commits in between, e.g. ``fig2_timer``'s done-polling timer —
#     needs no per-query machinery at all.  Once the per-module detector
#     (:meth:`HybridSim._apply_query`) sees ``_POLL_STREAK`` consecutive
#     periodic failures, the K future outcomes that are *definitively*
#     false against the committed tables (the target event's commit time is
#     immutable, so ``(lim - t0) // p`` verdicts are known at once —
#     Table 2 vectorized over the window) are resolved in one burst: rows,
#     times and constraints are appended in bulk and the generator is
#     resumed in a tight verification loop that falls back to per-query
#     interpretation the moment a yield diverges from the recorded pattern
#     (different site, different gap, or a non-timing op).  Undecidable
#     outcomes never burst (``K = 0`` when the target event is uncommitted),
#     so the earliest-query forced-false rule is preserved verbatim.
#
# The result is bit-identical to the generator engine (same graph, times,
# FIFO tables, constraints and stats.{nodes,edges,queries}) because both
# engines compute the same unique fixpoint: every resolution is made against
# final committed times, and forced-false resolutions are only applied when
# no event can still commit before the query's cycle.
#
# Segment memoization (:class:`HybridCache`): module bodies are pure and
# re-runnable (the DSL contract), so a module's yield stream is a
# deterministic function of the values sent into it (read values + query
# outcomes).  A completed run therefore caches, per module, the full
# yield-level stream; later runs of the *same design shape* (e.g.
# ``classify_dynamic``'s repeated builder calls under perturbed depths)
# replay the cached stream without ever invoking the generator, validating
# every read value and query outcome against live state.  Validated blocking
# segments replay array-at-a-time (:class:`_RunArrays`,
# :meth:`HybridSim._replay_cached_bulk`): the cached yield stream is
# compiled once into flat row arrays and a window of rows is committed per
# step after a single per-FIFO value check, instead of re-dispatching every
# yield through Python.  On divergence the engine first looks for another
# cached branch whose prefix re-converges with the live outcome, and only
# then materializes the real generator, fast-forwarding it with the
# already-delivered send values.

# module states
_H_READY, _H_PARK_READ, _H_PARK_QUERY, _H_DONE = 0, 1, 2, 3

# query codes
_QC_READ_NB, _QC_WRITE_NB, _QC_EMPTY, _QC_FULL = 0, 1, 2, 3
_QC_IS_READ_SIDE = (True, False, True, False)
_QC_TO_RTYPE = (RequestType.FIFO_NB_READ, RequestType.FIFO_NB_WRITE,
                RequestType.FIFO_CAN_READ, RequestType.FIFO_CAN_WRITE)

# yield-op classes -> row opcodes, for fast-forward verification
_CLS_TO_OP = {Read: OP_READ, Write: OP_WRITE, ReadNB: OP_READ_NB,
              WriteNB: OP_WRITE_NB, Empty: OP_EMPTY, Full: OP_FULL,
              Delay: OP_DELAY, Emit: OP_EMIT}

# query-op lookups for the recorder's hot dispatch loops
_OP_TO_QC = {OP_READ_NB: _QC_READ_NB, OP_WRITE_NB: _QC_WRITE_NB,
             OP_EMPTY: _QC_EMPTY, OP_FULL: _QC_FULL}
_CLS_TO_QC = {ReadNB: _QC_READ_NB, WriteNB: _QC_WRITE_NB,
              Empty: _QC_EMPTY, Full: _QC_FULL}

_VEC_MIN = 48          # pending-slice length above which the solver vectorizes
_BATCH_MIN = 128       # total pending rows above which _solve_batch engages
_POLL_STREAK = 3       # periodic failures before query periodization kicks in
_CACHE_BULK_MIN = 4    # cached-row window length worth array dispatch
_PARK_VEC_MIN = 24     # parked-query count above which pricing vectorizes


class _GrowBuf:
    """Amortized-doubling int64 append buffer (per-FIFO committed times)."""

    __slots__ = ("a", "n")

    def __init__(self):
        self.a = np.empty(16, dtype=np.int64)
        self.n = 0

    def append(self, v: int) -> None:
        if self.n == len(self.a):
            self.a = np.concatenate([self.a, self.a])
        self.a[self.n] = v
        self.n += 1

    def extend(self, vals: np.ndarray) -> None:
        need = self.n + len(vals)
        if need > len(self.a):
            cap = len(self.a)
            while cap < need:
                cap *= 2
            b = np.empty(cap, dtype=np.int64)
            b[:self.n] = self.a[:self.n]
            self.a = b
        self.a[self.n:need] = vals
        self.n = need


@dataclass
class _CachedRun:
    """One module's completed yield-level stream (see :class:`HybridCache`).

    ``ylog[i]`` is the i-th yielded op as ``(opcode, fifo_id, payload)``;
    ``sends[i]`` is the value sent into the generator to resume after yield
    ``i``.  Payloads: Read -> value read, Write -> value written,
    ReadNB -> (ok, value), WriteNB -> (ok, value), Empty/Full -> verdict
    bool (pre-negation), Delay -> cycles, Emit -> (key, value), dead probe
    -> None.  ``arr`` is the lazily-built :class:`_RunArrays` compilation of
    the stream for array-at-a-time replay (identity-compared: two runs with
    the same ylog are the same run regardless of compilation state).
    """

    ylog: list
    sends: list
    arr: Any = field(default=None, repr=False, compare=False)


class _RunArrays:
    """A cached run's yield stream compiled to flat row arrays.

    Built once per :class:`_CachedRun` (lazily, on first bulk replay) and
    shared by every subsequent replay of that branch.  The stream is lowered
    exactly like :func:`record_trace` lowers a live generator: committing
    blocking accesses become *rows* (delays and dead probes fold into the
    row's ``gap``, ``Emit``\\ s are kept aside with their positions), query
    yields become *stop events* that bound the bulk-replayable windows.
    Because each FIFO side belongs to a single module (SPSC), the per-FIFO
    sequence numbers of a from-scratch replay are deterministic and are
    precomputed in ``row_seq``.
    """

    __slots__ = ("ev_pos", "ev_rowidx", "next_q", "boundary",
                 "row_code", "row_fifo", "row_gap", "row_seq", "row_pos",
                 "row_probes_cum", "read_fifos", "write_fifos",
                 "rrow_of", "rvals_of", "wrow_of", "wvals_of",
                 "emit_pos", "emit_kv")

    def __init__(self, ylog: list):
        ev_pos: list = []
        ev_rowidx: list = []
        row_code: list = []
        row_fifo: list = []
        row_gap: list = []
        row_seq: list = []
        row_pos: list = []
        row_probes: list = []
        emit_pos: list = []
        emit_kv: list = []
        rrow_of: Dict[int, list] = {}
        rvals_of: Dict[int, list] = {}
        wrow_of: Dict[int, list] = {}
        wvals_of: Dict[int, list] = {}
        rcnt: Dict[int, int] = {}
        wcnt: Dict[int, int] = {}
        boundary = np.zeros(len(ylog) + 1, dtype=bool)
        boundary[0] = True
        gap, probes = 1, 0
        for pos, (code, f, payload) in enumerate(ylog):
            if code == OP_DELAY:
                gap += payload
            elif code == OP_EMIT:
                emit_pos.append(pos)
                emit_kv.append(payload)
            elif code == OP_PROBE_DEAD:
                gap += 1
                probes += 1
            elif code == OP_READ or code == OP_WRITE:
                boundary[pos + 1] = True
                ev_pos.append(pos)
                ev_rowidx.append(len(row_code))
                row_code.append(code)
                row_fifo.append(f)
                row_gap.append(gap)
                row_pos.append(pos)
                row_probes.append(probes)
                if code == OP_READ:
                    s = rcnt.get(f, 0) + 1
                    rcnt[f] = s
                    rrow_of.setdefault(f, []).append(len(row_code) - 1)
                    rvals_of.setdefault(f, []).append(payload)
                else:
                    s = wcnt.get(f, 0) + 1
                    wcnt[f] = s
                    wrow_of.setdefault(f, []).append(len(row_code) - 1)
                    wvals_of.setdefault(f, []).append(payload)
                row_seq.append(s)
                gap, probes = 1, 0
            else:                     # query yield: bounds the bulk window
                boundary[pos + 1] = True
                ev_pos.append(pos)
                ev_rowidx.append(-1)
                gap, probes = 1, 0
        self.ev_pos = np.asarray(ev_pos, dtype=np.int64)
        self.ev_rowidx = np.asarray(ev_rowidx, dtype=np.int64)
        # next query event at-or-after each event index (len(ev) = none)
        nq = np.empty(len(ev_pos) + 1, dtype=np.int64)
        nq[len(ev_pos)] = len(ev_pos)
        for i in range(len(ev_pos) - 1, -1, -1):
            nq[i] = i if ev_rowidx[i] < 0 else nq[i + 1]
        self.next_q = nq
        self.boundary = boundary
        self.row_code = row_code
        self.row_fifo = row_fifo
        self.row_gap = row_gap
        self.row_seq = row_seq
        self.row_pos = np.asarray(row_pos, dtype=np.int64)
        self.row_probes_cum = np.concatenate(
            [[0], np.cumsum(np.asarray(row_probes, dtype=np.int64))])
        self.read_fifos = sorted(rrow_of)
        self.write_fifos = sorted(wrow_of)
        self.rrow_of = {f: np.asarray(v, dtype=np.int64)
                        for f, v in rrow_of.items()}
        self.rvals_of = rvals_of
        self.wrow_of = {f: np.asarray(v, dtype=np.int64)
                        for f, v in wrow_of.items()}
        self.wvals_of = wvals_of
        self.emit_pos = np.asarray(emit_pos, dtype=np.int64)
        self.emit_kv = emit_kv


class _FullRun:
    """One design's complete solved run, cached for bulk verified replay.

    Stored by :meth:`HybridSim._finish` under the design's *content*
    fingerprint (:func:`program_fingerprint` — FIFO names/depths plus
    module bytecode, constants and closure values), so two designs share
    an entry only when their generators are guaranteed to replay the same
    yield streams.  A warm hit replays the whole run without touching a
    single generator: every module's row arrays and committed times are
    installed in bulk, then *verified* per entry against the claimed
    tables (each row's time must equal ``max(chain, source + 1)`` and
    each query outcome must match the Table-2 verdict it claims — the
    dependency graph of a completed run is acyclic, so pointwise
    fixpoint equality pins the unique solution).  Any mismatch rejects
    the entry and falls back to the exact engine protocol.
    """

    __slots__ = ("kind", "fifo", "gap", "seq", "times", "end_gap", "cons",
                 "outputs", "leftover", "reader_of", "writer_of", "stats",
                 "n_rows")

    def __init__(self, kind, fifo, gap, seq, times, end_gap, cons, outputs,
                 leftover, reader_of, writer_of, stats, n_rows):
        self.kind = kind              # per-module int64 row-opcode arrays
        self.fifo = fifo              # per-module row fifo ids
        self.gap = gap                # per-module row gaps
        self.seq = seq                # per-module 1-based per-FIFO seqs
        self.times = times            # per-module committed times
        self.end_gap = end_gap        # per-module trailing gap
        self.cons = cons              # (n, 6) query/constraint records
        self.outputs = outputs
        self.leftover = leftover      # per-fifo values left in the buffers
        self.reader_of = reader_of
        self.writer_of = writer_of
        self.stats = stats            # semantic counters of the execution
        self.n_rows = n_rows


class HybridCache:
    """Cross-run segment memoization for the hybrid engine.

    Keyed by a depth-insensitive content :meth:`signature` (program name +
    FIFO/module names + per-module bytecode/closure hash) and module id —
    **not** by FIFO depths, which is the point: repeated simulations of
    the same design under perturbed depths (``classify_dynamic``, DSE
    fallbacks) replay cached module streams and re-run generators only
    past a genuine control-flow divergence.  Stores up to ``max_variants``
    outcome branches per module, most recent first.  A second layer keyed
    by the full content fingerprint (depths included) holds complete
    solved runs (:class:`_FullRun`) for bulk verified replay.

    Counters: ``hits`` (modules fully replayed without touching their
    generator), ``misses`` (no cached branch at run start), ``switches``
    (divergence repaired by another cached branch whose prefix re-converges)
    and ``divergences`` (generator materialized and fast-forwarded);
    ``full_hits`` / ``full_misses`` / ``full_rejects`` count the
    whole-run layer.
    """

    def __init__(self, max_variants: int = 6, max_full: int = 8):
        self.max_variants = max_variants
        self.max_full = max_full
        self._runs: Dict[tuple, List[_CachedRun]] = {}
        self._full: "OrderedDict[str, _FullRun]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.switches = 0
        self.divergences = 0
        self.full_hits = 0            # whole runs replayed + verified in bulk
        self.full_misses = 0
        self.full_rejects = 0         # entries that failed verification

    @staticmethod
    def signature(program: Program) -> tuple:
        """Depth-insensitive content key for the segment/variant cache.

        Names alone are NOT enough: two builds of the same design with
        different *builder arguments* (``branch(96)`` vs ``branch(160)``)
        share every name, and a cached yield stream from one would replay
        outcome-compatibly on the other right up to its early end — the
        shorter run's results, silently.  Hashing each module's bytecode +
        constants + captured closure values pins the control flow; FIFO
        depths are deliberately excluded (captured FIFOs hash by name
        only), because depth perturbations are exactly the reuse this
        cache serves — divergence checking handles depth-induced outcome
        changes, but it cannot see closure constants that shorten a loop.
        """
        import hashlib
        h = hashlib.sha256()
        for m in program.modules:
            h.update(m.name.encode())
            h.update(b"|")
            _fp_update(h, m.fn, fifo_depth=False)
        return (program.name,
                tuple(f.name for f in program.fifos),
                tuple(m.name for m in program.modules),
                h.hexdigest())

    def lookup(self, sig: tuple, mid: int) -> List[_CachedRun]:
        return self._runs.get((sig, mid), [])

    def store(self, sig: tuple, mid: int, run: _CachedRun) -> None:
        runs = self._runs.setdefault((sig, mid), [])
        runs.insert(0, run)
        del runs[self.max_variants:]

    def promote(self, sig: tuple, mid: int, run: _CachedRun) -> None:
        runs = self._runs.get((sig, mid), [])
        if run in runs and runs[0] is not run:
            runs.remove(run)
            runs.insert(0, run)

    def lookup_full(self, key: str) -> Optional[_FullRun]:
        run = self._full.get(key)
        if run is None:
            self.full_misses += 1
            return None
        self._full.move_to_end(key)
        return run

    def store_full(self, key: str, run: _FullRun) -> None:
        self._full[key] = run
        self._full.move_to_end(key)
        while len(self._full) > self.max_full:
            self._full.popitem(last=False)

    def peek_full(self, key: str) -> Optional[_FullRun]:
        """Non-counting, non-LRU-touching read — the sweep cache spills
        verified whole-run entries alongside its ``CacheEntry`` without
        perturbing hit/miss stats (``sweep/cache.py``)."""
        return self._full.get(key)


class _HMod:
    """Per-module recorder state of the hybrid engine."""

    __slots__ = ("mid", "name", "gen", "started", "state", "send",
                 "kind", "fifo", "gap", "seq", "times", "gap_acc", "end_gap",
                 "park_fid", "qid", "q_code", "q_fifo", "q_seq", "q_payload",
                 "q_time", "cand", "cand_alts", "pos", "ylog", "sends",
                 "p_code", "p_fifo", "p_seq", "p_gap", "p_row", "streak",
                 "burst", "pending_op", "p_hist", "pat", "pat_k")

    def __init__(self, mid: int, name: str):
        self.mid = mid
        self.name = name
        self.gen = None
        self.started = False
        self.state = _H_READY
        self.send = None
        self.kind: list = []          # row opcodes
        self.fifo: list = []          # row fifo ids (-1 for none)
        self.gap: list = []           # SEQ gap into each row (cycles)
        self.seq: list = []           # 1-based per-FIFO seq (prospective for
                                      # failed NB / probes)
        self.times: list = []         # committed times; len == solve frontier
        self.gap_acc = 1
        self.end_gap = 1
        self.park_fid = -1
        self.qid = -1
        self.q_code = -1
        self.q_fifo = -1
        self.q_seq = -1
        self.q_payload = None
        self.q_time = -1
        self.cand: Optional[_CachedRun] = None
        self.cand_alts: List[_CachedRun] = []
        self.pos = 0                  # next yield index (cache replay)
        self.ylog: Optional[list] = None
        self.sends: Optional[list] = None
        # poll-loop detector (query periodization): last failed query's
        # site/gap/row and the length of the current periodic failure streak
        self.p_code = -1
        self.p_fifo = -1
        self.p_seq = -1
        self.p_gap = -1
        self.p_row = -2
        self.streak = 0
        self.burst = False            # detector armed a burst attempt
        self.pending_op = None        # yield fetched but not yet dispatched
        # generalized periodic-pattern detector: recent consecutive NB query
        # steps (code, fifo, gap, outcome), the armed repeating pattern
        # tuple, and the index of the next expected step within it
        self.p_hist: list = []
        self.pat: Optional[tuple] = None
        self.pat_k = 0


class HybridSim:
    """Segmented trace-compiled simulation of dynamic (NB/probe) designs.

    One instance = one run.  See the section comment above for the
    algorithm; :func:`simulate_hybrid` is the front door.  Raises
    :class:`TraceUnsupported` on true deadlocks, WAR cycles and SPSC
    violations so ``simulate(..., trace="auto")`` can reproduce the
    generator engine's exact report.
    """

    def __init__(self, program: Program, cache: Optional[HybridCache] = None,
                 max_steps: int = 50_000_000, periodize: bool = True,
                 batch_min: int = _BATCH_MIN):
        self.program = program
        self.cache = cache
        self.max_steps = max_steps
        self.periodize = periodize
        self.batch_min = batch_min    # <= 0 disables the batch solver
        self.depths = [f.depth for f in program.fifos]
        n_fifo = len(program.fifos)
        self.mods = [_HMod(m.mid, m.name) for m in program.modules]
        self.buffers: List[deque] = [deque() for _ in range(n_fifo)]
        self.fw_times = [_GrowBuf() for _ in range(n_fifo)]  # committed writes
        self.fr_times = [_GrowBuf() for _ in range(n_fifo)]  # committed reads
        self.wseq = [0] * n_fifo      # recorded committed writes per FIFO
        self.rseq = [0] * n_fifo      # recorded committed reads per FIFO
        self.writer_of: Dict[int, int] = {}
        self.reader_of: Dict[int, int] = {}
        self.waiting_reader: Dict[int, int] = {}
        self.outputs: Dict[str, Any] = {}
        self.constraints: list = []   # (q_code, fifo, seq, mid, row, outcome)
        self.heap: List[Tuple[int, int, int]] = []   # (time, qid, mid)
        self.unpriced: set = set()
        self.solve_dirty: set = set()
        self.pending: set = set()     # mids with recorded-but-untimed rows
        self.n_done = 0               # modules in _H_DONE state
        # parked-query watch slots: a read-side query's verdict can only
        # flip when its FIFO's *write* table grows (and vice versa), and
        # SPSC means at most one parked query watches each (fifo, side) —
        # so every commit site can wake exactly the right parked queries
        # and quiescence never rescans a heap nothing could have changed
        self.qwatch_w = [-1] * n_fifo   # parked read-side query mid per fifo
        self.qwatch_r = [-1] * n_fifo   # parked write-side query mid per fifo
        self.rp_wake: set = set()       # parked mids whose table grew
        self.runq: deque = deque()
        self.queued = [False] * len(self.mods)
        self._qid = 0
        self.steps = 0
        self.activations = 0
        self.phases = 0
        self.queries = 0
        self.forced = 0
        self.skipped_probes = 0
        self.bulk_queries = 0         # queries resolved by periodized bursts
        self.bursts = 0
        self.batch_rows = 0           # rows committed by the batch solver
        self.batch_solves = 0
        self._batch_futile = -1       # pending volume of the last no-commit
        #                               batch attempt (futility gate)
        self._batch_backoff = 0       # pending volume below which the batch
        #                               solver stays off (low-yield backoff)
        self.cache_bulk_rows = 0      # cached rows replayed array-at-a-time
        self._full_replay = False     # this run was served by _replay_full
        if cache is not None:
            self.sig = HybridCache.signature(program)
            # full content fingerprint for whole-run replay: the segment
            # signature above deliberately ignores FIFO depths (divergence
            # checking absorbs depth-induced outcome changes), but a bulk
            # replay installs committed *times*, which depend on depths —
            # its key must pin them too
            self._fkey = program_fingerprint(program)
            for st in self.mods:
                st.ylog, st.sends = [], []
                st.cand_alts = cache.lookup(self.sig, st.mid)
                if st.cand_alts:
                    st.cand = st.cand_alts[0]
                else:
                    cache.misses += 1

    # ----------------------------------------------------------------- utils
    def _unsup(self, msg: str) -> TraceUnsupported:
        return TraceUnsupported(f"{self.program.name}: {msg}")

    def _check_endpoint(self, f: int, mid: int, write_side: bool) -> None:
        table = self.writer_of if write_side else self.reader_of
        prev = table.setdefault(f, mid)
        if prev != mid:
            raise self._unsup(
                f"fifo {f} has two {'writer' if write_side else 'reader'} "
                f"modules — SPSC violation; deferring to the generator "
                f"engine's endpoint check")

    def _enqueue(self, mid: int) -> None:
        if not self.queued[mid]:
            self.queued[mid] = True
            self.runq.append(mid)

    def _mark_dirty(self, mid: int) -> None:
        # only modules with recorded-but-untimed rows can profit from a
        # frontier retry; marking others would just break the empty-dirty
        # fast paths (a module recording new rows later re-enters the
        # worklist through ``self.pending``)
        if mid >= 0:
            st = self.mods[mid]
            if len(st.kind) != len(st.times):
                self.solve_dirty.add(mid)

    # --------------------------------------------------- eager row timing
    # When a module records a blocking row while its chain is timed up to
    # that row (lock-step execution, the forced-poll ping-pong hot case),
    # the row's time is computable immediately from the committed tables —
    # same formula as the frontier, so committing it here instead of
    # waiting for the next ``_solve`` changes nothing but when the work
    # happens.  Rows whose RAW/WAR source is uncommitted simply stay
    # pending and flow through the regular solver.
    def _eager_read(self, st: _HMod, f: int, s: int) -> None:
        wt = self.fw_times[f]
        if s > wt.n:
            return
        times_l = st.times
        t = (times_l[-1] if times_l else 0) + st.gap[-1]
        c = int(wt.a[s - 1]) + 1
        if c > t:
            t = c
        self.fr_times[f].append(t)
        times_l.append(t)
        self._mark_dirty(self.writer_of.get(f, -1))
        w = self.qwatch_r[f]
        if w >= 0:
            self.rp_wake.add(w)

    def _eager_write(self, st: _HMod, f: int, s: int) -> None:
        tg = s - self.depths[f]
        times_l = st.times
        t = (times_l[-1] if times_l else 0) + st.gap[-1]
        if tg > 0:
            rt = self.fr_times[f]
            if tg > rt.n:
                return
            c = int(rt.a[tg - 1]) + 1
            if c > t:
                t = c
        self.fw_times[f].append(t)
        times_l.append(t)
        self._mark_dirty(self.reader_of.get(f, -1))
        w = self.qwatch_w[f]
        if w >= 0:
            self.rp_wake.add(w)

    # ------------------------------------------------------- frontier solver
    def _advance_frontier(self, st: _HMod) -> bool:
        """Time the maximal ready prefix of ``st``'s pending rows.

        Pending rows are always blocking accesses (query rows are committed
        with their resolution time the moment they resolve), so each row's
        time is ``max(t_prev + gap, src + 1)`` with ``src`` the RAW matching
        write (reads) or the WAR target read (writes, seq > depth).  Large
        pending slices go through the vectorized cummax path — the "compile
        the blocking segment" move of paper Sec. 5.1.
        """
        times_l = st.times
        lo, hi = len(times_l), len(st.kind)
        if lo >= hi:
            return False
        kind_l, fifo_l, gap_l, seq_l = st.kind, st.fifo, st.gap, st.seq
        fw, fr, depths = self.fw_times, self.fr_times, self.depths
        t_prev = times_l[lo - 1] if lo else 0
        if hi - lo == 1:
            # exactly one pending row — the write-before-poll / pipeline
            # ping-pong hot case: commit it without touched-set bookkeeping
            f = fifo_l[lo]
            s = seq_l[lo]
            t = t_prev + gap_l[lo]
            if kind_l[lo] == OP_READ:
                wt = fw[f]
                if s > wt.n:
                    return False
                c = int(wt.a[s - 1]) + 1
                if c > t:
                    t = c
                fr[f].append(t)
                self._mark_dirty(self.writer_of.get(f, -1))
                w = self.qwatch_r[f]
            else:                                   # OP_WRITE
                tg = s - depths[f]
                if tg > 0:
                    rt = fr[f]
                    if tg > rt.n:
                        return False
                    c = int(rt.a[tg - 1]) + 1
                    if c > t:
                        t = c
                fw[f].append(t)
                self._mark_dirty(self.reader_of.get(f, -1))
                w = self.qwatch_w[f]
            if w >= 0:
                self.rp_wake.add(w)
            times_l.append(t)
            return True
        touched_w: set = set()
        touched_r: set = set()
        # scalar pass over the first few pending rows: a frontier that
        # advances in FIFO-depth-sized hops (pipeline ping-pong) never pays
        # numpy call overhead
        cap = min(hi, lo + _VEC_MIN)
        i = lo
        while i < cap:
            f = fifo_l[i]
            s = seq_l[i]
            t = t_prev + gap_l[i]
            if kind_l[i] == OP_READ:
                wt = fw[f]
                if s > wt.n:
                    break
                c = int(wt.a[s - 1]) + 1
                if c > t:
                    t = c
                fr[f].append(t)
                touched_r.add(f)
            else:                                   # OP_WRITE
                tg = s - depths[f]
                if tg > 0:
                    rt = fr[f]
                    if tg > rt.n:
                        break
                    c = int(rt.a[tg - 1]) + 1
                    if c > t:
                        t = c
                fw[f].append(t)
                touched_w.add(f)
            times_l.append(t)
            t_prev = t
            i += 1
        if i == cap and cap < hi:
            # long runnable stretch: batch the rest through the vectorized
            # cummax in geometrically growing windows (each window is only
            # materialized as arrays once per visit)
            self._advance_frontier_np(st, hi, touched_r, touched_w)
        if touched_w:
            qw, wake = self.qwatch_w, self.rp_wake
            for f in touched_w:
                self._mark_dirty(self.reader_of.get(f, -1))
                w = qw[f]
                if w >= 0:
                    wake.add(w)
        if touched_r:
            qr, wake = self.qwatch_r, self.rp_wake
            for f in touched_r:
                self._mark_dirty(self.writer_of.get(f, -1))
                w = qr[f]
                if w >= 0:
                    wake.add(w)
        return len(times_l) > lo

    def _advance_frontier_np(self, st: _HMod, hi: int,
                             touched_r: set, touched_w: set) -> None:
        """Windowed vectorized frontier advance: ``t = cw + cummax(c - cw)``
        over the maximal ready prefix, window doubling per round."""
        dep = np.asarray(self.depths, dtype=np.int64)
        window = 2 * _VEC_MIN
        while True:
            lo = len(st.times)
            if lo >= hi:
                return
            w = min(hi - lo, window)
            kind = np.asarray(st.kind[lo:lo + w], dtype=np.int64)
            fifo = np.asarray(st.fifo[lo:lo + w], dtype=np.int64)
            gap = np.asarray(st.gap[lo:lo + w], dtype=np.int64)
            seq = np.asarray(st.seq[lo:lo + w], dtype=np.int64)
            nwt = np.fromiter((b.n for b in self.fw_times), np.int64,
                              len(self.fw_times))
            nrt = np.fromiter((b.n for b in self.fr_times), np.int64,
                              len(self.fr_times))
            rd = kind == OP_READ
            avail = np.empty(w, dtype=bool)
            avail[rd] = seq[rd] <= nwt[fifo[rd]]
            wr = ~rd
            tg = seq[wr] - dep[fifo[wr]]
            avail[wr] = (tg <= 0) | (tg <= nrt[fifo[wr]])
            stop = w if avail.all() else int(np.argmin(avail))
            if stop == 0:
                return
            kind, fifo, gap, seq, rd = (kind[:stop], fifo[:stop], gap[:stop],
                                        seq[:stop], rd[:stop])
            c = np.full(stop, NEGI, dtype=np.int64)
            for f in np.unique(fifo):
                m_r = rd & (fifo == f)
                if m_r.any():
                    c[m_r] = self.fw_times[f].a[seq[m_r] - 1] + 1
                m_w = ~rd & (fifo == f)
                if m_w.any():
                    sw = seq[m_w]
                    con = sw > self.depths[f]
                    if con.any():
                        idx = np.flatnonzero(m_w)[con]
                        c[idx] = (self.fr_times[f].a[sw[con]
                                                     - self.depths[f] - 1] + 1)
            t_prev = st.times[lo - 1] if lo else 0
            cw = t_prev + np.cumsum(gap)
            t = cw + np.maximum.accumulate(np.maximum(c - cw, 0))
            st.times.extend(t.tolist())
            for f in np.unique(fifo):
                m_r = rd & (fifo == f)
                if m_r.any():
                    self.fr_times[f].extend(t[m_r])
                    touched_r.add(f)
                m_w = ~rd & (fifo == f)
                if m_w.any():
                    self.fw_times[f].extend(t[m_w])
                    touched_w.add(f)
            if stop < w:
                return
            window *= 2

    def _solve_batch(self) -> bool:
        """Provisional-times batch solve of every recorded-but-untimed row.

        Replaces the FIFO-depth-sized hops of :meth:`_advance_frontier` on
        tightly-coupled pipelines: every module's pending window enters one
        multi-chain longest-path system (committed times as boundary
        conditions), solved by the same per-chain ``t = cw + cummax(c-cw)``
        Gauss-Seidel sweep as :func:`_solve_times`.  Windows are first
        *truncated* at the earliest row whose RAW/WAR source event is not
        recorded anywhere (its module is parked at a query) — iterated to a
        fixpoint, since truncating a writer window can strand a reader row —
        which is what validates the committed prefix: every surviving row
        depends only on committed times or rows inside the windows.

        Returns True when any row was committed.  Non-convergence (a WAR
        cycle: times grow past the acyclic bound) commits nothing and
        returns False — the scalar frontier then stalls on the cycle and
        ``run()`` reports it as a deadlock via :class:`TraceUnsupported`.
        """
        fw, fr = self.fw_times, self.fr_times
        n_fifo = len(self.depths)
        dep = np.asarray(self.depths, dtype=np.int64)
        fwn = np.fromiter((b.n for b in fw), np.int64, n_fifo)
        frn = np.fromiter((b.n for b in fr), np.int64, n_fifo)
        sts, kinds, fifos, gaps, seqs, t0s = [], [], [], [], [], []
        for mid in sorted(self.pending):
            st = self.mods[mid]
            lo, hi = len(st.times), len(st.kind)
            if lo >= hi:
                continue
            sts.append(st)
            kinds.append(np.asarray(st.kind[lo:], dtype=np.int64))
            fifos.append(np.asarray(st.fifo[lo:], dtype=np.int64))
            gaps.append(np.asarray(st.gap[lo:], dtype=np.int64))
            seqs.append(np.asarray(st.seq[lo:], dtype=np.int64))
            t0s.append(st.times[lo - 1] if lo else 0)
        n_win = len(sts)
        if not n_win:
            return False
        # ---- truncate windows at unrecorded sources (iterated fixpoint)
        e = [len(k) for k in kinds]
        wwin = np.full(n_fifo, -1, dtype=np.int64)   # window holding f's
        rwin = np.full(n_fifo, -1, dtype=np.int64)   # pending writes/reads
        wpos: Dict[int, np.ndarray] = {}
        rpos: Dict[int, np.ndarray] = {}
        for i in range(n_win):
            wr = kinds[i] != OP_READ
            for f in np.unique(fifos[i]):
                m = fifos[i] == f
                pw = np.flatnonzero(m & wr)
                if len(pw):
                    wwin[f] = i
                    wpos[int(f)] = pw
                pr = np.flatnonzero(m & ~wr)
                if len(pr):
                    rwin[f] = i
                    rpos[int(f)] = pr
        for _ in range(4 * n_win + 8):
            avail_w = np.zeros(n_fifo, dtype=np.int64)
            avail_r = np.zeros(n_fifo, dtype=np.int64)
            for f, p in wpos.items():
                avail_w[f] = int(np.searchsorted(p, e[int(wwin[f])]))
            for f, p in rpos.items():
                avail_r[f] = int(np.searchsorted(p, e[int(rwin[f])]))
            changed = False
            for i in range(n_win):
                lim = e[i]
                if not lim:
                    continue
                k, f, s = kinds[i][:lim], fifos[i][:lim], seqs[i][:lim]
                rd = k == OP_READ
                bad = rd & (s > fwn[f] + avail_w[f])
                tg = s - dep[f]
                bad |= ~rd & (tg > 0) & (tg > frn[f] + avail_r[f])
                if bad.any():
                    e[i] = int(np.argmax(bad))
                    changed = True
            if not changed:
                break
        else:
            return False
        if not any(e):
            return False
        # ---- build the provisional system: cw, constant sources, edges
        cws, cs, ts = [], [], []
        buckets: Dict[int, List[Tuple[int, np.ndarray, np.ndarray]]] = {}
        total_gap = 0
        n_edges = 0
        max_committed = 0
        for i in range(n_win):
            lim = e[i]
            k, f, s, g = (kinds[i][:lim], fifos[i][:lim], seqs[i][:lim],
                          gaps[i][:lim])
            cw = t0s[i] + np.cumsum(g)
            c = np.full(lim, NEGI, dtype=np.int64)
            total_gap += int(g.sum())
            max_committed = max(max_committed, t0s[i])
            rd = k == OP_READ
            for fid in np.unique(f):
                fid = int(fid)
                m_r = rd & (f == fid)
                if m_r.any():
                    sv = s[m_r]
                    com = sv <= fwn[fid]
                    if com.any():
                        idx = np.flatnonzero(m_r)[com]
                        c[idx] = fw[fid].a[sv[com] - 1] + 1
                    pend = ~com
                    if pend.any():
                        dst = np.flatnonzero(m_r)[pend]
                        src = wpos[fid][sv[pend] - fwn[fid] - 1]
                        buckets.setdefault(int(wwin[fid]), []).append(
                            (i, src, dst))
                        n_edges += len(dst)
                m_w = ~rd & (f == fid)
                if m_w.any():
                    tg = s[m_w] - int(dep[fid])
                    con = tg > 0
                    com = con & (tg <= frn[fid])
                    if com.any():
                        idx = np.flatnonzero(m_w)[com]
                        c[idx] = fr[fid].a[tg[com] - 1] + 1
                    pend = con & ~com
                    if pend.any():
                        dst = np.flatnonzero(m_w)[pend]
                        src = rpos[fid][tg[pend] - frn[fid] - 1]
                        buckets.setdefault(int(rwin[fid]), []).append(
                            (i, src, dst))
                        n_edges += len(dst)
            if lim:
                # committed sources (incl. from fully-timed modules) push
                # the acyclic bound past the pending modules' own times
                max_committed = max(max_committed, int(c.max()))
            cws.append(cw)
            cs.append(c)
            ts.append(np.full(lim, NEGI, dtype=np.int64))
        # ---- Gauss-Seidel sweep to fixpoint (dirty-window tracking)
        bound = max_committed + total_gap + n_edges + 1
        dirty = [lim > 0 for lim in e]
        sweeps = 0
        while any(dirty):
            sweeps += 1
            if sweeps > n_win + 4:
                if sweeps > sum(e) + 2 or max(
                        (int(t.max()) for t in ts if len(t)),
                        default=0) > bound:
                    return False         # WAR cycle: defer to scalar/deadlock
            for i in range(n_win):
                if not dirty[i]:
                    continue
                dirty[i] = False
                seg = np.maximum(cs[i] - cws[i], 0)
                np.maximum.accumulate(seg, out=seg)
                seg += cws[i]
                if np.array_equal(seg, ts[i]):
                    continue
                ts[i] = seg
                for (di, src, dst) in buckets.get(i, ()):
                    cand = seg[src] + 1
                    old = cs[di][dst]
                    moved = cand > old
                    if moved.any():
                        cs[di][dst] = np.maximum(old, cand)
                        dirty[di] = True
        # ---- commit: everything in the truncated windows is final
        for i in range(n_win):
            lim = e[i]
            if not lim:
                continue
            st, t = sts[i], ts[i]
            st.times.extend(t.tolist())
            k, f = kinds[i][:lim], fifos[i][:lim]
            rd = k == OP_READ
            for fid in np.unique(f):
                fid = int(fid)
                m_r = rd & (f == fid)
                if m_r.any():
                    fr[fid].extend(t[m_r])
                    w = self.qwatch_r[fid]
                    if w >= 0:
                        self.rp_wake.add(w)
                m_w = ~rd & (f == fid)
                if m_w.any():
                    fw[fid].extend(t[m_w])
                    w = self.qwatch_w[fid]
                    if w >= 0:
                        self.rp_wake.add(w)
            self.batch_rows += lim
        self.batch_solves += 1
        return True

    def _solve(self) -> bool:
        """Run the frontier solvers to fixpoint over the dirty-module set.

        Seeds the worklist from ``self.pending`` — the incrementally
        maintained set of modules with recorded-but-untimed rows (updated
        by the run loop after every activation and by ``_issue_query``) —
        so a solve costs O(pending modules), not a scan of every module in
        the design.  Large pending volumes go through the provisional-times
        batch solver first (:meth:`_solve_batch`); the scalar frontier mops
        up the remainder and is the sole path when the batch solver bails
        (WAR cycles).
        """
        dirty = self.solve_dirty
        pend = self.pending
        if not pend and not dirty:
            return False
        mods = self.mods
        pending = 0
        for mid in pend:
            st = mods[mid]
            d = len(st.kind) - len(st.times)
            if d > 0:
                pending += d
                dirty.add(mid)
        changed = False
        # Futility gate: when a batch attempt committed nothing (every
        # window truncated to zero — e.g. most modules parked for good in a
        # deadlocking 1000-module corpus design), re-running it per query
        # at the same pending volume just rebuilds the same system.  The
        # scalar frontier below computes the identical fixpoint in small
        # hops, so skipping the batch can never change results — only
        # which solver commits the rows.
        if (pending >= self.batch_min > 0 and pending != self._batch_futile
                and pending >= self._batch_backoff):
            rows0 = self.batch_rows
            if self._solve_batch():
                changed = True
                self._batch_futile = -1
                got = self.batch_rows - rows0
                # Low-yield backoff: when a large system is rebuilt only to
                # commit a trickle of rows (run-ahead recording throttled by
                # WAR on lazily-committing NB reads), the next attempt at a
                # similar volume rebuilds the same system.  Hold the batch
                # solver off until the pending volume has grown past the
                # uncommitted remainder by a full batch quantum; the scalar
                # frontier commits the trickle at O(rows) in the meantime.
                if got * 4 < pending:
                    self._batch_backoff = pending - got + self.batch_min
                else:
                    self._batch_backoff = 0
            else:
                self._batch_futile = pending
        while dirty:
            st = mods[dirty.pop()]
            if self._advance_frontier(st):
                changed = True
        if pend:
            done = [mid for mid in pend
                    if len(mods[mid].times) == len(mods[mid].kind)]
            for mid in done:
                pend.discard(mid)
        return changed

    # --------------------------------------------------------------- queries
    def _verdict(self, code: int, f: int, s: int, t: int) -> Optional[bool]:
        """Table-2 resolution against the committed time tables; ``None`` =
        target event not yet committed (same rule as FifoTable.can_*_at)."""
        if _QC_IS_READ_SIDE[code]:
            wt = self.fw_times[f]
            if s <= wt.n:
                return bool(wt.a[s - 1] < t)
            return None
        tg = s - self.depths[f]
        if tg <= 0:
            return True
        rt = self.fr_times[f]
        if tg <= rt.n:
            return bool(rt.a[tg - 1] < t)
        return None

    def _apply_query(self, st: _HMod, outcome: bool) -> None:
        """Commit a resolved query at its source cycle ``st.q_time`` —
        the generator engine's ``_apply_query_result``, on flat arrays."""
        code, f, s, t = st.q_code, st.q_fifo, st.q_seq, st.q_time
        row = len(st.kind)
        self.constraints.append((code, f, s, st.mid, row, outcome))
        payload = st.q_payload
        # the query is resolving: retire its (fifo, side) watch slot
        if _QC_IS_READ_SIDE[code]:
            self.qwatch_w[f] = -1
        else:
            self.qwatch_r[f] = -1
        if code == _QC_READ_NB:
            if outcome:
                v = self.buffers[f].popleft()
                st.kind.append(OP_READ_NB)
                self.rseq[f] = s
                self.fr_times[f].append(t)
                w = self.qwatch_r[f]
                if w >= 0:
                    self.rp_wake.add(w)
                self._mark_dirty(self.writer_of.get(f, -1))
                st.send = (True, v)
            else:
                st.kind.append(OP_NB_FAIL)
                st.send = (False, None)
            expected = st.send
        elif code == _QC_WRITE_NB:
            if outcome:
                st.kind.append(OP_WRITE_NB)
                self.wseq[f] = s
                self.fw_times[f].append(t)
                w = self.qwatch_w[f]
                if w >= 0:
                    self.rp_wake.add(w)
                self._mark_dirty(self.reader_of.get(f, -1))
                self.buffers[f].append(payload)
                w = self.waiting_reader.pop(f, None)
                if w is not None:
                    self._enqueue(w)
                st.send = True
            else:
                st.kind.append(OP_NB_FAIL)
                st.send = False
            expected = (outcome, payload)
        else:                                       # Empty / Full probe
            st.kind.append(OP_PROBE)
            st.send = not outcome
            expected = outcome
        st.fifo.append(f)
        st.gap.append(st.gap_acc)
        st.seq.append(s)
        st.times.append(t)
        g = st.gap_acc
        st.gap_acc = 1
        st.q_payload = None
        st.state = _H_READY
        # ---- steady-state periodic-pattern detector (query periodization).
        # Single-site all-fail streaks (>= _POLL_STREAK consecutive failures
        # at one site, same gap, no commits in between) keep the dedicated
        # closed-form burst path (_poll_horizon/_burst_polls).  Everything
        # else that repeats — multi-site poll rotations, steady NB success
        # streams, mixed fail/success periods — arms a generalized pattern
        # tuple of (code, fifo, gap, outcome) steps consumed by
        # _burst_pattern.  Steps must be row-consecutive queries: any
        # blocking row in between resets both detectors.
        if self.periodize:
            consec = row == st.p_row + 1
            st.p_row = row
            if outcome:
                st.streak = 0
            elif (consec and code == st.p_code and f == st.p_fifo
                    and s == st.p_seq and g == st.p_gap):
                st.streak += 1
                if st.streak >= _POLL_STREAK and st.pat is None:
                    st.burst = True
            else:
                st.p_code, st.p_fifo, st.p_seq, st.p_gap = code, f, s, g
                st.streak = 1
            if code <= _QC_WRITE_NB:
                step = (code, f, g, outcome)
                pat = st.pat
                hist = st.p_hist
                if pat is not None and consec:
                    if step == pat[st.pat_k]:
                        k2 = st.pat_k + 1
                        if k2 == len(pat):
                            st.pat_k = 0
                            st.burst = True
                        else:
                            st.pat_k = k2
                    else:                     # pattern broke: re-detect
                        st.pat = None
                        hist.clear()
                        hist.append(step)
                else:
                    if pat is not None:       # non-consecutive row: disarm
                        st.pat = None
                        hist.clear()
                    elif not consec:
                        hist.clear()
                    hist.append(step)
                    L = len(hist)
                    if L > 12:                # 3 periods of the max P == 4
                        del hist[0]
                        L = 12
                    for P in (1, 2, 3, 4):    # arm the shortest period seen
                        if L < 3 * P:         # need 3 observed periods
                            break
                        for i in range(1, 2 * P + 1):
                            if hist[-i] != hist[-i - P]:
                                break
                        else:
                            if P == 1 and not outcome:
                                break         # single-site all-fail: streak
                            st.pat = tuple(hist[-P:])
                            st.pat_k = 0
                            st.burst = True
                            break
            elif st.pat is not None or st.p_hist:
                st.pat = None                 # used probes break NB patterns
                st.p_hist.clear()
        op_code = (OP_READ_NB, OP_WRITE_NB, OP_EMPTY, OP_FULL)[code]
        if st.cand is not None:
            want = (st.cand.ylog[st.pos][2]
                    if st.pos < len(st.cand.ylog) else None)
            if want == expected:
                st.pos += 1
            else:
                self._diverge(st, (op_code, f, expected), st.send)
        elif st.ylog is not None:
            st.ylog.append((op_code, f, expected))
            st.sends.append(st.send)

    # ------------------------------------------------- query periodization
    def _poll_horizon(self, st: _HMod) -> int:
        """Number of future polls of ``st``'s detected loop that resolve
        *definitively false* against the committed time tables.

        Paper Table 2, vectorized over the periodic window: the k-th future
        poll prices at ``t0 + k*p`` and fails while that cycle is <= the
        (immutable) commit time of the target event, so the whole window of
        verdicts is ``(lim - t0) // p`` — known at once, with no per-query
        resolution.  Returns 0 when the target event is uncommitted (the
        verdict would be undecidable: the forced-false rule must keep
        handling it) or when the loop could succeed immediately.
        """
        code, f, s = st.q_code, st.q_fifo, st.q_seq
        p = st.p_gap
        if p <= 0:
            return 0
        if _QC_IS_READ_SIDE[code]:
            wt = self.fw_times[f]
            if s > wt.n:
                return 0
            lim = int(wt.a[s - 1])
        else:
            tg = s - self.depths[f]
            if tg <= 0:
                return 0
            rt = self.fr_times[f]
            if tg > rt.n:
                return 0
            lim = int(rt.a[tg - 1])
        return (lim - st.times[-1]) // p

    def _burst_polls(self, st: _HMod, K: int) -> bool:
        """Resolve up to ``K`` periodic poll outcomes in one burst.

        The module has just had a failed query resolved at its detected
        poll site; all of the next ``K`` polls are known to fail
        (:meth:`_poll_horizon`).  Rows, times and constraints are appended
        in bulk while the module's stream (generator or cached branch) is
        advanced through a tight verification loop that admits only the
        recorded pattern — timing-only body ops followed by the same query
        at the same gap.  Any divergence stops the burst *before* the
        off-pattern poll is committed and hands the pending yield back to
        the normal per-query dispatch, so results stay bit-identical.
        Returns True when the module terminated during the burst.
        """
        code, f, s = st.q_code, st.q_fifo, st.q_seq
        p = st.p_gap
        op_code = (OP_READ_NB, OP_WRITE_NB, OP_EMPTY, OP_FULL)[code]
        # failed NB accesses commit as NB_FAIL rows, probes as PROBE rows —
        # exactly what _apply_query records (op_code is the *ylog* encoding)
        row_code = OP_NB_FAIL if code <= _QC_WRITE_NB else OP_PROBE
        if code == _QC_READ_NB:
            fail_send: Any = (False, None)
        elif code == _QC_WRITE_NB:
            fail_send = False
        else:
            fail_send = True              # Empty/Full: send = not outcome
        kind_l, fifo_l, gap_l = st.kind, st.fifo, st.gap
        seq_l, times_l = st.seq, st.times
        cons = self.constraints
        mid = st.mid
        t = times_l[-1]
        k = 0
        if st.cand is not None:
            # cached-branch burst: verify entries, never touch a generator;
            # rows/times/constraints are flushed in bulk after the loop
            ylog = st.cand.ylog
            L = len(ylog)
            pos = st.pos
            probes_total = 0
            while k < K:
                g_extra, probes, npos = 0, 0, pos
                while npos < L:
                    e = ylog[npos]
                    c0 = e[0]
                    if c0 == OP_DELAY:
                        g_extra += e[2]
                    elif c0 == OP_PROBE_DEAD:
                        g_extra += 1
                        probes += 1
                    else:
                        break
                    npos += 1
                if npos >= L:
                    break
                e = ylog[npos]
                if e[0] != op_code or e[1] != f:
                    break
                pay = e[2]
                if code == _QC_READ_NB:
                    if pay != (False, None):
                        break
                elif code == _QC_WRITE_NB:
                    if not (type(pay) is tuple and pay[0] is False):
                        break
                elif pay is not False:
                    break
                if st.gap_acc + g_extra != p:
                    break
                st.gap_acc = 1
                probes_total += probes
                pos = npos + 1
                k += 1
            if k:
                row0 = len(kind_l)
                self.queries += k
                self.skipped_probes += probes_total
                self.steps += pos - st.pos
                cons.extend(zip(repeat(code, k), repeat(f, k), repeat(s, k),
                                repeat(mid, k), range(row0, row0 + k),
                                repeat(False, k)))
                kind_l.extend([row_code] * k)
                fifo_l.extend([f] * k)
                gap_l.extend([p] * k)
                seq_l.extend([s] * k)
                times_l.extend(range(t + p, t + k * p + 1, p))
            st.pos = pos
            st.send = fail_send
        else:
            # live-generator burst: rows/times/constraints are flushed in
            # bulk after the verification loop — the loop itself is only
            # generator resumptions plus pattern checks
            gen = st.gen
            gen_send = gen.send
            log = st.ylog is not None
            send = st.send
            qcls = (ReadNB, WriteNB, Empty, Full)[code]
            stopped = False
            n_send = 0
            budget = self.max_steps - self.steps
            try:
                while k < K:
                    op = gen_send(send)
                    n_send += 1
                    if n_send > budget:
                        raise RuntimeError(
                            f"step budget exceeded ({self.max_steps}); "
                            f"possible livelock — neither OmniSim nor "
                            f"co-sim detects livelock")
                    send = None
                    cls = op.__class__
                    while True:        # timing-only body ops keep the pattern
                        if cls is Delay:
                            st.gap_acc += op.cycles
                            if log:
                                st.ylog.append((OP_DELAY, -1, op.cycles))
                                st.sends.append(None)
                        elif cls is Emit:
                            self.outputs[op.key] = op.value
                            if log:
                                st.ylog.append((OP_EMIT, -1,
                                                (op.key, op.value)))
                                st.sends.append(None)
                        elif (cls is Empty or cls is Full) and not op.used:
                            self.skipped_probes += 1
                            st.gap_acc += 1
                            if log:
                                st.ylog.append((OP_PROBE_DEAD, op.fifo.fid,
                                                None))
                                st.sends.append(None)
                        else:
                            break
                        op = gen_send(None)
                        n_send += 1
                        if n_send > budget:
                            raise RuntimeError(
                                f"step budget exceeded ({self.max_steps}); "
                                f"possible livelock — neither OmniSim nor "
                                f"co-sim detects livelock")
                        cls = op.__class__
                    if (cls is not qcls or op.fifo.fid != f
                            or st.gap_acc != p):
                        st.pending_op = op
                        break
                    st.gap_acc = 1
                    if log:
                        if code == _QC_READ_NB:
                            st.ylog.append((op_code, f, (False, None)))
                        elif code == _QC_WRITE_NB:
                            st.ylog.append((op_code, f, (False, op.value)))
                        else:
                            st.ylog.append((op_code, f, False))
                        st.sends.append(fail_send)
                    send = fail_send
                    k += 1
                else:
                    st.send = fail_send
                if st.pending_op is not None:
                    st.send = None
            except StopIteration:
                st.state = _H_DONE
                st.end_gap = st.gap_acc
                self.n_done += 1
                stopped = True
            self.steps += n_send
            if k:
                row0 = len(kind_l)
                self.queries += k
                cons.extend(zip(repeat(code, k), repeat(f, k), repeat(s, k),
                                repeat(mid, k), range(row0, row0 + k),
                                repeat(False, k)))
                kind_l.extend([row_code] * k)
                fifo_l.extend([f] * k)
                gap_l.extend([p] * k)
                seq_l.extend([s] * k)
                times_l.extend(range(t + p, t + k * p + 1, p))
            if stopped:
                if k:
                    self.bursts += 1
                    self.bulk_queries += k
                    st.p_row = len(kind_l) - 1
                return True
        if k:
            self.bursts += 1
            self.bulk_queries += k
            st.p_row = len(kind_l) - 1
        if self.steps > self.max_steps:
            raise RuntimeError(
                f"step budget exceeded ({self.max_steps}); possible "
                f"livelock — neither OmniSim nor co-sim detects livelock")
        return False

    def _pattern_horizon(self, st: _HMod) -> int:
        """Number of full periods of ``st.pat`` whose verdicts are all
        derivable from the committed time tables right now.

        Generalizes :meth:`_poll_horizon` to multi-site patterns and
        success steps.  Step ``j`` of period ``m`` prices at
        ``t0 + m*p + offs[j]`` and accesses per-FIFO seq
        ``b + m*d + pre[j]`` (``d`` = successes per period at that
        (fifo, side), ``pre[j]`` = successes at it earlier in the period),
        so each step's verdict window is a closed form (constant-seq
        failures against one immutable commit time) or one vectorized
        compare against the ``fw_times``/``fr_times`` arrays.  The burst
        horizon is the min over steps — conservative by construction:
        only pre-burst table entries are consulted, and committed times
        are immutable, so every admitted verdict is exact.
        """
        pat = st.pat
        P = len(pat)
        offs = []
        acc = 0
        for (_c, _f, g, _o) in pat:
            acc += g
            offs.append(acc)
        p = acc
        if p <= 0:
            return 0
        t0 = st.times[-1]
        d_map: Dict[Tuple[int, int], int] = {}
        pre = []
        for (c, f, _g, o) in pat:
            key = (f, c & 1)
            pre.append(d_map.get(key, 0))
            if o:
                d_map[key] = d_map.get(key, 0) + 1
        M = 1 << 16                  # caps the vectorized window per burst
        for j, (c, f, _g, o) in enumerate(pat):
            d = d_map.get((f, c & 1), 0)
            off = offs[j]
            if c == _QC_READ_NB:
                b = self.rseq[f] + 1 + pre[j]
                wt = self.fw_times[f]
                if o:
                    if d <= 0:
                        return 0
                    avail = (wt.n - b) // d + 1 if wt.n >= b else 0
                    cap = min(M, avail)
                    if cap <= 0:
                        return 0
                    m = np.arange(cap, dtype=np.int64)
                    ok = wt.a[b + m * d - 1] < t0 + m * p + off
                    c_j = cap if ok.all() else int(np.argmin(ok))
                elif d == 0:
                    if b > wt.n:
                        return 0     # undecidable: forced rule must handle
                    c_j = (int(wt.a[b - 1]) - t0 - off) // p + 1
                else:
                    avail = (wt.n - b) // d + 1 if wt.n >= b else 0
                    cap = min(M, avail)
                    if cap <= 0:
                        return 0
                    m = np.arange(cap, dtype=np.int64)
                    ok = wt.a[b + m * d - 1] >= t0 + m * p + off
                    c_j = cap if ok.all() else int(np.argmin(ok))
            else:                                   # _QC_WRITE_NB
                b = self.wseq[f] + 1 + pre[j]
                dep = self.depths[f]
                rt = self.fr_times[f]
                if o:
                    if d <= 0:
                        return 0
                    # tg(m) = b + m*d - dep: True while tg <= 0, then needs
                    # the committed WAR-target read time to precede t(m)
                    m0 = (dep - b) // d + 1 if dep >= b else 0
                    avail = ((rt.n + dep - b) // d + 1
                             if rt.n + dep >= b else 0)
                    cap = min(M, avail)
                    if cap <= 0:
                        return 0
                    if cap <= m0:
                        c_j = cap
                    else:
                        m = np.arange(m0, cap, dtype=np.int64)
                        tg = b + m * d - dep
                        ok = rt.a[tg - 1] < t0 + m * p + off
                        c_j = m0 + (len(m) if ok.all()
                                    else int(np.argmin(ok)))
                elif d == 0:
                    tg = b - dep
                    if tg <= 0 or tg > rt.n:
                        return 0
                    c_j = (int(rt.a[tg - 1]) - t0 - off) // p + 1
                else:
                    if b - dep <= 0:
                        return 0     # next verdict is True, not the fail
                    avail = ((rt.n + dep - b) // d + 1
                             if rt.n + dep >= b else 0)
                    cap = min(M, avail)
                    if cap <= 0:
                        return 0
                    m = np.arange(cap, dtype=np.int64)
                    tg = b + m * d - dep
                    ok = rt.a[tg - 1] >= t0 + m * p + off
                    c_j = cap if ok.all() else int(np.argmin(ok))
            if c_j < M:
                M = c_j
                if M <= 0:
                    return 0
        return M

    def _burst_pattern(self, st: _HMod) -> bool:
        """Resolve full periods of the armed pattern in one burst.

        The multi-site / success-stream counterpart of
        :meth:`_burst_polls`: the horizon fixes every step's verdict in
        advance, and the module's stream is advanced through a per-step
        verification loop that admits only the recorded pattern — same
        query class, site and gap, timing-only body ops absorbed.  Success
        steps commit for real as they verify (buffer pops/pushes, seq
        bumps, ``fw``/``fr`` appends at the closed-form step times), so a
        divergence stops the burst *before* the off-pattern yield commits
        and results stay bit-identical.  Returns True when the module
        terminated during the burst.
        """
        if st.pending_op is not None:
            return False
        pat = st.pat
        P = len(pat)
        M = self._pattern_horizon(st)
        if M <= 0:
            return False
        K = M * P
        buffers = self.buffers
        rseq, wseq = self.rseq, self.wseq
        fw, fr = self.fw_times, self.fr_times
        cons = self.constraints
        kind_l, fifo_l, gap_l = st.kind, st.fifo, st.gap
        seq_l, times_l = st.seq, st.times
        mid = st.mid
        t = times_l[-1]
        touched_r: set = set()
        touched_w: set = set()
        k = 0
        stopped = False
        if st.cand is not None:
            # cached-branch arm: verify ylog entries against the pattern
            # and the live buffers; any mismatch (including a value
            # mismatch on a success) stops the burst and hands the entry
            # to the normal cached dispatch, which re-verifies and
            # diverges properly
            ylog = st.cand.ylog
            L = len(ylog)
            pos = st.pos
            probes_total = 0
            n_ent = 0
            while k < K:
                g_extra, probes, npos = 0, 0, pos
                while npos < L:
                    e = ylog[npos]
                    c0 = e[0]
                    if c0 == OP_DELAY:
                        g_extra += e[2]
                    elif c0 == OP_PROBE_DEAD:
                        g_extra += 1
                        probes += 1
                    else:
                        break
                    npos += 1
                if npos >= L:
                    break
                code_j, f_j, g_j, out_j = pat[k % P]
                op_code = OP_READ_NB if code_j == _QC_READ_NB else OP_WRITE_NB
                e = ylog[npos]
                if (e[0] != op_code or e[1] != f_j
                        or st.gap_acc + g_extra != g_j):
                    break
                pay = e[2]
                if type(pay) is not tuple or pay[0] is not out_j:
                    break
                if code_j == _QC_READ_NB:
                    s = rseq[f_j] + 1
                    if out_j:
                        buf = buffers[f_j]
                        if not buf or buf[0] != pay[1]:
                            break             # value divergence: fall back
                        v = buf.popleft()
                        rseq[f_j] = s
                        fr[f_j].append(t + g_j)
                        touched_r.add(f_j)
                        kind_l.append(OP_READ_NB)
                        st.send = (True, v)
                    else:
                        kind_l.append(OP_NB_FAIL)
                        st.send = (False, None)
                else:
                    s = wseq[f_j] + 1
                    if out_j:
                        wseq[f_j] = s
                        fw[f_j].append(t + g_j)
                        touched_w.add(f_j)
                        buffers[f_j].append(pay[1])
                        kind_l.append(OP_WRITE_NB)
                        st.send = True
                    else:
                        kind_l.append(OP_NB_FAIL)
                        st.send = False
                t += g_j
                st.gap_acc = 1
                cons.append((code_j, f_j, s, mid, len(times_l), out_j))
                fifo_l.append(f_j)
                gap_l.append(g_j)
                seq_l.append(s)
                times_l.append(t)
                probes_total += probes
                n_ent += npos + 1 - pos
                pos = npos + 1
                k += 1
            self.steps += n_ent
            self.skipped_probes += probes_total
            st.pos = pos
            diverged = k < K
        else:
            # live-generator arm
            gen = st.gen
            gen_send = gen.send
            log = st.ylog is not None
            send = st.send
            budget = self.max_steps - self.steps
            n_send = 0
            try:
                while k < K:
                    op = gen_send(send)
                    n_send += 1
                    if n_send > budget:
                        raise RuntimeError(
                            f"step budget exceeded ({self.max_steps}); "
                            f"possible livelock — neither OmniSim nor "
                            f"co-sim detects livelock")
                    send = None
                    cls = op.__class__
                    while True:    # timing-only body ops keep the pattern
                        if cls is Delay:
                            st.gap_acc += op.cycles
                            if log:
                                st.ylog.append((OP_DELAY, -1, op.cycles))
                                st.sends.append(None)
                        elif cls is Emit:
                            self.outputs[op.key] = op.value
                            if log:
                                st.ylog.append((OP_EMIT, -1,
                                                (op.key, op.value)))
                                st.sends.append(None)
                        elif (cls is Empty or cls is Full) and not op.used:
                            self.skipped_probes += 1
                            st.gap_acc += 1
                            if log:
                                st.ylog.append((OP_PROBE_DEAD, op.fifo.fid,
                                                None))
                                st.sends.append(None)
                        else:
                            break
                        op = gen_send(None)
                        n_send += 1
                        if n_send > budget:
                            raise RuntimeError(
                                f"step budget exceeded ({self.max_steps}); "
                                f"possible livelock — neither OmniSim nor "
                                f"co-sim detects livelock")
                        cls = op.__class__
                    code_j, f_j, g_j, out_j = pat[k % P]
                    qcls = ReadNB if code_j == _QC_READ_NB else WriteNB
                    if (cls is not qcls or op.fifo.fid != f_j
                            or st.gap_acc != g_j):
                        st.pending_op = op
                        break
                    t += g_j
                    st.gap_acc = 1
                    if code_j == _QC_READ_NB:
                        s = rseq[f_j] + 1
                        if out_j:
                            v = buffers[f_j].popleft()
                            rseq[f_j] = s
                            fr[f_j].append(t)
                            touched_r.add(f_j)
                            kind_l.append(OP_READ_NB)
                            send = (True, v)
                        else:
                            kind_l.append(OP_NB_FAIL)
                            send = (False, None)
                        if log:
                            st.ylog.append((OP_READ_NB, f_j, send))
                            st.sends.append(send)
                    else:
                        s = wseq[f_j] + 1
                        pay = op.value
                        if out_j:
                            wseq[f_j] = s
                            fw[f_j].append(t)
                            touched_w.add(f_j)
                            buffers[f_j].append(pay)
                            kind_l.append(OP_WRITE_NB)
                            send = True
                        else:
                            kind_l.append(OP_NB_FAIL)
                            send = False
                        if log:
                            st.ylog.append((OP_WRITE_NB, f_j, (out_j, pay)))
                            st.sends.append(send)
                    cons.append((code_j, f_j, s, mid, len(times_l), out_j))
                    fifo_l.append(f_j)
                    gap_l.append(g_j)
                    seq_l.append(s)
                    times_l.append(t)
                    k += 1
                else:
                    st.send = send
                if st.pending_op is not None:
                    st.send = None
            except StopIteration:
                st.state = _H_DONE
                st.end_gap = st.gap_acc
                self.n_done += 1
                stopped = True
            self.steps += n_send
            diverged = st.pending_op is not None
        # table growth during the burst wakes exactly like the frontier
        for f_j in touched_r:
            self._mark_dirty(self.writer_of.get(f_j, -1))
            w = self.qwatch_r[f_j]
            if w >= 0:
                self.rp_wake.add(w)
        for f_j in touched_w:
            self._mark_dirty(self.reader_of.get(f_j, -1))
            w = self.qwatch_w[f_j]
            if w >= 0:
                self.rp_wake.add(w)
            wr = self.waiting_reader.pop(f_j, None)
            if wr is not None:
                self._enqueue(wr)
        if k:
            self.queries += k
            self.bursts += 1
            self.bulk_queries += k
            st.p_row = len(kind_l) - 1
        if diverged and not stopped:
            st.pat = None
            st.p_hist.clear()
            st.streak = 0
        if self.steps > self.max_steps:
            raise RuntimeError(
                f"step budget exceeded ({self.max_steps}); possible "
                f"livelock — neither OmniSim nor co-sim detects livelock")
        return stopped

    def _force_earliest(self) -> None:
        """Earliest-query forced-false rule (paper Sec. 7.1).

        Sound under run-ahead recording: at a stuck state every recorded-
        but-untimed event transitively waits (through chain and RAW/WAR
        sources) on some pending query's module resuming, resumptions occur
        at cycles > the earliest priced query's cycle, and any *unpriced*
        query's own cycle depends on such an event — so no future commit can
        land strictly before the forced query's cycle.
        """
        while self.heap:
            t, qid, mid = heapq.heappop(self.heap)
            st = self.mods[mid]
            if st.state != _H_PARK_QUERY or st.qid != qid:
                continue
            self.forced += 1
            self._apply_query(st, False)
            self._enqueue(mid)
            return
        raise AssertionError("_force_earliest called with no priced query")

    def _resolve_parked(self) -> bool:
        """At quiescence: price newly-solvable queries, then resolve every
        currently-definitive one earliest-first (engine step ❹).

        Gated on the watch slots: a parked verdict can only flip from
        undecidable when its target table grows, every commit site wakes
        the (unique, by SPSC) watcher of the grown (fifo, side), and
        unpriced queries can only price after their own chain advanced —
        so a phase in which no watched table grew and nothing is unpriced
        is two set checks, not a heap scan.  That is the common case on
        forced-false-heavy designs, where each phase forces exactly one
        query.  Past the gate, resolution drains the heap scalar-wise
        below :data:`_PARK_VEC_MIN` parked queries and through the
        vectorized numpy pricer above it.
        """
        if self.unpriced:
            for mid in sorted(self.unpriced):
                st = self.mods[mid]
                if st.state != _H_PARK_QUERY:
                    self.unpriced.discard(mid)
                    continue
                if len(st.times) == len(st.kind):
                    t = (st.times[-1] if st.times else 0) + st.gap_acc
                    st.q_time = t
                    self.unpriced.discard(mid)
                    heapq.heappush(self.heap, (t, st.qid, mid))
                    self.rp_wake.add(mid)   # first verdict check is here
        if not self.rp_wake:
            return False
        self.rp_wake.clear()
        heap = self.heap
        if not heap:
            return False
        if len(heap) >= _PARK_VEC_MIN:
            return self._resolve_parked_np()
        mods = self.mods
        resolved = False
        remaining: List[Tuple[int, int, int]] = []
        while heap:
            entry = heapq.heappop(heap)
            t, qid, mid = entry
            st = mods[mid]
            if st.state != _H_PARK_QUERY or st.qid != qid:
                continue
            v = self._verdict(st.q_code, st.q_fifo, st.q_seq, t)
            if v is None:
                remaining.append(entry)
                continue
            self._apply_query(st, v)
            self._enqueue(mid)
            resolved = True
        self.heap = remaining        # drained in heap order -> still a heap
        return resolved

    def _resolve_parked_np(self) -> bool:
        """Vectorized parked-query resolution for wide designs.

        One pass builds flat arrays of every live parked query and prices
        all verdicts against the ``fw_times``/``fr_times`` numpy tables at
        once (per-unique-FIFO gathers), instead of a heappop + per-query
        ``_verdict`` round trip per entry — the ``_solve_batch`` move
        applied to engine step ❹.  Verdicts decided against the pre-pass
        tables are identical to the sequential drain's (committed times
        are immutable, so a decided verdict can never change); queries
        that only become decidable from commits made *during* this pass
        resolve on the next quiescence round with the same outcome.
        """
        heap = self.heap
        mods = self.mods
        n = len(heap)
        t_a = np.zeros(n, dtype=np.int64)
        qid_a = np.zeros(n, dtype=np.int64)
        code_a = np.zeros(n, dtype=np.int64)
        fifo_a = np.zeros(n, dtype=np.int64)
        seq_a = np.zeros(n, dtype=np.int64)
        live = np.zeros(n, dtype=bool)
        for i, (t, qid, mid) in enumerate(heap):
            st = mods[mid]
            if st.state != _H_PARK_QUERY or st.qid != qid:
                continue
            live[i] = True
            t_a[i] = t
            qid_a[i] = qid
            code_a[i] = st.q_code
            fifo_a[i] = st.q_fifo
            seq_a[i] = st.q_seq
        if not live.any():
            self.heap = []
            return False
        n_fifo = len(self.depths)
        fwn = np.fromiter((b.n for b in self.fw_times), np.int64, n_fifo)
        frn = np.fromiter((b.n for b in self.fr_times), np.int64, n_fifo)
        dep = np.asarray(self.depths, dtype=np.int64)
        rs = (code_a % 2) == 0        # _QC_READ_NB / _QC_EMPTY are read-side
        out = np.zeros(n, dtype=bool)
        m_r = live & rs & (seq_a <= fwn[fifo_a])
        for f in np.unique(fifo_a[m_r]):
            mm = m_r & (fifo_a == f)
            out[mm] = self.fw_times[f].a[seq_a[mm] - 1] < t_a[mm]
        tg = seq_a - dep[fifo_a]
        m_w0 = live & ~rs & (tg <= 0)
        out[m_w0] = True
        m_w = live & ~rs & (tg > 0) & (tg <= frn[fifo_a])
        for f in np.unique(fifo_a[m_w]):
            mm = m_w & (fifo_a == f)
            out[mm] = self.fr_times[f].a[tg[mm] - 1] < t_a[mm]
        dec = m_r | m_w0 | m_w
        idx = np.flatnonzero(dec)
        if not len(idx):
            return False              # heap untouched: every live entry kept
        order = idx[np.lexsort((qid_a[idx], t_a[idx]))]
        for i in order:
            mid = heap[i][2]
            self._apply_query(mods[mid], bool(out[i]))
            self._enqueue(mid)
        kept = [heap[i] for i in np.flatnonzero(live & ~dec)]
        heapq.heapify(kept)
        self.heap = kept
        return True

    # -------------------------------------------------------- cache plumbing
    # Invariants: while ``st.cand`` is set, the module's processed yield
    # history IS ``st.cand.ylog[:st.pos]`` (every value/outcome-carrying
    # entry is validated against live state before being applied), so
    # ``st.ylog``/``st.sends`` are not maintained; they are reconstructed
    # from the candidate prefix on divergence.  Live modules with a cache
    # attached log every yield.

    @staticmethod
    def _log(st: _HMod, code: int, f: int, payload) -> None:
        st.ylog.append((code, f, payload))

    @staticmethod
    def _ff_match(cls, code: int) -> bool:
        """Loose yield-vs-log check during generator fast-forward."""
        if code == OP_PROBE_DEAD:
            return cls is Empty or cls is Full
        return _CLS_TO_OP.get(cls) == code

    def _diverge(self, st: _HMod, expected_entry: tuple, send) -> None:
        """Cached branch diverged from live state: switch to a cached branch
        that re-converges with the live outcome if one exists, else
        materialize the generator (fast-forwarded with the already-delivered
        send values, which equal the validated candidate prefix)."""
        pos = st.pos
        prefix = st.cand.ylog[:pos]
        for alt in st.cand_alts:
            if alt is st.cand or len(alt.ylog) <= pos:
                continue
            if alt.ylog[pos] == expected_entry and alt.ylog[:pos] == prefix:
                self.cache.switches += 1
                st.cand = alt
                st.pos += 1
                return
        self.cache.divergences += 1
        sends = st.cand.sends[:pos]
        st.cand = None
        st.ylog = prefix + [expected_entry]
        st.sends = sends + [send]
        gen = self.program.modules[st.mid].fn()
        try:
            op = next(gen)
            for i in range(pos):
                if not self._ff_match(op.__class__, prefix[i][0]):
                    raise self._unsup(
                        f"module '{st.name}' is not re-runnable (yield "
                        f"stream diverged on replay); bodies must be pure")
                op = gen.send(sends[i])
        except StopIteration:
            raise self._unsup(
                f"module '{st.name}' is not re-runnable (terminated early "
                f"on replay); bodies must be pure")
        if not self._ff_match(op.__class__, expected_entry[0]):
            raise self._unsup(
                f"module '{st.name}' is not re-runnable (yield stream "
                f"diverged on replay); bodies must be pure")
        st.gen = gen
        st.started = True

    def _replay_cached_bulk(self, st: _HMod) -> bool:
        """Replay a window of validated cached rows array-at-a-time.

        Instead of re-dispatching every cached yield through Python, the
        candidate branch's compiled :class:`_RunArrays` view identifies the
        run of committing blocking rows ahead of ``st.pos`` (bounded by the
        next query), validates the whole window with one per-FIFO check —
        expected read values against the current buffer contents, sequence
        alignment against the live counters — and commits rows, buffers,
        emits and probe counts in bulk.  Windows stop conservatively at the
        first read not satisfiable from the *current* buffers (a later
        per-yield step parks or diverges there, exactly as before), so the
        fast path changes only the dispatch granularity, never an outcome.
        """
        cand = st.cand
        arr = cand.arr
        if arr is None:
            arr = cand.arr = _RunArrays(cand.ylog)
        pos = st.pos
        if not arr.boundary[pos]:
            return False
        ev_pos = arr.ev_pos
        e0 = int(np.searchsorted(ev_pos, pos))
        if e0 >= len(ev_pos) or arr.ev_rowidx[e0] < 0:
            return False
        r0 = int(arr.ev_rowidx[e0])
        r1 = r0 + int(arr.next_q[e0]) - e0
        if r1 - r0 < _CACHE_BULK_MIN:
            return False
        # cap the window at the first read not satisfiable (count or value)
        # from the current buffer contents; verify replay seq alignment
        r_stop = r1
        for f in arr.read_fifos:
            rr = arr.rrow_of[f]
            o0 = int(np.searchsorted(rr, r0))
            o1 = int(np.searchsorted(rr, r_stop))
            if o1 == o0:
                continue
            if self.rseq[f] != o0:       # misaligned: per-yield path decides
                return False
            vals = arr.rvals_of[f]
            k, need = 0, o1 - o0
            for v in self.buffers[f]:
                if vals[o0 + k] != v:
                    break
                k += 1
                if k == need:
                    break
            if k < need:
                r_stop = int(rr[o0 + k])
        if r_stop <= r0:
            return False
        for f in arr.write_fifos:
            wr = arr.wrow_of[f]
            o0 = int(np.searchsorted(wr, r0))
            if int(np.searchsorted(wr, r_stop)) > o0 and self.wseq[f] != o0:
                return False
        # ---- commit the validated window
        gap0 = st.gap_acc
        st.kind.extend(arr.row_code[r0:r_stop])
        st.fifo.extend(arr.row_fifo[r0:r_stop])
        gaps = arr.row_gap[r0:r_stop]
        if gap0 != 1:
            gaps = [gap0 + gaps[0] - 1] + gaps[1:]
        st.gap.extend(gaps)
        st.seq.extend(arr.row_seq[r0:r_stop])
        mid = st.mid
        for f in arr.read_fifos:
            rr = arr.rrow_of[f]
            o0 = int(np.searchsorted(rr, r0))
            o1 = int(np.searchsorted(rr, r_stop))
            if o1 == o0:
                continue
            self._check_endpoint(f, mid, False)
            buf = self.buffers[f]
            for _ in range(o1 - o0):
                buf.popleft()
            self.rseq[f] = o1
        for f in arr.write_fifos:
            wr = arr.wrow_of[f]
            o0 = int(np.searchsorted(wr, r0))
            o1 = int(np.searchsorted(wr, r_stop))
            if o1 == o0:
                continue
            self._check_endpoint(f, mid, True)
            self.buffers[f].extend(arr.wvals_of[f][o0:o1])
            self.wseq[f] = o1
            w = self.waiting_reader.pop(f, None)
            if w is not None:
                self._enqueue(w)
        p_end = int(arr.row_pos[r_stop - 1]) + 1
        if len(arr.emit_pos):
            a = int(np.searchsorted(arr.emit_pos, pos))
            b = int(np.searchsorted(arr.emit_pos, p_end))
            for i in range(a, b):
                kv = arr.emit_kv[i]
                self.outputs[kv[0]] = kv[1]
        self.skipped_probes += int(arr.row_probes_cum[r_stop]
                                   - arr.row_probes_cum[r0])
        self.steps += p_end - pos
        self.cache_bulk_rows += r_stop - r0
        st.pos = p_end
        st.gap_acc = 1
        return True

    # ------------------------------------------------------------- recording
    def _issue_query(self, st: _HMod, code: int, f: int, payload) -> bool:
        """Handle a query op; True if resolved inline (task may continue)."""
        self.queries += 1
        read_side = _QC_IS_READ_SIDE[code]
        self._check_endpoint(f, st.mid, not read_side)
        s = (self.rseq[f] if read_side else self.wseq[f]) + 1
        st.q_code, st.q_fifo, st.q_seq, st.q_payload = code, f, s, payload
        if len(st.times) != len(st.kind):
            # chain not timed up to the query: try to close the gap now.
            # When no other module has pending rows and nothing is dirty,
            # this module's own frontier is the entire fixpoint (its
            # sources are all committed or unrecorded) — skip the solver
            # wrapper and batch gate
            if not self.pending and not self.solve_dirty:
                self._advance_frontier(st)
                if len(st.times) != len(st.kind):
                    self.pending.add(st.mid)
                    self._solve()
            else:
                self.pending.add(st.mid)
                self._solve()
        if len(st.times) == len(st.kind):
            t = (st.times[-1] if st.times else 0) + st.gap_acc
            st.q_time = t
            # inlined _verdict (hot path: most queries price right here)
            if read_side:
                wt = self.fw_times[f]
                if s <= wt.n:
                    self._apply_query(st, bool(wt.a[s - 1] < t))
                    return True
            else:
                tg = s - self.depths[f]
                if tg <= 0:
                    self._apply_query(st, True)
                    return True
                rt = self.fr_times[f]
                if tg <= rt.n:
                    self._apply_query(st, bool(rt.a[tg - 1] < t))
                    return True
            self._qid += 1
            st.qid = self._qid
            st.state = _H_PARK_QUERY
            if read_side:
                self.qwatch_w[f] = st.mid
            else:
                self.qwatch_r[f] = st.mid
            heapq.heappush(self.heap, (t, st.qid, st.mid))
            return False
        self._qid += 1
        st.qid = self._qid
        st.state = _H_PARK_QUERY
        if read_side:
            self.qwatch_w[f] = st.mid
        else:
            self.qwatch_r[f] = st.mid
        self.unpriced.add(st.mid)
        return False

    def _advance(self, mid: int) -> None:
        """Drive one module until it parks, finishes, or the run queue must
        rotate — the hybrid recorder's hot loop (cheap list appends instead
        of the generator engine's per-op graph-object churn; endpoint checks
        and row recording are inlined, the step budget lives in a local that
        is flushed around the bulk helpers)."""
        st = self.mods[mid]
        state = st.state
        if state == _H_DONE or state == _H_PARK_QUERY:
            return
        self.activations += 1
        buffers = self.buffers
        rseq, wseq = self.rseq, self.wseq
        waiting_reader = self.waiting_reader
        reader_of, writer_of = self.reader_of, self.writer_of
        kapp, fapp = st.kind.append, st.fifo.append
        gapp, sapp = st.gap.append, st.seq.append
        if state == _H_PARK_READ:
            f = st.park_fid
            buf = buffers[f]
            if not buf:
                raise self._unsup(
                    f"fifo {f} drained by another reader while "
                    f"'{st.name}' was parked — SPSC violation; deferring to "
                    f"the generator engine's endpoint check")
            v = buf.popleft()
            if st.cand is not None:
                if st.cand.ylog[st.pos][2] != v:
                    self._diverge(st, (OP_READ, f, v), v)
                else:
                    st.pos += 1
            elif st.ylog is not None:
                st.ylog[-1] = (OP_READ, f, v)     # patch the parked entry
                st.sends.append(v)
            s = rseq[f] = rseq[f] + 1
            kapp(OP_READ)
            fapp(f)
            gapp(st.gap_acc)
            sapp(s)
            st.gap_acc = 1
            st.send = v
            st.park_fid = -1
            st.state = _H_READY
            if len(st.kind) - len(st.times) == 1:
                self._eager_read(st, f, s)
        steps = self.steps
        max_steps = self.max_steps
        try:
            while True:
                # ---- periodized poll loop: burst-resolve K outcomes at once
                if st.burst:
                    st.burst = False
                    self.steps = steps
                    if st.pat is not None:
                        if self._burst_pattern(st):
                            return
                    else:
                        K = self._poll_horizon(st)
                        if K > 0 and self._burst_polls(st, K):
                            return
                    steps = self.steps
                # ---- fetch the next yielded op (cached stream or generator)
                steps += 1
                if steps > max_steps:
                    raise RuntimeError(
                        f"step budget exceeded ({max_steps}); possible "
                        f"livelock — neither OmniSim nor co-sim detects "
                        f"livelock")
                cand = st.cand
                if cand is not None:
                    if st.pos >= len(cand.ylog):
                        st.state = _H_DONE
                        st.end_gap = st.gap_acc
                        self.n_done += 1
                        if self.cache is not None:
                            self.cache.hits += 1
                            self.cache.promote(self.sig, mid, cand)
                        return
                    self.steps = steps
                    if self._replay_cached_bulk(st):
                        steps = self.steps
                        continue
                    code, f, payload = cand.ylog[st.pos]
                    # dispatch on the cached opcode
                    if code == OP_READ:
                        if reader_of.setdefault(f, mid) != mid:
                            raise self._unsup(
                                f"fifo {f} has two reader modules — SPSC "
                                f"violation; deferring to the generator "
                                f"engine's endpoint check")
                        buf = buffers[f]
                        if not buf:
                            prev = waiting_reader.get(f)
                            if prev is not None and prev != mid:
                                raise self._unsup(
                                    f"two modules read fifo {f} — SPSC "
                                    f"violation; deferring to the generator "
                                    f"engine's endpoint check")
                            waiting_reader[f] = mid
                            st.park_fid = f
                            st.state = _H_PARK_READ
                            return
                        v = buf.popleft()
                        if payload != v:
                            self._diverge(st, (OP_READ, f, v), v)
                        else:
                            st.pos += 1
                        s = rseq[f] = rseq[f] + 1
                        kapp(OP_READ)
                        fapp(f)
                        gapp(st.gap_acc)
                        sapp(s)
                        st.gap_acc = 1
                        st.send = v
                        if len(st.kind) - len(st.times) == 1:
                            self._eager_read(st, f, s)
                    elif code == OP_WRITE:
                        if writer_of.setdefault(f, mid) != mid:
                            raise self._unsup(
                                f"fifo {f} has two writer modules — SPSC "
                                f"violation; deferring to the generator "
                                f"engine's endpoint check")
                        st.pos += 1
                        s = wseq[f] = wseq[f] + 1
                        kapp(OP_WRITE)
                        fapp(f)
                        gapp(st.gap_acc)
                        sapp(s)
                        st.gap_acc = 1
                        if len(st.kind) - len(st.times) == 1:
                            self._eager_write(st, f, s)
                        buffers[f].append(payload)
                        if waiting_reader:
                            w = waiting_reader.pop(f, None)
                            if w is not None:
                                self._enqueue(w)
                        st.send = None
                    elif code == OP_DELAY:
                        st.pos += 1
                        st.gap_acc += payload
                        st.send = None
                    elif code == OP_EMIT:
                        st.pos += 1
                        self.outputs[payload[0]] = payload[1]
                        st.send = None
                    elif code == OP_PROBE_DEAD:
                        st.pos += 1
                        self.skipped_probes += 1
                        st.gap_acc += 1
                        st.send = None
                    else:   # query op: OP_READ_NB / OP_WRITE_NB / OP_EMPTY/FULL
                        qc = _OP_TO_QC[code]
                        qpayload = payload[1] if code == OP_WRITE_NB else None
                        if not self._issue_query(st, qc, f, qpayload):
                            return
                    continue
                # ---- live generator path
                log = st.ylog is not None
                op = st.pending_op
                if op is not None:      # yield left over from a burst break
                    st.pending_op = None
                else:
                    gen = st.gen
                    if gen is None:
                        gen = st.gen = self.program.modules[mid].fn()
                    try:
                        if not st.started:
                            st.started = True
                            op = next(gen)
                        else:
                            op = gen.send(st.send)
                    except StopIteration:
                        st.state = _H_DONE
                        st.end_gap = st.gap_acc
                        self.n_done += 1
                        return
                st.send = None
                cls = op.__class__
                if cls is Read:
                    f = op.fifo.fid
                    if reader_of.setdefault(f, mid) != mid:
                        raise self._unsup(
                            f"fifo {f} has two reader modules — SPSC "
                            f"violation; deferring to the generator engine's "
                            f"endpoint check")
                    buf = buffers[f]
                    if not buf:
                        prev = waiting_reader.get(f)
                        if prev is not None and prev != mid:
                            raise self._unsup(
                                f"two modules read fifo '{op.fifo.name}' — "
                                f"SPSC violation; deferring to the generator "
                                f"engine's endpoint check")
                        waiting_reader[f] = mid
                        st.park_fid = f
                        st.state = _H_PARK_READ
                        if log:
                            self._log(st, OP_READ, f, None)  # patched on wake
                        return
                    v = buf.popleft()
                    s = rseq[f] = rseq[f] + 1
                    kapp(OP_READ)
                    fapp(f)
                    gapp(st.gap_acc)
                    sapp(s)
                    st.gap_acc = 1
                    st.send = v
                    if len(st.kind) - len(st.times) == 1:
                        self._eager_read(st, f, s)
                    if log:
                        self._log(st, OP_READ, f, v)
                        st.sends.append(v)
                elif cls is Write:
                    f = op.fifo.fid
                    if writer_of.setdefault(f, mid) != mid:
                        raise self._unsup(
                            f"fifo {f} has two writer modules — SPSC "
                            f"violation; deferring to the generator engine's "
                            f"endpoint check")
                    s = wseq[f] = wseq[f] + 1
                    kapp(OP_WRITE)
                    fapp(f)
                    gapp(st.gap_acc)
                    sapp(s)
                    st.gap_acc = 1
                    if len(st.kind) - len(st.times) == 1:
                        self._eager_write(st, f, s)
                    buffers[f].append(op.value)
                    if waiting_reader:
                        w = waiting_reader.pop(f, None)
                        if w is not None:
                            self._enqueue(w)
                    if log:
                        self._log(st, OP_WRITE, f, op.value)
                        st.sends.append(None)
                elif cls is Delay:
                    st.gap_acc += op.cycles
                    if log:
                        self._log(st, OP_DELAY, -1, op.cycles)
                        st.sends.append(None)
                elif cls is Emit:
                    self.outputs[op.key] = op.value
                    if log:
                        self._log(st, OP_EMIT, -1, (op.key, op.value))
                        st.sends.append(None)
                elif (cls is Empty or cls is Full) and not op.used:
                    self.skipped_probes += 1
                    st.gap_acc += 1
                    if log:
                        self._log(st, OP_PROBE_DEAD, op.fifo.fid, None)
                        st.sends.append(None)
                elif cls in (ReadNB, WriteNB, Empty, Full):
                    if not self._issue_query(st, _CLS_TO_QC[cls],
                                             op.fifo.fid,
                                             getattr(op, "value", None)):
                        return
                else:
                    raise TypeError(f"unknown op {op!r}")
        finally:
            self.steps = steps

    # ------------------------------------------------ whole-run cached replay
    def _replay_full(self, full: _FullRun) -> bool:
        """Bulk-replay a cached complete run with per-entry verification.

        Phase 1 verifies, touching no engine state: every row's committed
        time must equal ``max(t_prev + gap, source + 1)`` against the
        claimed per-FIFO tables (query rows carry no source: their time
        must be chain-exact), and every recorded query outcome must match
        the Table-2 verdict those tables imply.  A completed run's
        dependency graph is acyclic, so pointwise fixpoint equality pins
        the unique solution — any corruption or semantic drift rejects
        the entry.  Phase 2 installs the arrays and counters; the caller
        then finishes through the ordinary :meth:`_finish`.
        """
        mods = self.mods
        n_mod = len(mods)
        depths = self.depths
        n_fifo = len(depths)
        kinds, fifos, gaps = full.kind, full.fifo, full.gap
        seqs, times = full.seq, full.times
        # ---- claimed per-FIFO tables (SPSC: row order == seq order)
        fw_tab: List[Optional[np.ndarray]] = [None] * n_fifo
        fr_tab: List[Optional[np.ndarray]] = [None] * n_fifo
        for f, mid in full.writer_of.items():
            k = kinds[mid]
            m = ((k == OP_WRITE) | (k == OP_WRITE_NB)) & (fifos[mid] == f)
            fw_tab[f] = times[mid][m]
        for f, mid in full.reader_of.items():
            k = kinds[mid]
            m = ((k == OP_READ) | (k == OP_READ_NB)) & (fifos[mid] == f)
            fr_tab[f] = times[mid][m]
        # ---- per-row time verification: t == max(chain, source + 1)
        for mid in range(n_mod):
            k = kinds[mid]
            n = len(k)
            if n == 0:
                continue
            fo, g, s, t = fifos[mid], gaps[mid], seqs[mid], times[mid]
            c = np.full(n, NEGI, dtype=np.int64)
            rd = k == OP_READ
            if rd.any():
                for f in np.unique(fo[rd]):
                    m = rd & (fo == f)
                    tab = fw_tab[f]
                    sv = s[m]
                    if tab is None or sv[-1] > len(tab):
                        return False          # blocking read never satisfied
                    c[m] = tab[sv - 1] + 1
            wr = k == OP_WRITE
            if wr.any():
                for f in np.unique(fo[wr]):
                    m = wr & (fo == f)
                    tg = s[m] - depths[f]
                    con = tg > 0
                    if con.any():
                        tab = fr_tab[f]
                        if tab is None or tg[con][-1] > len(tab):
                            return False      # WAR slot never freed
                        idx = np.flatnonzero(m)[con]
                        c[idx] = tab[tg[con] - 1] + 1
            prev = np.empty(n, dtype=np.int64)
            prev[0] = 0
            prev[1:] = t[:-1]
            if not np.array_equal(t, np.maximum(prev + g, c)):
                return False
        # ---- per-query outcome verification against the verified tables
        cons = full.cons
        if len(cons):
            offs = np.zeros(n_mod + 1, dtype=np.int64)
            for mid in range(n_mod):
                offs[mid + 1] = offs[mid] + len(times[mid])
            tglob = (np.concatenate(times) if offs[-1]
                     else np.zeros(0, dtype=np.int64))
            cf, cs = cons[:, 1], cons[:, 2]
            cout = cons[:, 5] != 0
            tq = tglob[offs[cons[:, 3]] + cons[:, 4]]
            rs = (cons[:, 0] % 2) == 0        # read-side query codes
            v = np.zeros(len(cons), dtype=bool)
            for f in np.unique(cf[rs]):
                m = rs & (cf == f)
                tab = fw_tab[f]
                nw = 0 if tab is None else len(tab)
                sv = cs[m]
                ok = sv <= nw
                res = np.zeros(len(sv), dtype=bool)
                if ok.any():
                    res[ok] = tab[sv[ok] - 1] < tq[m][ok]
                v[m] = res
            ws = ~rs
            for f in np.unique(cf[ws]):
                m = ws & (cf == f)
                tab = fr_tab[f]
                nr = 0 if tab is None else len(tab)
                tg = cs[m] - depths[f]
                res = tg <= 0
                dec = ~res & (tg <= nr)
                if dec.any():
                    res[dec] = tab[tg[dec] - 1] < tq[m][dec]
                v[m] = res
            if not np.array_equal(v, cout):
                return False
        # ---- verified: install the run (read-only shared arrays)
        for mid, st in enumerate(mods):
            st.kind = kinds[mid]
            st.fifo = fifos[mid]
            st.gap = gaps[mid]
            st.seq = seqs[mid]
            st.times = times[mid]
            st.end_gap = full.end_gap[mid]
            st.state = _H_DONE
        self.n_done = n_mod
        self.outputs = dict(full.outputs)
        self.buffers = [list(vals) for vals in full.leftover]
        self.reader_of = dict(full.reader_of)
        self.writer_of = dict(full.writer_of)
        self.constraints = cons
        stt = full.stats
        self.queries = stt["queries"]
        self.forced = stt["forced"]
        self.phases = stt["phases"]
        self.activations = stt["activations"]
        self.skipped_probes = stt["skipped_probes"]
        self.bulk_queries = stt["bulk_queries"]
        self.bursts = stt["bursts"]
        self.cache_bulk_rows = full.n_rows
        self._full_replay = True
        self.cache.full_hits += 1
        return True

    # ------------------------------------------------------------------- run
    def run(self) -> SimResult:
        if self.cache is not None and self.periodize:
            full = self.cache.lookup_full(self._fkey)
            if full is not None:
                if self._replay_full(full):
                    return self._finish()
                self.cache.full_rejects += 1
        mods = self.mods
        n_mod = len(mods)
        for st in mods:
            self._enqueue(st.mid)
        runq = self.runq
        pending = self.pending
        while True:
            while runq:
                mid = runq.popleft()
                self.queued[mid] = False
                self._advance(mid)
                st = mods[mid]
                if len(st.kind) != len(st.times):
                    pending.add(mid)
            # ---- quiescence (engine protocol step ❹) ----
            self.phases += 1
            if self.n_done == n_mod:
                break
            if pending or self.solve_dirty:
                self._solve()
            # inline watch-slot gate: _resolve_parked can only make progress
            # when something is unpriced or a watched table grew
            if ((self.unpriced or self.rp_wake)
                    and self._resolve_parked()):
                continue
            if self.heap:
                self._force_earliest()
                continue
            blocked = [st.name for st in mods if st.state != _H_DONE]
            raise self._unsup(
                f"quiescence with no resolvable query — modules {blocked} "
                f"are deadlocked; the generator engine will report the "
                f"exact stall cycle")
        self._solve()
        if any(len(st.times) != len(st.kind) for st in mods):
            raise self._unsup(
                "recorded events cannot all commit under these depths "
                "(structural deadlock or WAR cycle); the generator engine "
                "will report the exact stall cycle")
        return self._finish()

    # --------------------------------------------------------------- finish
    def _finish(self) -> SimResult:
        program = self.program
        mods = self.mods
        n_mod = len(mods)
        n_fifo = len(program.fifos)
        counts = [len(st.kind) for st in mods]
        n = sum(counts) + 2 * n_mod
        seq_w = np.zeros(n, dtype=np.int64)
        node_kind = np.empty(n, dtype=np.int8)
        node_fifo = np.full(n, -1, dtype=np.int64)
        node_seq = np.full(n, -1, dtype=np.int64)
        base = np.full(n, NEGI, dtype=np.int64)
        times = np.zeros(n, dtype=np.int64)
        module_arr = np.empty(n, dtype=np.int64)
        slices: List[Tuple[int, int]] = []
        row_kind_parts, row_fifo_parts, row_node_parts = [], [], []
        row_seq_parts = []
        off = 0
        for m, st in enumerate(mods):
            L = counts[m]
            hi = off + L + 2
            slices.append((off, hi))
            module_arr[off:hi] = m
            node_kind[off] = _NK_START
            base[off] = 0
            times[off] = 0
            rk = np.asarray(st.kind, dtype=np.int64)
            node_kind[off + 1:hi - 1] = _ROW_TO_NK[rk]
            node_fifo[off + 1:hi - 1] = st.fifo
            node_seq[off + 1:hi - 1] = st.seq
            seq_w[off + 1:hi - 1] = st.gap
            seq_w[hi - 1] = st.end_gap
            t_rows = np.asarray(st.times, dtype=np.int64)
            times[off + 1:hi - 1] = t_rows
            times[hi - 1] = (int(t_rows[-1]) if L else 0) + st.end_gap
            node_kind[hi - 1] = _NK_END
            row_kind_parts.append(rk)
            row_fifo_parts.append(np.asarray(st.fifo, dtype=np.int64))
            row_seq_parts.append(np.asarray(st.seq, dtype=np.int64))
            row_node_parts.append(np.arange(off + 1, hi - 1, dtype=np.int64))
            off = hi
        z = np.zeros(0, np.int64)
        kind_all = np.concatenate(row_kind_parts) if row_kind_parts else z
        fifo_all = np.concatenate(row_fifo_parts) if row_fifo_parts else z
        seq_all = np.concatenate(row_seq_parts) if row_seq_parts else z
        node_all = np.concatenate(row_node_parts) if row_node_parts else z
        is_read = (kind_all == OP_READ) | (kind_all == OP_READ_NB)
        is_write = (kind_all == OP_WRITE) | (kind_all == OP_WRITE_NB)
        fifo_w_nodes: List[np.ndarray] = []
        fifo_r_nodes: List[np.ndarray] = []
        fifo_w_blocking: List[np.ndarray] = []
        raw_dst_parts, raw_src_parts = [], []
        war_dst_parts, war_src_parts = [], []
        fifo_wmod = np.full(n_fifo, -1, dtype=np.int64)
        fifo_rmod = np.full(n_fifo, -1, dtype=np.int64)
        for fid in range(n_fifo):
            on_f = fifo_all == fid
            w_sel = on_f & is_write
            r_sel = on_f & is_read
            # committed accesses sorted by per-FIFO seq (commit order; each
            # side is a single module, so chain order == seq order, but the
            # concatenation above is module-major)
            w_order = np.argsort(seq_all[w_sel], kind="stable")
            r_order = np.argsort(seq_all[r_sel], kind="stable")
            w_nodes = node_all[w_sel][w_order]
            r_nodes = node_all[r_sel][r_order]
            fifo_w_nodes.append(np.ascontiguousarray(w_nodes))
            fifo_r_nodes.append(np.ascontiguousarray(r_nodes))
            blocking = np.asarray(kind_all[w_sel][w_order] == OP_WRITE,
                                  dtype=bool)
            fifo_w_blocking.append(blocking)
            fifo_wmod[fid] = self.writer_of.get(fid, -1)
            fifo_rmod[fid] = self.reader_of.get(fid, -1)
            # RAW: r-th blocking read <- r-th write (NB reads: constraint only)
            blk_r = kind_all[r_sel][r_order] == OP_READ
            if blk_r.any():
                raw_dst_parts.append(r_nodes[blk_r])
                raw_src_parts.append(w_nodes[:len(r_nodes)][blk_r])
            # WAR: w-th blocking write (w > S) <- (w-S)-th read
            S = self.depths[fid]
            nw = len(w_nodes)
            if nw > S:
                w_tail = np.arange(S, nw)
                blk_w = blocking[S:]
                sel = w_tail[blk_w]
                if len(sel):
                    war_dst_parts.append(w_nodes[sel])
                    war_src_parts.append(r_nodes[sel - S])
        raw_dst = np.concatenate(raw_dst_parts) if raw_dst_parts else z
        raw_src = np.concatenate(raw_src_parts) if raw_src_parts else z
        war_dst = np.concatenate(war_dst_parts) if war_dst_parts else z
        war_src = np.concatenate(war_src_parts) if war_src_parts else z
        ct = CompiledTrace(n=n, n_modules=n_mod, slices=slices, seq_w=seq_w,
                           base=base, node_kind=node_kind,
                           node_fifo=node_fifo, node_seq=node_seq,
                           fifo_w_nodes=fifo_w_nodes,
                           fifo_r_nodes=fifo_r_nodes, fifo_wmod=fifo_wmod,
                           fifo_rmod=fifo_rmod, raw_dst=raw_dst,
                           raw_src=raw_src, trace=None)
        cycles = int(times.max()) if n else 0

        from .engine import OmniSim
        from .incremental import CompiledGraph
        engine = OmniSim(program)
        engine.outputs = dict(self.outputs)
        engine.graph = TraceSimGraph(ct, times, war_dst, war_src, module_arr)
        for fobj in program.fifos:
            tbl = engine.fifos[fobj.fid]
            w_nodes = fifo_w_nodes[fobj.fid]
            r_nodes = fifo_r_nodes[fobj.fid]
            tbl._w_nodes = w_nodes.astype(np.int64, copy=True)
            tbl._w_times = times[w_nodes]
            tbl._nw = len(w_nodes)
            tbl._r_nodes = r_nodes.astype(np.int64, copy=True)
            tbl._r_times = times[r_nodes]
            tbl._nr = len(r_nodes)
            tbl.values.extend(self.buffers[fobj.fid])
        engine._writer_of = dict(self.writer_of)
        engine._reader_of = dict(self.reader_of)
        # materialize the recorded constraints (engine-identical records):
        # one 2D array carries all columns, so the per-query Python work is a
        # single C-level map/zip instead of five listcomps
        n_cons = len(self.constraints)
        cons_cols = (np.asarray(self.constraints, dtype=np.int64).reshape(
            n_cons, 6) if n_cons else np.zeros((0, 6), np.int64))
        offs_arr = np.asarray([lo for (lo, _) in slices] or [0], np.int64)
        src_col = offs_arr[cons_cols[:, 3]] + 1 + cons_cols[:, 4]

        def _materialize(cons_cols=cons_cols, src_col=src_col):
            return map(Constraint._make, zip(
                map(_QC_TO_RTYPE.__getitem__, cons_cols[:, 0].tolist()),
                cons_cols[:, 1].tolist(), cons_cols[:, 2].tolist(),
                src_col.tolist(), (cons_cols[:, 5] != 0).tolist()))

        constraints = _LazyConstraints(_materialize)
        engine.constraints = constraints
        stats = engine.stats
        stats.nodes = n - n_mod
        stats.edges = engine.graph.n_edges
        stats.queries = self.queries
        stats.queries_forced_false = self.forced
        stats.queries_periodized = self.bulk_queries
        stats.quiescence_rounds = self.phases
        stats.resumes = self.activations
        stats.skipped_probes = self.skipped_probes
        # pre-built incremental cache: resimulate/resimulate_batch skip
        # graph re-interpretation entirely (same contract as the pure
        # trace path, extended with NB constraints + blocking-write masks)
        fifos_cg = [(w.copy(), r.copy(), blk.copy())
                    for w, r, blk in zip(fifo_w_nodes, fifo_r_nodes,
                                         fifo_w_blocking)]
        # read-side query codes are _QC_READ_NB (0) and _QC_EMPTY (2)
        c_kind = (cons_cols[:, 0] % 2).astype(np.int64)
        engine._incr_cache = CompiledGraph(
            n=n,
            raw_dst=raw_dst.copy(),
            raw_src=raw_src.copy(),
            raw_w=np.ones(len(raw_dst), np.int64),
            base=base.copy(),
            chains=[np.arange(lo, hi, dtype=np.int64) for (lo, hi) in slices],
            seq_w=seq_w.copy(),
            fifos=fifos_cg,
            c_kind=c_kind,
            c_fifo=cons_cols[:, 1].copy(),
            c_seq=cons_cols[:, 2].copy(),
            c_src=src_col,
            c_out=cons_cols[:, 5] != 0,
        )
        n_segments = 0
        for rk in row_kind_parts:
            if len(rk):
                blk = rk <= OP_WRITE
                n_segments += int(blk[0]) + int(
                    np.count_nonzero(blk[1:] & ~blk[:-1]))
        engine._hybrid = {
            "ops": int(len(kind_all)),
            "queries": self.queries,
            "forced_false": self.forced,
            "phases": self.phases,
            "segments": n_segments,      # maximal compiled blocking runs
            "bulk_queries": self.bulk_queries,   # periodized poll outcomes
            "bursts": self.bursts,
            "batch_rows": self.batch_rows,       # batch-solver commits
            "batch_solves": self.batch_solves,
            "cache_bulk_rows": self.cache_bulk_rows,
        }
        # commit the memoization caches only on success; a whole-run replay
        # never ran a generator, so its (empty) ylogs must not overwrite the
        # variant cache and its arrays are already stored
        if self.cache is not None and not self._full_replay:
            for st in mods:
                if st.gen is None and st.cand is not None:
                    continue             # full cache replay: nothing new
                self.cache.store(self.sig, st.mid,
                                 _CachedRun(st.ylog, st.sends))
            self.cache.store_full(self._fkey, _FullRun(
                row_kind_parts,
                row_fifo_parts,
                [np.asarray(st.gap, dtype=np.int64) for st in mods],
                row_seq_parts,
                [np.asarray(st.times, dtype=np.int64) for st in mods],
                [st.end_gap for st in mods],
                cons_cols,
                dict(self.outputs),
                [list(self.buffers[fid]) for fid in range(n_fifo)],
                dict(self.reader_of),
                dict(self.writer_of),
                dict(queries=self.queries, forced=self.forced,
                     phases=self.phases, activations=self.activations,
                     skipped_probes=self.skipped_probes,
                     bulk_queries=self.bulk_queries, bursts=self.bursts),
                int(len(kind_all)),
            ))
        return SimResult(
            program=program.name,
            outputs=dict(self.outputs),
            cycles=cycles,
            engine="omnisim-hybrid",
            stats=stats,
            graph=engine,
            constraints=constraints,
            depths=program.depths(),
        )


def simulate_hybrid(program: Program, max_steps: int = 50_000_000,
                    cache: Optional[HybridCache] = None,
                    periodize: bool = True) -> SimResult:
    """Segmented trace-compiled simulation for dynamic designs.

    Records and array-replays the blocking segments between NB/probe query
    points, interpreting only at the queries (paper Sec. 5.1 applied to
    Type B/C designs).  Returns a :class:`~repro.core.program.SimResult`
    indistinguishable from the generator engine's, with
    ``engine="omnisim-hybrid"`` and a pre-built incremental cache so
    ``resimulate``/``resimulate_batch`` work unchanged.  ``cache`` (a
    :class:`HybridCache`) memoizes module yield streams across repeated
    simulations of the same design shape.  ``periodize`` (default True)
    enables steady-state query periodization: fixed poll loops resolve K
    definitively-false outcomes per step against the committed time tables
    instead of one generator resumption per query (disable it to benchmark
    or to cross-check the per-query path — results are bit-identical
    either way, see ``tests/test_golden.py``).  Raises
    :class:`TraceUnsupported` on deadlocks and SPSC violations; callers
    normally go through ``repro.core.simulate(..., trace="auto")`` which
    falls back to the generator engine for the paper-exact report.
    """
    return HybridSim(program, cache=cache, max_steps=max_steps,
                     periodize=periodize).run()
