"""Trace compilation: replay the *initial* simulation at array speed.

The paper's Sec. 5.1 observation — once a design's FIFO-access trace is
known, simulation collapses from interpreting module bodies to replaying a
compiled trace — applied to the DSL engine.  This is the same move
LightningSimV2 (arXiv:2404.09471) makes over LightningSim's interpreted
traces (arXiv:2304.11219), lifted from *re*-simulation to the very first
simulation of a design.

Pipeline (``simulate_traced``):

  1. **Record** (:func:`record_trace`): every module generator is entered
     exactly once and driven to completion under *untimed* Kahn-process-
     network semantics (unbounded FIFOs, block only on an empty read, round
     robin between modules).  Blocking dataflow designs are deterministic
     KPNs, so the recorded op stream, FIFO values and ``Emit`` outputs are
     identical to what the timed engine would produce — per module we keep
     flat op arrays (opcode, fifo id, inter-op gap in cycles).  A live
     non-blocking access or status probe makes control flow potentially
     cycle-dependent: recording aborts with :class:`TraceUnsupported` and
     the engine falls back to the generator path (``core/engine.py``).

  2. **Compile** (:func:`compile_trace`): the op arrays are turned into the
     simulation-graph skeleton *without running anything*: per-module chains
     (SEQ weights = 1 + accumulated ``Delay``), RAW edges (r-th read <- r-th
     write, weight 1) and, per depth vector, WAR edges (w-th write <-
     (w-S)-th read, weight 1) — exactly the edges the engine's
     ``_exec_read``/``_exec_write`` would have created one Python object at
     a time.  Compilation works on the expanded arrays (graph, times and
     FIFO tables are inherently O(events)); after the run, steady-state
     loops are periodized — the trace *retained* on the engine is
     re-rolled to ``lead + body x reps`` (:meth:`ModuleTrace.periodize`),
     so a million-event pipeline keeps O(period) trace metadata around.

  3. **Replay** (:func:`simulate_traced`): node commit times are the
     longest path over that graph, computed by a per-chain ``cummax``
     Gauss-Seidel fixpoint with dirty-chain tracking — array-level dispatch
     instead of per-op generator resumption.  The result is bit-identical
     to the generator engine (tests pin ``SimResult`` equality across the
     taxonomy designs): same cycles, outputs, FIFO tables and graph, plus a
     pre-built :class:`~repro.core.incremental.CompiledGraph` so the first
     ``resimulate``/``resimulate_batch`` call skips graph re-interpretation
     entirely.

Structural deadlocks (a blocking write whose target read never occurs, or
regenerated WAR edges forming a cycle) and untimed-KPN deadlocks (cyclic
blocking waits) raise :class:`TraceUnsupported`; the generator engine then
reproduces the paper-exact deadlock report (stall cycle, blocked modules).

All times are hardware **cycles** (1-based commit cycles, START nodes at
cycle 0); all per-FIFO sequence numbers are 1-based **event** counts, as in
paper Table 2.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .events import Node, NodeKind, SimStats
from .program import (Delay, Emit, Empty, Full, Program, Read, ReadNB,
                      SimResult, Write, WriteNB)

NEGI = np.int64(-(1 << 60))

# ---------------------------------------------------------------------------
# Flat op encoding (one row per recorded op).  Only OP_READ/OP_WRITE survive
# into the compiled arrays — delays fold into the gap column, dead probes
# into a 1-cycle gap, Emits into the outputs dict — but the full opcode
# space is defined so partial recordings and future NB periodization have a
# stable encoding.
# ---------------------------------------------------------------------------
OP_READ, OP_WRITE, OP_READ_NB, OP_WRITE_NB = 0, 1, 2, 3
OP_EMPTY, OP_FULL, OP_DELAY, OP_EMIT = 4, 5, 6, 7

# node-kind codes of the compiled graph (map to events.NodeKind)
_NK_START, _NK_END, _NK_READ, _NK_WRITE = 0, 1, 2, 3
_NK_TO_NODEKIND = {_NK_START: NodeKind.START, _NK_END: NodeKind.END,
                   _NK_READ: NodeKind.FIFO_READ, _NK_WRITE: NodeKind.FIFO_WRITE}


class TraceUnsupported(Exception):
    """The design (or this run of it) cannot be trace-compiled.

    Raised on live non-blocking accesses / status probes (cycle-dependent
    control flow), untimed-KPN deadlock, SPSC violations, and depth-induced
    structural deadlocks or WAR cycles.  ``simulate(..., trace="auto")``
    catches it and falls back to the generator engine, which handles every
    design class (paper Fig. 3, Type A/B/C).
    """


# ---------------------------------------------------------------------------
# Recorded per-module op streams
# ---------------------------------------------------------------------------
@dataclass
class ModuleTrace:
    """One module's recorded op stream as flat arrays.

    ``kind[i]``/``fifo[i]`` identify the i-th FIFO access (OP_READ or
    OP_WRITE); ``gap[i]`` is the static-schedule distance in cycles from the
    previous access (1 + accumulated ``Delay``/dead-probe cycles — the SEQ
    edge weight of paper Sec. 7.3.1).  ``end_gap`` is the distance from the
    last access to the module END event.

    Periodized form (``reps > 1``): the stored arrays are the first ``lead``
    ops followed by one period of the steady-state loop body; the full
    stream is ``lead + body x reps`` (:meth:`expand`).
    """

    mid: int
    name: str
    kind: np.ndarray                # (L,) int8
    fifo: np.ndarray                # (L,) int64
    gap: np.ndarray                 # (L,) int64 — cycles
    end_gap: int
    lead: int = 0
    reps: int = 1

    @property
    def n_ops(self) -> int:
        """Number of FIFO accesses in the *expanded* stream (events)."""
        return self.lead + (len(self.kind) - self.lead) * self.reps

    @property
    def n_stored(self) -> int:
        """Number of op rows actually stored (lead + one body period)."""
        return len(self.kind)

    def expand(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Materialize the full (kind, fifo, gap) arrays via ``np.tile``."""
        if self.reps == 1:
            return self.kind, self.fifo, self.gap
        lead = self.lead
        return (
            np.concatenate([self.kind[:lead], np.tile(self.kind[lead:], self.reps)]),
            np.concatenate([self.fifo[:lead], np.tile(self.fifo[lead:], self.reps)]),
            np.concatenate([self.gap[:lead], np.tile(self.gap[lead:], self.reps)]),
        )

    def periodize(self, min_body: int = 4) -> "ModuleTrace":
        """Detect a steady-state loop and return the compressed trace.

        Finds the smallest period ``p`` (after a short lead of 0-2 warm-up
        ops) such that the remaining stream is an integer number of exact
        (kind, fifo, gap) repetitions, mirroring the paper's dynamic-stage
        unrolling of Sec. 5.1 in reverse: we *re-roll* the unrolled steady
        state.  Returns ``self`` unchanged when no period is found.
        """
        if self.reps != 1 or len(self.kind) < 2 * min_body:
            return self
        L = len(self.kind)
        key = self.fifo * 8 + self.kind          # one comparable op id
        for lead in range(0, min(3, L)):
            T = L - lead
            for p in range(1, T // 2 + 1):
                if T % p:
                    continue
                # cheap reject: first period vs second period
                if not np.array_equal(key[lead:lead + p],
                                      key[lead + p:lead + 2 * p]):
                    continue
                if not np.array_equal(self.gap[lead:lead + p],
                                      self.gap[lead + p:lead + 2 * p]):
                    continue
                # full verify: stream is periodic with period p after lead
                if (np.array_equal(key[lead:L - p], key[lead + p:])
                        and np.array_equal(self.gap[lead:L - p],
                                           self.gap[lead + p:])):
                    return ModuleTrace(
                        mid=self.mid, name=self.name,
                        kind=self.kind[:lead + p].copy(),
                        fifo=self.fifo[:lead + p].copy(),
                        gap=self.gap[:lead + p].copy(),
                        end_gap=self.end_gap, lead=lead, reps=T // p)
        return self


@dataclass
class RecordedTrace:
    """A whole design's recorded op streams + functional results.

    ``outputs`` are the design's ``Emit`` records (complete — recording runs
    every module to termination); ``leftovers[fid]`` are payloads written
    but never consumed (they become the FIFO tables' end-of-run residue).
    ``steps`` counts per-op generator ``send`` calls; ``activations``
    counts module (re)activations by the recording scheduler — the
    analogue of the generator engine's task-resume counter.
    """

    program: str
    modules: List[ModuleTrace]
    outputs: Dict[str, Any]
    leftovers: List[list]
    skipped_probes: int = 0
    steps: int = 0
    activations: int = 0

    @property
    def n_ops(self) -> int:
        return sum(m.n_ops for m in self.modules)

    @property
    def n_stored(self) -> int:
        return sum(m.n_stored for m in self.modules)

    def periodize(self) -> "RecordedTrace":
        """Compress every module stream in place; returns self."""
        self.modules = [m.periodize() for m in self.modules]
        return self


# ---------------------------------------------------------------------------
# Pass 1: record — generators entered at most once per module
# ---------------------------------------------------------------------------
def record_trace(program: Program, max_steps: int = 50_000_000) -> RecordedTrace:
    """Run every module generator once, untimed, and record its op stream.

    Untimed KPN semantics: FIFOs are unbounded, a ``Read`` from an empty
    FIFO parks the module until its (single) writer produces, modules are
    scheduled round-robin.  For blocking-only designs this yields exactly
    the functional behavior of the timed engine (KPN determinism); any live
    NB access/probe, a parked module that never wakes (cyclic blocking
    wait — a true design deadlock), or a second reader racing a parked one
    raises :class:`TraceUnsupported`.

    Raises ``RuntimeError`` when ``max_steps`` generator resumptions are
    exceeded (possible livelock), matching the generator engine's budget.
    """
    modules = program.modules
    n_mod = len(modules)
    buffers: List[deque] = [deque() for _ in program.fifos]
    kinds: List[list] = [[] for _ in range(n_mod)]
    fids: List[list] = [[] for _ in range(n_mod)]
    gaps: List[list] = [[] for _ in range(n_mod)]
    end_gap = [1] * n_mod
    outputs: Dict[str, Any] = {}
    gens = [m.fn() for m in modules]
    done = [False] * n_mod
    parked: List[Optional[Read]] = [None] * n_mod
    gap_acc = [1] * n_mod
    waiting_reader: Dict[int, int] = {}
    skipped_probes = 0
    steps = 0
    activations = 0
    runq: deque = deque(range(n_mod))
    while runq:
        mid = runq.popleft()
        activations += 1
        gen_send = gens[mid].send
        kapp, fapp, gapp = kinds[mid].append, fids[mid].append, gaps[mid].append
        gap = gap_acc[mid]
        op = parked[mid]
        if op is not None:                 # woken: re-execute the parked Read
            parked[mid] = None
            fid = op.fifo.fid
            buf = buffers[fid]
            if not buf:                    # a second reader drained the FIFO
                raise TraceUnsupported(
                    f"{program.name}: FIFO '{op.fifo.name}' drained by "
                    f"another reader while '{modules[mid].name}' was parked "
                    f"— SPSC violation; deferring to the generator engine's "
                    f"endpoint check")
            send = buf.popleft()
            kapp(OP_READ)
            fapp(fid)
            gapp(gap)
            gap = 1
        else:
            send = None
        while True:
            steps += 1
            if steps > max_steps:
                raise RuntimeError(
                    f"step budget exceeded ({max_steps}); possible livelock "
                    f"— neither OmniSim nor co-sim detects livelock")
            try:
                op = gen_send(send)
            except StopIteration:
                done[mid] = True
                end_gap[mid] = gap
                break
            send = None
            cls = op.__class__
            if cls is Read:
                fid = op.fifo.fid
                buf = buffers[fid]
                if buf:
                    send = buf.popleft()
                    kapp(OP_READ)
                    fapp(fid)
                    gapp(gap)
                    gap = 1
                else:
                    prev = waiting_reader.get(fid)
                    if prev is not None and prev != mid:
                        raise TraceUnsupported(
                            f"{program.name}: two modules read FIFO "
                            f"'{op.fifo.name}' — SPSC violation; deferring "
                            f"to the generator engine's endpoint check")
                    waiting_reader[fid] = mid
                    parked[mid] = op
                    break
            elif cls is Write:
                fid = op.fifo.fid
                buffers[fid].append(op.value)
                kapp(OP_WRITE)
                fapp(fid)
                gapp(gap)
                gap = 1
                if waiting_reader:
                    w = waiting_reader.pop(fid, None)
                    if w is not None:
                        runq.append(w)
            elif cls is Delay:
                gap += op.cycles
            elif cls is Emit:
                outputs[op.key] = op.value
            elif (cls is Empty or cls is Full) and not op.used:
                # dead probe (paper Sec. 7.3.2): costs 1 cycle, no query
                skipped_probes += 1
                gap += 1
            elif cls in (ReadNB, WriteNB, Empty, Full):
                raise TraceUnsupported(
                    f"{program.name}: module '{modules[mid].name}' issues "
                    f"{cls.__name__} — outcome is cycle-dependent, control "
                    f"flow may diverge; using the generator path")
            else:
                raise TypeError(f"unknown op {op!r}")
        gap_acc[mid] = gap
    if not all(done):
        blocked = [modules[m].name for m in range(n_mod) if not done[m]]
        raise TraceUnsupported(
            f"{program.name}: cyclic blocking wait (untimed KPN deadlock) — "
            f"modules {blocked} never terminate; the generator engine will "
            f"report the exact stall cycle")
    mtraces = [
        ModuleTrace(mid=m, name=modules[m].name,
                    kind=np.asarray(kinds[m], dtype=np.int8),
                    fifo=np.asarray(fids[m], dtype=np.int64),
                    gap=np.asarray(gaps[m], dtype=np.int64),
                    end_gap=end_gap[m])
        for m in range(n_mod)
    ]
    return RecordedTrace(program=program.name, modules=mtraces,
                         outputs=outputs,
                         leftovers=[list(b) for b in buffers],
                         skipped_probes=skipped_probes, steps=steps,
                         activations=activations)


# ---------------------------------------------------------------------------
# Pass 2: compile — op arrays -> simulation-graph skeleton
# ---------------------------------------------------------------------------
@dataclass
class CompiledTrace:
    """Depth-independent graph skeleton compiled from a RecordedTrace.

    Node ids are chain-major: module ``m`` owns the contiguous id range
    ``slices[m]`` as ``[START, op_0 .. op_{k-1}, END]``.  ``seq_w[i]`` is
    the SEQ-edge weight into node ``i`` (0 at chain heads); RAW edges are
    depth-independent; WAR edges are generated per depth vector by
    :meth:`war_edges`.  Everything is in cycles / 1-based event counts.
    """

    n: int
    n_modules: int
    slices: List[Tuple[int, int]]       # per-module (lo, hi) node id range
    seq_w: np.ndarray                   # (n,) int64 — SEQ weight into node
    base: np.ndarray                    # (n,) int64 — START time 0, else NEGI
    node_kind: np.ndarray               # (n,) int8 — _NK_* codes
    node_fifo: np.ndarray               # (n,) int64 — FIFO id or -1
    node_seq: np.ndarray                # (n,) int64 — 1-based fifo seq or -1
    fifo_w_nodes: List[np.ndarray]      # per FIFO: write node ids, seq order
    fifo_r_nodes: List[np.ndarray]      # per FIFO: read node ids, seq order
    fifo_wmod: np.ndarray               # per FIFO: writer module (-1 = none)
    fifo_rmod: np.ndarray               # per FIFO: reader module (-1 = none)
    raw_dst: np.ndarray                 # RAW edges (read <- write, w=1)
    raw_src: np.ndarray
    trace: RecordedTrace = field(repr=False, default=None)

    def war_edges(self, depths) -> Tuple[np.ndarray, np.ndarray]:
        """Regenerate the depth-dependent WAR edges for ``depths``.

        The w-th write of a FIFO with depth S waits on the (w-S)-th read
        (paper Table 2).  A write whose target read never occurs can never
        commit — a structural deadlock under these depths — which raises
        :class:`TraceUnsupported` so the generator engine can produce the
        paper-exact deadlock report.
        """
        dst_parts, src_parts = [], []
        for fid, w_nodes in enumerate(self.fifo_w_nodes):
            S = int(depths[fid])
            nw = len(w_nodes)
            if nw <= S:
                continue
            r_nodes = self.fifo_r_nodes[fid]
            if nw - len(r_nodes) > S:
                raise TraceUnsupported(
                    f"write #{len(r_nodes) + S + 1} on fifo {fid} can never "
                    f"commit with depth {S} (structural deadlock)")
            dst_parts.append(w_nodes[S:])
            src_parts.append(r_nodes[:nw - S])
        if not dst_parts:
            z = np.zeros(0, np.int64)
            return z, z
        return np.concatenate(dst_parts), np.concatenate(src_parts)


def compile_trace(rec: RecordedTrace, n_fifos: int) -> CompiledTrace:
    """Lower a RecordedTrace into the chain/edge arrays of CompiledTrace.

    Purely array work — no generator is resumed.  Enforces the engine's
    SPSC endpoint rule (one writer module and one reader module per FIFO)
    on the recorded streams; violations raise :class:`TraceUnsupported` so
    the generator engine surfaces its own AssertionError.
    """
    n_mod = len(rec.modules)
    expanded = [m.expand() for m in rec.modules]
    counts = [len(k) for (k, _, _) in expanded]
    n = sum(counts) + 2 * n_mod
    seq_w = np.zeros(n, dtype=np.int64)
    node_kind = np.empty(n, dtype=np.int8)
    node_fifo = np.full(n, -1, dtype=np.int64)
    node_seq = np.full(n, -1, dtype=np.int64)
    base = np.full(n, NEGI, dtype=np.int64)
    slices: List[Tuple[int, int]] = []
    all_fifo, all_kind, all_node, all_mod = [], [], [], []
    off = 0
    for m, (k, f, g) in enumerate(expanded):
        L = counts[m]
        hi = off + L + 2
        slices.append((off, hi))
        node_kind[off] = _NK_START
        base[off] = 0                       # START commits at cycle 0
        node_kind[off + 1:hi - 1] = np.where(k == OP_WRITE, _NK_WRITE, _NK_READ)
        node_kind[hi - 1] = _NK_END
        node_fifo[off + 1:hi - 1] = f
        seq_w[off + 1:hi - 1] = g
        seq_w[hi - 1] = rec.modules[m].end_gap
        all_fifo.append(f)
        all_kind.append(k)
        all_node.append(np.arange(off + 1, hi - 1, dtype=np.int64))
        all_mod.append(np.full(L, m, dtype=np.int64))
        off = hi
    fifo_all = (np.concatenate(all_fifo) if all_fifo
                else np.zeros(0, np.int64))
    kind_all = (np.concatenate(all_kind).astype(np.int64) if all_kind
                else np.zeros(0, np.int64))
    node_all = (np.concatenate(all_node) if all_node
                else np.zeros(0, np.int64))
    mod_all = (np.concatenate(all_mod) if all_mod
               else np.zeros(0, np.int64))
    # group events by (fifo, kind); stable sort keeps each side's per-module
    # issue order, which IS commit/seq order because FIFOs are SPSC
    order = np.lexsort((kind_all, fifo_all))
    f_s, k_s, n_s, m_s = (fifo_all[order], kind_all[order], node_all[order],
                          mod_all[order])
    fifo_w_nodes: List[np.ndarray] = []
    fifo_r_nodes: List[np.ndarray] = []
    fifo_wmod = np.full(n_fifos, -1, dtype=np.int64)
    fifo_rmod = np.full(n_fifos, -1, dtype=np.int64)
    raw_dst_parts, raw_src_parts = [], []
    for fid in range(n_fifos):
        lo = int(np.searchsorted(f_s, fid, side="left"))
        hi = int(np.searchsorted(f_s, fid, side="right"))
        mid_split = lo + int(np.searchsorted(k_s[lo:hi], OP_WRITE))
        r_nodes = n_s[lo:mid_split]
        w_nodes = n_s[mid_split:hi]
        for side_nodes, side_mods, table in (
                (r_nodes, m_s[lo:mid_split], fifo_rmod),
                (w_nodes, m_s[mid_split:hi], fifo_wmod)):
            if len(side_nodes):
                mods = np.unique(side_mods)
                if len(mods) > 1:
                    raise TraceUnsupported(
                        f"fifo {fid} has {len(mods)} endpoint modules on one "
                        f"side — SPSC violation; deferring to the generator "
                        f"engine's endpoint check")
                table[fid] = int(mods[0])
        fifo_w_nodes.append(np.ascontiguousarray(w_nodes))
        fifo_r_nodes.append(np.ascontiguousarray(r_nodes))
        node_seq[w_nodes] = np.arange(1, len(w_nodes) + 1)
        node_seq[r_nodes] = np.arange(1, len(r_nodes) + 1)
        nr = len(r_nodes)
        if nr:                              # r-th read <- r-th write, w=1
            raw_dst_parts.append(r_nodes)
            raw_src_parts.append(w_nodes[:nr])
    raw_dst = (np.concatenate(raw_dst_parts) if raw_dst_parts
               else np.zeros(0, np.int64))
    raw_src = (np.concatenate(raw_src_parts) if raw_src_parts
               else np.zeros(0, np.int64))
    return CompiledTrace(n=n, n_modules=n_mod, slices=slices, seq_w=seq_w,
                         base=base, node_kind=node_kind, node_fifo=node_fifo,
                         node_seq=node_seq, fifo_w_nodes=fifo_w_nodes,
                         fifo_r_nodes=fifo_r_nodes, fifo_wmod=fifo_wmod,
                         fifo_rmod=fifo_rmod, raw_dst=raw_dst,
                         raw_src=raw_src, trace=rec)


# ---------------------------------------------------------------------------
# Pass 3: replay — Gauss-Seidel chain fixpoint (array-level dispatch)
# ---------------------------------------------------------------------------
def _solve_times(ct: CompiledTrace, war_dst: np.ndarray,
                 war_src: np.ndarray) -> Tuple[np.ndarray, int]:
    """Longest-path node times over SEQ chains + RAW/WAR cross edges.

    Within a chain, ``t = cw + cummax(c - cw)`` (cw = cumulative SEQ
    weight) resolves all sequential propagation in one vectorized pass;
    cross edges are bucketed by (source module, destination module) — one
    bucket per FIFO side, since FIFOs are SPSC — and swept Gauss-Seidel in
    module order with dirty-chain tracking, so each sweep only recomputes
    chains some cross edge actually moved.  Converges in O(module-graph
    hops), not O(events).  A WAR cycle makes times grow past the acyclic
    bound: raises :class:`TraceUnsupported` (the timed engine would
    deadlock; the generator path reports it exactly).

    Returns ``(times, sweeps)`` — times in cycles.
    """
    n = ct.n
    n_ch = ct.n_modules
    cw = np.concatenate([np.cumsum(ct.seq_w[lo:hi]) for (lo, hi) in ct.slices]) \
        if n else np.zeros(0, np.int64)
    c = ct.base.copy()
    t = np.full(n, NEGI, dtype=np.int64)
    starts = np.asarray([lo for (lo, _) in ct.slices] or [0], np.int64)

    def chain_of(col: int) -> int:
        return int(np.searchsorted(starts, col, side="right") - 1)

    # bucket cross edges by source chain (RAW: writer -> reader module;
    # WAR: reader -> writer module) — no sort needed, FIFO sides are SPSC
    out_buckets: Dict[int, List[Tuple[int, np.ndarray, np.ndarray]]] = {}
    for dst, src in ((ct.raw_dst, ct.raw_src), (war_dst, war_src)):
        if not len(dst):
            continue
        # split by fifo-contiguous runs: each concatenated part came from
        # one fifo, i.e. one (src chain, dst chain) pair
        cut = np.flatnonzero(np.diff(np.searchsorted(starts, src, "right"))
                             | np.diff(np.searchsorted(starts, dst, "right")))
        bounds = np.concatenate([[0], cut + 1, [len(dst)]])
        for a, b in zip(bounds[:-1], bounds[1:]):
            sc, dc = chain_of(int(src[a])), chain_of(int(dst[a]))
            out_buckets.setdefault(sc, []).append((dc, src[a:b], dst[a:b]))

    bound = int(ct.seq_w.sum() + len(ct.raw_dst) + len(war_dst) + 1)
    dirty = np.ones(n_ch, dtype=bool)
    sweeps = 0
    max_sweeps = n + 2
    while dirty.any():
        sweeps += 1
        if sweeps > max_sweeps or (sweeps > n_ch + 4 and t.max() > bound):
            raise TraceUnsupported(
                "WAR edges form a cycle — the recorded event order is "
                "invalid under these depths (the design deadlocks)")
        for ci in range(n_ch):
            if not dirty[ci]:
                continue
            dirty[ci] = False
            lo, hi = ct.slices[ci]
            seg = c[lo:hi] - cw[lo:hi]
            np.maximum.accumulate(seg, out=seg)
            seg += cw[lo:hi]
            if np.array_equal(seg, t[lo:hi]):
                continue
            t[lo:hi] = seg
            for (dc, s_ids, d_ids) in out_buckets.get(ci, ()):
                cand = t[s_ids] + 1
                old = c[d_ids]
                moved = cand > old
                if moved.any():
                    c[d_ids] = np.maximum(old, cand)
                    dirty[dc] = True
    return t, sweeps


# ---------------------------------------------------------------------------
# Array-backed simulation graph (API-compatible with graph.SimGraph reads)
# ---------------------------------------------------------------------------
class TraceSimGraph:
    """The replayed simulation graph, stored as numpy arrays.

    Drop-in for :class:`~repro.core.graph.SimGraph` consumers that *read*
    a finished graph — ``nodes`` (materialized lazily as
    :class:`~repro.core.events.Node` objects for e.g. the taxonomy
    classifier), ``times()``, ``to_csr()``, ``n_nodes``/``n_edges`` — while
    the hot path never touches per-node Python objects.  Node times are in
    cycles; node ids are chain-major (see :class:`CompiledTrace`), which is
    *not* a topological order — use level-scheduled or fixpoint longest-path
    backends, not ``longest_path_python``.
    """

    def __init__(self, ct: CompiledTrace, times: np.ndarray,
                 war_dst: np.ndarray, war_src: np.ndarray,
                 module_arr: np.ndarray):
        self._ct = ct
        self._times = times
        self._module = module_arr
        self._cross_dst = (np.concatenate([ct.raw_dst, war_dst])
                           if len(ct.raw_dst) or len(war_dst)
                           else np.zeros(0, np.int64))
        self._cross_src = (np.concatenate([ct.raw_src, war_src])
                           if len(ct.raw_src) or len(war_src)
                           else np.zeros(0, np.int64))
        self._nodes: Optional[List[Node]] = None

    # -- SimGraph read API ---------------------------------------------------
    @property
    def n_nodes(self) -> int:
        return self._ct.n

    @property
    def n_edges(self) -> int:
        # SEQ edges into every non-head node + RAW/WAR cross edges
        return (self._ct.n - self._ct.n_modules) + len(self._cross_dst)

    def times(self) -> np.ndarray:
        """Commit cycle of every node (same as SimGraph.times())."""
        return self._times.copy()

    @property
    def nodes(self) -> List[Node]:
        """Materialize Node objects (lazily, once) for object-level readers."""
        if self._nodes is None:
            ct = self._ct
            nodes = []
            heads = {lo for (lo, _) in ct.slices}
            for i in range(ct.n):
                node = Node(idx=i, module=int(self._module[i]),
                            kind=_NK_TO_NODEKIND[int(ct.node_kind[i])],
                            time=int(self._times[i]),
                            fifo=int(ct.node_fifo[i]),
                            seq=int(ct.node_seq[i]))
                if i not in heads:
                    node.preds.append((i - 1, int(ct.seq_w[i])))
                nodes.append(node)
            for dst, src in zip(self._cross_dst, self._cross_src):
                nodes[int(dst)].preds.append((int(src), 1))
            self._nodes = nodes
        return self._nodes

    def to_csr(self):
        """CSR by destination — same convention as SimGraph.to_csr()."""
        ct = self._ct
        n = ct.n
        head_mask = np.zeros(n, dtype=bool)
        for (lo, _) in ct.slices:
            head_mask[lo] = True
        seq_dst = np.flatnonzero(~head_mask)
        dsts = np.concatenate([seq_dst, self._cross_dst])
        srcs = np.concatenate([seq_dst - 1, self._cross_src])
        wgts = np.concatenate([ct.seq_w[seq_dst],
                               np.ones(len(self._cross_dst), np.int64)])
        order = np.argsort(dsts, kind="stable")
        counts = np.bincount(dsts, minlength=n)
        indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        base = np.where(indptr[1:] == indptr[:-1], self._times, 0)
        return indptr, srcs[order], wgts[order], base.astype(np.int64)


# ---------------------------------------------------------------------------
# CompiledGraph bridge: incremental/DSE reuse without graph re-interpretation
# ---------------------------------------------------------------------------
def to_compiled_graph(ct: CompiledTrace):
    """Build the incremental-resimulation cache directly from the trace.

    The returned :class:`~repro.core.incremental.CompiledGraph` is what
    ``compile_graph(engine)`` would have extracted by walking the Python
    node objects of a generator-path run — chains, SEQ weights, RAW edges,
    per-FIFO event arrays (all writes blocking: the compiled path carries
    no NB accesses) and an empty constraint set.  ``simulate_traced``
    installs it as the engine's ``_incr_cache``, so the first
    ``resimulate``/``resimulate_batch`` call skips re-interpretation.
    """
    from .incremental import CompiledGraph
    fifos = [(w.copy(), r.copy(), np.ones(len(w), dtype=bool))
             for w, r in zip(ct.fifo_w_nodes, ct.fifo_r_nodes)]
    z = np.zeros(0, np.int64)
    return CompiledGraph(
        n=ct.n,
        raw_dst=ct.raw_dst.copy(),
        raw_src=ct.raw_src.copy(),
        raw_w=np.ones(len(ct.raw_dst), np.int64),
        base=ct.base.copy(),
        chains=[np.arange(lo, hi, dtype=np.int64) for (lo, hi) in ct.slices],
        seq_w=ct.seq_w.copy(),
        fifos=fifos,
        c_kind=z, c_fifo=z, c_seq=z, c_src=z,
        c_out=np.zeros(0, dtype=bool),
    )


# ---------------------------------------------------------------------------
# Front door
# ---------------------------------------------------------------------------
def simulate_traced(program: Program,
                    max_steps: int = 50_000_000) -> SimResult:
    """Record, compile and replay ``program`` — the trace-compiled initial
    simulation (paper Sec. 5.1).

    Returns a :class:`~repro.core.program.SimResult` interchangeable with
    the generator engine's (same outputs, cycles, FIFO tables, graph and
    incremental-resimulation behavior) with ``engine="omnisim-trace"``.
    Raises :class:`TraceUnsupported` when the design needs the generator
    path (live NB accesses/probes, deadlocks, SPSC violations); callers
    normally go through ``repro.core.simulate(..., trace="auto")`` which
    handles the fallback.
    """
    rec = record_trace(program, max_steps)
    ct = compile_trace(rec, len(program.fifos))
    depths = program.depths()
    war_dst, war_src = ct.war_edges(depths)
    times, sweeps = _solve_times(ct, war_dst, war_src)
    cycles = int(times.max()) if ct.n else 0

    # populate an engine shell so downstream consumers (incremental, DSE,
    # taxonomy, kernels.finalize_times) see exactly the generator engine's
    # end state
    from .engine import OmniSim
    engine = OmniSim(program)
    engine.outputs = dict(rec.outputs)
    module_arr = np.empty(ct.n, dtype=np.int64)
    for m, (lo, hi) in enumerate(ct.slices):
        module_arr[lo:hi] = m
    engine.graph = TraceSimGraph(ct, times, war_dst, war_src, module_arr)
    for f in program.fifos:
        tbl = engine.fifos[f.fid]
        w_nodes = ct.fifo_w_nodes[f.fid]
        r_nodes = ct.fifo_r_nodes[f.fid]
        tbl._w_nodes = w_nodes.astype(np.int64, copy=True)
        tbl._w_times = times[w_nodes]
        tbl._nw = len(w_nodes)
        tbl._r_nodes = r_nodes.astype(np.int64, copy=True)
        tbl._r_times = times[r_nodes]
        tbl._nr = len(r_nodes)
        tbl.values.extend(rec.leftovers[f.fid])
        if len(w_nodes):
            engine._writer_of[f.fid] = int(ct.fifo_wmod[f.fid])
        if len(r_nodes):
            engine._reader_of[f.fid] = int(ct.fifo_rmod[f.fid])
    stats = engine.stats
    # the generator engine counts nodes in _new_node, which START bypasses
    stats.nodes = ct.n - ct.n_modules
    stats.edges = engine.graph.n_edges
    stats.resumes = rec.activations          # scheduler (re)activations
    stats.skipped_probes = rec.skipped_probes
    stats.quiescence_rounds = sweeps
    engine._incr_cache = to_compiled_graph(ct)
    engine._trace = rec.periodize()          # compact steady-state storage
    return SimResult(
        program=program.name,
        outputs=dict(rec.outputs),
        cycles=cycles,
        engine="omnisim-trace",
        stats=stats,
        graph=engine,
        constraints=[],
        depths=depths,
    )
