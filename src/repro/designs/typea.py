"""Type A designs — analogues of the LightningSimV2 benchmark suite (Table 5).

All designs here use acyclic module graphs and blocking-only FIFO accesses,
so they are simulable by the decoupled two-phase baseline
(``core/lightningsim.py``); the OmniSim engine must produce byte-identical
outputs and cycle counts (tests assert this).  Workload sizes scale from the
small Vitis examples up to FlowGNN- and SkyNet-like deep pipelines used for
the speed comparison.

Each builder takes size parameters so benchmarks can sweep scale.
"""
from __future__ import annotations

from ..core.program import Delay, Emit, Program, Read, Write


# -------------------------------------------------------------------- basics
def producer_consumer(n: int = 256, depth: int = 2) -> Program:
    prog = Program("producer_consumer", declared_type="A")
    data = prog.fifo("data", depth)

    @prog.module("producer")
    def producer():
        for i in range(1, n + 1):
            yield Write(data, i)

    @prog.module("consumer")
    def consumer():
        total = 0
        for _ in range(n):
            total += (yield Read(data))
        yield Emit("sum", total)

    return prog


def fir_filter(n: int = 512, taps: int = 8) -> Program:
    """Streaming FIR: source -> MAC (II=1 after a `taps`-cycle ramp) -> sink."""
    prog = Program("fir_filter", declared_type="A")
    x = prog.fifo("x", 2)
    y = prog.fifo("y", 2)
    coeff = [(k % 5) + 1 for k in range(taps)]

    @prog.module("source")
    def source():
        for i in range(n):
            yield Write(x, i % 97)

    @prog.module("fir")
    def fir():
        window = [0] * taps
        for _ in range(n):
            v = yield Read(x)
            window = [v] + window[:-1]
            acc = sum(c * w for c, w in zip(coeff, window))
            yield Write(y, acc)

    @prog.module("sink")
    def sink():
        total = 0
        for _ in range(n):
            total += (yield Read(y))
        yield Emit("checksum", total)

    return prog


def window_conv(rows: int = 32, cols: int = 32, k: int = 3) -> Program:
    """Line-buffer 2D convolution pipeline (fixed-point window conv)."""
    prog = Program("window_conv", declared_type="A")
    pix = prog.fifo("pix", 4)
    out = prog.fifo("out", 4)

    @prog.module("reader")
    def reader():
        for r in range(rows):
            for c in range(cols):
                yield Write(pix, (r * 31 + c * 7) % 255)

    @prog.module("conv")
    def conv():
        linebuf = [[0] * cols for _ in range(k)]
        for r in range(rows):
            for c in range(cols):
                v = yield Read(pix)
                linebuf[r % k][c] = v
                if r >= k - 1 and c >= k - 1:
                    acc = 0
                    for i in range(k):
                        for j in range(k):
                            acc += linebuf[(r - i) % k][c - j]
                    yield Write(out, acc)

    @prog.module("writer")
    def writer():
        total = 0
        cnt = (rows - k + 1) * (cols - k + 1)
        for _ in range(cnt):
            total += (yield Read(out))
        yield Emit("checksum", total)

    return prog


def matmul_stream(m: int = 16, k: int = 16, n: int = 16) -> Program:
    """Streaming matmul: A-feeder and B-feeder into a MAC engine."""
    prog = Program("matmul_stream", declared_type="A")
    fa = prog.fifo("a", 8)
    fb = prog.fifo("b", 8)
    fc = prog.fifo("c", 8)

    @prog.module("feed_a")
    def feed_a():
        for i in range(m):
            for p in range(k):
                yield Write(fa, (i * k + p) % 13)

    @prog.module("feed_b")
    def feed_b():
        for i in range(m):            # B re-streamed per row of A
            for p in range(k):
                for j in range(n):
                    yield Write(fb, (p * n + j) % 11)

    @prog.module("mac")
    def mac():
        for i in range(m):
            acc = [0] * n
            for p in range(k):
                a = yield Read(fa)
                for j in range(n):
                    b = yield Read(fb)
                    acc[j] += a * b
            for j in range(n):
                yield Write(fc, acc[j])

    @prog.module("drain")
    def drain():
        total = 0
        for _ in range(m * n):
            total += (yield Read(fc))
        yield Emit("checksum", total)

    return prog


def sqrt_pipe(n: int = 256, latency: int = 12) -> Program:
    """Fixed-point square root: deep pipeline, II=1, latency `latency`."""
    prog = Program("sqrt_pipe", declared_type="A")
    xin = prog.fifo("xin", 2)
    xout = prog.fifo("xout", 2)

    @prog.module("source")
    def source():
        for i in range(n):
            yield Write(xin, i * i % 4096)

    @prog.module("isqrt")
    def isqrt():
        yield Delay(latency)          # pipeline fill
        for _ in range(n):
            v = yield Read(xin)
            yield Write(xout, int(v ** 0.5))

    @prog.module("sink")
    def sink():
        total = 0
        for _ in range(n):
            total += (yield Read(xout))
        yield Emit("checksum", total)

    return prog


def parallel_loops(n: int = 256) -> Program:
    """Two independent chains joined by an adder (parallel loops example)."""
    prog = Program("parallel_loops", declared_type="A")
    f1 = prog.fifo("f1", 2)
    f2 = prog.fifo("f2", 2)
    fo = prog.fifo("fo", 2)

    @prog.module("gen_a")
    def gen_a():
        for i in range(n):
            yield Write(f1, 3 * i)

    @prog.module("gen_b")
    def gen_b():
        for i in range(n):
            yield Delay(1)            # slower producer: joins stall
            yield Write(f2, 5 * i)

    @prog.module("join")
    def join():
        for _ in range(n):
            a = yield Read(f1)
            b = yield Read(f2)
            yield Write(fo, a + b)

    @prog.module("sink")
    def sink():
        total = 0
        for _ in range(n):
            total += (yield Read(fo))
        yield Emit("checksum", total)

    return prog


def nested_loops(outer: int = 24, inner: int = 24) -> Program:
    """Perfect nested loops with an II=2 inner body."""
    prog = Program("nested_loops", declared_type="A")
    f = prog.fifo("f", 2)

    @prog.module("compute")
    def compute():
        for i in range(outer):
            yield Delay(2)            # loop-entry overhead
            for j in range(inner):
                yield Write(f, i * j)
                yield Delay(1)        # II=2

    @prog.module("sink")
    def sink():
        total = 0
        for _ in range(outer * inner):
            total += (yield Read(f))
        yield Emit("checksum", total)

    return prog


def accumulators(n: int = 256, stages: int = 4) -> Program:
    """Chain of accumulate-and-forward stages (sequential accumulators)."""
    prog = Program("accumulators", declared_type="A")
    chans = [prog.fifo(f"c{i}", 2) for i in range(stages + 1)]

    @prog.module("source")
    def source():
        for i in range(n):
            yield Write(chans[0], i % 17)

    def make_stage(s: int):
        def stage():
            acc = 0
            for _ in range(n):
                v = yield Read(chans[s])
                acc += v
                yield Write(chans[s + 1], acc)
        return stage

    for s in range(stages):
        prog.add_module(f"acc{s}", make_stage(s))

    @prog.module("sink")
    def sink():
        total = 0
        for _ in range(n):
            total += (yield Read(chans[stages]))
        yield Emit("checksum", total)

    return prog


def vector_add_stream(n: int = 1024) -> Program:
    """Vitis accel example: two HBM streams added into an output stream."""
    prog = Program("vector_add_stream", declared_type="A")
    a = prog.fifo("a", 16)
    b = prog.fifo("b", 16)
    c = prog.fifo("c", 16)

    @prog.module("mm2s_a")
    def mm2s_a():
        for i in range(n):
            yield Write(a, i)

    @prog.module("mm2s_b")
    def mm2s_b():
        for i in range(n):
            yield Write(b, 2 * i)

    @prog.module("vadd")
    def vadd():
        for _ in range(n):
            x = yield Read(a)
            y = yield Read(b)
            yield Write(c, x + y)

    @prog.module("s2mm")
    def s2mm():
        total = 0
        for _ in range(n):
            total += (yield Read(c))
        yield Emit("checksum", total)

    return prog


def merge_sort_staged(log_n: int = 6) -> Program:
    """Parallelized merge sort: log_n merge stages connected by FIFOs."""
    n = 1 << log_n
    prog = Program("merge_sort_staged", declared_type="A")
    chans = [prog.fifo(f"s{i}", max(2, 1 << i)) for i in range(log_n + 1)]
    data = [(7919 * i + 13) % 1024 for i in range(n)]

    @prog.module("source")
    def source():
        for v in data:
            yield Write(chans[0], v)

    def make_stage(s: int):
        width = 1 << s

        def stage():
            for _ in range(n // (2 * width)):
                left, right = [], []
                for _ in range(width):
                    left.append((yield Read(chans[s])))
                for _ in range(width):
                    right.append((yield Read(chans[s])))
                i = j = 0
                while i < len(left) or j < len(right):
                    if j >= len(right) or (i < len(left) and left[i] <= right[j]):
                        yield Write(chans[s + 1], left[i])
                        i += 1
                    else:
                        yield Write(chans[s + 1], right[j])
                        j += 1
        return stage

    for s in range(log_n):
        prog.add_module(f"merge{s}", make_stage(s))

    @prog.module("sink")
    def sink():
        prev = -1
        ok = True
        checksum = 0
        for _ in range(n):
            v = yield Read(chans[log_n])
            ok = ok and (v >= prev)
            prev = v
            checksum = (checksum * 31 + v) % 1_000_000_007
        yield Emit("sorted", ok)
        yield Emit("checksum", checksum)

    return prog


def huffman_pipe(n: int = 512) -> Program:
    """Huffman-encoding-like pipeline: histogram -> code-assign -> encode."""
    prog = Program("huffman_pipe", declared_type="A")
    sym = prog.fifo("sym", 4)
    sym2 = prog.fifo("sym2", 1024)     # replay buffer
    bits = prog.fifo("bits", 4)
    data = [(i * 31 + 7) % 16 for i in range(n)]

    @prog.module("source")
    def source():
        for v in data:
            yield Write(sym, v)

    @prog.module("hist_replay")
    def hist_replay():
        hist = [0] * 16
        buf = []
        for _ in range(n):
            v = yield Read(sym)
            hist[v] += 1
            buf.append(v)
        # code length ~ rank by frequency (simplified canonical codes)
        order = sorted(range(16), key=lambda s: -hist[s])
        length = {s: 1 + r // 2 for r, s in enumerate(order)}
        for v in buf:
            yield Write(sym2, length[v])

    @prog.module("encoder")
    def encoder():
        total_bits = 0
        for _ in range(n):
            total_bits += (yield Read(sym2))
            yield Write(bits, total_bits)

    @prog.module("sink")
    def sink():
        last = 0
        for _ in range(n):
            last = yield Read(bits)
        yield Emit("total_bits", last)

    return prog


# ----------------------------------------------------- large-scale pipelines
def flowgnn_like(n_nodes: int = 128, layers: int = 4) -> Program:
    """FlowGNN-style: per-layer gather/scatter/update modules in a chain."""
    prog = Program("flowgnn_like", declared_type="A")
    chans = [prog.fifo(f"h{i}", 8) for i in range(2 * layers + 1)]

    @prog.module("loader")
    def loader():
        for v in range(n_nodes):
            yield Write(chans[0], (v * 17 + 3) % 256)

    def make_gather(layer: int):
        def gather():
            prev = 0
            for _ in range(n_nodes):
                v = yield Read(chans[2 * layer])
                yield Write(chans[2 * layer + 1], v + prev)   # neighbor mix
                prev = v
        return gather

    def make_update(layer: int):
        def update():
            for _ in range(n_nodes):
                v = yield Read(chans[2 * layer + 1])
                yield Delay(1)                                # MLP latency
                yield Write(chans[2 * layer + 2], (3 * v + 1) % 65536)
        return update

    for L in range(layers):
        prog.add_module(f"gather{L}", make_gather(L))
        prog.add_module(f"update{L}", make_update(L))

    @prog.module("readout")
    def readout():
        total = 0
        for _ in range(n_nodes):
            total += (yield Read(chans[2 * layers]))
        yield Emit("checksum", total % 1_000_000_007)

    return prog


def skynet_like(items: int = 2048, depth: int = 24) -> Program:
    """SkyNet-style deep CNN pipeline: `depth` stages, large item count.

    The heavyweight speed benchmark: ~items*depth FIFO events.
    """
    prog = Program("skynet_like", declared_type="A")
    chans = [prog.fifo(f"l{i}", 4) for i in range(depth + 1)]

    @prog.module("dma_in")
    def dma_in():
        for i in range(items):
            yield Write(chans[0], i % 251)

    def make_layer(s: int):
        def layer():
            for _ in range(items):
                v = yield Read(chans[s])
                yield Write(chans[s + 1], (v * 5 + s) % 65521)
        return layer

    for s in range(depth):
        prog.add_module(f"conv{s}", make_layer(s))

    @prog.module("dma_out")
    def dma_out():
        total = 0
        for _ in range(items):
            total += (yield Read(chans[depth]))
        yield Emit("checksum", total % 1_000_000_007)

    return prog


def high_latency_pipe(items: int = 200, stages: int = 6, ii: int = 64) -> Program:
    """Deep pipeline with high-II stages: cycle count >> event count.

    The regime where event-driven simulation structurally beats
    cycle-stepping (the paper's co-sim weakness): the oracle must step every
    idle cycle while OmniSim's cost scales with FIFO events only.
    """
    prog = Program(f"latency_pipe_ii{ii}", declared_type="A")
    chans = [prog.fifo(f"c{i}", 2) for i in range(stages + 1)]

    @prog.module("src")
    def src():
        for i in range(items):
            yield Write(chans[0], i)

    def mk(s):
        def stage():
            for _ in range(items):
                v = yield Read(chans[s])
                yield Delay(ii - 2)
                yield Write(chans[s + 1], v + 1)
        return stage

    for s in range(stages):
        prog.add_module(f"st{s}", mk(s))

    @prog.module("sink")
    def sink():
        tot = 0
        for _ in range(items):
            tot += (yield Read(chans[stages]))
        yield Emit("sum", tot)

    return prog


TYPEA_DESIGNS = {
    "producer_consumer": producer_consumer,
    "fir_filter": fir_filter,
    "window_conv": window_conv,
    "matmul_stream": matmul_stream,
    "sqrt_pipe": sqrt_pipe,
    "parallel_loops": parallel_loops,
    "nested_loops": nested_loops,
    "accumulators": accumulators,
    "vector_add_stream": vector_add_stream,
    "merge_sort_staged": merge_sort_staged,
    "huffman_pipe": huffman_pipe,
    "flowgnn_like": flowgnn_like,
    "skynet_like": skynet_like,
    "latency_pipe": high_latency_pipe,
}
