"""Benchmark design registry: the paper's Type B/C designs, the Type A
suite, and dynamic (query-sparse Type B/C) designs beyond the paper."""
from .dynamic import DYNAMIC_DESIGNS
from .paper import PAPER_DESIGNS
from .typea import TYPEA_DESIGNS

ALL_DESIGNS = {**PAPER_DESIGNS, **TYPEA_DESIGNS, **DYNAMIC_DESIGNS}

__all__ = ["PAPER_DESIGNS", "TYPEA_DESIGNS", "DYNAMIC_DESIGNS", "ALL_DESIGNS"]
