"""Benchmark design registry: the paper's Type B/C designs + Type A suite."""
from .paper import PAPER_DESIGNS
from .typea import TYPEA_DESIGNS

ALL_DESIGNS = {**PAPER_DESIGNS, **TYPEA_DESIGNS}

__all__ = ["PAPER_DESIGNS", "TYPEA_DESIGNS", "ALL_DESIGNS"]
