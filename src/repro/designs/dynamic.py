"""Dynamic (Type B/C) benchmark designs beyond the paper's Table 4.

The paper designs (``designs/paper.py``) are query-*dominated*: most of
their ops are NB accesses or probes, so every engine pays per-query
interpretation.  The designs here have the opposite profile — deep blocking
pipelines with *sparse* query points — which is exactly where the hybrid
segmented replay (``core/trace.py::simulate_hybrid``) shines: the blocking
segments compile to flat arrays and only the occasional query drops to the
generator protocol.

Module order matters for the ``trace="auto"`` probe cost: the NB module
comes first so the straight-line recorder aborts to the hybrid path after a
single op instead of replaying a whole pipeline stage.
"""
from __future__ import annotations

from ..core.program import Delay, Emit, Program, Read, ReadNB, Write


def watchdog_pipe(items: int = 2048, stages: int = 4, depth: int = 16,
                  poll_gap: int = 64) -> Program:
    """A skynet-like blocking pipeline supervised by a polling watchdog.

    ``stages`` blocking stages stream ``items`` elements (the Type A bulk of
    the design); the sink signals completion on a ``done`` FIFO, and a
    watchdog polls it with a non-blocking read every ``poll_gap`` cycles —
    the classic status-register pattern no decoupled simulator can time.
    Queries are ~``cycles / poll_gap`` of the op stream, so the hybrid
    engine compiles almost everything.
    """
    prog = Program("watchdog_pipe", declared_type="C")
    done = prog.fifo("done", 1)
    links = [prog.fifo(f"s{i}", depth) for i in range(stages + 1)]

    @prog.module("watchdog")          # first: auto-probe bails out fast
    def watchdog():
        polls = 0
        while True:
            ok, _ = yield ReadNB(done)
            polls += 1
            if ok:
                break
            yield Delay(poll_gap - 1)
        yield Emit("polls", polls)

    @prog.module("source")
    def source():
        for i in range(items):
            yield Write(links[0], (i * 7 + 3) % 251)

    def make_stage(k: int):
        def stage():
            acc = 0
            for _ in range(items):
                v = yield Read(links[k])
                acc = (acc + v) % 65521
                yield Write(links[k + 1], (v * 3 + k) % 251)
            yield Emit(f"stage{k}_acc", acc)
        return stage

    for k in range(stages):
        prog.add_module(f"stage{k}", make_stage(k))

    @prog.module("sink")
    def sink():
        total = 0
        for _ in range(items):
            total += (yield Read(links[stages]))
        yield Write(done, 1)
        yield Emit("checksum", total)

    return prog


def fig2_poll_burst(items: int = 2048, stages: int = 2, depth: int = 8,
                    gaps=(1, 1, 1, 1, 1, 2, 1, 1, 1, 7)) -> Program:
    """A ``fig2_timer``-class poller with *bursty, non-uniform* poll gaps.

    A small blocking pipeline streams ``items`` elements; the sink signals
    completion on ``done`` and a poller ReadNB-polls it, but with a gap that
    cycles through ``gaps`` instead of staying fixed — bursts of back-to-back
    polls separated by longer pauses, like a core that polls a status
    register hard right after issuing work and backs off in between.  The
    query periodizer's steady-state detector only fires inside the
    constant-gap runs and must fall back to per-query interpretation at
    every gap change, so this design exercises both the burst fast path and
    its divergence fallback (``benchmarks/tables.py::
    table_query_periodization`` reports the speedup for both profiles).
    """
    prog = Program("fig2_poll_burst", declared_type="C")
    done = prog.fifo("done", 1)
    links = [prog.fifo(f"q{i}", depth) for i in range(stages + 1)]

    @prog.module("poller")            # first: auto-probe bails out fast
    def poller():
        polls = 0
        i = 0
        while True:
            ok, _ = yield ReadNB(done)
            polls += 1
            if ok:
                break
            g = gaps[i % len(gaps)]
            i += 1
            if g > 1:
                yield Delay(g - 1)
        yield Emit("polls", polls)

    @prog.module("source")
    def source():
        for i in range(items):
            yield Write(links[0], (i * 5 + 1) % 241)

    def make_stage(k: int):
        def stage():
            acc = 0
            for _ in range(items):
                v = yield Read(links[k])
                acc = (acc + v * (k + 2)) % 65521
                yield Write(links[k + 1], (v * 7 + k) % 241)
            yield Emit(f"stage{k}_acc", acc)
        return stage

    for k in range(stages):
        prog.add_module(f"stage{k}", make_stage(k))

    @prog.module("sink")
    def sink():
        total = 0
        for _ in range(items):
            total += (yield Read(links[stages]))
        yield Write(done, 1)
        yield Emit("checksum", total)

    return prog


def multisite_poll(items: int = 1024, depth: int = 64,
                   pause: int = 2) -> Program:
    """One watcher round-robins ReadNB over *two* FIFOs fed at different
    rates — the multi-site periodic pattern.

    The watcher's loop body is ``ReadNB(a); ReadNB(b); Delay(pause)`` —
    ``pause + 2`` cycles per iteration.  ``feed_a`` produces exactly one
    value per iteration (every poll of site A succeeds) and ``feed_b``
    one value per *two* iterations (site B alternates hit/miss), so the
    steady state is a repeating four-step ``(site, gap, outcome)``
    tuple: A-hit, B-hit, A-hit, B-miss.  A single-site streak detector
    sees nothing periodic here; the generalized pattern periodizer arms
    on the tuple and commits whole windows of mixed-outcome queries
    against the feeders' run-ahead write tables (horizon = min over the
    two sites).
    """
    prog = Program("multisite_poll", declared_type="C")
    a = prog.fifo("a", depth)
    b = prog.fifo("b", depth)
    period = pause + 2                # cycles per watcher iteration
    total = items + items // 2

    @prog.module("watcher")           # first: auto-probe bails out fast
    def watcher():
        acc = 0
        got = 0
        polls = 0
        while got < total:
            ok, v = yield ReadNB(a)
            polls += 1
            if ok:
                acc = (acc + v) % 65521
                got += 1
            ok, v = yield ReadNB(b)
            polls += 1
            if ok:
                acc = (acc + 3 * v) % 65521
                got += 1
            if pause:
                yield Delay(pause)
        yield Emit("checksum", acc)
        yield Emit("polls", polls)

    def make_feed(fifo, n, gap, salt):
        def feed():
            for i in range(n):
                yield Write(fifo, (i * salt + 1) % 251)
                if gap > 1:
                    yield Delay(gap - 1)
        return feed

    prog.add_module("feed_a", make_feed(a, items, period, 7))
    prog.add_module("feed_b", make_feed(b, items // 2, 2 * period, 13))
    return prog


def nb_success_stream(items: int = 4096, depth: int = 64,
                      gap: int = 2) -> Program:
    """Steady-state *successful* NB stream: a run-ahead producer fills a
    deep FIFO while a ReadNB consumer drains it at the matched rate.

    Once the stream warms up every poll succeeds, so a fail-streak
    detector never fires — but the success pattern has a fixed period
    whose commit times are derivable from the producer's committed
    write table, and the periodizer verifies + commits reads in windows
    bounded by the producer's run-ahead (≈ ``depth`` rows at a time).
    """
    prog = Program("nb_success_stream", declared_type="C")
    data = prog.fifo("data", depth)

    @prog.module("drain")             # first: auto-probe bails out fast
    def drain():
        acc = 0
        got = 0
        misses = 0
        while got < items:
            ok, v = yield ReadNB(data)
            if ok:
                acc = (acc + v) % 65521
                got += 1
            else:
                misses += 1
            if gap > 1:
                yield Delay(gap - 1)
        yield Emit("checksum", acc)
        yield Emit("misses", misses)

    @prog.module("feed")
    def feed():
        for i in range(items):
            yield Write(data, (i * 11 + 5) % 257)
            if gap > 1:
                yield Delay(gap - 1)

    return prog


DYNAMIC_DESIGNS = {
    "watchdog_pipe": watchdog_pipe,
    "fig2_poll_burst": fig2_poll_burst,
    "multisite_poll": multisite_poll,
    "nb_success_stream": nb_success_stream,
}
