"""The paper's Type B / Type C benchmark designs (Table 4).

Eleven designs that no prior HLS tool simulates correctly at C level.  We
author them in the dataflow DSL with the same structure and — where the value
is analytically determined — the same expected outputs as the paper's Table 3:

  * fig4_ex2      sum_out = 2051325  (= sum(1..2025))
  * fig4_ex3      sum     = 4098600  (= 2 * sum(0..2024)); C-sim: sum=0 with
                  2025 'read while empty' warnings + leftover-data warning
  * fig4_ex4a/b   partial sums (timing-dependent; our deterministic values,
                  asserted identical between OmniSim and the cycle-stepped
                  RTL oracle — the paper's actual claim)
  * fig2_timer    internal timer counts 6075 cycles (= 3 x 2025)
  * deadlock      detected immediately, simulator never hangs
  * branch        downstream executor redirects the upstream fetcher
  * multicore     16 cores x (fetcher + executor) + dispatcher + collector
                  = 34 modules, 64 FIFOs

Minor deviations from Table 4's module/FIFO counts (we do not replicate the
Vitis testbench wrapper as a module) are noted in DESIGN.md.
"""
from __future__ import annotations

from ..core.program import (Delay, Emit, Empty, Full, Program, Read, ReadNB,
                            Write, WriteNB)

N = 2025  # the paper's element count (sum(1..2025) = 2051325)


# ---------------------------------------------------------------------------
# fig4_ex2 — Type B: NB accesses in infinite loops, done-signal termination.
# ---------------------------------------------------------------------------
def fig4_ex2(n: int = N) -> Program:
    prog = Program("fig4_ex2", declared_type="B")
    data = prog.fifo("data", 2)
    done = prog.fifo("done", 1)
    # hardware reads past the logical end of the buffer return garbage (0,
    # modeled by bounded slack); sequential C-sim instead overruns the array
    # unboundedly -> SIGSEGV (Table 3).
    input_arr = list(range(1, n + 1)) + [0] * (3 * n)

    @prog.module("producer")
    def producer():
        i = 0
        while True:
            ok, _ = yield ReadNB(done)
            if ok:
                break
            v = input_arr[i]
            ok = yield WriteNB(data, v)
            if ok:
                i += 1

    @prog.module("consumer")
    def consumer():
        total = 0
        for _ in range(n):
            v = yield Read(data)
            total += v
        yield Write(done, 1)
        yield Emit("sum_out", total)

    return prog


# ---------------------------------------------------------------------------
# fig4_ex3 — Type B: cyclic dependency over blocking FIFOs.
# ---------------------------------------------------------------------------
def fig4_ex3(n: int = N) -> Program:
    prog = Program("fig4_ex3", declared_type="B")
    cmd = prog.fifo("cmd", 2)
    resp = prog.fifo("resp", 2)

    @prog.module("controller")
    def controller():
        total = 0
        for i in range(n):
            yield Write(cmd, i)
            r = yield Read(resp)      # C-sim: empty -> warning x2025, r = 0
            total += r
        yield Emit("sum", total)

    @prog.module("processor")
    def processor():
        for _ in range(n):
            v = yield Read(cmd)
            yield Write(resp, 2 * v)

    return prog


# ---------------------------------------------------------------------------
# fig4_ex4a — Type C: silent drop when the FIFO is full (i++ regardless).
# ---------------------------------------------------------------------------
def fig4_ex4a(n: int = N) -> Program:
    prog = Program("fig4_ex4a", declared_type="C")
    data = prog.fifo("data", 2)

    @prog.module("producer")
    def producer():
        for i in range(1, n + 1):
            yield WriteNB(data, i)    # outcome ignored: dropped data is lost

    @prog.module("consumer")          # 3 cycles per element -> backpressure
    def consumer():
        total = 0
        for _ in range(n):
            ok, v = yield ReadNB(data)
            if ok:
                total += v
            yield Delay(2)
        yield Emit("sum_out", total)

    return prog


# ---------------------------------------------------------------------------
# fig4_ex4a_d — Type C: as ex4a but the producer runs an infinite loop
# terminated by a done signal (cyclic).  C-sim crashes (array overrun).
# ---------------------------------------------------------------------------
def fig4_ex4a_d(n: int = N) -> Program:
    prog = Program("fig4_ex4a_d", declared_type="C")
    data = prog.fifo("data", 2)
    done = prog.fifo("done", 1)
    input_arr = list(range(1, n + 1)) + [0] * (6 * n)

    @prog.module("producer")
    def producer():
        i = 0
        while True:
            ok, _ = yield ReadNB(done)
            if ok:
                break
            v = input_arr[i]          # overruns under C-sim -> SIGSEGV
            yield WriteNB(data, v)
            i += 1                    # silent drop: i++ even on failure

    @prog.module("consumer")
    def consumer():
        total = 0
        for _ in range(n):
            ok, v = yield ReadNB(data)
            if ok:
                total += v
            yield Delay(2)
        yield Write(done, 1)
        yield Emit("sum_out", total)

    return prog


# ---------------------------------------------------------------------------
# fig4_ex4b — Type C: if-else branch counts dropped elements explicitly.
# ---------------------------------------------------------------------------
def fig4_ex4b(n: int = N) -> Program:
    prog = Program("fig4_ex4b", declared_type="C")
    data = prog.fifo("data", 2)

    @prog.module("producer")
    def producer():
        dropped = 0
        for i in range(1, n + 1):
            ok = yield WriteNB(data, i)
            if not ok:
                dropped += 1
        yield Emit("Dropped", dropped)

    @prog.module("consumer")
    def consumer():
        total = 0
        for _ in range(n):
            ok, v = yield ReadNB(data)
            if ok:
                total += v
            yield Delay(2)
        yield Emit("sum_out", total)

    return prog


# ---------------------------------------------------------------------------
# fig4_ex4b_d — Type C: ex4b with done-signal termination (cyclic).
# ---------------------------------------------------------------------------
def fig4_ex4b_d(n: int = N) -> Program:
    prog = Program("fig4_ex4b_d", declared_type="C")
    data = prog.fifo("data", 2)
    done = prog.fifo("done", 1)
    input_arr = list(range(1, n + 1)) + [0] * (6 * n)

    @prog.module("producer")
    def producer():
        i = 0
        dropped = 0
        while True:
            ok, _ = yield ReadNB(done)
            if ok:
                break
            v = input_arr[i]
            ok = yield WriteNB(data, v)
            if ok:
                i += 1
            else:
                dropped += 1
                i += 1               # drop and move on
        yield Emit("Dropped", dropped)

    @prog.module("consumer")
    def consumer():
        total = 0
        for _ in range(n):
            ok, v = yield ReadNB(data)
            if ok:
                total += v
            yield Delay(2)
        yield Write(done, 1)
        yield Emit("sum_out", total)

    return prog


# ---------------------------------------------------------------------------
# fig4_ex5 — Type C: congestion-aware dispatch to the less-busy processor.
# ---------------------------------------------------------------------------
SENTINEL = -1


def fig4_ex5(n: int = N) -> Program:
    prog = Program("fig4_ex5", declared_type="C")
    f1 = prog.fifo("to_p1", 2)
    f2 = prog.fifo("to_p2", 2)

    @prog.module("controller")
    def controller():
        for i in range(1, n + 1):
            full1 = yield Full(f1)
            if not full1:
                yield Write(f1, i)   # preferred path
            else:
                yield Write(f2, i)   # overflow path (P2 is fast: no stall)
        yield Write(f1, SENTINEL)
        yield Write(f2, SENTINEL)

    @prog.module("P1")               # slow processor: 3 cycles per item
    def p1():
        count, total = 0, 0
        while True:
            v = yield Read(f1)
            if v == SENTINEL:
                break
            yield Delay(2)
            count += 1
            total += v
        yield Emit("processed_by_P1", count)
        yield Emit("sum_out_P1", total)

    @prog.module("P2")               # fast processor
    def p2():
        count, total = 0, 0
        while True:
            v = yield Read(f2)
            if v == SENTINEL:
                break
            count += 1
            total += v
        yield Emit("processed_by_P2", count)
        yield Emit("sum_out_P2", total)

    return prog


# ---------------------------------------------------------------------------
# fig2_timer — Type C: a timer module counts the cycles of a compute module.
# Calibrated so the timer reports exactly 3 cycles/item x 2025 items = 6075.
# ---------------------------------------------------------------------------
def fig2_timer(n: int = N) -> Program:
    prog = Program("fig2_timer", declared_type="C")
    result = prog.fifo("result", 4)
    done = prog.fifo("done", 1)

    @prog.module("sink")             # drains results (C-sim: reads empty x n)
    def sink():
        total = 0
        for _ in range(n):
            v = yield Read(result)
            total += v
        yield Emit("sink_sum", total)

    @prog.module("compute")          # 3 cycles per item: write + delay(2)
    def compute():
        yield Delay(1)               # schedule offset: item k commits at 3k-1
        for k in range(1, n + 1):
            yield Write(result, k)
            if k < n:
                yield Delay(2)
        yield Write(done, 1)         # committed at cycle 3n exactly

    @prog.module("timer")            # polls the done signal every cycle
    def timer():
        cycles = 0
        while True:
            ok, _ = yield ReadNB(done)
            if ok:
                break
            cycles += 1
        yield Emit("timer_cycles", cycles)

    return prog


# ---------------------------------------------------------------------------
# deadlock — Type B: two tasks blocking-read each other first.
# ---------------------------------------------------------------------------
def deadlock(n: int = N) -> Program:
    prog = Program("deadlock", declared_type="B")
    a2b = prog.fifo("a2b", 2)
    b2a = prog.fifo("b2a", 2)

    @prog.module("task_a")
    def task_a():
        total = 0
        for i in range(n):
            v = yield Read(b2a)      # waits for B ...
            total += v
            yield Write(a2b, i)
        yield Emit("sum", total)

    @prog.module("task_b")
    def task_b():
        total = 0
        for i in range(n):
            v = yield Read(a2b)      # ... while B waits for A
            total += v
            yield Write(b2a, i)
        yield Emit("sum_b", total)

    return prog


# ---------------------------------------------------------------------------
# branch — Type C: a downstream executor redirects the upstream fetcher.
# ---------------------------------------------------------------------------
def branch(prog_len: int = 1024, stride: int = 16) -> Program:
    prog = Program("branch", declared_type="C")
    instr = prog.fifo("instr", 4)
    redirect = prog.fifo("redirect", 2)

    @prog.module("fetcher")
    def fetcher():
        pc = 0
        fetched = 0
        while pc < prog_len:
            ok, target = yield ReadNB(redirect)
            if ok:
                pc = target           # squash wrong-path fetch stream
            yield Write(instr, pc)
            fetched += 1
            pc += 1
        yield Write(instr, SENTINEL)
        yield Emit("fetched", fetched)

    @prog.module("executor")
    def executor():
        expected = 0
        executed = 0
        while True:
            pc = yield Read(instr)
            if pc == SENTINEL:
                break
            if pc != expected:
                continue              # wrong-path instruction: discard
            executed += 1
            if pc % stride == 0:      # taken branch: jump ahead
                expected = pc + stride // 2
                yield WriteNB(redirect, expected)
            else:
                expected = pc + 1
        yield Emit("executed", executed)

    return prog


# ---------------------------------------------------------------------------
# multicore — Type C: 16 branch cores + dispatcher + collector
#             = 34 modules, 64 FIFOs (paper Table 4).
# ---------------------------------------------------------------------------
def multicore(cores: int = 16, prog_len: int = 128, stride: int = 8) -> Program:
    prog = Program("multicore", declared_type="C")
    work = [prog.fifo(f"work{c}", 2) for c in range(cores)]
    instr = [prog.fifo(f"instr{c}", 4) for c in range(cores)]
    redirect = [prog.fifo(f"redirect{c}", 2) for c in range(cores)]
    result = [prog.fifo(f"result{c}", 2) for c in range(cores)]

    @prog.module("dispatcher")
    def dispatcher():
        for c in range(cores):
            yield Write(work[c], prog_len + c * stride)

    def make_fetcher(c: int):
        def fetcher():
            limit = yield Read(work[c])
            pc = 0
            fetched = 0
            while pc < limit:
                ok, target = yield ReadNB(redirect[c])
                if ok:
                    pc = target
                yield Write(instr[c], pc)
                fetched += 1
                pc += 1
            yield Write(instr[c], SENTINEL)
            # fetched count travels through the instr FIFO so the result
            # FIFO keeps a single writer (SPSC, as synthesized hardware).
            yield Write(instr[c], fetched)
        return fetcher

    def make_executor(c: int):
        def executor():
            expected = 0
            executed = 0
            while True:
                pc = yield Read(instr[c])
                if pc == SENTINEL:
                    fetched = yield Read(instr[c])
                    break
                if pc != expected:
                    continue
                executed += 1
                if pc % stride == 0:
                    expected = pc + stride // 2
                    yield WriteNB(redirect[c], expected)
                else:
                    expected = pc + 1
            yield Write(result[c], fetched)
            yield Write(result[c], executed)
        return executor

    for c in range(cores):
        prog.add_module(f"fetcher{c}", make_fetcher(c))
        prog.add_module(f"executor{c}", make_executor(c))

    @prog.module("collector")
    def collector():
        total_fetched = 0
        total_executed = 0
        for c in range(cores):
            f = yield Read(result[c])
            e = yield Read(result[c])
            total_fetched += f
            total_executed += e
        yield Emit("total_fetched", total_fetched)
        yield Emit("total_executed", total_executed)

    return prog


PAPER_DESIGNS = {
    "fig4_ex2": fig4_ex2,
    "fig4_ex3": fig4_ex3,
    "fig4_ex4a": fig4_ex4a,
    "fig4_ex4a_d": fig4_ex4a_d,
    "fig4_ex4b": fig4_ex4b,
    "fig4_ex4b_d": fig4_ex4b_d,
    "fig4_ex5": fig4_ex5,
    "fig2_timer": fig2_timer,
    "deadlock": deadlock,
    "branch": branch,
    "multicore": multicore,
}
