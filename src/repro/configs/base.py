"""Architecture configuration schema.

One dataclass drives every model family in the zoo (dense / MoE / SSM /
hybrid / encoder-decoder / VLM- and audio-frontend LMs).  Exact public
configurations live in ``configs/<arch>.py``; reduced smoke variants are
derived with ``.smoke()``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden size
    num_shared_experts: int = 0
    d_shared: int = 0             # shared-expert hidden size
    router_aux_loss: float = 0.0
    impl: str = "dense"           # "dense" (masked) | "ep" (all-to-all)


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16           # per-channel state size (Mamba N)
    conv_kernel: int = 4
    expand: int = 2
    chunk: int = 256              # chunked-scan block length


@dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 8          # one sLSTM block per this many blocks
    mlstm_expand: int = 2
    conv_kernel: int = 4
    chunk: int = 256


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense|moe|ssm|hybrid|encdec|vlm|audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // num_heads
    # attention details
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    logit_softcap: float = 0.0            # gemma2: 30.0 final / 50.0 attn
    attn_softcap: float = 0.0
    sliding_window: int = 0               # 0 = disabled
    local_global_pattern: bool = False    # gemma2: alternate local/global
    post_norms: bool = False              # gemma2: sandwich (pre+post) norms
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    # family-specific
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # encoder-decoder (seamless-m4t): num_layers applies to each side
    encoder_layers: int = 0
    # frontends (vlm/audio): stub embeddings prepended to the token stream
    frontend_tokens: int = 0              # patches / frames per example
    # execution policy
    tp_degree: int = 16                   # 1 = pure DP (mesh 'model' axis
                                          # joins the data axes)
    kv_quant: bool = False                # int8 KV cache (per-row scales)
    dtype: str = "bfloat16"               # compute dtype
    param_dtype: str = "float32"
    remat: bool = True                    # activation checkpointing per layer
    scan_layers: bool = True              # scan over stacked layer params
    use_pallas: bool = False              # Pallas kernels (TPU target only)
    cost_analysis_mode: bool = False      # unrolled/direct paths: HLO cost
                                          # analysis counts scan bodies once,
                                          # so cost-extrapolation variants
                                          # avoid inner scans entirely
    # full attention? -> long_500k cell is skipped (needs sub-quadratic)
    subquadratic: bool = False
    # decode support (encoder-only archs would set False; all ours decode)
    supports_decode: bool = True

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def smoke(self) -> "ArchConfig":
        """Reduced config of the same family for CPU smoke tests."""
        changes = dict(
            num_layers=min(self.num_layers, 2),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads < self.num_heads else 4,
            d_ff=256,
            head_dim=32,
            vocab_size=512,
            frontend_tokens=min(self.frontend_tokens, 8),
            encoder_layers=min(self.encoder_layers, 2),
            dtype="float32",
            remat=False,
            scan_layers=self.scan_layers,
        )
        if self.moe is not None:
            changes["moe"] = dataclasses.replace(
                self.moe, num_experts=8, top_k=2, d_expert=64,
                d_shared=64 if self.moe.num_shared_experts else 0)
        if self.ssm is not None:
            changes["ssm"] = dataclasses.replace(self.ssm, state_dim=8,
                                                 chunk=16)
        if self.xlstm is not None:
            changes["xlstm"] = dataclasses.replace(self.xlstm, slstm_every=2,
                                                   chunk=16)
        return dataclasses.replace(self, **changes)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeCell:
    """One (input-shape) cell of the dry-run matrix."""
    name: str                     # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                     # "train" | "prefill" | "decode"


SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "decode"),
)


def shape_applicable(cfg: ArchConfig, cell: ShapeCell) -> Tuple[bool, str]:
    """Per-spec skip rules: long_500k only for sub-quadratic archs;
    decode shapes only for archs with a decode step."""
    if cell.name == "long_500k" and not cfg.subquadratic:
        return False, "full-attention arch: long_500k needs sub-quadratic attention (skip per spec)"
    if cell.kind == "decode" and not cfg.supports_decode:
        return False, "encoder-only arch has no decode step"
    return True, ""
