"""gemma2-2b — local+global alternating attention, logit softcaps.
[arXiv:2408.00118; hf]  26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-2b", family="dense",
    num_layers=26, d_model=2304, num_heads=8, num_kv_heads=4,
    d_ff=9216, vocab_size=256_000, head_dim=256,
    logit_softcap=30.0, attn_softcap=50.0,
    sliding_window=4096, local_global_pattern=True, post_norms=True,
    tie_embeddings=True,
    subquadratic=False,   # global layers are full attention -> skip long_500k
)
