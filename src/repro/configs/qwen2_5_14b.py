"""qwen2.5-14b — dense GQA with QKV bias.
[hf:Qwen/Qwen2.5-14B; hf]  48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-14b", family="dense",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=13824, vocab_size=152064, head_dim=128,
    rope_theta=1_000_000.0, qkv_bias=True, tie_embeddings=False,
    subquadratic=False,
)
