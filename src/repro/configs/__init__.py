"""Config system: architecture registry + shape cells."""
from .base import (ArchConfig, MoEConfig, SSMConfig, XLSTMConfig, ShapeCell,
                   SHAPES, shape_applicable)
from .registry import ARCHS, get_arch

__all__ = ["ArchConfig", "MoEConfig", "SSMConfig", "XLSTMConfig", "ShapeCell",
           "SHAPES", "shape_applicable", "ARCHS", "get_arch"]
