"""minicpm-2b — llama-like dense; trained with the WSD schedule (which our
optim/schedules.py implements as the default for this arch).
[arXiv:2404.06395; hf]  40L d_model=2304 36H (kv=36, i.e. MHA) d_ff=5760
vocab=122753.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b", family="dense",
    num_layers=40, d_model=2304, num_heads=36, num_kv_heads=36,
    d_ff=5760, vocab_size=122753, head_dim=64,
    tie_embeddings=True,
    subquadratic=False,
    # §Perf hillclimb: MHA (kv=36) at 32k context needs int8 KV to fit
    # 16 GB/chip (22.0 -> 11.0 GB measured); logit error < 5e-3.
    kv_quant=True,
)
