"""qwen3-moe-30b-a3b — 128-expert top-8 MoE.
[hf:Qwen/Qwen3-30B-A3B; hf]  48L d_model=2048 32H (GQA kv=4) d_ff=768(expert)
vocab=151936.
"""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4,
    d_ff=768, vocab_size=151936, head_dim=128,
    rope_theta=1_000_000.0, tie_embeddings=False,
    moe=MoEConfig(num_experts=128, top_k=8, d_expert=768, impl="ep"),
    subquadratic=False,
)
