"""internvl2-1b — InternViT frontend (stub) + InternLM2 backbone.
[arXiv:2404.16821; hf]  24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b", family="vlm",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    d_ff=4864, vocab_size=151655, head_dim=64,
    rope_theta=1_000_000.0, tie_embeddings=True,
    frontend_tokens=256,          # ViT patch embeddings provided by stub
    subquadratic=False,
)
