"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

from typing import Dict

from .base import ArchConfig
from .internvl2_1b import CONFIG as INTERNVL2_1B
from .qwen2_5_14b import CONFIG as QWEN2_5_14B
from .gemma2_2b import CONFIG as GEMMA2_2B
from .smollm_135m import CONFIG as SMOLLM_135M
from .minicpm_2b import CONFIG as MINICPM_2B
from .hymba_1_5b import CONFIG as HYMBA_1_5B
from .qwen3_moe_30b_a3b import CONFIG as QWEN3_MOE_30B_A3B
from .granite_moe_3b_a800m import CONFIG as GRANITE_MOE_3B_A800M
from .seamless_m4t_medium import CONFIG as SEAMLESS_M4T_MEDIUM
from .xlstm_1_3b import CONFIG as XLSTM_1_3B

ARCHS: Dict[str, ArchConfig] = {
    c.name: c for c in (
        INTERNVL2_1B, QWEN2_5_14B, GEMMA2_2B, SMOLLM_135M, MINICPM_2B,
        HYMBA_1_5B, QWEN3_MOE_30B_A3B, GRANITE_MOE_3B_A800M,
        SEAMLESS_M4T_MEDIUM, XLSTM_1_3B,
    )
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch '{name}'; available: {sorted(ARCHS)}")
    return ARCHS[name]
