"""smollm-135m — llama-architecture small model.
[hf:HuggingFaceTB/SmolLM-135M; hf]  30L d_model=576 9H (GQA kv=3) d_ff=1536
vocab=49152.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-135m", family="dense",
    num_layers=30, d_model=576, num_heads=9, num_kv_heads=3,
    d_ff=1536, vocab_size=49152, head_dim=64,
    tie_embeddings=True,
    subquadratic=False,
    # §Perf iteration F: at 135M params a 16-way TP slice is ~2 MB per
    # matrix — all-gather latency dominates.  Pure DP replicates the model
    # per chip and leaves only the gradient all-reduce.
    tp_degree=1,
)
