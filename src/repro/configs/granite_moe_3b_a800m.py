"""granite-moe-3b-a800m — 40-expert top-8 MoE.
[hf:ibm-granite/granite-3.0-3b-a800m-base; hf]  32L d_model=1536 24H (kv=8)
d_ff=512(expert) vocab=49155.
"""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m", family="moe",
    num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8,
    d_ff=512, vocab_size=49155, head_dim=64,
    tie_embeddings=True,
    moe=MoEConfig(num_experts=40, top_k=8, d_expert=512, impl="ep"),
    subquadratic=False,
)
