"""hymba-1.5b — hybrid: parallel attention + Mamba heads in every block.
[arXiv:2411.13676; hf]  32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
ssm_state=16.
"""
from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
    d_ff=5504, vocab_size=32001, head_dim=64,
    ssm=SSMConfig(state_dim=16, conv_kernel=4, expand=2, chunk=256),
    sliding_window=1024,          # hymba: most layers use SWA + meta tokens
    tie_embeddings=True,
    subquadratic=True,            # SSM path carries long-range state
)
