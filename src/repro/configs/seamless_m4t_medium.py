"""seamless-m4t-medium — encoder-decoder, multimodal (audio frontend stub).
[arXiv:2308.11596; hf]  12L(enc)+12L(dec) d_model=1024 16H d_ff=4096
vocab=256206.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="audio",
    num_layers=12, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab_size=256206, head_dim=64,
    encoder_layers=12,
    frontend_tokens=512,          # speech frame embeddings from the stub
    tie_embeddings=True,
    subquadratic=False,
)
