"""xlstm-1.3b — sLSTM + mLSTM blocks (xLSTM[7:1]); d_ff=0: the up-projection
lives inside the mLSTM/sLSTM blocks.
[arXiv:2405.04517; unverified]  48L d_model=2048 4H vocab=50304.
"""
from .base import ArchConfig, XLSTMConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304, head_dim=512,
    xlstm=XLSTMConfig(slstm_every=8, mlstm_expand=2, conv_kernel=4, chunk=256),
    tie_embeddings=True,
    subquadratic=True,            # recurrent state: O(1) per decode step
)
