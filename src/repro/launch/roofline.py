"""Roofline-term derivation from compiled dry-run artifacts.

Per (arch x shape x mesh) cell we derive the three terms (seconds/step):

    compute    = HLO_FLOPs / (chips x 197e12 bf16 FLOP/s)
    memory     = HLO_bytes / (chips x 819e9 B/s HBM)
    collective = collective_bytes / (chips x 50e9 B/s ICI per link)

**Scan-body caveat** (measured, see EXPERIMENTS.md §Dry-run): XLA's
HloCostAnalysis counts a while-loop body ONCE, so a depth-L scanned model
under-reports by ~L.  We therefore lower each cell at two shallow depths
(L1 < L2, same shapes otherwise), take the per-layer delta, and extrapolate:

    total(L) = cost(L1) + (L - L1) / (L2 - L1) * (cost(L2) - cost(L1))

The same extrapolation applies to per-device collective bytes parsed from
the partitioned HLO.  Residual inner time-scans (sLSTM steps, SSD chunk
carries) are small and noted per-arch.  MODEL_FLOPS uses the 6·N·D
convention (6·N_active·D for MoE) plus exact attention terms.
"""
from __future__ import annotations

import dataclasses
import re
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax

from ..configs.base import ArchConfig, ShapeCell

# TPU v5e-class hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"^\s*%?\S*\s*=\s*\(?([a-z0-9]+)\[([\d,]*)\]"          # result type
    r".*?\b(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)", re.M)


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum per-device payload bytes of every collective op in the HLO.

    Sizes in the partitioned module are already per-partition.  We count the
    result buffer of each collective (a good proxy for link payload; for
    all-reduce the payload equals the buffer size per ring pass).
    """
    out: Dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        dt, dims, kind = m.group(1), m.group(2), m.group(3)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        out[kind] = out.get(kind, 0.0) + n * _DTYPE_BYTES[dt]
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


@dataclass
class CellCost:
    """Raw per-device costs of one compiled executable."""
    flops: float
    bytes_accessed: float
    coll_bytes: float
    coll_breakdown: Dict[str, float]
    temp_bytes: float = 0.0
    arg_bytes: float = 0.0


def cost_of(compiled) -> CellCost:
    ca = compiled.cost_analysis()
    txt = compiled.as_text()
    coll = collective_bytes(txt)
    ma = compiled.memory_analysis()
    return CellCost(
        flops=float(ca.get("flops", 0.0)),
        bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        coll_bytes=coll["total"],
        coll_breakdown=coll,
        temp_bytes=float(ma.temp_size_in_bytes),
        arg_bytes=float(ma.argument_size_in_bytes),
    )


def extrapolate(c1: CellCost, c2: CellCost, L1: int, L2: int,
                L) -> CellCost:
    """Linear depth extrapolation (scan bodies counted once — see module
    docstring).  Per-layer deltas are clamped >= 0: XLA occasionally
    optimizes the deeper shallow variant harder, and a negative per-layer
    cost is physically meaningless."""
    def ex(a, b):
        return a + (L - L1) / (L2 - L1) * max(b - a, 0.0)

    return CellCost(
        flops=ex(c1.flops, c2.flops),
        bytes_accessed=ex(c1.bytes_accessed, c2.bytes_accessed),
        coll_bytes=ex(c1.coll_bytes, c2.coll_bytes),
        coll_breakdown={k: ex(c1.coll_breakdown.get(k, 0.0),
                              c2.coll_breakdown.get(k, 0.0))
                        for k in set(c1.coll_breakdown) | set(c2.coll_breakdown)},
    )


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops: float            # cluster-wide (per-device x chips)
    useful_ratio: float         # MODEL_FLOPS / HLO_FLOPS
    roofline_fraction: float    # max-term share vs sum (intensity proxy)

    def row(self):
        return (f"{self.compute_s*1e3:9.2f} {self.memory_s*1e3:9.2f} "
                f"{self.collective_s*1e3:9.2f}  {self.dominant:10s} "
                f"{self.useful_ratio:6.2f}")


def roofline_terms(cost: CellCost, chips: int, model_flops: float) -> Roofline:
    compute = cost.flops / PEAK_FLOPS          # per-device flops / per-chip peak
    memory = cost.bytes_accessed / HBM_BW
    coll = cost.coll_bytes / ICI_BW
    terms = {"compute": compute, "memory": memory, "collective": coll}
    dominant = max(terms, key=terms.get)
    hlo_cluster = cost.flops * chips
    useful = model_flops / hlo_cluster if hlo_cluster else 0.0
    total = compute + memory + coll
    frac = terms[dominant] / total if total else 0.0
    return Roofline(compute, memory, coll, dominant, model_flops,
                    hlo_cluster, useful, frac)


def chunk_scan_corrections(cfg: ArchConfig, cell: ShapeCell,
                           chips: int) -> Dict[str, float]:
    """Analytic per-device corrections for inner chunk scans whose bodies
    HLO cost analysis counts once (attention query-block scan, fused-CE
    chunk scan).  Each correction adds the missing (nQ - 1)/nQ share of the
    scan's analytic FLOPs/bytes."""
    from ..models.attention import QCHUNK
    from ..models.lm import CE_CHUNK
    from ..models.common import padded_vocab
    S, B = cell.seq_len, cell.global_batch
    out = {"flops": 0.0, "bytes": 0.0}
    if cell.kind == "decode":
        return out                      # decode has no inner chunk scans
    hd = cfg.resolved_head_dim
    train = cell.kind == "train"
    fb = 3.0 if train else 1.0          # fwd+bwd multiplier
    remat = 2.0 if (train and cfg.remat) else 1.0   # chunk body checkpointed
    # attention scores+probs: 4 * H * hd * S^2/2 per example per layer (fwd)
    if S > QCHUNK and S % QCHUNK == 0 and cfg.family != "ssm":
        nq = S // QCHUNK
        layers = cfg.num_layers + (cfg.encoder_layers if cfg.family == "audio" else 0)
        attn = 4.0 * layers * cfg.num_heads * hd * (S * S / 2) * B
        attn = attn * (fb if not train else (fb + (remat - 1)))
        out["flops"] += attn / chips * (1 - 1.0 / nq)
        # score traffic (bf16 write+read) — an HBM upper bound
        out["bytes"] += (2 * 2 * layers * cfg.num_heads * (S * S / 2) * B
                         / chips * (1 - 1.0 / nq))
    # fused-CE chunk scan (train only)
    if train and S > CE_CHUNK and S % CE_CHUNK == 0:
        nce = S // CE_CHUNK
        Vp = padded_vocab(cfg.vocab_size)
        ce = 2.0 * B * S * cfg.d_model * Vp * (fb + (remat - 1))
        out["flops"] += ce / chips * (1 - 1.0 / nce)
        out["bytes"] += 2 * B * S * Vp * 4 / chips * (1 - 1.0 / nce)
    return out


# ------------------------------------------------------------- model FLOPs
def param_count(cfg: ArchConfig, active_only: bool = False) -> float:
    """Analytic parameter count (embedding excluded from the 6ND count)."""
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    attn = d * cfg.num_heads * hd * 2 + d * cfg.num_kv_heads * hd * 2
    if cfg.family == "moe":
        mo = cfg.moe
        e = mo.top_k if active_only else mo.num_experts
        ffn = 3 * d * mo.d_expert * e
        block = attn + ffn
        n = block * cfg.num_layers
    elif cfg.family == "ssm":
        xc = cfg.xlstm
        di = xc.mlstm_expand * d
        mlstm = d * 2 * di + 2 * di * di + di * 2 * cfg.num_heads + di * d
        slstm = 4 * d * d + d * d
        G = cfg.num_layers // xc.slstm_every
        M = xc.slstm_every - 1
        n = G * (M * mlstm + slstm)
    else:
        ffn = 3 * d * cfg.d_ff
        block = attn + ffn
        if cfg.family == "hybrid":
            ssm = cfg.ssm
            di = ssm.expand * d
            block += d * 2 * di + di * (2 * ssm.state_dim) + di * d
        n = block * cfg.num_layers
        if cfg.family == "audio":
            # encoder layers + decoder cross-attention
            n += cfg.encoder_layers * (attn + ffn) + cfg.num_layers * attn
    return float(n)


def model_flops(cfg: ArchConfig, cell: ShapeCell) -> float:
    """6·N·D (train) / 2·N·D (inference) + exact attention-score terms."""
    N = param_count(cfg, active_only=True)
    S = cell.seq_len
    B = cell.global_batch
    hd = cfg.resolved_head_dim
    if cell.kind == "train":
        tokens = B * S
        base = 6.0 * N * tokens
        attn_sc = 12.0 * cfg.num_layers * cfg.num_heads * hd * S * S / 2 * B
        return base + attn_sc
    if cell.kind == "prefill":
        tokens = B * S
        base = 2.0 * N * tokens
        attn_sc = 4.0 * cfg.num_layers * cfg.num_heads * hd * S * S / 2 * B
        return base + attn_sc
    # decode: one token, attention over the cache
    base = 2.0 * N * B
    attn_sc = 4.0 * cfg.num_layers * cfg.num_heads * hd * S * B
    if cfg.family == "ssm":
        attn_sc = 0.0
    return base + attn_sc
