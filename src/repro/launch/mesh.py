"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — required because the
dry-run forces 512 host devices via XLA_FLAGS before first jax init, while
smoke tests must see the single real CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh over the real local device(s) for smoke runs."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
