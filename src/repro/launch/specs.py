"""ShapeDtypeStruct stand-ins + shardings for every (arch x shape) cell.

``input_specs`` returns (args, in_shardings, fn) for the cell's entry point:
train_4k lowers ``train_step``; prefill_32k lowers ``prefill_step``;
decode_32k / long_500k lower ``decode_step`` (one new token against a full
KV/state cache of the cell's seq_len) — never train_step, per the spec.

No device memory is allocated: params/opt/cache structs come from
``jax.eval_shape`` over the real init functions, so the dry-run exercises
exactly the shapes the real system would.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, ShapeCell
from ..distrib.sharding import (batch_spec, cache_spec, dp_axes, param_specs,
                                set_tp_degree, _path_names)
from ..models import api
from ..optim.adamw import init_adamw
from ..train.step import make_decode_step, make_prefill_step, make_train_step


def params_struct(cfg: ArchConfig):
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(functools.partial(api.init_params, cfg=cfg), key)


def opt_struct(params):
    return jax.eval_shape(init_adamw, params)


def batch_struct(cfg: ArchConfig, cell: ShapeCell, with_targets: bool):
    B, S = cell.global_batch, cell.seq_len
    S_tok = S - cfg.frontend_tokens if cfg.family == "vlm" else S
    batch = {"tokens": jax.ShapeDtypeStruct((B, S_tok), jnp.int32)}
    if with_targets:
        batch["targets"] = jax.ShapeDtypeStruct((B, S_tok), jnp.int32)
    if cfg.frontend_tokens:
        batch["frontend"] = jax.ShapeDtypeStruct(
            (B, cfg.frontend_tokens, cfg.d_model), jnp.float32)
    return batch


def _ns(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


def batch_shardings(mesh: Mesh, batch):
    return {k: NamedSharding(mesh, batch_spec(mesh, v.ndim,
                                              batch_size=v.shape[0]))
            for k, v in batch.items()}


def cache_struct_and_sharding(cfg: ArchConfig, cell: ShapeCell, mesh: Mesh):
    B = cell.global_batch
    struct = jax.eval_shape(
        lambda: api.init_cache(cfg, B, max_len=cell.seq_len))
    batch_one = B == 1
    specs = jax.tree_util.tree_map_with_path(
        lambda path, leaf: cache_spec(mesh, _path_names(path), leaf.ndim,
                                      batch_one=batch_one),
        struct)
    return struct, _ns(mesh, specs)


def input_specs(cfg: ArchConfig, cell: ShapeCell, mesh: Mesh):
    """Returns (fn, args, in_shardings, out_shardings, donate_argnums)."""
    # pure-DP policy applies to training cells; serving keeps TP so the
    # KV cache / vocab stay sharded over 'model'.
    tp = getattr(cfg, "tp_degree", 16)
    set_tp_degree(1 if (tp == 1 and cell.kind == "train") else 16)
    pstruct = params_struct(cfg)
    pspecs = param_specs(pstruct)
    psh = _ns(mesh, pspecs)
    repl = NamedSharding(mesh, P())

    if cell.kind == "train":
        fn = make_train_step(cfg)
        ostruct = opt_struct(pstruct)
        osh = _ns(mesh, param_specs(ostruct))
        batch = batch_struct(cfg, cell, with_targets=True)
        bsh = batch_shardings(mesh, batch)
        metrics_sh = {"loss": repl, "grad_norm": repl, "lr": repl}
        # donate params+opt: the update is in-place on real hardware
        return (fn, (pstruct, ostruct, batch), (psh, osh, bsh),
                (psh, osh, metrics_sh), (0, 1))

    if cell.kind == "prefill":
        fn = make_prefill_step(cfg)
        batch = batch_struct(cfg, cell, with_targets=False)
        bsh = batch_shardings(mesh, batch)
        vocab_axis = None if getattr(cfg, "tp_degree", 16) == 1 else "model"
        out_sh = NamedSharding(mesh, P(dp_axes(mesh) or None, vocab_axis))
        return fn, (pstruct, batch), (psh, bsh), out_sh, ()

    # decode: one new token against a seq_len-deep cache
    fn = make_decode_step(cfg)
    B = cell.global_batch
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tok_sh = NamedSharding(mesh, batch_spec(mesh, 2, shard_batch=B > 1,
                                            batch_size=B))
    cstruct, csh = cache_struct_and_sharding(cfg, cell, mesh)
    # donate the cache: decode updates it in place
    return (fn, (pstruct, tokens, cstruct), (psh, tok_sh, csh),
            (tok_sh, csh), (2,))
