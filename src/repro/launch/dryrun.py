import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production meshes, prove memory fits, and extract roofline terms.

The two lines above MUST run before any other import (jax locks the device
count at first init); do not move them.

Usage:
    python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
    python -m repro.launch.dryrun --all --mesh both --out reports/dryrun
    python -m repro.launch.dryrun --all --mesh pod --roofline

Per cell this produces: compile status, memory_analysis (bytes/device —
"proves it fits"), cost_analysis FLOPs/bytes, the HLO collective schedule,
and depth-extrapolated roofline terms (see launch/roofline.py for why
extrapolation is needed: scan bodies are cost-counted once).

(No ``from __future__ import annotations`` here: the XLA_FLAGS lines must be
the first statements in the file.)
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Dict, Optional

import jax

from ..configs import ARCHS, SHAPES, get_arch, shape_applicable
from ..configs.base import ArchConfig, ShapeCell
from ..distrib.sharding import set_active_mesh
from .mesh import make_production_mesh
from .roofline import (CellCost, chunk_scan_corrections, cost_of,
                       extrapolate, model_flops, roofline_terms)
from .specs import input_specs

HBM_PER_CHIP = 16e9          # v5e


def _depth_variant(cfg: ArchConfig, layers: int) -> ArchConfig:
    # cost variants unroll the LAYER scan so per-layer deltas are exact;
    # inner chunk scans (attention / CE) keep the real dataflow — their
    # once-counted bodies are corrected analytically in roofline.py.
    kw = dict(scan_layers=False)
    if cfg.family == "ssm":
        return cfg.replace(num_layers=2 * layers,
                           xlstm=dataclasses.replace(cfg.xlstm, slstm_every=2),
                           **kw)
    if cfg.family == "audio":
        return cfg.replace(num_layers=layers, encoder_layers=layers, **kw)
    return cfg.replace(num_layers=layers, **kw)


def _compile(cfg: ArchConfig, cell: ShapeCell, mesh):
    set_active_mesh(mesh)
    fn, args, in_sh, out_sh, donate = input_specs(cfg, cell, mesh)
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=donate).lower(*args)
        compiled = lowered.compile()
    return lowered, compiled


def run_cell(arch: str, shape: str, multi_pod: bool,
             with_roofline: bool = True, cfg_overrides: Optional[Dict] = None
             ) -> Dict:
    cfg = get_arch(arch)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    cell = next(c for c in SHAPES if c.name == shape)
    ok, reason = shape_applicable(cfg, cell)
    rec: Dict = {"arch": arch, "shape": shape,
                 "mesh": "2x16x16" if multi_pod else "16x16"}
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    try:
        _, compiled = _compile(cfg, cell, mesh)
    except Exception as e:          # a dry-run failure is a bug in the system
        rec.update(status="FAILED", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        return rec
    compile_s = time.time() - t0
    ma = compiled.memory_analysis()
    full_cost = cost_of(compiled)
    per_dev = ma.temp_size_in_bytes + ma.argument_size_in_bytes \
        + ma.output_size_in_bytes - ma.alias_size_in_bytes
    rec.update(
        status="ok", chips=chips, compile_s=round(compile_s, 1),
        memory={
            "temp_gb": ma.temp_size_in_bytes / 1e9,
            "args_gb": ma.argument_size_in_bytes / 1e9,
            "out_gb": ma.output_size_in_bytes / 1e9,
            "aliased_gb": ma.alias_size_in_bytes / 1e9,
            "per_device_gb": per_dev / 1e9,
            "fits_16gb_hbm": bool(per_dev <= HBM_PER_CHIP),
        },
        raw_cost={"flops": full_cost.flops,
                  "bytes_accessed": full_cost.bytes_accessed,
                  "collective_bytes": full_cost.coll_bytes,
                  "collectives": full_cost.coll_breakdown},
    )

    if with_roofline and not multi_pod:
        # depth-extrapolated costs (scan bodies counted once in HLO cost)
        period = 2 if cfg.local_global_pattern else 1
        L1, L2 = period, 2 * period
        L = cfg.num_layers
        try:
            _, comp1 = _compile(_depth_variant(cfg, L1), cell, mesh)
            _, comp2 = _compile(_depth_variant(cfg, L2), cell, mesh)
            c1, c2 = cost_of(comp1), cost_of(comp2)
            if cfg.family == "ssm":
                # variants have G=L1,L2 groups of (1 mLSTM + 1 sLSTM); the
                # full model has G groups of (M mLSTM + 1 sLSTM).  One extra
                # group-unit costs (m + s); convert the full model to
                # equivalent group-units using the analytic mLSTM share.
                G = L // cfg.xlstm.slstm_every
                M = cfg.xlstm.slstm_every - 1
                d = cfg.d_model
                di = cfg.xlstm.mlstm_expand * d
                f_m = 2 * d * di + 2 * di * di + di * d   # mLSTM params
                f_s = 5 * d * d                           # sLSTM params
                share = f_m / (f_m + f_s)
                L_eff = G * (M * share + (1 - share))
                cost = extrapolate(c1, c2, L1, L2, L_eff)
            else:
                cost = extrapolate(c1, c2, L1, L2, cfg.num_layers)
        except Exception as e:
            rec["roofline_error"] = f"{type(e).__name__}: {e}"
            cost = full_cost
        mf = model_flops(cfg, cell)
        corr = chunk_scan_corrections(cfg, cell, chips)
        cost.flops += corr["flops"]
        cost.bytes_accessed += corr["bytes"]
        roof = roofline_terms(cost, chips, mf)
        rec["chunk_scan_correction"] = corr
        rec["roofline"] = {
            "compute_s": roof.compute_s,
            "memory_s": roof.memory_s,
            "collective_s": roof.collective_s,
            "dominant": roof.dominant,
            "model_flops": mf,
            "hlo_flops_cluster": roof.hlo_flops,
            "useful_ratio": roof.useful_ratio,
            "dominant_fraction": roof.roofline_fraction,
            "collectives": cost.coll_breakdown,
        }
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="both")
    ap.add_argument("--out", default="reports/dryrun")
    ap.add_argument("--no-roofline", action="store_true")
    args = ap.parse_args()

    archs = sorted(ARCHS) if args.all or not args.arch else [args.arch]
    shapes = [c.name for c in SHAPES] if args.all or not args.shape \
        else [args.shape]
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'mp' if mp else 'sp'}"
                rec = run_cell(arch, shape, mp,
                               with_roofline=not args.no_roofline)
                path = os.path.join(args.out, tag + ".json")
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                status = rec["status"]
                if status == "FAILED":
                    failures += 1
                mem = rec.get("memory", {})
                roof = rec.get("roofline", {})
                print(f"{tag:55s} {status:8s} "
                      f"mem={mem.get('per_device_gb', 0):6.2f}GB "
                      f"dom={roof.get('dominant', '-'):10s} "
                      f"compile={rec.get('compile_s', 0):5.1f}s",
                      flush=True)
                if status == "FAILED":
                    print("   ", rec.get("error"), flush=True)
    print(f"\n{'PASS' if failures == 0 else 'FAIL'}: {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
