"""Serving launcher: batched prefill + decode with a paged-ish KV cache.

``python -m repro.launch.serve --arch smollm-135m --smoke`` runs a small
batched-generation demo on the host: requests arrive in a queue, are
prefilled in batches, then decode in lockstep with per-slot stopping.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_arch
from ..distrib.sharding import set_active_mesh
from ..models import api
from ..serve.engine import ServeEngine
from .mesh import make_host_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=24)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    mesh = make_host_mesh()
    set_active_mesh(None)        # host demo: no sharding constraints

    key = jax.random.PRNGKey(args.seed)
    params = api.init_params(key, cfg)
    engine = ServeEngine(cfg, params, batch=args.batch, max_len=args.max_len)

    prompts = np.random.default_rng(args.seed).integers(
        0, cfg.vocab_size, size=(args.batch, args.prompt_len))
    t0 = time.time()
    out = engine.generate(prompts, gen_len=args.gen_len)
    dt = time.time() - t0
    toks = args.batch * args.gen_len
    print(f"generated {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s batch={args.batch})")
    print("sample continuation token ids:", out[0, :12].tolist())


if __name__ == "__main__":
    main()
