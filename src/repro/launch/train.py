"""Training launcher: ``python -m repro.launch.train --arch smollm-135m``.

Production behaviors, all exercisable on one host:
  * elastic mesh construction (distrib/elastic.py) — uses every device the
    runtime exposes, shrinking the 'data' axis on degraded fleets;
  * auto-restart: resumes from the latest complete checkpoint (atomic,
    versioned) including the data-iterator state;
  * straggler monitor hooks (per-step wall time EWMA);
  * optional int8 error-feedback gradient compression.

For CPU-host experimentation use ``--smoke`` (reduced config, tiny mesh).
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np

from ..configs import get_arch
from ..data.pipeline import DataConfig, SyntheticTokenStream
from ..distrib.checkpoint import CheckpointManager
from ..distrib.elastic import StragglerMonitor, make_elastic_mesh
from ..distrib.sharding import (batch_spec, param_specs, set_active_mesh,
                                shardings_for)
from ..models import api
from ..optim.adamw import init_adamw
from ..train.step import make_train_step
from .mesh import make_host_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config for CPU hosts")
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--grad-compression", action="store_true",
                    help="int8 error-feedback gradient compression (DP "
                         "bandwidth reduction demo)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    mesh = make_host_mesh() if args.smoke or len(jax.devices()) < 16 \
        else make_elastic_mesh()
    set_active_mesh(mesh)
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    data = SyntheticTokenStream(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=args.seed,
        frontend_tokens=cfg.frontend_tokens, d_model=cfg.d_model))

    ckpt = CheckpointManager(os.path.join(args.ckpt_dir, cfg.name))
    key = jax.random.PRNGKey(args.seed)
    params = api.init_params(key, cfg)
    opt_state = init_adamw(params)
    start_step = 0
    latest = ckpt.latest()
    if latest is not None:
        params, opt_state, extra = ckpt.restore(latest, params, opt_state)
        data.restore(extra["data"])
        start_step = latest
        print(f"restored checkpoint step {latest}")

    psh = shardings_for(mesh, param_specs(params))
    params = jax.device_put(params, psh)
    opt_state = jax.device_put(opt_state, shardings_for(
        mesh, param_specs(opt_state)))

    step_fn = jax.jit(make_train_step(
        cfg, total_steps=args.steps, peak_lr=args.lr,
        grad_compression=args.grad_compression), donate_argnums=(0, 1))
    monitor = StragglerMonitor()

    from jax.sharding import NamedSharding
    bsh = {k: NamedSharding(mesh, batch_spec(mesh, v.ndim))
           for k, v in data.next_batch().items()}
    data.restore({"step": start_step, "seed": args.seed, "host_id": 0})

    t_start = time.time()
    for step in range(start_step, args.steps):
        host_batch = data.next_batch()
        batch = {k: jax.device_put(v, bsh[k]) for k, v in host_batch.items()}
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        metrics = jax.device_get(metrics)
        dt = time.time() - t0
        monitor.record(0, dt)
        if (step + 1) % args.log_every == 0:
            print(f"step {step+1:6d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} {dt*1e3:.0f}ms", flush=True)
        if (step + 1) % args.ckpt_every == 0 or step + 1 == args.steps:
            path = ckpt.save(step + 1, params, opt_state,
                             extra={"data": data.state()})
            print(f"checkpoint -> {path}")
        if monitor.stragglers():
            print("straggler detected; in production this host is evicted "
                  "and the elastic re-mesh path rebalances the fleet")
    total = time.time() - t_start
    print(f"done: {args.steps - start_step} steps in {total:.1f}s")


if __name__ == "__main__":
    main()
