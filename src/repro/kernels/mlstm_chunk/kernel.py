"""Pallas TPU kernel: chunked mLSTM / SSD recurrence.

One grid step processes one (batch*head, chunk) tile entirely in VMEM:

    y[t] = sum_{s<=t} exp(cum[t]-cum[s]) * ig[s] * (q[t].k[s]) * v[s]
           + exp(cum[t]) * q[t] @ state_carry

The [c, c] decay-masked score tile is MXU-shaped; the matrix state carry
[P, Pv] lives in VMEM scratch and persists across the chunk axis of the grid
(TPU grids iterate sequentially — the chunk axis is declared "arbitrary").
This is the same chunk dataflow as models/ssm.ssd_scan / models/xlstm, i.e.
the TPU-native replacement for the CUDA selective-scan kernel (DESIGN.md
hardware-adaptation notes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mlstm_kernel(q_ref, k_ref, v_ref, ig_ref, la_ref, o_ref, state_scr, *,
                  chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    q = q_ref[0].astype(jnp.float32)            # [c, P]
    k = k_ref[0].astype(jnp.float32)            # [c, P]
    v = v_ref[0].astype(jnp.float32)            # [c, Pv]
    ig = ig_ref[0].astype(jnp.float32)          # [1, c]
    la = la_ref[0].astype(jnp.float32)          # [1, c]

    cum = jnp.cumsum(la, axis=1)                # [1, c]
    # decay-masked scores: L[t, s] = exp(cum[t] - cum[s]) for s <= t
    diff = cum[0][:, None] - cum[0][None, :]    # [c, c]
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(s_idx <= t_idx, jnp.exp(diff), 0.0)
    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [c, c]
    scores = scores * L
    iv = ig[0][:, None] * v                     # [c, Pv]
    y_local = jax.lax.dot_general(scores, iv, (((1,), (0,)), ((), ())))

    # carry contribution: exp(cum[t]) * q[t] @ state
    carry = jax.lax.dot_general(q, state_scr[...],
                                (((1,), (0,)), ((), ())))        # [c, Pv]
    y = y_local + jnp.exp(cum[0])[:, None] * carry
    o_ref[0] = y.astype(o_ref.dtype)

    # state update: state' = exp(cum[-1]) * state
    #               + sum_s exp(cum[-1]-cum[s]) k[s] (ig[s] v[s])^T
    # (iv already carries the input gate — do not re-apply it to k)
    decay_to_end = jnp.exp(cum[0][-1] - cum[0])                  # [c]
    kw = k * decay_to_end[:, None]                               # [c, P]
    state_scr[...] = state_scr[...] * jnp.exp(cum[0][-1]) \
        + jax.lax.dot_general(kw, iv, (((0,), (0,)), ((), ())))  # [P, Pv]


def mlstm_chunk_bhsd(q, k, v, ig, la, *, chunk: int = 128,
                     interpret: bool = False):
    """q, k: [BH, S, P]; v: [BH, S, Pv]; ig, la: [BH, S].  Returns
    [BH, S, Pv].  The chunk axis is sequential per BH row (state carry)."""
    BH, S, P = q.shape
    Pv = v.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nC = S // chunk
    grid = (BH, nC)
    ig2 = ig.reshape(BH, 1, S)
    la2 = la.reshape(BH, 1, S)

    kernel = functools.partial(_mlstm_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, Pv), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, c: (b, 0, c)),
            pl.BlockSpec((1, 1, chunk), lambda b, c: (b, 0, c)),
        ],
        out_specs=pl.BlockSpec((1, chunk, Pv), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, Pv), q.dtype),
        scratch_shapes=[pltpu.VMEM((P, Pv), jnp.float32)],
        interpret=interpret,
    )(q, k, v, ig2, la2)
