"""Pure-jnp oracle for the chunked mLSTM kernel: direct O(S^2) recurrence."""
from __future__ import annotations

import jax.numpy as jnp


def mlstm_ref(q, k, v, ig, la):
    """q, k: [BH, S, P]; v: [BH, S, Pv]; ig, la: [BH, S].

    y[t] = sum_{s<=t} exp(cum[t] - cum[s]) ig[s] (q[t].k[s]) v[s]
    """
    BH, S, P = q.shape
    cum = jnp.cumsum(la, axis=1)                                # [BH, S]
    diff = cum[:, :, None] - cum[:, None, :]                    # [BH, S, S]
    causal = jnp.tril(jnp.ones((S, S), bool))
    L = jnp.where(causal[None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("btp,bsp->bts", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * L
    iv = ig[..., None] * v.astype(jnp.float32)
    return jnp.einsum("bts,bsp->btp", scores, iv).astype(q.dtype)
