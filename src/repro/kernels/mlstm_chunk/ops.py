"""Jitted wrapper for the chunked mLSTM kernel (model-facing API)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import mlstm_chunk_bhsd


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_chunk(q, k, v, ig, la, *, chunk: int = 128, interpret: bool = True):
    """q, k: [B, S, H, P]; v: [B, S, H, Pv]; ig, la: [B, S, H].

    Returns [B, S, H, Pv].  interpret=True is the CPU-validation mode.
    """
    B, S, H, P = q.shape
    Pv = v.shape[-1]
    qb = q.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    kb = k.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    vb = v.transpose(0, 2, 1, 3).reshape(B * H, S, Pv)
    igb = ig.transpose(0, 2, 1).reshape(B * H, S)
    lab = la.transpose(0, 2, 1).reshape(B * H, S)
    out = mlstm_chunk_bhsd(qb, kb, vb, igb, lab, chunk=min(chunk, S),
                           interpret=interpret)
    return out.reshape(B, H, S, Pv).transpose(0, 2, 1, 3)
