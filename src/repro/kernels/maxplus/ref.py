"""Pure-jnp oracle for the max-plus longest-path kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import NEG


def maxplus_sweep_ref(a: jnp.ndarray, t: jnp.ndarray,
                      base: jnp.ndarray) -> jnp.ndarray:
    """t'[i] = max(base[i], max_j (a[i, j] + t[j]))."""
    cand = jnp.max(a + t[None, :], axis=1)
    return jnp.maximum(base, cand)


def longest_path_ref(a: jnp.ndarray, base: jnp.ndarray,
                     iters: int) -> jnp.ndarray:
    def body(_, t):
        return maxplus_sweep_ref(a, t, base)

    return jax.lax.fori_loop(0, iters, body, base)
