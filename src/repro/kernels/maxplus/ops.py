"""Jitted wrapper: fixpoint longest path over dense max-plus tiles.

``longest_path(A, base)`` iterates blocked relaxation sweeps until the time
vector stops changing (bounded by the graph diameter, itself <= N).  Used by
the OmniSim engine for device-resident incremental re-finalization of
simulation graphs that fit the dense representation (graph.to_dense_blocks
pads to the 128 tile size).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import BLK, NEG, maxplus_sweep
from .ref import maxplus_sweep_ref


@functools.partial(jax.jit, static_argnames=("max_iters", "use_pallas",
                                             "interpret"))
def longest_path(a: jnp.ndarray, base: jnp.ndarray, *, max_iters: int = 0,
                 use_pallas: bool = True, interpret: bool = True):
    """Fixpoint t = max(base, A (+) t).  a: [N, N] int32; base: [N] int32.

    ``interpret=True`` (default) executes the Pallas kernel body in Python —
    the CPU-validation mode; on real TPU pass interpret=False.
    """
    n = a.shape[0]
    assert n % BLK == 0
    iters = max_iters or n

    def sweep(t):
        if use_pallas:
            return maxplus_sweep(a, t, base, interpret=interpret)
        return maxplus_sweep_ref(a, t, base)

    def cond(state):
        t, prev, k = state
        return jnp.logical_and(k < iters, jnp.any(t != prev))

    def body(state):
        t, _, k = state
        return sweep(t), t, k + 1

    t0 = base
    t1 = sweep(t0)
    t, _, _ = jax.lax.while_loop(cond, body, (t1, t0, jnp.int32(1)))
    return t


def finalize_times(graph, *, use_pallas: bool = True, interpret: bool = True):
    """Longest-path node times for a SimGraph via the dense-blocked kernel."""
    import numpy as np

    from ...core.graph import to_dense_blocks
    indptr, src, wgt, base = graph.to_csr()
    a, b = to_dense_blocks(indptr, src, wgt, base, pad_to=BLK)
    # clip the int64 -INF sentinel in numpy BEFORE the int32 transfer —
    # casting -(1<<40) through int32 would wrap to 0 (a phantom edge).
    a32 = jnp.asarray(np.maximum(a, int(NEG)).astype(np.int32))
    b32 = jnp.asarray(np.maximum(b, int(NEG)).astype(np.int32))
    t = longest_path(a32, b32, use_pallas=use_pallas, interpret=interpret)
    return t[:graph.n_nodes]
