"""Sparse chain-structured batched max-plus solver (Pallas TPU kernel).

The dense kernel in ``kernel.py`` materializes the whole max-plus
adjacency — O(n^2) per depth config — which caps the ``backend="jax"``
DSE lane at tiny graphs.  This module is its sparse replacement: it runs
the chain-decomposed fixpoint of ``core.dse._solve_block_numpy`` /
``core.graph.longest_path_chains_batched`` directly over the chain-major
flat arrays (:class:`repro.core.graph.ChainFlatArrays`), so a block of K
depth configs costs O(K·n + K·edges) memory and sweeps of 10^5–10^6
configs stay device-resident.

Per fixpoint round (K configs at once):

  1. **chain pass** — ``t = cw + segcummax(c - cw)``: one *segmented*
     cummax over the (K, npad) contribution matrix, segment boundaries at
     chain starts.  This is the Pallas kernel: a Hillis–Steele doubling
     scan (log2(npad) shifted-max steps, each a full-tile VPU op) over
     (ROWS, npad) VMEM tiles, gridded over config rows.  ``max`` is
     idempotent, so overlapping windows need no flag bookkeeping — a
     column takes its shifted partner iff the partner is at/after its
     own chain start.
  2. **cross pass** — static RAW edges (``c[dst] = max(c[dst],
     t[src]+w)``) and depth-dependent WAR edges scattered back into the
     contribution matrix.  Destinations are unique by construction (one
     RAW in-edge per read node, one WAR in-edge per write node), so the
     scatter-max is exact; XLA's native gather/scatter handles the
     irregular indexing between kernel sweeps.

WAR targets are computed **on-device** from the flat FIFO tables and the
depth block: write ``wseq`` of FIFO ``f`` under depth ``S = Db[k, f]``
waits on read ``wseq - S - 1`` (weight 1), masked out where the target
does not exist.  Regeneration is therefore one gather per solve, not a
host round-trip per config.

Rows diverge independently: a config whose regenerated WAR edges form a
cycle grows its times past the acyclic ``bound`` and is frozen (reported
non-converged = CYCLE upstream) without taxing the other rows.

Everything is int32 on device — callers must clip against :data:`NEG`
and refuse graphs whose path-length bound nears int32 range (see
``core.dse``'s saturation guard); this mirrors the wrap-around hazard
``ops.finalize_times`` documents for the dense path.

Shape bucketing: batch, edge and WAR-table lengths are padded up to
powers of two (padding rows replicate row 0; padding edges carry the
-INF weight, a max-identity) so repeated solves across designs and slab
tails hit the jit cache instead of recompiling per shape.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ...core.graph import ChainFlatArrays

# int32 -INF sentinel — matches the numpy solver's int32 mode, and leaves
# headroom: with bound < 2^28 (enforced upstream) no max-plus candidate
# t + w can underflow/overflow int32 arithmetic.
NEG = -(1 << 29)
LANES = 128        # node-axis padding unit (TPU lane width)
ROWS = 8           # minimum configs per kernel row tile (sublane width)
_TILE_BYTES = 1 << 21   # per-buffer VMEM budget for one (rows, npad) tile


def _pow2(x: int, floor: int) -> int:
    p = floor
    while p < x:
        p *= 2
    return p


def _rows_for(K: int, npad: int) -> int:
    """Row-tile height: as tall as the VMEM budget allows (fewer grid
    steps — interpret mode executes them sequentially), never taller than
    the (power-of-two) batch.  Both are powers of two, so rows | K."""
    cap = ROWS
    while cap * 2 * npad * 4 <= _TILE_BYTES and cap < 512:
        cap *= 2
    return min(K, cap)


# ---------------------------------------------------------------------------
# segmented cummax: the chain pass
# ---------------------------------------------------------------------------
def _doubling_scan(x, seg, col, limit):
    """Hillis–Steele segmented max-scan body shared by the Pallas kernel
    and the jnp reference: log2(limit) shifted-max steps; a column accepts
    its ``s``-shifted partner iff the partner sits at/after the column's
    own segment start (idempotent max ⇒ overlap is harmless).  ``limit``
    (a power of two >= the longest segment) caps the step count — chains
    are usually far shorter than the padded node axis."""
    s = 1
    while s < limit:
        shifted = jnp.concatenate(
            [jnp.full((x.shape[0], s), NEG, x.dtype), x[:, :-s]], axis=1)
        take = (col - s) >= seg
        x = jnp.where(take, jnp.maximum(x, shifted), x)
        s *= 2
    return x


def _segcummax_kernel(limit, x_ref, seg_ref, o_ref):
    x = x_ref[...]                              # (rows, npad) int32
    seg = seg_ref[...]                          # (1, npad) int32
    col = jax.lax.broadcasted_iota(jnp.int32, (1, x.shape[1]), 1)
    o_ref[...] = _doubling_scan(x, seg, col, limit)


def _scan_limit(npad: int, max_seg) -> int:
    return npad if max_seg is None else min(_pow2(max(max_seg, 1), 16), npad)


def segmented_cummax_ref(x: jnp.ndarray, seg_start: jnp.ndarray,
                         max_seg=None):
    """jnp reference: inclusive per-segment running max along axis 1."""
    n = x.shape[1]
    col = jnp.arange(n, dtype=jnp.int32)[None, :]
    return _doubling_scan(x, seg_start[None, :].astype(jnp.int32), col,
                          _scan_limit(n, max_seg))


def segmented_cummax(x: jnp.ndarray, seg_start: jnp.ndarray, *,
                     max_seg=None, use_pallas: bool = True,
                     interpret: bool = True):
    """Segmented cummax over (K, npad); ``seg_start[j]`` is column j's
    segment start, ``max_seg`` an optional bound on segment length (caps
    the scan's doubling steps).  K must be a ROWS multiple and npad a
    LANES multiple for the Pallas path (callers bucket-pad; see
    :func:`solve_chains`)."""
    if not use_pallas:
        return segmented_cummax_ref(x, seg_start, max_seg)
    K, npad = x.shape
    rows = _rows_for(K, npad)
    assert K % rows == 0 and npad % LANES == 0, (K, npad)
    seg2 = seg_start.reshape(1, npad).astype(jnp.int32)
    return pl.pallas_call(
        functools.partial(_segcummax_kernel, _scan_limit(npad, max_seg)),
        grid=(K // rows,),
        in_specs=[
            pl.BlockSpec((rows, npad), lambda i: (i, 0)),
            pl.BlockSpec((1, npad), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rows, npad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((K, npad), x.dtype),
        interpret=interpret,
    )(x, seg2)


# ---------------------------------------------------------------------------
# the batched fixpoint
# ---------------------------------------------------------------------------
@functools.partial(jax.jit,
                   static_argnames=("max_seg", "use_pallas", "interpret"))
def _fixpoint(c0, cw, seg_start, raw_dst, raw_src, raw_w,
              war_dst, war_wseq, war_fid, war_nr, war_roff, war_rcols,
              Db, bound, iters, *, max_seg: int, use_pallas: bool,
              interpret: bool):
    K, npad = c0.shape
    cw_row = cw[None, :]

    # depth-dependent WAR targets, computed on-device once per solve:
    # write wseq of FIFO fid waits on read (wseq - S - 1) under depth S
    have_war = war_dst.shape[0] > 0
    if have_war:
        S = Db[:, war_fid]                                    # (K, m)
        tgt = war_wseq[None, :] - S - 1
        war_valid = (tgt >= 0) & (tgt < war_nr[None, :])
        war_src = war_rcols[war_roff[None, :]
                            + jnp.clip(tgt, 0, war_nr[None, :] - 1)]

    def chain_pass(c):
        seg = segmented_cummax(c - cw_row, seg_start, max_seg=max_seg,
                               use_pallas=use_pallas, interpret=interpret)
        return seg + cw_row

    def cross_pass(c, t):
        c2 = c
        if raw_dst.shape[0]:
            # w == NEG marks bucket-padding edges; real weights are >= 0.
            # An unmasked padding edge would lift a NEG contribution to
            # NEG + t[src] and perturb unreached-node sentinel times.
            cand = jnp.where(raw_w[None, :] > jnp.int32(NEG),
                             t[:, raw_src] + raw_w[None, :], jnp.int32(NEG))
            c2 = c2.at[:, raw_dst].max(cand)
        if have_war:
            cand = jnp.take_along_axis(t, war_src, axis=1) + 1
            cand = jnp.where(war_valid, cand, jnp.int32(NEG))
            c2 = c2.at[:, war_dst].max(cand)
        return c2

    def body(state):
        c, _, diverged, _, rounds = state
        t = chain_pass(c)
        diverged = diverged | (t > bound).any(axis=1)
        c2 = cross_pass(c, t)
        c2 = jnp.where(diverged[:, None], c, c2)   # freeze cyclic rows
        pending = (c2 != c).any(axis=1) & ~diverged
        return c2, t, diverged, pending, rounds + 1

    def cond(state):
        _, _, _, pending, rounds = state
        return jnp.logical_and(pending.any(), rounds < iters)

    state0 = (c0, c0, jnp.zeros(K, bool), jnp.ones(K, bool), jnp.int32(0))
    _, t, diverged, pending, rounds = jax.lax.while_loop(cond, body, state0)
    # pending rows at the cap never reached a fixpoint (cycle), same as
    # longest_path_chains_batched's iteration-cap leftover rows
    return t, ~(diverged | pending), rounds


def solve_chains(arr: ChainFlatArrays, Db: np.ndarray, *,
                 use_pallas: bool = True, interpret: bool = True):
    """Solve K depth configs over one chain-flat graph.

    ``Db``: (K, n_fifos) depth block.  Returns ``(times, converged,
    rounds)`` — ``times`` (n, K) int32 in chain-major node order (the
    layout ``core.dse.solve_block_status`` consumes), ``converged[k]``
    False where config k's regenerated WAR edges form a cycle.
    """
    K = len(Db)
    if K == 0 or arr.n == 0:
        return (np.zeros((arr.n, K), np.int32), np.ones(K, bool), 0)
    # bucket the batch axis so slab tails reuse the compiled solver; the
    # padding rows replicate row 0 and converge exactly when it does
    Kp = _pow2(K, max(ROWS, 1))
    Dp = np.minimum(np.asarray(Db, np.int64), 1 << 30).astype(np.int32)
    if Kp != K:
        Dp = np.concatenate([Dp, np.broadcast_to(Dp[:1], (Kp - K,
                                                          Dp.shape[1]))])
    c0 = jnp.asarray(np.broadcast_to(arr.c_seed, (Kp, arr.npad)))
    t, conv, rounds = _fixpoint(
        c0, jnp.asarray(arr.cw), jnp.asarray(arr.seg_start),
        jnp.asarray(arr.raw_dst), jnp.asarray(arr.raw_src),
        jnp.asarray(arr.raw_w),
        jnp.asarray(arr.war_dst), jnp.asarray(arr.war_wseq),
        jnp.asarray(arr.war_fid), jnp.asarray(arr.war_nr),
        jnp.asarray(arr.war_roff), jnp.asarray(arr.war_rcols),
        jnp.asarray(Dp), jnp.int32(arr.bound),
        jnp.int32(arr.n + 2),
        max_seg=arr.max_seg,
        use_pallas=use_pallas, interpret=interpret)
    times = np.ascontiguousarray(np.asarray(t)[:K, :arr.n].T)
    return times, np.asarray(conv)[:K], int(rounds)
