"""Pallas TPU kernel: blocked max-plus matrix-vector relaxation.

The OmniSim finalization pass computes node times as the longest path
through the simulation graph: t = max(base, max_j (t_j + A[i, j])) iterated
to fixpoint (paper Sec. 6.2 "Finalization" / LightningSimV2's compiled
graph pass).  On TPU the dense-blocked form maps onto VMEM tiles:

  * A is tiled [BLK_I, BLK_J] (int32, -INF for absent edges) — each tile is
    one VMEM-resident block, hardware-aligned at 128;
  * the grid is (num_i_blocks, num_j_blocks); j is the reduction axis,
    accumulated in the output block with a running elementwise max, so the
    working set is exactly one A tile + two vector tiles;
  * one kernel launch performs one relaxation sweep; the ops.py wrapper
    iterates sweeps until fixpoint (bounded by the graph diameter).

This is the paper's §7.3.1 graph-layout optimization re-thought for the TPU
memory hierarchy: instead of CSR-vs-adjacency-list pointer layouts, the
graph becomes dense tiles sized to VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = jnp.int32(-(1 << 30))
BLK = 128


def _sweep_kernel(t_ref, a_ref, base_ref, out_ref):
    """One (i_block, j_block) step: out[i] = max(out[i], base[i],
    max_j(A[i,j] + t[j]))."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = base_ref[...]

    a = a_ref[...]                       # [BLK, BLK] int32
    t = t_ref[...]                       # [1, BLK] int32
    cand = a + t                         # broadcast over rows of A^T? see map
    # A[i, j] + t[j]: t broadcasts along i (rows)
    best = jnp.max(cand, axis=1)         # [BLK]
    out_ref[...] = jnp.maximum(out_ref[...], best[None, :])


def maxplus_sweep(a: jnp.ndarray, t: jnp.ndarray,
                  base: jnp.ndarray, *, interpret: bool = False):
    """One relaxation sweep.  a: [N, N] int32 (a[i, j] = weight j->i or
    -INF); t, base: [N] int32.  Returns updated t' [N]."""
    n = a.shape[0]
    assert n % BLK == 0, f"pad N to a multiple of {BLK}"
    t2 = t.reshape(1, n)
    base2 = base.reshape(1, n)
    grid = (n // BLK, n // BLK)
    out = pl.pallas_call(
        _sweep_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BLK), lambda i, j: (0, j)),        # t[j block]
            pl.BlockSpec((BLK, BLK), lambda i, j: (i, j)),      # A tile
            pl.BlockSpec((1, BLK), lambda i, j: (0, i)),        # base[i block]
        ],
        out_specs=pl.BlockSpec((1, BLK), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.int32),
        interpret=interpret,
    )(t2, a, base2)
    return out.reshape(n)
