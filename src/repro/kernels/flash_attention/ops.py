"""Jitted wrapper for the flash-attention kernel (model-facing API)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_BLK_K, DEFAULT_BLK_Q, flash_attention_bhsd


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "interpret", "blk_q", "blk_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, interpret: bool = True,
                    blk_q: int = DEFAULT_BLK_Q, blk_k: int = DEFAULT_BLK_K):
    """q: [B, S, H, hd]; k, v: [B, S, Hkv, hd] -> [B, S, H, hd].

    interpret=True is the CPU-validation mode; pass False on real TPUs.
    """
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qb = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kb = k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, hd)
    vb = v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, hd)
    out = flash_attention_bhsd(qb, kb, vb, causal=causal, window=window,
                               softcap=softcap, group_size=G,
                               blk_q=min(blk_q, S), blk_k=min(blk_k, S),
                               interpret=interpret)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
