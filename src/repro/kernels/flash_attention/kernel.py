"""Pallas TPU kernel: causal GQA flash attention (online softmax).

The train-step hot spot.  Blocking is VMEM-native:

  * grid = (BH, num_q_blocks, num_k_blocks): one (q-block, k-block) tile
    pair per step; k is the innermost (sequential) axis so the running
    max / denominator / accumulator scratch carries across k steps;
  * q tiles [BLK_Q, hd], k/v tiles [BLK_K, hd] — hd is a lane multiple
    (64/128/256), BLK_Q/BLK_K default 128/256 (8-sublane aligned);
  * causal + sliding-window masking by block-level iota comparison; fully
    masked k-blocks still execute (grid is static) but their contribution
    is exp(-inf)=0 — the ops.py wrapper shrinks the k range per q block
    instead where it can (causal upper bound).
  * GQA: query head h reads kv head h // group_size via the BlockSpec
    index map — no KV duplication in VMEM.

Numerics follow the standard flash recurrence in f32 scratch regardless of
input dtype; optional score softcap (gemma2) is applied pre-masking.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLK_Q = 128
DEFAULT_BLK_K = 128
NEG_INF = float("-inf")


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, blk_q: int, blk_k: int, num_k_blocks: int,
               causal: bool, window: int, softcap: float):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale          # [blk_q, hd]
    k = k_ref[0].astype(jnp.float32)                  # [blk_k, hd]
    v = v_ref[0].astype(jnp.float32)                  # [blk_k, hd]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [blk_q, blk_k]
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = qi * blk_q + jax.lax.broadcasted_iota(jnp.int32,
                                                  (blk_q, blk_k), 0)
    k_pos = kj * blk_k + jax.lax.broadcasted_iota(jnp.int32,
                                                  (blk_q, blk_k), 1)
    mask = jnp.ones((blk_q, blk_k), jnp.bool_)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window > 0:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                               # [blk_q, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows (m_new = -inf): exp(-inf - -inf) -> use 0
    safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - safe_m), 0.0)
    p = jnp.where(mask, jnp.exp(s - safe_m), 0.0)     # [blk_q, blk_k]
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(kj == num_k_blocks - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, window: int = 0,
                         softcap: float = 0.0, group_size: int = 1,
                         blk_q: int = DEFAULT_BLK_Q,
                         blk_k: int = DEFAULT_BLK_K,
                         interpret: bool = False):
    """q: [BH, S, hd]; k, v: [BHkv, S, hd] with BH = BHkv * group_size.

    Head-major layout: row bh of q maps to row bh // group_size of k/v.
    Returns [BH, S, hd].
    """
    BH, S, hd = q.shape
    assert S % blk_q == 0 and S % blk_k == 0, (S, blk_q, blk_k)
    nq = S // blk_q
    nk = S // blk_k
    grid = (BH, nq, nk)

    kernel = functools.partial(
        _fa_kernel, scale=1.0 / math.sqrt(hd), blk_q=blk_q, blk_k=blk_k,
        num_k_blocks=nk, causal=causal, window=window, softcap=softcap)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, blk_k, hd),
                         lambda b, i, j, g=group_size: (b // g, j, 0)),
            pl.BlockSpec((1, blk_k, hd),
                         lambda b, i, j, g=group_size: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            # f32 VMEM scratch: running max, denominator, output accumulator
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
