"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  softcap: float = 0.0, group_size: int = 1):
    """q: [BH, S, hd]; k, v: [BHkv, S, hd].  Exact softmax attention."""
    BH, S, hd = q.shape
    k = jnp.repeat(k, group_size, axis=0)
    v = jnp.repeat(v, group_size, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)       # fully-masked rows
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
