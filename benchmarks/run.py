"""Benchmark harness: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run

Prints human-readable tables followed by ``name,us_per_call,derived`` CSV.
"""
from __future__ import annotations


def main() -> None:
    from benchmarks.tables import (fig8_perfsim, fig8_speed_scaling,
                                   pipeline_table, table3_funcsim,
                                   table5_vs_decoupled, table6_incremental)
    rows = []
    rows += table3_funcsim()
    rows += fig8_perfsim()
    rows += fig8_speed_scaling()
    rows += table5_vs_decoupled()
    rows += table6_incremental()
    rows += pipeline_table()
    print("\n== CSV (name,us_per_call,derived) ==")
    for r in rows:
        print(r)


if __name__ == "__main__":
    main()
