"""Benchmark harness: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run

Prints human-readable tables followed by ``name,us_per_call,derived`` CSV,
and writes the core-engine perf numbers (us/config for looped vs batched
incremental re-simulation) to ``BENCH_core.json`` so future PRs have a
machine-readable trajectory to compare against.
"""
from __future__ import annotations

import json
import os


def main() -> None:
    from benchmarks import tables
    from benchmarks.tables import (fig8_perfsim, fig8_speed_scaling,
                                   pipeline_table, table3_funcsim,
                                   table5_vs_decoupled, table6_batch_dse,
                                   table6_incremental, table_trace_replay)
    rows = []
    rows += table3_funcsim()
    rows += fig8_perfsim()
    rows += fig8_speed_scaling()
    rows += table5_vs_decoupled()
    rows += table6_incremental()
    rows += table6_batch_dse()
    rows += table_trace_replay()
    rows += pipeline_table()
    print("\n== CSV (name,us_per_call,derived) ==")
    for r in rows:
        print(r)
    out = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_core.json")
    with open(out, "w") as f:
        json.dump(tables.BENCH_CORE, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"\nwrote {out}")


if __name__ == "__main__":
    main()
