"""Benchmark harness: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run                   # full suite
    PYTHONPATH=src python -m benchmarks.run --quick           # smoke
    PYTHONPATH=src python -m benchmarks.run --quick --out P   # route output

Prints human-readable tables followed by ``name,us_per_call,derived`` CSV,
and writes the core-engine perf numbers (incremental/batched
re-simulation, trace-compiled and hybrid segmented initial simulation) to
``BENCH_core.json`` so future PRs have a machine-readable trajectory to
compare against.

``--quick`` runs only the key-producing benchmarks at reduced sizes —
every required key is still written (tests/test_bench_schema.py validates
the schema), but the values are not comparable with the full-size
trajectory, so quick output defaults to ``BENCH_core.quick.json`` (or
``--out PATH``) instead of overwriting the committed file.
"""
from __future__ import annotations

import json
import os
import sys


def main(quick: bool = False, out: str = None) -> None:
    from benchmarks import tables
    tables.QUICK = quick
    from benchmarks.tables import (fig8_perfsim, fig8_speed_scaling,
                                   pipeline_table, table3_funcsim,
                                   table5_vs_decoupled, table6_batch_dse,
                                   table6_incremental, table_corpus_scaling,
                                   table_delta_resim, table_hybrid_replay,
                                   table_query_periodization,
                                   table_sparse_maxplus,
                                   table_sweep_faults, table_sweep_service,
                                   table_trace_replay)
    rows = []
    if not quick:
        rows += table3_funcsim()
        rows += fig8_perfsim()
        rows += fig8_speed_scaling()
        rows += table5_vs_decoupled()
        rows += table6_incremental()
    rows += table6_batch_dse()
    rows += table_sweep_service()
    rows += table_sweep_faults()
    rows += table_trace_replay()
    rows += table_hybrid_replay()
    rows += table_query_periodization()
    rows += table_corpus_scaling()
    rows += table_sparse_maxplus()
    rows += table_delta_resim()
    if not quick:
        rows += pipeline_table()
    print("\n== CSV (name,us_per_call,derived) ==")
    for r in rows:
        print(r)
    if out is None:
        # quick numbers come from reduced sizes and are not comparable with
        # the committed trajectory — keep them out of BENCH_core.json unless
        # the caller routes them explicitly with --out
        name = "BENCH_core.quick.json" if quick else "BENCH_core.json"
        out = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), name)
    with open(out, "w") as f:
        json.dump(tables.BENCH_CORE, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"\nwrote {out}")


if __name__ == "__main__":
    argv = sys.argv[1:]
    out_path = None
    if "--out" in argv:
        i = argv.index("--out")
        if i + 1 >= len(argv):
            sys.exit("usage: python -m benchmarks.run [--quick] [--out PATH]")
        out_path = argv[i + 1]
    main(quick="--quick" in argv, out=out_path)
