"""Benchmark implementations — one function per paper table/figure.

Each returns a list of CSV rows (name, us_per_call, derived) plus prints a
human-readable table.  The "co-sim" baseline is the cycle-stepped RTL oracle
(core/rtlsim.py) — see DESIGN.md Sec. 7 for why.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

from repro.core import (LightningSim, UnsupportedDesignError, csim,
                        resimulate, resimulate_batch, simulate, simulate_rtl)
from repro.designs import PAPER_DESIGNS, TYPEA_DESIGNS

# machine-readable core-perf numbers, filled by the benchmarks below and
# dumped to BENCH_core.json by benchmarks/run.py so future PRs have a
# trajectory to compare against
BENCH_CORE: Dict[str, float] = {}

# ``benchmarks/run.py --quick`` sets this: reduced design sizes, fewer
# repeats — every BENCH_CORE key is still produced (the schema test in
# tests/test_bench_schema.py relies on that), the values just carry more
# noise.
QUICK = False


def _timeit(fn, repeats: int = 1):
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return out, best


# ---------------------------------------------------------------- Table 3
def table3_funcsim() -> List[str]:
    """Functionality simulation across C-sim / co-sim / OmniSim."""
    rows = []
    print("\n== Table 3: Func Sim comparison (C-sim vs co-sim vs OmniSim) ==")
    print(f"{'design':14s} {'C-sim':>34s} {'co-sim':>26s} {'OmniSim':>26s} {'match':>6s}")
    for name, builder in PAPER_DESIGNS.items():
        c = csim(builder())
        r = simulate_rtl(builder())
        o, dt = _timeit(lambda: simulate(builder()))
        cs = c.outputs.get("__crash__") or \
            {k: v for k, v in c.outputs.items() if not k.startswith("__")}
        ro = "DEADLOCK" if r.deadlock else \
            {k: v for k, v in r.outputs.items() if not k.startswith("__")}
        oo = "DEADLOCK detected" if o.deadlock else \
            {k: v for k, v in o.outputs.items() if not k.startswith("__")}
        match = (o.deadlock == r.deadlock) and (o.deadlock or
                                                o.outputs == r.outputs)
        print(f"{name:14s} {str(cs)[:34]:>34s} {str(ro)[:26]:>26s} "
              f"{str(oo)[:26]:>26s} {'YES' if match else 'NO':>6s}")
        rows.append(f"table3/{name},{dt*1e6:.0f},match={match}")
    return rows


# ------------------------------------------------------------- Fig 8(a,b)
def fig8_perfsim() -> List[str]:
    """Cycle accuracy + speed vs the cycle-stepped oracle."""
    rows = []
    print("\n== Fig 8: cycle accuracy and speed vs co-sim (RTL oracle) ==")
    print(f"{'design':14s} {'cosim cyc':>10s} {'omni cyc':>10s} {'err%':>6s} "
          f"{'cosim ms':>9s} {'omni ms':>8s} {'speedup':>8s}")
    geo_acc, geo_spd, n = 0.0, 1.0, 0
    for name, builder in PAPER_DESIGNS.items():
        r, t_rtl = _timeit(lambda: simulate_rtl(builder()))
        o, t_omni = _timeit(lambda: simulate(builder()))
        if r.deadlock:
            print(f"{name:14s} {'DEADLOCK':>10s} {'DEADLOCK':>10s}")
            rows.append(f"fig8/{name},{t_omni*1e6:.0f},deadlock_detected=True")
            continue
        err = abs(o.cycles - r.cycles) / r.cycles * 100
        spd = t_rtl / t_omni
        geo_spd *= spd
        n += 1
        print(f"{name:14s} {r.cycles:10d} {o.cycles:10d} {err:5.2f}% "
              f"{t_rtl*1e3:8.1f} {t_omni*1e3:7.1f} {spd:7.2f}x")
        rows.append(f"fig8/{name},{t_omni*1e6:.0f},"
                    f"cycle_err_pct={err:.4f};speedup_vs_cosim={spd:.2f}")
    if n:
        print(f"{'geomean speedup':>62s} {geo_spd ** (1 / n):7.2f}x")
        rows.append(f"fig8/geomean,0,speedup={geo_spd ** (1/n):.2f}")
    return rows


# ---------------------------------------------------------------- Table 5
def table5_vs_decoupled() -> List[str]:
    """OmniSim vs the decoupled two-phase baseline on the Type A suite."""
    rows = []
    print("\n== Table 5: Type A suite — decoupled baseline vs OmniSim ==")
    print(f"{'design':20s} {'LS total ms':>12s} {'Omni ms':>9s} {'ratio':>7s} "
          f"{'same?':>6s}")
    for name, builder in TYPEA_DESIGNS.items():
        ls, t_ls = _timeit(lambda: LightningSim(builder()).run(), repeats=2)
        om, t_om = _timeit(lambda: simulate(builder()), repeats=2)
        same = ls.outputs == om.outputs and ls.cycles == om.cycles
        print(f"{name:20s} {t_ls*1e3:11.1f} {t_om*1e3:8.1f} "
              f"{t_ls/t_om:6.2f}x {'YES' if same else 'NO':>6s}")
        rows.append(f"table5/{name},{t_om*1e6:.0f},"
                    f"ratio_vs_decoupled={t_ls/t_om:.2f};exact_match={same}")
    # the decoupled baseline cannot run any Type B/C design at all
    unsupported = 0
    for name, builder in PAPER_DESIGNS.items():
        try:
            LightningSim(builder()).run()
        except UnsupportedDesignError:
            unsupported += 1
    print(f"decoupled baseline rejects {unsupported}/{len(PAPER_DESIGNS)} "
          f"Type B/C designs; OmniSim simulates all of them")
    rows.append(f"table5/unsupported_by_baseline,0,count={unsupported}")
    return rows


# ---------------------------------------------------------------- Table 6
def table6_incremental() -> List[str]:
    """fig4_ex5 FIFO-depth changes: incremental vs full re-simulation."""
    rows = []
    print("\n== Table 6: incremental re-simulation (fig4_ex5) ==")
    builder = PAPER_DESIGNS["fig4_ex5"]
    r0, t_full = _timeit(lambda: simulate(builder()))
    print(f"initial run (2,2): cycles={r0.cycles}  {t_full*1e3:.1f} ms")
    rows.append(f"table6/initial,{t_full*1e6:.0f},cycles={r0.cycles}")
    for depths in ((2, 100), (100, 2)):
        r0i = simulate(builder())
        _ = resimulate(r0i, depths)          # warm the cache
        r0i = simulate(builder())
        inc, t_inc = _timeit(lambda: resimulate(r0i, depths))
        ok = "OK" if inc.ok else "violated -> full re-sim"
        spd = t_full / t_inc
        print(f"depths {depths}: {ok}; cycles={inc.result.cycles} "
              f"{t_inc*1e3:.2f} ms ({spd:.0f}x vs full)")
        rows.append(f"table6/depths_{depths[0]}_{depths[1]},{t_inc*1e6:.0f},"
                    f"ok={inc.ok};cycles={inc.result.cycles};speedup={spd:.0f}")
    return rows


# ------------------------------------------------------- Table 6 extension
def table6_batch_dse() -> List[str]:
    """Depth-batched DSE: K configs per resimulate_batch call vs a Python
    loop of resimulate() calls (the core/dse.py throughput engine)."""
    import numpy as np

    from repro.designs.typea import skynet_like
    rows = []
    print("\n== Table 6 (batch): depth-batched DSE on skynet_like ==")
    items = 128 if QUICK else 512
    builder = lambda: skynet_like(items=items, depth=12)
    base, t_full = _timeit(lambda: simulate(builder()))
    rng = np.random.default_rng(0)
    K = 64 if QUICK else 256
    D = rng.integers(4, 17, size=(K, len(base.depths)))
    resimulate(base, tuple(int(d) for d in D[0]))          # warm the cache
    resimulate_batch(base, D[:2])
    t0 = time.perf_counter()
    for row in D:
        resimulate(base, tuple(int(d) for d in row), fallback=False)
    t_loop = time.perf_counter() - t0
    out, t_batch = _timeit(lambda: resimulate_batch(base, D, fallback=False))
    spd = t_loop / t_batch
    us_loop = t_loop / K * 1e6
    us_batch = t_batch / K * 1e6
    print(f"{K} configs: looped {t_loop*1e3:7.1f} ms ({us_loop:6.0f} us/cfg)"
          f"  batched {t_batch*1e3:6.1f} ms ({us_batch:5.0f} us/cfg)"
          f"  speedup {spd:5.1f}x  reused {out.n_reused}/{K}")
    print(f"vs full re-simulation per config: "
          f"{t_full / (t_batch / K):,.0f}x")
    rows.append(f"table6_batch/skynet_like_K{K},{us_batch:.1f},"
                f"speedup_vs_loop={spd:.1f};reused={out.n_reused}")
    BENCH_CORE.update({
        "full_sim_us": t_full * 1e6,
        "looped_resimulate_us_per_config": us_loop,
        "batched_resimulate_us_per_config": us_batch,
        "batch_speedup_vs_loop": spd,
        "batch_K": K,
        "batch_reused": out.n_reused,
    })
    return rows


# ------------------------------------------------ Sec 5.1 trace compilation
def table_trace_replay() -> List[str]:
    """Initial simulation via trace-compiled replay vs the generator path
    (core/trace.py, ISSUE 2 acceptance: >= 5x on skynet_like)."""
    from repro.designs.typea import skynet_like

    rows = []
    print("\n== Sec 5.1: trace-compiled initial simulation vs generator ==")
    print(f"{'design':22s} {'gen ms':>8s} {'trace ms':>9s} {'speedup':>8s} "
          f"{'ops':>8s} {'stored':>7s} {'same?':>6s}")
    cases = {
        "skynet_like": (lambda: skynet_like(items=256, depth=12)) if QUICK
        else (lambda: skynet_like()),                     # items=2048, d=24
        "skynet_like_small": lambda: skynet_like(items=128 if QUICK else 512,
                                                 depth=12),
        "flowgnn_like": lambda: TYPEA_DESIGNS["flowgnn_like"](
            n_nodes=128 if QUICK else 1024, layers=8),
    }
    for name, builder in cases.items():
        # like-for-like: same best-of-2 timing discipline for both paths
        gen, t_gen = _timeit(lambda: simulate(builder(), trace="never"),
                             repeats=2)
        tr, t_tr = _timeit(lambda: simulate(builder(), trace="always"),
                           repeats=2)
        same = (gen.outputs == tr.outputs and gen.cycles == tr.cycles
                and gen.deadlock == tr.deadlock)
        rec = tr.graph._trace            # periodized op streams
        spd = t_gen / t_tr
        print(f"{name:22s} {t_gen*1e3:7.1f} {t_tr*1e3:8.1f} {spd:7.1f}x "
              f"{rec.n_ops:8d} {rec.n_stored:7d} {'YES' if same else 'NO':>6s}")
        rows.append(f"trace_replay/{name},{t_tr*1e6:.0f},"
                    f"speedup_vs_generator={spd:.1f};exact_match={same}")
        if name == "skynet_like":
            BENCH_CORE.update({
                "initial_sim_generator_us": t_gen * 1e6,
                "initial_sim_trace_us": t_tr * 1e6,
                "trace_replay_speedup_initial": spd,
                "trace_ops": rec.n_ops,
                "trace_ops_stored_after_periodization": rec.n_stored,
            })
    return rows


# ------------------------------------------- Sec 5.1 hybrid (NB/probe) replay
def table_hybrid_replay() -> List[str]:
    """Repeated simulation of *dynamic* (Type B/C) designs via the hybrid
    engine's cached replay vs the generator engine (ISSUE 9 acceptance:
    >= 4x on branch and multicore).

    This is the *warm* profile a DSE loop actually pays: the first hybrid
    run simulates cold (segmented replay) and stores the complete solved
    run in a :class:`~repro.core.trace.HybridCache`; every repeat is a
    whole-run verified replay — bulk array install plus O(N) per-entry
    verification against the claimed FIFO tables, no generator resumption
    at all.  The cold path alone tops out near 2x on the forced-query-
    dominated paper designs (branch/multicore ping-pong one forced poll
    per phase, which no steady-state detector can periodize), so the
    cached fast path is what makes them fast.  Writes
    ``hybrid_replay_speedup_<design>`` (warm) and
    ``hybrid_replay_cold_speedup_<design>`` keys into BENCH_core.json.
    """
    from repro.core.trace import HybridCache
    from repro.designs.dynamic import watchdog_pipe

    rows = []
    print("\n== Sec 5.1 hybrid: cached replay on dynamic designs ==")
    print(f"{'design':16s} {'gen ms':>8s} {'cold ms':>8s} {'warm ms':>8s} "
          f"{'speedup':>8s} {'ops':>8s} {'queries':>8s} {'same?':>6s}")
    if QUICK:
        cases = {
            "fig2_timer": lambda: PAPER_DESIGNS["fig2_timer"](n=512),
            "branch": lambda: PAPER_DESIGNS["branch"](prog_len=512),
            "multicore": lambda: PAPER_DESIGNS["multicore"](cores=8,
                                                            prog_len=64),
            "watchdog_pipe": lambda: watchdog_pipe(items=512, stages=4),
        }
    else:
        cases = {
            "fig2_timer": lambda: PAPER_DESIGNS["fig2_timer"](),
            "branch": lambda: PAPER_DESIGNS["branch"](),
            "multicore": lambda: PAPER_DESIGNS["multicore"](),
            "watchdog_pipe": lambda: watchdog_pipe(items=8192, stages=6),
        }
    for name, builder in cases.items():
        gen, t_gen = _timeit(lambda: simulate(builder(), trace="never"),
                             repeats=1 if QUICK else 2)
        cache = HybridCache()
        cold, t_cold = _timeit(
            lambda: simulate(builder(), trace="always", hybrid_cache=cache),
            repeats=1)
        hyb, t_hyb = _timeit(
            lambda: simulate(builder(), trace="always", hybrid_cache=cache),
            repeats=2 if QUICK else 3)
        assert hyb.engine == "omnisim-hybrid", name
        assert cache.full_hits >= 1 and cache.full_rejects == 0, name
        same = (gen.outputs == hyb.outputs and gen.cycles == hyb.cycles
                and gen.deadlock == hyb.deadlock
                and cold.outputs == hyb.outputs)
        info = hyb.graph._hybrid
        spd = t_gen / t_hyb
        print(f"{name:16s} {t_gen*1e3:7.1f} {t_cold*1e3:7.1f} "
              f"{t_hyb*1e3:7.1f} {spd:7.2f}x {info['ops']:8d} "
              f"{info['queries']:8d} {'YES' if same else 'NO':>6s}")
        rows.append(f"hybrid_replay/{name},{t_hyb*1e6:.0f},"
                    f"speedup_vs_generator={spd:.2f};exact_match={same}")
        BENCH_CORE[f"hybrid_replay_speedup_{name}"] = spd
        BENCH_CORE[f"hybrid_replay_cold_speedup_{name}"] = t_gen / t_cold
        if name == "watchdog_pipe":
            BENCH_CORE.update({
                "hybrid_sim_generator_us_watchdog_pipe": t_gen * 1e6,
                "hybrid_sim_hybrid_us_watchdog_pipe": t_hyb * 1e6,
                "hybrid_queries_watchdog_pipe": info["queries"],
                "hybrid_ops_watchdog_pipe": info["ops"],
            })
    return rows


# ---------------------------------------- Sec 5.1 query periodization burst
def table_query_periodization() -> List[str]:
    """Steady-state query periodization on poll-dominated designs
    (ISSUE 4 acceptance: >= 4x on fig2_timer).

    The hybrid engine's poll-loop detector resolves K definitively-false
    outcomes per burst against the committed FIFO tables instead of one
    generator resumption + Table-2 resolution per query.  fig2_timer is the
    uniform-gap poll loop (one burst covers the whole run); fig2_poll_burst
    cycles through non-uniform gaps, so the detector re-arms per constant-
    gap run and the divergence fallback is on the measured path too;
    multisite_poll round-robins over two FIFOs fed at different rates (the
    multi-site ``(site, gap, outcome)`` tuple pattern a single-site streak
    detector cannot see); nb_success_stream is a steady *successful* NB
    stream, periodized against the producer's run-ahead write table.
    Writes ``query_periodization_*`` keys into BENCH_core.json.
    """
    from repro.designs.dynamic import (fig2_poll_burst, multisite_poll,
                                       nb_success_stream)

    rows = []
    print("\n== Sec 5.1 periodization: poll loops vs generator engine ==")
    print(f"{'design':17s} {'gen ms':>8s} {'hybrid ms':>10s} {'speedup':>8s} "
          f"{'queries':>8s} {'bulk':>8s} {'bursts':>7s} {'same?':>6s}")
    if QUICK:
        cases = {
            "fig2_timer": lambda: PAPER_DESIGNS["fig2_timer"](n=512),
            "fig2_poll_burst": lambda: fig2_poll_burst(items=512, stages=2),
            "multisite_poll": lambda: multisite_poll(items=512),
            "nb_success_stream": lambda: nb_success_stream(items=1024),
        }
    else:
        cases = {
            "fig2_timer": lambda: PAPER_DESIGNS["fig2_timer"](),
            "fig2_poll_burst": lambda: fig2_poll_burst(),
            "multisite_poll": lambda: multisite_poll(),
            "nb_success_stream": lambda: nb_success_stream(),
        }
    for name, builder in cases.items():
        gen, t_gen = _timeit(lambda: simulate(builder(), trace="never"),
                             repeats=2 if QUICK else 3)
        hyb, t_hyb = _timeit(lambda: simulate(builder(), trace="always"),
                             repeats=2 if QUICK else 3)
        assert hyb.engine == "omnisim-hybrid", name
        same = (gen.outputs == hyb.outputs and gen.cycles == hyb.cycles
                and gen.stats.queries == hyb.stats.queries
                and gen.stats.queries_forced_false
                == hyb.stats.queries_forced_false)
        info = hyb.graph._hybrid
        spd = t_gen / t_hyb
        print(f"{name:17s} {t_gen*1e3:7.1f} {t_hyb*1e3:9.1f} {spd:7.2f}x "
              f"{info['queries']:8d} {info['bulk_queries']:8d} "
              f"{info['bursts']:7d} {'YES' if same else 'NO':>6s}")
        rows.append(f"query_periodization/{name},{t_hyb*1e6:.0f},"
                    f"speedup_vs_generator={spd:.2f};"
                    f"bulk={info['bulk_queries']};exact_match={same}")
        BENCH_CORE[f"query_periodization_speedup_{name}"] = spd
        if name == "fig2_timer":
            BENCH_CORE.update({
                "query_periodization_sim_generator_us_fig2_timer": t_gen * 1e6,
                "query_periodization_sim_hybrid_us_fig2_timer": t_hyb * 1e6,
                "query_periodization_bulk_queries_fig2_timer":
                    int(info["bulk_queries"]),
            })
        elif name in ("multisite_poll", "nb_success_stream"):
            BENCH_CORE[f"query_periodization_bulk_queries_{name}"] = \
                int(info["bulk_queries"])
    return rows


# ----------------------------------------------- ISSUE 5: served DSE sweeps
def table_sweep_service() -> List[str]:
    """Sweep service vs a naive per-request resimulate() loop on
    skynet_like (ISSUE 5 acceptance: warm-cache served throughput >= 5x
    the loop), plus dedup ratio and cache hit rate."""
    import numpy as np

    from repro.designs.typea import skynet_like
    from repro.sweep import SweepService

    rows = []
    print("\n== ISSUE 5: served DSE sweeps (repro/sweep) ==")
    items = 128 if QUICK else 512
    builder = lambda: skynet_like(items=items, depth=12)
    K = 96 if QUICK else 512
    n_fifo = len(builder().fifos)
    rng = np.random.default_rng(0)
    # requests re-propose configurations (grids revisit corners, halving
    # re-evaluates survivors): sample rows from a small pool so the block
    # dedup has real duplicates to collapse
    pool = rng.integers(4, 17, size=(max(K // 4, 1), n_fifo))
    D = pool[rng.integers(0, len(pool), size=K)]

    # naive per-request loop: one warm resimulate() call per config
    base, _ = _timeit(lambda: simulate(builder()))
    resimulate(base, tuple(int(d) for d in D[0]))          # warm the cache
    t0 = time.perf_counter()
    for row in D:
        resimulate(base, tuple(int(d) for d in row), fallback=False)
    t_loop = time.perf_counter() - t0

    svc = SweepService(block=128, shards=2, mode="thread")
    try:
        t0 = time.perf_counter()
        cold = svc.sweep(builder(), D)         # pays initial sim + hoisting
        t_cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        warm = svc.sweep(builder(), D)         # served from the warm cache
        t_warm = time.perf_counter() - t0
        st = svc.stats()
    finally:
        svc.close()
    assert (cold.cycles == warm.cycles).all()
    cps_cold = K / t_cold
    cps_warm = K / t_warm
    spd = t_loop / t_warm
    print(f"{K} configs ({cold.n_unique} unique): loop {t_loop*1e3:7.1f} ms"
          f"  cold {t_cold*1e3:7.1f} ms ({cps_cold:,.0f} cfg/s)"
          f"  warm {t_warm*1e3:6.1f} ms ({cps_warm:,.0f} cfg/s)"
          f"  speedup {spd:5.1f}x")
    print(f"dedup {st['scheduler']['dedup_ratio']:.2f}x  "
          f"cache hit rate {st['cache']['hit_rate']:.2f}  "
          f"blocks {st['scheduler']['blocks']}")
    rows.append(f"sweep_service/skynet_like_K{K},{t_warm/K*1e6:.1f},"
                f"speedup_vs_loop={spd:.1f};"
                f"dedup={st['scheduler']['dedup_ratio']:.2f}")
    BENCH_CORE.update({
        "sweep_warm_configs_per_sec": cps_warm,
        "sweep_cold_configs_per_sec": cps_cold,
        "sweep_service_speedup_vs_loop": spd,
        "sweep_dedup_ratio": st["scheduler"]["dedup_ratio"],
        "sweep_cache_hit_rate": st["cache"]["hit_rate"],
    })
    return rows


# ------------------------------------------ ISSUE 6: sweeps under faults
def table_sweep_faults() -> List[str]:
    """Fault-tolerant serving overhead (ISSUE 6): warm served throughput
    with a seeded FaultInjector (transient shard faults, retried) vs
    fault-free, and interactive p99 latency while a bulk tenant's design
    faults at a 10% shard rate."""
    import numpy as np

    from repro.designs.typea import producer_consumer, skynet_like
    from repro.sweep import FaultInjector, RetryPolicy, SweepService

    rows = []
    print("\n== ISSUE 6: sweep serving under injected faults ==")
    items = 128 if QUICK else 512
    builder = lambda: skynet_like(items=items, depth=12)
    K = 96 if QUICK else 512
    n_fifo = len(builder().fifos)
    rng = np.random.default_rng(0)
    pool = rng.integers(4, 17, size=(max(K // 4, 1), n_fifo))
    D = pool[rng.integers(0, len(pool), size=K)]

    def warm_run(injector=None, retry=None):
        svc = SweepService(block=128, shards=2, mode="thread",
                           injector=injector, retry=retry)
        try:
            svc.sweep(builder(), D)            # cold: build + warm-up
            t0 = time.perf_counter()
            out = svc.sweep(builder(), D)
            dt = time.perf_counter() - t0
            st = svc.stats()
        finally:
            svc.close()
        return out, dt, st

    clean, t_clean, _ = warm_run()
    # transient faults at a 10% shard rate (plus a guaranteed first-draw
    # fault so the retry path is always on the measured profile), all
    # absorbed by a fast retry policy
    inj = FaultInjector(seed=0).arm("shard.fault", at=[0], rate=0.10)
    faulty, t_fault, st = warm_run(
        injector=inj, retry=RetryPolicy(max_attempts=4, backoff_s=1e-3,
                                        max_backoff_s=5e-3))
    delivered = faulty.status != 5             # FAULTED: retries exhausted
    assert (faulty.cycles[delivered] == clean.cycles[delivered]).all()
    cps_clean = K / t_clean
    cps_fault = K / t_fault
    overhead = t_fault / t_clean
    retries = int(st["scheduler"]["retries"])
    print(f"{K} configs warm: fault-free {t_clean*1e3:6.1f} ms "
          f"({cps_clean:,.0f} cfg/s)  10% faults {t_fault*1e3:6.1f} ms "
          f"({cps_fault:,.0f} cfg/s)  overhead {overhead:.2f}x  "
          f"retries {retries}  faulted rows "
          f"{int(st['scheduler']['faulted_rows'])}")
    rows.append(f"sweep_faults/skynet_like_K{K},{t_fault/K*1e6:.1f},"
                f"recovery_overhead={overhead:.2f};retries={retries}")

    # interactive p99 while a bulk tenant's design faults at 10%: the
    # quarantine threshold is raised so the poisoned design keeps being
    # scheduled (worst case for the co-tenant), and the clean tenant's
    # small requests ride the interactive lane
    n_live = 12 if QUICK else 40
    live_builder = lambda: producer_consumer(n=64, depth=4)
    inj2 = FaultInjector(seed=1)
    svc = SweepService(block=64, shards=2, mode="thread", injector=inj2,
                       quarantine_after=10**6,
                       retry=RetryPolicy(max_attempts=3, backoff_s=1e-3,
                                         max_backoff_s=5e-3))
    try:
        bulk_key = svc.warm(builder()).key
        inj2.arm("shard.fault", rate=0.10, key=bulk_key)
        svc.warm(live_builder())
        Dl = np.array([[1], [2], [4], [8]])
        svc.sweep(live_builder(), Dl)          # warm the interactive path
        hb = svc.submit(builder(), D, tenant="bulk", priority="bulk")
        lat = []
        for _ in range(n_live):
            t0 = time.perf_counter()
            svc.sweep(live_builder(), Dl, tenant="live")
            lat.append(time.perf_counter() - t0)
        hb.result()
    finally:
        svc.close()
    p99_ms = float(np.percentile(np.asarray(lat), 99) * 1e3)
    print(f"interactive p99 with bulk tenant faulting at 10%: "
          f"{p99_ms:.2f} ms over {n_live} requests")
    rows.append(f"sweep_faults/interactive_p99,{p99_ms*1e3:.1f},"
                f"bulk_fault_rate=0.10")
    BENCH_CORE.update({
        "sweep_fault_free_configs_per_sec": cps_clean,
        "sweep_fault_injected_configs_per_sec": cps_fault,
        "sweep_fault_recovery_overhead": overhead,
        "sweep_fault_retries": retries,
        "sweep_fault_p99_interactive_ms": p99_ms,
    })
    return rows


# ------------------------------------- ISSUE 7: corpus scaling benchmark
def table_corpus_scaling() -> List[str]:
    """Per-engine throughput on constrained-random corpus designs at 100 /
    300 / 1000 modules (ISSUE 7): generator vs auto (hybrid) modules/sec,
    warm sweep-service configs/sec on the 300-module design, and the
    sampled RTL-oracle agreement count."""
    import numpy as np

    from repro.corpus import BENCH_SPEC, generate, rtl_crosscheck
    from repro.sweep import SweepService

    rows = []
    print("\n== ISSUE 7: corpus scaling (constrained-random designs) ==")
    print(f"{'scale':>6s} {'mods':>5s} {'cycles':>7s} {'gen ms':>7s} "
          f"{'auto ms':>8s} {'gen mod/s':>10s} {'auto mod/s':>11s}")
    repeats = 1 if QUICK else 3

    def live_case(scale):
        # first live seed keeps the benchmark on the engine (not on the
        # deadlock early-out), deterministically
        for seed in range(8):
            c = generate(seed, scale=scale, spec=BENCH_SPEC)
            if not simulate(c.builder(), trace="never").deadlock:
                return c
        raise AssertionError(f"no live corpus seed at scale {scale}")

    case300 = None
    for scale in (100, 300, 1000):
        c = live_case(scale)
        if scale == 300:
            case300 = c
        mods = c.meta["modules"]
        g, t_gen = _timeit(lambda: simulate(c.builder(), trace="never"),
                           repeats)
        a, t_auto = _timeit(lambda: simulate(c.builder(), trace="auto"),
                            repeats)
        assert a.cycles == g.cycles and a.outputs == g.outputs
        print(f"{scale:6d} {mods:5d} {g.cycles:7d} {t_gen*1e3:6.1f} "
              f"{t_auto*1e3:7.1f} {mods/t_gen:10,.0f} {mods/t_auto:11,.0f}")
        rows.append(f"corpus_scaling/m{scale},{t_auto*1e6:.0f},"
                    f"modules={mods};cycles={g.cycles}")
        BENCH_CORE[f"corpus_modules_per_sec_generator_{scale}"] = mods / t_gen
        BENCH_CORE[f"corpus_modules_per_sec_auto_{scale}"] = mods / t_auto

    # warm sweep-service throughput over depth variants of the 300-module
    # design: offsets only grow depths, so every variant stays live
    g = simulate(case300.builder(), trace="auto")
    base = np.asarray(g.depths, dtype=np.int64)
    K = 16 if QUICK else 64
    rng = np.random.default_rng(7)
    pool = base + rng.integers(0, 5, size=(max(K // 4, 1), base.size))
    D = pool[rng.integers(0, len(pool), size=K)]
    svc = SweepService(block=16, shards=2, mode="thread")
    try:
        svc.sweep(case300.builder(), D)        # cold: build + warm-up
        t0 = time.perf_counter()
        svc.sweep(case300.builder(), D)
        t_warm = time.perf_counter() - t0
    finally:
        svc.close()
    cps = K / t_warm
    print(f"sweep service on {case300.meta['modules']}-module design: "
          f"{K} configs warm in {t_warm*1e3:.1f} ms ({cps:,.0f} cfg/s)")
    rows.append(f"corpus_scaling/sweep300_K{K},{t_warm/K*1e6:.1f},"
                f"configs_per_sec={cps:.0f}")
    BENCH_CORE["corpus_sweep_configs_per_sec_300"] = cps

    # sampled RTL-oracle cross-check: cycle-exact agreement required
    rtl_cases = ([(s, 10) for s in range(6)] + [(s, 32) for s in range(5)]
                 + [(0, 100)])
    agree = 0
    for seed, scale in rtl_cases:
        c = generate(seed, scale=scale, spec=BENCH_SPEC)
        r = rtl_crosscheck(c.builder)
        assert r["agree"], f"{c.name}: engine vs RTL oracle disagree: {r}"
        agree += 1
    print(f"RTL oracle agreement: {agree}/{len(rtl_cases)} corpus designs "
          f"cycle-exact")
    rows.append(f"corpus_scaling/rtl_agree,{0:.0f},count={agree}")
    BENCH_CORE["corpus_rtl_agree_count"] = agree
    return rows


# ----------------------------------------- sparse Pallas max-plus DSE lane
def table_sparse_maxplus() -> List[str]:
    """Sparse chain-structured Pallas max-plus solver (``backend="jax"``,
    interpret mode — CI needs no TPU) on a 100-module corpus design:
    device-lane throughput at K = 1e3 / 1e4 / 1e5 depth configs, plus the
    ratio against the numpy Gauss-Seidel fixpoint at the largest K.  The
    dense ``jax_dense`` lowering cannot run this design at all — its
    (K, npad, npad) working set is O(n^2) per config.  ``--quick`` keeps
    every key but solves K/100 configs per point."""
    import numpy as np

    from repro.core.dse import solve_block_status
    from repro.core.incremental import compile_graph
    from repro.corpus import BENCH_SPEC, generate

    rows = []
    print("\n== Sparse max-plus: backend=\"jax\" on a 100-module corpus "
          "design ==")
    # recorded next to the maxplus_sparse_* keys: interpret mode executes
    # the Pallas kernel body through XLA on CPU, so its numbers are not
    # comparable with a compiled-device trajectory — flip this on real TPUs
    jax_interpret = True
    for seed in range(8):           # first live seed, deterministically
        c = generate(seed, scale=100, spec=BENCH_SPEC)
        base_run = simulate(c.builder(), trace="auto")
        if not base_run.deadlock:
            break
    g = compile_graph(base_run.graph)
    base = np.asarray([int(d) for d in base_run.depths], np.int64)
    rng = np.random.default_rng(0)
    shrink = 100 if QUICK else 1
    block = 1024

    def depths(K):
        # offsets only grow depths, so every config stays live
        return base[None, :] + rng.integers(0, 5, size=(K, base.size))

    # warm both solvers (jit compile + chain-flat export on the jax side)
    solve_block_status(g, depths(min(block, 1000 // shrink)),
                       backend="jax", block=block,
                       jax_interpret=jax_interpret)
    Kn = max(1000 // shrink, 1)
    s_np, t_np = _timeit(lambda: solve_block_status(g, depths(Kn),
                                                    backend="numpy",
                                                    block=block))
    us_np = t_np / Kn * 1e6
    print(f"{'K':>8s} {'sparse ms':>10s} {'us/cfg':>7s} "
          f"{'vs numpy':>9s} {'reused':>7s}")
    us_jx = us_np
    for K in (1000, 10_000, 100_000):
        Keff = max(K // shrink, 1)
        D = depths(Keff)
        out, t_jx = _timeit(lambda: solve_block_status(
            g, D, backend="jax", block=block, jax_interpret=jax_interpret))
        us_jx = t_jx / Keff * 1e6
        reused = int((out[0] == 0).sum())
        print(f"{Keff:8d} {t_jx*1e3:10.1f} {us_jx:7.0f} "
              f"{us_np/us_jx:8.2f}x {reused:7d}")
        rows.append(f"sparse_maxplus/{c.name}_K{K},{us_jx:.1f},"
                    f"reused={reused};Keff={Keff}")
        BENCH_CORE[f"maxplus_sparse_us_per_config_{K}"] = us_jx
    # interpret mode runs the TPU kernel through XLA on CPU, so this ratio
    # understates the device lane; it pins the trajectory either way
    BENCH_CORE["maxplus_sparse_vs_numpy_speedup"] = us_np / us_jx
    BENCH_CORE["maxplus_sparse_jax_interpret"] = jax_interpret
    print(f"numpy baseline: {us_np:.0f} us/cfg at K={Kn} "
          f"(ratio at largest K: {us_np/us_jx:.2f}x)")
    return rows


# -------------------------------------------------- Fig 8(b) scaling regime
def fig8_speed_scaling() -> List[str]:
    """Event-driven vs cycle-stepped scaling: speedup grows with idle cycles
    (the co-sim regime the paper targets — RTL simulators pay every cycle)."""
    from repro.designs.typea import high_latency_pipe
    rows = []
    print("\n== Fig 8(b) scaling: speedup vs idle-cycle fraction ==")
    print(f"{'II':>5s} {'cycles':>8s} {'cosim ms':>9s} {'omni ms':>8s} "
          f"{'speedup':>8s}")
    for ii in (8, 32, 64, 128, 256, 512):
        r, t_rtl = _timeit(lambda: simulate_rtl(high_latency_pipe(ii=ii)))
        o, t_om = _timeit(lambda: simulate(high_latency_pipe(ii=ii)))
        assert o.outputs == r.outputs and o.cycles == r.cycles
        print(f"{ii:5d} {o.cycles:8d} {t_rtl*1e3:8.1f} {t_om*1e3:7.1f} "
              f"{t_rtl/t_om:7.2f}x")
        rows.append(f"fig8_scaling/ii{ii},{t_om*1e6:.0f},"
                    f"speedup_vs_cosim={t_rtl/t_om:.2f};cycles={o.cycles}")
    return rows


# ------------------------------------- structural deltas: edit-and-resim
def table_delta_resim() -> List[str]:
    """Edit-and-resimulate (ISSUE 10): serve every corpus edit class on a
    300-module design through ``SweepService.edit_session`` and compare
    against a from-scratch ``simulate`` of the edited design.  Each pair
    gets its own session pinned to its base design (a fresh tenant editing
    that design), so every ``update()`` exercises the real served path:
    fingerprint, classify, patch-or-reject, insert.

    ``delta_resim_speedup_300`` is the acceptance scenario of the issue —
    one module body-edited, ``update()`` time vs cold ``simulate`` time
    (acceptance >= 5); ``delta_reuse_fraction_300`` the worst-case module
    reuse among the patch-served classes (acceptance >= 0.9); and
    ``delta_reject_rate`` the fraction of edit classes the classifier /
    write-stream / verify gates push to a cold rebuild — positive by
    construction because the corpus includes adversarial (value / rename /
    topology) edits.  Every served result, patched or cold, is asserted
    bit-identical to the from-scratch run.  ``--quick`` runs 60-module
    designs under the same keys."""
    from repro.corpus import BLOCKING_SPEC, edit_pairs, result_record
    from repro.corpus.spec import IntRange
    from repro.core.engine import simulate
    from repro.sweep.service import SweepService

    rows = []
    scale = 60 if QUICK else 300
    repeats = 1 if QUICK else 3
    # heavier module bodies than the default corpus spec: the acceptance
    # scenario is an interactive edit of a *substantial* design
    spec = BLOCKING_SPEC.replace(items=IntRange(48, 96))
    print(f"\n== ISSUE 10: structural deltas on {scale}-module corpus "
          "designs ==")
    print(f"{'edit':>10s} {'served':>8s} {'reuse':>6s} {'cold ms':>8s} "
          f"{'update ms':>9s} {'speedup':>8s}")
    pairs = edit_pairs(11, scale=scale, spec=spec)
    simulate(pairs[0].base())            # untimed warmup (imports, numpy)
    body_speedup, reuse_min, rejects = None, 1.0, 0
    for p in pairs:
        base, edited = p.base(), p.edited()
        cold, t_cold = _timeit(lambda: simulate(edited), repeats)
        # fresh session per repeat: each update() is a first edit against
        # a warm base, exactly the interactive loop's steady state
        t_upd, out, served = float("inf"), None, None
        for _ in range(repeats):
            svc = SweepService(autostart=False)
            sess = svc.edit_session(base)
            t0 = time.perf_counter()
            out = sess.update(edited)
            t_upd = min(t_upd, time.perf_counter() - t0)
            served = sess.entry.result
            svc.close()
        assert (out.mode == "patched") == (p.expect == "patched"), \
            (p.kind, out.mode, out.reason)
        assert result_record(served) == result_record(cold), p.kind
        if out.mode == "patched":
            reuse_min = min(reuse_min, out.reuse_fraction)
            if p.kind == "delay":        # the one-module body edit
                body_speedup = t_cold / t_upd
        else:
            rejects += 1
        print(f"{p.kind:>10s} {out.mode:>8s} {out.reuse_fraction:6.2f} "
              f"{t_cold*1e3:7.1f} {t_upd*1e3:8.1f} "
              f"{t_cold/t_upd:7.1f}x")
        rows.append(f"delta_resim/{p.kind}_m{scale},{t_upd*1e6:.0f},"
                    f"served={out.mode};speedup={t_cold/t_upd:.1f}")
    assert body_speedup is not None, "corpus emitted no body-edit pair"
    reject_rate = rejects / len(pairs)
    print(f"body-edit speedup {body_speedup:.1f}x, worst patched reuse "
          f"{reuse_min:.2f}, reject rate {reject_rate:.2f} "
          f"({rejects}/{len(pairs)})")
    BENCH_CORE["delta_resim_speedup_300"] = body_speedup
    BENCH_CORE["delta_reuse_fraction_300"] = reuse_min
    BENCH_CORE["delta_reject_rate"] = reject_rate
    return rows


# ----------------------------------------------------- beyond-paper: perfsim
def pipeline_table() -> List[str]:
    """OmniSim as distributed-schedule simulator (framework integration)."""
    from repro.perfsim.pipeline import PipelineSpec, simulate_pipeline
    rows = []
    print("\n== Beyond-paper: pipeline-schedule prediction (perfsim) ==")
    print(f"{'schedule':>8s} {'stages':>7s} {'mb':>4s} {'step ticks':>11s} "
          f"{'bubble':>7s} {'sim ms':>7s}")
    for schedule in ("gpipe", "1f1b"):
        for mb in (8, 32):
            spec = PipelineSpec(stages=8, microbatches=mb, fwd_ticks=40,
                                bwd_ticks=80, schedule=schedule)
            out, dt = _timeit(lambda: simulate_pipeline(spec))
            print(f"{schedule:>8s} {8:7d} {mb:4d} {out.step_ticks:11d} "
                  f"{out.bubble_fraction:6.1%} {dt*1e3:6.1f}")
            rows.append(f"perfsim/{schedule}_mb{mb},{dt*1e6:.0f},"
                        f"step_ticks={out.step_ticks};"
                        f"bubble={out.bubble_fraction:.3f}")
    return rows
