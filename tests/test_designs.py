"""Paper benchmark designs: Table 3 / Table 4 reproduction.

The paper's claim: OmniSim's functional outputs match C/RTL co-simulation
*exactly* for all eleven Type B/C designs, while C-sim fails on every one.
Our co-sim stand-in is the cycle-stepped RTL oracle (DESIGN.md Sec. 7).
"""
import pytest

from repro.core import LightningSim, UnsupportedDesignError, classify, csim, \
    simulate, simulate_rtl
from repro.designs import PAPER_DESIGNS, TYPEA_DESIGNS

SMALL_N = 257        # keep unit tests fast; benchmarks use the full N=2025


@pytest.mark.parametrize("name", sorted(PAPER_DESIGNS))
def test_paper_design_matches_cosim(name):
    builder = PAPER_DESIGNS[name]
    r1 = simulate(builder())
    r2 = simulate_rtl(builder())
    assert r1.deadlock == r2.deadlock
    if not r1.deadlock:
        assert r1.outputs == r2.outputs
        assert r1.cycles == r2.cycles


@pytest.mark.parametrize("name", sorted(PAPER_DESIGNS))
def test_paper_design_schedule_independent(name):
    builder = PAPER_DESIGNS[name]
    base = simulate(builder())
    for seed in (0, 1):
        r = simulate(builder(), shuffle_seed=seed)
        assert r.outputs == base.outputs
        assert r.cycles == base.cycles


def test_table3_exact_paper_values():
    """Values that are analytically pinned by the designs (Table 3)."""
    assert simulate(PAPER_DESIGNS["fig4_ex2"]()).outputs["sum_out"] == 2051325
    assert simulate(PAPER_DESIGNS["fig4_ex3"]()).outputs["sum"] == 4098600
    r = simulate(PAPER_DESIGNS["fig2_timer"]())
    assert r.outputs["timer_cycles"] == 6075        # 3 cycles x 2025 items
    assert r.outputs["sink_sum"] == 2051325
    assert simulate(PAPER_DESIGNS["deadlock"]()).deadlock


def test_table3_csim_failures():
    """C-sim column of Table 3: crashes and wrong results."""
    # infinite producer loops -> array overrun -> SIGSEGV
    for name in ("fig4_ex2", "fig4_ex4a_d", "fig4_ex4b_d"):
        r = csim(PAPER_DESIGNS[name]())
        assert r.outputs.get("__crash__") == "@E Simulation failed: SIGSEGV."
    # cyclic blocking -> reads-while-empty -> sum = 0 + warnings
    r = csim(PAPER_DESIGNS["fig4_ex3"]())
    assert r.outputs["sum"] == 0
    assert sum("read while empty" in w for w in r.outputs["__warnings__"]) == 2025
    assert any("leftover" in w for w in r.outputs["__warnings__"])
    # NB writes 'always succeed' -> full (wrong) sum, Dropped = 0
    r = csim(PAPER_DESIGNS["fig4_ex4a"]())
    assert r.outputs["sum_out"] == 2051325
    r = csim(PAPER_DESIGNS["fig4_ex4b"]())
    assert r.outputs == {"sum_out": 2051325, "Dropped": 0}
    # the timer reads the done flag instantly -> counts 0 cycles
    r = csim(PAPER_DESIGNS["fig2_timer"]())
    assert r.outputs["timer_cycles"] == 0


def test_table4_design_inventory():
    """Structural properties from Table 4 (modules / FIFOs / NB / cyclic)."""
    mc = PAPER_DESIGNS["multicore"]()
    assert len(mc.modules) == 34
    assert len(mc.fifos) == 64
    r = simulate(mc)
    c = classify(mc, r)
    assert c.dtype == "C" and c.cyclic and c.has_nonblocking

    ex3 = PAPER_DESIGNS["fig4_ex3"]()
    c3 = classify(ex3, simulate(ex3))
    assert c3.dtype == "B" and c3.cyclic and not c3.has_nonblocking


@pytest.mark.parametrize("name", sorted(PAPER_DESIGNS))
def test_lightningsim_cannot_simulate_paper_designs(name):
    with pytest.raises(UnsupportedDesignError):
        LightningSim(PAPER_DESIGNS[name]()).run()


@pytest.mark.parametrize("name", sorted(TYPEA_DESIGNS))
def test_typea_all_engines_agree(name):
    builder = TYPEA_DESIGNS[name]
    r1 = simulate(builder())
    r2 = simulate_rtl(builder())
    r3 = LightningSim(builder()).run()
    assert r1.outputs == r2.outputs == r3.outputs
    assert r1.cycles == r2.cycles == r3.cycles


@pytest.mark.parametrize("name", sorted(TYPEA_DESIGNS))
def test_typea_classified_a(name):
    builder = TYPEA_DESIGNS[name]
    prog = builder()
    c = classify(prog, simulate(builder()))
    assert c.dtype == "A", f"{name}: {c}"
