"""Serving: batched generation + continuous batching."""
import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import api
from repro.serve.engine import ContinuousBatchingEngine, ServeEngine


@pytest.fixture(scope="module")
def small_lm():
    cfg = get_arch("smollm-135m").smoke()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_batched_generation(small_lm):
    cfg, params = small_lm
    eng = ServeEngine(cfg, params, batch=2, max_len=32)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 4))
    out = eng.generate(prompts, gen_len=6)
    assert out.shape == (2, 6)
    assert (out >= 0).all() and (out < cfg.vocab_size + 256).all()


def test_continuous_batching_completes_all(small_lm):
    cfg, params = small_lm
    eng = ContinuousBatchingEngine(cfg, params, batch=2, max_len=32)
    rng = np.random.default_rng(1)
    requests = [rng.integers(0, cfg.vocab_size, (3,)) for _ in range(5)]
    done = eng.run(requests, gen_len=4)
    assert len(done) == 5                       # 5 requests over 2 slots
    for slot, toks in done:
        assert len(toks) == 4


def test_continuous_batching_reuses_slots(small_lm):
    cfg, params = small_lm
    eng = ContinuousBatchingEngine(cfg, params, batch=1, max_len=32)
    rng = np.random.default_rng(2)
    done = eng.run([rng.integers(0, cfg.vocab_size, (2,)) for _ in range(3)],
                   gen_len=3)
    slots = [s for s, _ in done]
    assert slots == [0, 0, 0]                   # one slot served all three
