"""Differential fuzzing: generator vs trace vs hybrid vs incremental.

The hybrid engine's contract (ISSUE 3) is *bit-identical* results on every
design the generator engine can simulate.  ``fuzz_designs.build_case``
derives seeded random Programs covering the whole taxonomy — blocking
pipelines, NB drop/poll patterns, probes, watchdogs, cyclic credit loops,
true deadlocks — and every case is cross-checked:

  * ``trace="never"`` (generator reference) vs ``trace="auto"`` (straight-
    line trace, hybrid, or generator fallback — whatever auto selects):
    outputs, cycles, deadlock verdict + stall cycle, node-time multiset,
    FIFO tables, constraint count and the schedule-independent stats;
  * ``shuffle_seed`` sweeps: the generator engine under randomized task
    servicing must reproduce the same results (paper's determinism claim);
  * ``resimulate``/``resimulate_batch`` from a hybrid-compiled base vs a
    generator base, and against from-scratch simulation;
  * :class:`~repro.core.trace.HybridCache` memoized re-runs vs fresh runs.

~200 seeded cases run in tier-1; a slow-marked long tail scales the same
seeds up.  No hypothesis dependency — plain seeded randomness.
"""
import numpy as np
import pytest

from fuzz_designs import build_case, build_poll_case
from repro.core import resimulate, resimulate_batch, simulate
from repro.core.trace import HybridCache, TraceUnsupported, simulate_hybrid

N_TIER1_SEEDS = 208
N_POLL_SEEDS = 48


def _assert_equal(g, a, seed, check_stats=True):
    assert a.outputs == g.outputs, seed
    assert a.cycles == g.cycles, seed
    assert a.deadlock == g.deadlock, seed
    assert a.deadlock_cycle == g.deadlock_cycle, seed
    assert a.depths == g.depths, seed
    if g.deadlock:
        return
    assert len(a.constraints) == len(g.constraints), seed
    if check_stats:
        assert a.stats.nodes == g.stats.nodes, seed
        assert a.stats.edges == g.stats.edges, seed
        assert a.stats.queries == g.stats.queries, seed
        assert a.stats.skipped_probes == g.stats.skipped_probes, seed
    g1, g2 = g.graph.graph, a.graph.graph
    assert g1.n_nodes == g2.n_nodes and g1.n_edges == g2.n_edges, seed
    assert sorted(g1.times()) == sorted(g2.times()), seed
    for t1, t2 in zip(g.graph.fifos, a.graph.fifos):
        np.testing.assert_array_equal(np.sort(t1.write_times),
                                      np.sort(t2.write_times))
        np.testing.assert_array_equal(np.sort(t1.read_times),
                                      np.sort(t2.read_times))
        assert list(t1.values) == list(t2.values), seed


def _run_case(seed, scale=1):
    builder, meta = build_case(seed, scale=scale)
    g = simulate(builder(), trace="never")
    a = simulate(builder(), trace="auto")
    _assert_equal(g, a, (seed, meta))

    if seed % 4 == 0:
        # schedule independence: shuffled generator servicing order
        for s in (1, 7):
            r = simulate(builder(), trace="never", shuffle_seed=s)
            assert r.outputs == g.outputs, (seed, s, meta)
            assert r.cycles == g.cycles, (seed, s, meta)
            assert r.deadlock == g.deadlock, (seed, s, meta)

    if seed % 4 == 1 and not g.deadlock:
        # incremental/batched re-simulation differential (hybrid base vs
        # generator base vs from-scratch)
        rng = np.random.default_rng(seed)
        D = rng.integers(1, 8, size=(4, len(g.depths)))
        og = resimulate_batch(g, D)
        oa = resimulate_batch(a, D)
        np.testing.assert_array_equal(og.ok, oa.ok, err_msg=str(seed))
        np.testing.assert_array_equal(og.cycles, oa.cycles,
                                      err_msg=str(seed))
        np.testing.assert_array_equal(og.status, oa.status,
                                      err_msg=str(seed))
        dv = tuple(int(x) for x in D[0])
        inc = resimulate(a, dv)
        full = simulate(builder(), depths=dv, trace="never")
        assert inc.result.cycles == full.cycles, (seed, dv)
        assert inc.result.deadlock == full.deadlock, (seed, dv)
        assert inc.result.outputs == full.outputs, (seed, dv)

    if seed % 8 == 2:
        # memoized hybrid re-runs must stay exact (cache replay + divergence)
        cache = HybridCache()
        r1 = simulate(builder(), trace="auto", hybrid_cache=cache)
        r2 = simulate(builder(), trace="auto", hybrid_cache=cache)
        _assert_equal(r1, r2, (seed, "memo-rerun"))
        dv = tuple(max(1, d // 2) for d in g.depths)
        rc = simulate(builder(), depths=dv, trace="auto", hybrid_cache=cache)
        rf = simulate(builder(), depths=dv, trace="never")
        _assert_equal(rf, rc, (seed, "memo-depths", dv))


@pytest.mark.parametrize("seed", range(N_TIER1_SEEDS))
def test_fuzz_differential(seed):
    _run_case(seed)


@pytest.mark.slow
@pytest.mark.parametrize("seed", range(N_TIER1_SEEDS, N_TIER1_SEEDS + 100))
def test_fuzz_differential_long_tail(seed):
    _run_case(seed, scale=6)


def _run_poll_case(seed, scale=1):
    """Differential cross-check for the query-periodization fuzz corpus:
    generator reference vs auto (periodized hybrid) vs the un-periodized
    hybrid — the burst fast path and the per-query path must agree
    bit-for-bit, including query/forced-false stats."""
    builder, meta = build_poll_case(seed, scale=scale)
    g = simulate(builder(), trace="never")
    a = simulate(builder(), trace="auto")
    _assert_equal(g, a, (seed, meta))
    assert a.stats.queries_forced_false == g.stats.queries_forced_false, seed
    if not g.deadlock:
        hp = simulate_hybrid(builder(), periodize=True)
        hn = simulate_hybrid(builder(), periodize=False)
        _assert_equal(g, hp, (seed, "periodized", meta))
        _assert_equal(g, hn, (seed, "no-periodize", meta))
        assert hn.stats.queries_periodized == 0, seed
    if seed % 3 == 0 and not g.deadlock:
        cache = HybridCache()
        r1 = simulate(builder(), trace="auto", hybrid_cache=cache)
        r2 = simulate(builder(), trace="auto", hybrid_cache=cache)
        _assert_equal(r1, r2, (seed, "poll-memo"))


@pytest.mark.parametrize("seed", range(N_POLL_SEEDS))
def test_fuzz_poll_differential(seed):
    _run_poll_case(seed)


@pytest.mark.slow
@pytest.mark.parametrize("seed", range(N_POLL_SEEDS, N_POLL_SEEDS + 24))
def test_fuzz_poll_differential_long_tail(seed):
    _run_poll_case(seed, scale=5)


def test_fuzz_poll_exercises_periodizer():
    """The poll corpus must hit both sides of the periodizer: bulk-resolved
    bursts AND queries left to per-query interpretation (gap changes,
    nested sites, final successes)."""
    bulk = bursts = per_query = 0
    for seed in range(N_POLL_SEEDS):
        builder, _ = build_poll_case(seed)
        try:
            r = simulate_hybrid(builder())
        except TraceUnsupported:
            continue                   # reported deadlocks stay covered above
        info = r.graph._hybrid
        bulk += info["bulk_queries"]
        bursts += info["bursts"]
        per_query += info["queries"] - info["bulk_queries"]
    assert bulk > 0 and bursts > 0       # fast path exercised
    assert per_query > 0                 # fallback exercised


def test_fuzz_poll_covers_multisite_and_success_streams():
    """The seed range must include live multi-site watcher and NB-success
    drain cases, and at least one of each must actually reach the bulk
    fast path (mixed-outcome tuples / success streams are periodizable by
    construction at commensurate rates)."""
    ms_live = nd_live = ms_bulk = nd_bulk = 0
    for seed in range(N_POLL_SEEDS):
        builder, meta = build_poll_case(seed)
        if not (meta["msite"] or meta["nbdrain"]):
            continue
        try:
            r = simulate_hybrid(builder())
        except TraceUnsupported:
            continue
        bulk = r.graph._hybrid["bulk_queries"]
        if meta["msite"]:
            ms_live += 1
            ms_bulk += bulk
        if meta["nbdrain"]:
            nd_live += 1
            nd_bulk += bulk
    assert ms_live > 0 and nd_live > 0
    assert ms_bulk > 0 and nd_bulk > 0


def test_fuzz_poll_exercises_batch_solver():
    """The tier-1 poll cases are too small to cross the default batch-
    solver threshold, so a corpus slice runs with the solver forced on
    (batch_min=1) and is cross-checked against the generator engine —
    periodization and the batch solver compose on real fuzz designs."""
    from repro.core.trace import HybridSim

    batch = 0
    for seed in range(0, N_POLL_SEEDS, 5):
        builder, meta = build_poll_case(seed)
        g = simulate(builder(), trace="never")
        if g.deadlock:
            continue
        hb = HybridSim(builder(), batch_min=1).run()
        _assert_equal(g, hb, (seed, "batch-forced", meta))
        batch += hb.graph._hybrid["batch_rows"]
    assert batch > 0                     # the solver actually committed rows


def test_fuzz_covers_all_engines():
    """The seed range must actually exercise every path: straight-line
    trace, hybrid, generator fallback, and deadlock verdicts."""
    engines = set()
    deadlocks = 0
    for seed in range(N_TIER1_SEEDS):
        builder, _ = build_case(seed)
        r = simulate(builder(), trace="auto")
        engines.add(r.engine)
        deadlocks += int(r.deadlock)
    assert engines == {"omnisim", "omnisim-trace", "omnisim-hybrid"}
    assert deadlocks >= 5
