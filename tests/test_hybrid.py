"""Hybrid trace compilation (ISSUE 3): exactness, downstream reuse, memo.

The contract: ``simulate(p, trace="auto")`` on a dynamic (NB/probe) design
takes the hybrid segmented replay and produces a ``SimResult``
indistinguishable from the generator engine's — outputs, cycles, deadlock
reports, graph shape and times, FIFO tables, constraints, and the
schedule-independent stats — while ``resimulate``/``resimulate_batch``
work unchanged on the pre-built incremental cache.
"""
import numpy as np
import pytest

from repro.core import (classify, classify_dynamic, longest_path_numpy,
                        resimulate, resimulate_batch, simulate)
from repro.core.program import Delay, Emit, Program, Read, ReadNB, Write
from repro.core.trace import HybridCache, TraceUnsupported, simulate_hybrid
from repro.designs.dynamic import DYNAMIC_DESIGNS, watchdog_pipe
from repro.designs.paper import PAPER_DESIGNS

# every paper design with live NB/probe control flow must take the hybrid
# path under auto (deadlock stays on the generator path; fig4_ex3 is
# blocking-only and stays on the straight-line trace path)
_HYBRID_SMALL = {
    "fig4_ex2": lambda: PAPER_DESIGNS["fig4_ex2"](n=64),
    "fig4_ex4a": lambda: PAPER_DESIGNS["fig4_ex4a"](n=64),
    "fig4_ex4a_d": lambda: PAPER_DESIGNS["fig4_ex4a_d"](n=64),
    "fig4_ex4b": lambda: PAPER_DESIGNS["fig4_ex4b"](n=64),
    "fig4_ex4b_d": lambda: PAPER_DESIGNS["fig4_ex4b_d"](n=64),
    "fig4_ex5": lambda: PAPER_DESIGNS["fig4_ex5"](n=64),
    "fig2_timer": lambda: PAPER_DESIGNS["fig2_timer"](n=64),
    "branch": lambda: PAPER_DESIGNS["branch"](prog_len=128),
    "multicore": lambda: PAPER_DESIGNS["multicore"](cores=4, prog_len=32),
    "watchdog_pipe": lambda: watchdog_pipe(items=96, stages=2, depth=4,
                                           poll_gap=16),
}


def _assert_bit_identical(g, h, name):
    assert h.outputs == g.outputs, name
    assert h.cycles == g.cycles, name
    assert h.deadlock == g.deadlock, name
    assert h.depths == g.depths, name
    assert h.stats.nodes == g.stats.nodes, name
    assert h.stats.edges == g.stats.edges, name
    assert h.stats.queries == g.stats.queries, name
    assert h.stats.queries_forced_false == g.stats.queries_forced_false, name
    assert h.stats.skipped_probes == g.stats.skipped_probes, name
    assert len(h.constraints) == len(g.constraints), name
    g1, g2 = g.graph.graph, h.graph.graph
    assert g1.n_nodes == g2.n_nodes and g1.n_edges == g2.n_edges, name
    assert sorted(g1.times()) == sorted(g2.times()), name
    for t1, t2 in zip(g.graph.fifos, h.graph.fifos):
        np.testing.assert_array_equal(np.sort(t1.write_times),
                                      np.sort(t2.write_times))
        np.testing.assert_array_equal(np.sort(t1.read_times),
                                      np.sort(t2.read_times))
        assert list(t1.values) == list(t2.values), name


# ----------------------------------------------------------- exactness sweep
@pytest.mark.parametrize("name", sorted(_HYBRID_SMALL))
def test_hybrid_equals_generator(name):
    b = _HYBRID_SMALL[name]
    g = simulate(b(), trace="never")
    h = simulate(b(), trace="auto")
    assert h.engine == "omnisim-hybrid", name
    _assert_bit_identical(g, h, name)


@pytest.mark.parametrize("name", sorted(_HYBRID_SMALL))
def test_hybrid_graph_satisfies_csr_contract(name):
    """TraceSimGraph over a segmented run: CSR longest path reproduces the
    eager times (NB_FAIL/PROBE nodes included), and node materialization
    feeds the taxonomy classifier."""
    b = _HYBRID_SMALL[name]
    h = simulate(b(), trace="auto")
    graph = h.graph.graph
    indptr, src, wgt, base = graph.to_csr()
    np.testing.assert_array_equal(
        longest_path_numpy(indptr, src, wgt, base), graph.times())
    c = classify(b(), h)
    assert c.has_nonblocking, name


# --------------------------------------------------- downstream incremental
@pytest.mark.parametrize("name", ["fig4_ex5", "fig2_timer", "branch",
                                  "watchdog_pipe"])
def test_resimulate_batch_from_hybrid_base(name):
    """The pre-built CompiledGraph of a hybrid run must drive
    resimulate/resimulate_batch verdict-for-verdict like a generator base."""
    b = _HYBRID_SMALL[name]
    base_h = simulate(b(), trace="auto")
    base_g = simulate(b(), trace="never")
    assert getattr(base_h.graph, "_incr_cache", None) is not None
    rng = np.random.default_rng(17)
    D = rng.integers(1, 9, size=(12, len(base_h.depths)))
    oh = resimulate_batch(base_h, D)
    og = resimulate_batch(base_g, D)
    np.testing.assert_array_equal(oh.ok, og.ok)
    np.testing.assert_array_equal(oh.cycles, og.cycles)
    np.testing.assert_array_equal(oh.status, og.status)
    dv = tuple(int(x) for x in D[0])
    ih = resimulate(base_h, dv)
    full = simulate(b(), depths=dv, trace="never")
    assert ih.result.cycles == full.cycles
    assert ih.result.outputs == full.outputs


# ------------------------------------------------------- segment memoization
def test_cache_full_replay_skips_generators():
    cache = HybridCache()
    b = _HYBRID_SMALL["fig2_timer"]
    r1 = simulate(b(), trace="auto", hybrid_cache=cache)
    assert cache.hits == 0 and cache.misses == 3
    # warm repeat: the whole-run replay serves every row from the verified
    # _FullRun entry — no generator runs, no segment lookups at all
    r2 = simulate(b(), trace="auto", hybrid_cache=cache)
    assert cache.full_hits == 1 and cache.full_rejects == 0
    assert cache.divergences == 0
    assert (r2.graph._hybrid["cache_bulk_rows"] == r2.graph._hybrid["ops"]
            > 0)
    _assert_bit_identical(r1, r2, "full replay")
    # the per-module segment cache still drives the periodize=False path
    r3 = simulate(b(), trace="auto", hybrid_cache=cache, periodize=False)
    assert cache.hits == 3 and cache.divergences == 0
    _assert_bit_identical(r1, r3, "segment memo")


def test_cache_divergence_and_branch_reconvergence():
    """Perturbed depths flip NB outcomes: the first divergent run
    materializes generators; revisiting a previously-seen depth vector
    switches back to the stored branch instead of re-running them."""
    cache = HybridCache()
    b = lambda: PAPER_DESIGNS["fig4_ex4b"](n=64)
    base = simulate(b(), trace="auto", hybrid_cache=cache)
    r1 = simulate(b(), depths=(1,), trace="auto", hybrid_cache=cache)
    assert cache.divergences >= 1          # outcomes genuinely changed
    g1 = simulate(b(), depths=(1,), trace="never")
    _assert_bit_identical(g1, r1, "diverged run")
    assert r1.outputs != base.outputs      # the witness classify hunts for
    before = cache.divergences
    # periodize=False bypasses the whole-run replay, so this exercises the
    # segment cache's branch store: revisiting a seen depth vector switches
    # to the recorded branch instead of re-running generators
    r2 = simulate(b(), depths=(1,), trace="auto", hybrid_cache=cache,
                  periodize=False)
    assert cache.divergences == before     # replayed from the stored branch
    assert cache.hits + cache.switches >= 2
    _assert_bit_identical(g1, r2, "reconverged run")
    # the default path serves the same revisit from the _FullRun entry the
    # divergent run stored — keyed by content, so the perturbed-depth entry
    # never collides with the base run's
    r3 = simulate(b(), depths=(1,), trace="auto", hybrid_cache=cache)
    assert cache.full_hits == 1 and cache.full_rejects == 0
    assert cache.divergences == before
    _assert_bit_identical(g1, r3, "full replay at perturbed depths")


def test_cache_keys_on_content_not_names():
    """branch(96) and branch(160) share every name, and their NB outcome
    streams agree right up to the shorter run's end — a name-keyed segment
    cache silently replayed branch(96)'s results for branch(160) (zero
    divergences: the cached stream just ends early).  Both cache layers
    must key on module content so each size gets its own entries."""
    cache = HybridCache()
    b1 = lambda: PAPER_DESIGNS["branch"](prog_len=96)
    b2 = lambda: PAPER_DESIGNS["branch"](prog_len=160)
    assert HybridCache.signature(b1()) != HybridCache.signature(b2())
    g2 = simulate(b2(), trace="never")
    r1 = simulate(b1(), trace="always", hybrid_cache=cache)
    r2 = simulate(b2(), trace="always", hybrid_cache=cache)
    assert cache.full_hits == 0            # distinct fingerprints: cold both
    _assert_bit_identical(g2, r2, "branch(160) after branch(96) warmed")
    assert r1.cycles != r2.cycles and r1.outputs != r2.outputs
    w1 = simulate(b1(), trace="always", hybrid_cache=cache)
    w2 = simulate(b2(), trace="always", hybrid_cache=cache)
    assert cache.full_hits == 2 and cache.full_rejects == 0
    _assert_bit_identical(r1, w1, "branch(96) warm")
    _assert_bit_identical(r2, w2, "branch(160) warm")
    # depth perturbations of the SAME build still share segment entries
    # (the signature excludes FIFO depths)
    p1, p2 = b1(), b1()
    p2.fifos[0].depth += 3
    assert HybridCache.signature(p1) == HybridCache.signature(p2)


def test_full_replay_rejects_corrupt_entry_and_falls_back():
    """Per-entry verification: a tampered committed time (fixpoint layer)
    or a flipped query outcome (verdict layer) must reject the cached run
    and fall back to the exact protocol — which then re-stores a clean
    entry that serves the next warm hit."""
    from repro.core.trace import program_fingerprint

    cache = HybridCache()
    b = _HYBRID_SMALL["fig2_timer"]
    r1 = simulate(b(), trace="always", hybrid_cache=cache)
    key = program_fingerprint(b())
    run = cache.lookup_full(key)
    assert run is not None
    run.times[0][0] += 1                   # break the max-equation fixpoint
    r2 = simulate(b(), trace="always", hybrid_cache=cache)
    assert cache.full_rejects == 1 and cache.full_hits == 0
    _assert_bit_identical(r1, r2, "fallback after time corruption")
    run = cache.lookup_full(key)           # the fallback re-stored cleanly
    run.cons[0, 5] ^= 1                    # flip a recorded query verdict
    r3 = simulate(b(), trace="always", hybrid_cache=cache)
    assert cache.full_rejects == 2 and cache.full_hits == 0
    _assert_bit_identical(r1, r3, "fallback after outcome corruption")
    r4 = simulate(b(), trace="always", hybrid_cache=cache)
    assert cache.full_hits == 1
    _assert_bit_identical(r1, r4, "clean warm hit after re-store")


def test_classify_dynamic_uses_shared_cache():
    c = classify_dynamic(lambda: PAPER_DESIGNS["fig4_ex4b"](n=64))
    assert c.dtype == "C"
    c2 = classify_dynamic(lambda: PAPER_DESIGNS["fig2_timer"](n=64))
    assert c2.dtype == "C"
    c3 = classify_dynamic(lambda: PAPER_DESIGNS["fig4_ex2"](n=64))
    assert c3.dtype == "B"


def test_cache_fast_forward_through_probes_and_delays():
    """Divergence materialization must fast-forward the fresh generator
    through every yield class in the cached prefix — dead probes, delays,
    emits, blocking ops — before resuming live at the diverged query."""
    from repro.core.program import Full, WriteNB

    def build():
        prog = Program("ffwd", declared_type="C")
        f = prog.fifo("f", 3)

        @prog.module("p")
        def p():
            dropped = 0
            yield Emit("banner", "ffwd")
            for i in range(8):
                yield Full(f, used=False)      # dead probe in the prefix
                yield Delay(1)
                ok = yield WriteNB(f, i)       # outcome flips with depth
                if not ok:
                    dropped += 1
            yield Emit("dropped", dropped)

        @prog.module("c")
        def c():
            total = 0
            for _ in range(6):
                ok, v = yield ReadNB(f)
                if ok:
                    total += v
                yield Delay(2)
            yield Emit("got", total)

        return prog

    cache = HybridCache()
    base = simulate(build(), trace="auto", hybrid_cache=cache)
    for dv in ((1,), (8,), (2,), (1,)):
        r = simulate(build(), depths=dv, trace="auto", hybrid_cache=cache)
        g = simulate(build(), depths=dv, trace="never")
        _assert_bit_identical(g, r, dv)
    assert cache.divergences >= 1              # materialization exercised
    assert base.outputs["banner"] == "ffwd"


# ----------------------------------------------------------------- plumbing
def test_watchdog_registered_and_hybrid_info():
    assert "watchdog_pipe" in DYNAMIC_DESIGNS
    h = simulate(watchdog_pipe(items=64, stages=2, depth=4, poll_gap=8),
                 trace="always")
    assert h.engine == "omnisim-hybrid"
    info = h.graph._hybrid
    assert info["queries"] > 0 and info["ops"] > info["queries"]
    assert info["segments"] >= 3           # compiled blocking runs exist


def test_trace_always_raises_only_when_hybrid_cannot_help():
    # deadlock: even the hybrid path defers to the generator engine
    with pytest.raises(TraceUnsupported):
        simulate(PAPER_DESIGNS["deadlock"](n=8), trace="always")
    # dynamic control flow alone: handled, no raise
    r = simulate(PAPER_DESIGNS["fig2_timer"](n=32), trace="always")
    assert r.engine == "omnisim-hybrid"


def test_simulate_hybrid_direct_entry():
    r = simulate_hybrid(PAPER_DESIGNS["branch"](prog_len=64))
    g = simulate(PAPER_DESIGNS["branch"](prog_len=64), trace="never")
    _assert_bit_identical(g, r, "direct")


# ------------------------------------------------- batch frontier solver
def test_batch_solver_truncates_at_unrecorded_sources():
    """Provisional-prefix validation: the producer's recorded writes run
    far past the consumer's recorded reads (the consumer is parked at a
    query), so the batch solver must truncate the producer's window at the
    first write whose WAR-target read is unrecorded — committing only the
    validated prefix — and still match the generator engine exactly."""
    from repro.core.trace import HybridSim
    from repro.core.program import ReadNB as _ReadNB

    def build():
        prog = Program("trunc", declared_type="C")
        data = prog.fifo("data", 2)
        go = prog.fifo("go", 1)

        @prog.module("producer")       # records all 40 writes untimed
        def producer():
            for i in range(40):
                yield Write(data, i)
            yield Emit("sent", 40)

        @prog.module("consumer")       # parked at the poll while the
        def consumer():                # producer's window runs ahead
            polls = 0
            for _ in range(10):
                ok, _v = yield _ReadNB(go)
                polls += 1
                if ok:
                    break
            total = 0
            for _ in range(40):
                total += (yield Read(data))
            yield Emit("got", (total, polls))

        return prog

    g = simulate(build(), trace="never")
    h = HybridSim(build(), batch_min=1).run()
    _assert_bit_identical(g, h, "trunc")
    info = h.graph._hybrid
    assert info["batch_rows"] > 0          # batch solver actually engaged
    assert g.stats.queries_forced_false == h.stats.queries_forced_false > 0


def test_batch_solver_matches_scalar_frontier_on_coupled_pipeline():
    """Cross-module constraints land inside the provisional windows of a
    tightly-coupled pipeline (depth-sized WAR ping-pong): the batch solver
    and the scalar frontier must commit identical times, and both must
    match the generator engine."""
    from repro.core.trace import HybridSim

    b = lambda: watchdog_pipe(items=192, stages=3, depth=4, poll_gap=8)
    g = simulate(b(), trace="never")
    hb = HybridSim(b(), batch_min=1).run()         # batch solver forced
    hs = HybridSim(b(), batch_min=10**9).run()     # scalar frontier only
    _assert_bit_identical(g, hb, "batch")
    _assert_bit_identical(g, hs, "scalar")
    assert hb.graph._hybrid["batch_rows"] > 0
    assert hs.graph._hybrid["batch_rows"] == 0
    np.testing.assert_array_equal(hb.graph.graph.times(),
                                  hs.graph.graph.times())


def test_batch_solver_war_cycle_defers_to_generator():
    """A WAR cycle inside the provisional window (recorded order invalid
    under these depths): the batch solver must detect non-convergence,
    commit nothing, and let the run defer to the generator engine's exact
    deadlock report — with and without the batch solver forced on."""
    from repro.core.trace import HybridSim
    from repro.core.program import ReadNB as _ReadNB

    def build():
        prog = Program("warcycle", declared_type="C")
        x = prog.fifo("x", 1)
        y = prog.fifo("y", 1)
        z = prog.fifo("z", 1)

        @prog.module("a")
        def a():
            ok, _ = yield _ReadNB(z)   # dynamic: forces the hybrid path
            yield Write(x, 0)
            yield Write(x, 1)
            v = yield Read(y)
            yield Emit("a", (ok, v))

        @prog.module("b")
        def b():
            yield Write(y, 0)
            yield Write(y, 1)
            v = yield Read(x)
            yield Emit("b", v)

        return prog

    with pytest.raises(TraceUnsupported):
        HybridSim(build(), batch_min=1).run()
    with pytest.raises(TraceUnsupported):
        HybridSim(build(), batch_min=10**9).run()
    g = simulate(build(), trace="never")
    assert g.deadlock
    a = simulate(build(), trace="auto")    # falls back to the generator
    assert a.engine == "omnisim"
    assert a.deadlock and a.deadlock_cycle == g.deadlock_cycle
    assert a.outputs == g.outputs


def test_periodizer_stats_and_disable_knob():
    """Periodized and per-query paths are bit-identical; the knob and the
    stats plumbing (SimStats.queries_periodized, _hybrid counters) report
    what actually happened."""
    b = lambda: PAPER_DESIGNS["fig2_timer"](n=192)
    g = simulate(b(), trace="never")
    hp = simulate_hybrid(b(), periodize=True)
    hn = simulate_hybrid(b(), periodize=False)
    _assert_bit_identical(g, hp, "periodized")
    _assert_bit_identical(g, hn, "no-periodize")
    assert hp.stats.queries_periodized > 0
    assert hp.graph._hybrid["bulk_queries"] == hp.stats.queries_periodized
    assert hp.graph._hybrid["bursts"] >= 1
    assert hn.stats.queries_periodized == 0
    assert g.stats.queries_periodized == 0     # generator engine: never set
    # simulate() forwards the knob
    hp2 = simulate(b(), trace="always", periodize=False)
    _assert_bit_identical(g, hp2, "simulate-knob")
    assert hp2.stats.queries_periodized == 0
