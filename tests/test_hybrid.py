"""Hybrid trace compilation (ISSUE 3): exactness, downstream reuse, memo.

The contract: ``simulate(p, trace="auto")`` on a dynamic (NB/probe) design
takes the hybrid segmented replay and produces a ``SimResult``
indistinguishable from the generator engine's — outputs, cycles, deadlock
reports, graph shape and times, FIFO tables, constraints, and the
schedule-independent stats — while ``resimulate``/``resimulate_batch``
work unchanged on the pre-built incremental cache.
"""
import numpy as np
import pytest

from repro.core import (classify, classify_dynamic, longest_path_numpy,
                        resimulate, resimulate_batch, simulate)
from repro.core.program import Delay, Emit, Program, Read, ReadNB, Write
from repro.core.trace import HybridCache, TraceUnsupported, simulate_hybrid
from repro.designs.dynamic import DYNAMIC_DESIGNS, watchdog_pipe
from repro.designs.paper import PAPER_DESIGNS

# every paper design with live NB/probe control flow must take the hybrid
# path under auto (deadlock stays on the generator path; fig4_ex3 is
# blocking-only and stays on the straight-line trace path)
_HYBRID_SMALL = {
    "fig4_ex2": lambda: PAPER_DESIGNS["fig4_ex2"](n=64),
    "fig4_ex4a": lambda: PAPER_DESIGNS["fig4_ex4a"](n=64),
    "fig4_ex4a_d": lambda: PAPER_DESIGNS["fig4_ex4a_d"](n=64),
    "fig4_ex4b": lambda: PAPER_DESIGNS["fig4_ex4b"](n=64),
    "fig4_ex4b_d": lambda: PAPER_DESIGNS["fig4_ex4b_d"](n=64),
    "fig4_ex5": lambda: PAPER_DESIGNS["fig4_ex5"](n=64),
    "fig2_timer": lambda: PAPER_DESIGNS["fig2_timer"](n=64),
    "branch": lambda: PAPER_DESIGNS["branch"](prog_len=128),
    "multicore": lambda: PAPER_DESIGNS["multicore"](cores=4, prog_len=32),
    "watchdog_pipe": lambda: watchdog_pipe(items=96, stages=2, depth=4,
                                           poll_gap=16),
}


def _assert_bit_identical(g, h, name):
    assert h.outputs == g.outputs, name
    assert h.cycles == g.cycles, name
    assert h.deadlock == g.deadlock, name
    assert h.depths == g.depths, name
    assert h.stats.nodes == g.stats.nodes, name
    assert h.stats.edges == g.stats.edges, name
    assert h.stats.queries == g.stats.queries, name
    assert h.stats.queries_forced_false == g.stats.queries_forced_false, name
    assert h.stats.skipped_probes == g.stats.skipped_probes, name
    assert len(h.constraints) == len(g.constraints), name
    g1, g2 = g.graph.graph, h.graph.graph
    assert g1.n_nodes == g2.n_nodes and g1.n_edges == g2.n_edges, name
    assert sorted(g1.times()) == sorted(g2.times()), name
    for t1, t2 in zip(g.graph.fifos, h.graph.fifos):
        np.testing.assert_array_equal(np.sort(t1.write_times),
                                      np.sort(t2.write_times))
        np.testing.assert_array_equal(np.sort(t1.read_times),
                                      np.sort(t2.read_times))
        assert list(t1.values) == list(t2.values), name


# ----------------------------------------------------------- exactness sweep
@pytest.mark.parametrize("name", sorted(_HYBRID_SMALL))
def test_hybrid_equals_generator(name):
    b = _HYBRID_SMALL[name]
    g = simulate(b(), trace="never")
    h = simulate(b(), trace="auto")
    assert h.engine == "omnisim-hybrid", name
    _assert_bit_identical(g, h, name)


@pytest.mark.parametrize("name", sorted(_HYBRID_SMALL))
def test_hybrid_graph_satisfies_csr_contract(name):
    """TraceSimGraph over a segmented run: CSR longest path reproduces the
    eager times (NB_FAIL/PROBE nodes included), and node materialization
    feeds the taxonomy classifier."""
    b = _HYBRID_SMALL[name]
    h = simulate(b(), trace="auto")
    graph = h.graph.graph
    indptr, src, wgt, base = graph.to_csr()
    np.testing.assert_array_equal(
        longest_path_numpy(indptr, src, wgt, base), graph.times())
    c = classify(b(), h)
    assert c.has_nonblocking, name


# --------------------------------------------------- downstream incremental
@pytest.mark.parametrize("name", ["fig4_ex5", "fig2_timer", "branch",
                                  "watchdog_pipe"])
def test_resimulate_batch_from_hybrid_base(name):
    """The pre-built CompiledGraph of a hybrid run must drive
    resimulate/resimulate_batch verdict-for-verdict like a generator base."""
    b = _HYBRID_SMALL[name]
    base_h = simulate(b(), trace="auto")
    base_g = simulate(b(), trace="never")
    assert getattr(base_h.graph, "_incr_cache", None) is not None
    rng = np.random.default_rng(17)
    D = rng.integers(1, 9, size=(12, len(base_h.depths)))
    oh = resimulate_batch(base_h, D)
    og = resimulate_batch(base_g, D)
    np.testing.assert_array_equal(oh.ok, og.ok)
    np.testing.assert_array_equal(oh.cycles, og.cycles)
    np.testing.assert_array_equal(oh.status, og.status)
    dv = tuple(int(x) for x in D[0])
    ih = resimulate(base_h, dv)
    full = simulate(b(), depths=dv, trace="never")
    assert ih.result.cycles == full.cycles
    assert ih.result.outputs == full.outputs


# ------------------------------------------------------- segment memoization
def test_cache_full_replay_skips_generators():
    cache = HybridCache()
    b = _HYBRID_SMALL["fig2_timer"]
    r1 = simulate(b(), trace="auto", hybrid_cache=cache)
    assert cache.hits == 0 and cache.misses == 3
    r2 = simulate(b(), trace="auto", hybrid_cache=cache)
    assert cache.hits == 3 and cache.divergences == 0
    _assert_bit_identical(r1, r2, "memo")


def test_cache_divergence_and_branch_reconvergence():
    """Perturbed depths flip NB outcomes: the first divergent run
    materializes generators; revisiting a previously-seen depth vector
    switches back to the stored branch instead of re-running them."""
    cache = HybridCache()
    b = lambda: PAPER_DESIGNS["fig4_ex4b"](n=64)
    base = simulate(b(), trace="auto", hybrid_cache=cache)
    r1 = simulate(b(), depths=(1,), trace="auto", hybrid_cache=cache)
    assert cache.divergences >= 1          # outcomes genuinely changed
    g1 = simulate(b(), depths=(1,), trace="never")
    _assert_bit_identical(g1, r1, "diverged run")
    assert r1.outputs != base.outputs      # the witness classify hunts for
    before = cache.divergences
    r2 = simulate(b(), depths=(1,), trace="auto", hybrid_cache=cache)
    assert cache.divergences == before     # replayed from the stored branch
    assert cache.hits + cache.switches >= 2
    _assert_bit_identical(g1, r2, "reconverged run")


def test_classify_dynamic_uses_shared_cache():
    c = classify_dynamic(lambda: PAPER_DESIGNS["fig4_ex4b"](n=64))
    assert c.dtype == "C"
    c2 = classify_dynamic(lambda: PAPER_DESIGNS["fig2_timer"](n=64))
    assert c2.dtype == "C"
    c3 = classify_dynamic(lambda: PAPER_DESIGNS["fig4_ex2"](n=64))
    assert c3.dtype == "B"


def test_cache_fast_forward_through_probes_and_delays():
    """Divergence materialization must fast-forward the fresh generator
    through every yield class in the cached prefix — dead probes, delays,
    emits, blocking ops — before resuming live at the diverged query."""
    from repro.core.program import Full, WriteNB

    def build():
        prog = Program("ffwd", declared_type="C")
        f = prog.fifo("f", 3)

        @prog.module("p")
        def p():
            dropped = 0
            yield Emit("banner", "ffwd")
            for i in range(8):
                yield Full(f, used=False)      # dead probe in the prefix
                yield Delay(1)
                ok = yield WriteNB(f, i)       # outcome flips with depth
                if not ok:
                    dropped += 1
            yield Emit("dropped", dropped)

        @prog.module("c")
        def c():
            total = 0
            for _ in range(6):
                ok, v = yield ReadNB(f)
                if ok:
                    total += v
                yield Delay(2)
            yield Emit("got", total)

        return prog

    cache = HybridCache()
    base = simulate(build(), trace="auto", hybrid_cache=cache)
    for dv in ((1,), (8,), (2,), (1,)):
        r = simulate(build(), depths=dv, trace="auto", hybrid_cache=cache)
        g = simulate(build(), depths=dv, trace="never")
        _assert_bit_identical(g, r, dv)
    assert cache.divergences >= 1              # materialization exercised
    assert base.outputs["banner"] == "ffwd"


# ----------------------------------------------------------------- plumbing
def test_watchdog_registered_and_hybrid_info():
    assert "watchdog_pipe" in DYNAMIC_DESIGNS
    h = simulate(watchdog_pipe(items=64, stages=2, depth=4, poll_gap=8),
                 trace="always")
    assert h.engine == "omnisim-hybrid"
    info = h.graph._hybrid
    assert info["queries"] > 0 and info["ops"] > info["queries"]
    assert info["segments"] >= 3           # compiled blocking runs exist


def test_trace_always_raises_only_when_hybrid_cannot_help():
    # deadlock: even the hybrid path defers to the generator engine
    with pytest.raises(TraceUnsupported):
        simulate(PAPER_DESIGNS["deadlock"](n=8), trace="always")
    # dynamic control flow alone: handled, no raise
    r = simulate(PAPER_DESIGNS["fig2_timer"](n=32), trace="always")
    assert r.engine == "omnisim-hybrid"


def test_simulate_hybrid_direct_entry():
    r = simulate_hybrid(PAPER_DESIGNS["branch"](prog_len=64))
    g = simulate(PAPER_DESIGNS["branch"](prog_len=64), trace="never")
    _assert_bit_identical(g, r, "direct")
