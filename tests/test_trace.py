"""Trace compilation (core/trace.py): compiled-vs-generator exactness.

The contract (ISSUE 2 / paper Sec. 5.1): ``simulate(p, trace="auto")`` must
produce a ``SimResult`` indistinguishable from the generator engine's on
EVERY design — same outputs, cycles, deadlock report, FIFO tables, graph
times and downstream incremental/DSE behavior — replaying compiled op
arrays where the design allows it and falling back to the generator path
where control flow is cycle-dependent.
"""
import numpy as np
import pytest

from repro.core import (classify, resimulate, resimulate_batch, simulate,
                        longest_path_numpy)
from repro.core.program import (Delay, Emit, Program, Read, ReadNB, Write,
                                WriteNB)
from repro.core.trace import (TraceUnsupported, compile_trace, record_trace,
                              simulate_traced)
from repro.designs.paper import PAPER_DESIGNS
from repro.designs.typea import TYPEA_DESIGNS, producer_consumer, skynet_like

# reduced sizes: exactness is size-independent, keep the suite fast
_PAPER_SMALL = {
    "fig4_ex2": lambda: PAPER_DESIGNS["fig4_ex2"](n=64),
    "fig4_ex3": lambda: PAPER_DESIGNS["fig4_ex3"](n=64),
    "fig4_ex4a": lambda: PAPER_DESIGNS["fig4_ex4a"](n=64),
    "fig4_ex4a_d": lambda: PAPER_DESIGNS["fig4_ex4a_d"](n=64),
    "fig4_ex4b": lambda: PAPER_DESIGNS["fig4_ex4b"](n=64),
    "fig4_ex4b_d": lambda: PAPER_DESIGNS["fig4_ex4b_d"](n=64),
    "fig4_ex5": lambda: PAPER_DESIGNS["fig4_ex5"](n=64),
    "fig2_timer": lambda: PAPER_DESIGNS["fig2_timer"](n=64),
    "deadlock": lambda: PAPER_DESIGNS["deadlock"](n=8),
    "branch": lambda: PAPER_DESIGNS["branch"](prog_len=128),
    "multicore": lambda: PAPER_DESIGNS["multicore"](cores=4, prog_len=32),
}
_TYPEA_SMALL = {
    "producer_consumer": lambda: TYPEA_DESIGNS["producer_consumer"](n=48),
    "fir_filter": lambda: TYPEA_DESIGNS["fir_filter"](n=64),
    "window_conv": lambda: TYPEA_DESIGNS["window_conv"](rows=12, cols=12),
    "matmul_stream": lambda: TYPEA_DESIGNS["matmul_stream"](m=6, k=6, n=6),
    "sqrt_pipe": lambda: TYPEA_DESIGNS["sqrt_pipe"](n=48),
    "parallel_loops": lambda: TYPEA_DESIGNS["parallel_loops"](n=48),
    "nested_loops": lambda: TYPEA_DESIGNS["nested_loops"](outer=8, inner=8),
    "accumulators": lambda: TYPEA_DESIGNS["accumulators"](n=48),
    "vector_add_stream": lambda: TYPEA_DESIGNS["vector_add_stream"](n=96),
    "merge_sort_staged": lambda: TYPEA_DESIGNS["merge_sort_staged"](log_n=5),
    "huffman_pipe": lambda: TYPEA_DESIGNS["huffman_pipe"](n=64),
    "flowgnn_like": lambda: TYPEA_DESIGNS["flowgnn_like"](n_nodes=32),
    "skynet_like": lambda: TYPEA_DESIGNS["skynet_like"](items=48, depth=6),
    "latency_pipe": lambda: TYPEA_DESIGNS["latency_pipe"](items=24, ii=16),
}


def _assert_equal_results(r_gen, r_tr, name=""):
    assert r_tr.outputs == r_gen.outputs, name
    assert r_tr.cycles == r_gen.cycles, name
    assert r_tr.deadlock == r_gen.deadlock, name
    assert r_tr.deadlock_cycle == r_gen.deadlock_cycle, name
    assert r_tr.depths == r_gen.depths, name


# --------------------------------------------------------- exactness sweeps
@pytest.mark.parametrize("name", sorted(_TYPEA_SMALL))
def test_typea_compiled_equals_generator(name):
    """Blocking-only designs must take the compiled path and match exactly —
    including graph shape, times multiset and FIFO-table contents."""
    b = _TYPEA_SMALL[name]
    r_gen = simulate(b(), trace="never")
    r_tr = simulate(b(), trace="auto")
    assert r_tr.engine == "omnisim-trace", name
    _assert_equal_results(r_gen, r_tr, name)
    g1, g2 = r_gen.graph.graph, r_tr.graph.graph
    assert g1.n_nodes == g2.n_nodes and g1.n_edges == g2.n_edges
    assert r_gen.stats.nodes == r_tr.stats.nodes      # START excluded in both
    assert r_gen.stats.edges == r_tr.stats.edges
    assert sorted(g1.times()) == sorted(g2.times())
    for t1, t2 in zip(r_gen.graph.fifos, r_tr.graph.fifos):
        np.testing.assert_array_equal(np.sort(t1.write_times),
                                      np.sort(t2.write_times))
        np.testing.assert_array_equal(np.sort(t1.read_times),
                                      np.sort(t2.read_times))
        assert list(t1.values) == list(t2.values)   # leftover payloads


@pytest.mark.parametrize("name", sorted(_PAPER_SMALL))
def test_taxonomy_compiled_equals_generator(name):
    """Every taxonomy design (cyclic deps, NB accesses, deadlocks): auto
    mode must match the generator engine bit-for-bit, whether it compiled
    or fell back."""
    b = _PAPER_SMALL[name]
    r_gen = simulate(b(), trace="never")
    r_tr = simulate(b(), trace="auto")
    _assert_equal_results(r_gen, r_tr, name)
    if name in ("fig4_ex3",):        # cyclic but blocking-only: must compile
        assert r_tr.engine == "omnisim-trace"


@pytest.mark.parametrize("depth", [1, 2, 3, 7, 100])
@pytest.mark.parametrize("delay", [0, 1, 3])
def test_depth_delay_sweep_compiled(depth, delay):
    def build():
        prog = Program("pc", declared_type="A")
        data = prog.fifo("data", depth)

        @prog.module("producer")
        def producer():
            for i in range(1, 17):
                yield Write(data, i)

        @prog.module("consumer")
        def consumer():
            total = 0
            for _ in range(16):
                total += (yield Read(data))
                if delay:
                    yield Delay(delay)
            yield Emit("sum", total)

        return prog

    _assert_equal_results(simulate(build(), trace="never"),
                          simulate(build(), trace="always"))


# ------------------------------------------------------- fallback behaviour
def test_data_dependent_control_flow_takes_hybrid_path():
    """An NB outcome steering control flow cannot be straight-line compiled
    (``simulate_traced`` raises) — since PR 3 the hybrid segmented replay
    handles it: both 'always' and 'auto' return the hybrid result, exact."""
    def build():
        prog = Program("poll", declared_type="B")
        f = prog.fifo("f", 2)

        @prog.module("p")
        def p():
            yield Delay(10)
            yield Write(f, 42)

        @prog.module("c")
        def c():
            polls = 0
            while True:
                ok, v = yield ReadNB(f)
                polls += 1
                if ok:
                    break
            yield Emit("polls", polls)

        return prog

    with pytest.raises(TraceUnsupported):
        simulate_traced(build())          # the straight-line path still bails
    r = simulate(build(), trace="always")
    assert r.engine == "omnisim-hybrid"
    _assert_equal_results(simulate(build(), trace="never"), r)
    assert r.outputs == {"polls": 12}


def test_deadlock_falls_back_with_exact_stall_cycle():
    """Cyclic blocking wait: recording detects the untimed-KPN deadlock and
    the generator engine reports the exact stall cycle and blocked set."""
    b = _PAPER_SMALL["deadlock"]
    with pytest.raises(TraceUnsupported):
        simulate_traced(b())
    r = simulate(b(), trace="auto")
    assert r.deadlock and r.engine == "omnisim"
    assert set(r.outputs["__deadlock__"]) == {"task_a", "task_b"}


def test_depth_induced_deadlock_falls_back():
    """A design that only deadlocks because a FIFO is too small: the trace
    compiles, but WAR generation detects the structural deadlock (missing
    target read) and auto mode reproduces the generator report."""
    def leftover(depth):
        prog = Program("leftover", declared_type="A")
        d = prog.fifo("d", depth)

        @prog.module("p")
        def p():
            for i in range(8):
                yield Write(d, i)

        @prog.module("c")
        def c():
            tot = 0
            for _ in range(4):
                tot += (yield Read(d))
            yield Emit("sum", tot)

        return prog

    assert simulate(leftover(8)).engine == "omnisim-trace"
    with pytest.raises(TraceUnsupported):
        simulate_traced(leftover(3))
    _assert_equal_results(simulate(leftover(3), trace="never"),
                          simulate(leftover(3), trace="auto"))


def test_war_cycle_deadlock_falls_back():
    """Burst ping-pong with both channels at depth 1: regenerated WAR edges
    form a cycle — the replay refuses and the engine finds the deadlock."""
    def burst(depth):
        prog = Program("burst", declared_type="A")
        cmd = prog.fifo("cmd", depth)
        resp = prog.fifo("resp", depth)

        @prog.module("ctrl")
        def ctrl():
            for i in range(8):
                yield Write(cmd, i)
            tot = 0
            for _ in range(8):
                tot += (yield Read(resp))
            yield Emit("sum", tot)

        @prog.module("proc")
        def proc():
            for _ in range(8):
                v = yield Read(cmd)
                yield Write(resp, 2 * v)

        return prog

    assert simulate(burst(8)).engine == "omnisim-trace"
    with pytest.raises(TraceUnsupported):
        simulate_traced(burst(1))
    r = simulate(burst(1), trace="auto")
    assert r.deadlock
    _assert_equal_results(simulate(burst(1), trace="never"), r)


def test_hybrid_deadlock_mid_segment_falls_back_exact():
    """A design whose queries resolve fine until a blocking read that can
    never be satisfied: the hybrid engine must detect the mid-run deadlock,
    refuse (TraceUnsupported), and 'auto' must reproduce the generator
    engine's exact stall cycle, outputs and stats."""
    def build():
        prog = Program("dl_mid", declared_type="C")
        data = prog.fifo("data", 2)
        done = prog.fifo("done", 1)

        @prog.module("p")
        def p():
            sent = 0
            for i in range(4):
                ok = yield WriteNB(data, i)
                sent += int(ok)
            _ = yield Read(done)      # never written: deadlock mid-segment
            yield Emit("sent", sent)

        @prog.module("c")
        def c():
            total = 0
            for _ in range(3):
                ok, v = yield ReadNB(data)
                if ok:
                    total += v
                yield Delay(1)
            yield Emit("got", total)

        return prog

    from repro.core.trace import simulate_hybrid
    with pytest.raises(TraceUnsupported):
        simulate_hybrid(build())
    g = simulate(build(), trace="never")
    a = simulate(build(), trace="auto")
    assert a.engine == "omnisim"          # generator owns the deadlock report
    assert g.deadlock and a.deadlock
    assert a.deadlock_cycle == g.deadlock_cycle
    assert a.outputs == g.outputs         # includes __deadlock__ blocked set
    assert a.stats.queries == g.stats.queries
    assert a.stats.queries_forced_false == g.stats.queries_forced_false


def test_hybrid_spsc_violation_falls_back_to_engine_assertion():
    """Two readers on one FIFO in an NB design: the hybrid recorder defers
    and the generator engine's endpoint check raises the same
    AssertionError it always has."""
    def build():
        prog = Program("spsc_nb", declared_type="C")
        f = prog.fifo("f", 2)

        @prog.module("p")
        def p():
            for i in range(4):
                yield WriteNB(f, i)

        @prog.module("c1")
        def c1():
            yield Read(f)

        @prog.module("c2")
        def c2():
            yield Read(f)

        return prog

    from repro.core.trace import simulate_hybrid
    with pytest.raises(TraceUnsupported):
        simulate_hybrid(build())
    with pytest.raises(AssertionError, match="SPSC"):
        simulate(build(), trace="auto")


def test_spsc_violation_still_raises_engine_assertion():
    """Two readers on one FIFO: the recorder defers, and the engine's
    endpoint check raises the same AssertionError as before."""
    prog = Program("mpmc", declared_type="A")
    f = prog.fifo("f", 2)

    @prog.module("p")
    def p():
        for i in range(4):
            yield Write(f, i)

    @prog.module("c1")
    def c1():
        yield Read(f)

    @prog.module("c2")
    def c2():
        yield Read(f)

    with pytest.raises(AssertionError, match="SPSC"):
        simulate(prog, trace="auto")


def test_spsc_drain_while_parked_falls_back():
    """A second reader draining a FIFO while the first is parked on it must
    fall back (not crash) and surface the engine's SPSC diagnostic."""
    prog = Program("mpmc2", declared_type="A")
    f = prog.fifo("f", 2)
    g2 = prog.fifo("g2", 2)

    @prog.module("ra")
    def ra():
        yield Read(f)                    # parks on empty f

    @prog.module("w")
    def w():
        yield Write(g2, 1)
        yield Write(f, 2)

    @prog.module("rb")
    def rb():
        yield Read(g2)
        yield Read(f)                    # drains f before ra wakes

    with pytest.raises(AssertionError, match="SPSC"):
        simulate(prog, trace="auto")


def test_shuffle_seed_uses_generator_path():
    r = simulate(producer_consumer(n=16), shuffle_seed=3)
    assert r.engine == "omnisim"


def test_trace_always_with_shuffle_seed_is_an_error():
    """'always' promises replay-or-raise; a shuffle seed (which only the
    generator scheduler honors) contradicts it."""
    with pytest.raises(ValueError, match="shuffle_seed"):
        simulate(producer_consumer(n=8), shuffle_seed=1, trace="always")
    with pytest.raises(ValueError, match="trace"):
        simulate(producer_consumer(n=8), trace="sometimes")


# ----------------------------------------------------- recorded-trace shape
def test_record_trace_arrays():
    rec = record_trace(producer_consumer(n=8, depth=2))
    assert [m.name for m in rec.modules] == ["producer", "consumer"]
    prod, cons = rec.modules
    assert prod.n_ops == 8 and (prod.kind == 1).all()       # OP_WRITE
    assert cons.n_ops == 8 and (cons.kind == 0).all()       # OP_READ
    assert rec.outputs == {"sum": sum(range(1, 9))}
    ct = compile_trace(rec, 1)
    assert ct.n == 8 + 8 + 4                                # ops + START/END
    assert len(ct.raw_dst) == 8                             # one RAW per read
    np.testing.assert_array_equal(ct.fifo_wmod, [0])
    np.testing.assert_array_equal(ct.fifo_rmod, [1])


def test_periodization_roundtrip():
    """Steady-state loops are re-rolled losslessly; skynet compresses by
    orders of magnitude."""
    rec = record_trace(skynet_like(items=128, depth=8))
    full = [m.expand() for m in rec.modules]
    rec.periodize()
    assert rec.n_stored < rec.n_ops / 20
    for m, (k, f, g) in zip(rec.modules, full):
        k2, f2, g2 = m.expand()
        np.testing.assert_array_equal(k, k2)
        np.testing.assert_array_equal(f, f2)
        np.testing.assert_array_equal(g, g2)


def test_trace_graph_csr_and_nodes():
    """TraceSimGraph must satisfy the SimGraph read contract: CSR longest
    path reproduces the eager times, and node materialization feeds the
    taxonomy classifier."""
    r = simulate(skynet_like(items=24, depth=4))
    assert r.engine == "omnisim-trace"
    g = r.graph.graph
    indptr, src, wgt, base = g.to_csr()
    np.testing.assert_array_equal(longest_path_numpy(indptr, src, wgt, base),
                                  g.times())
    c = classify(skynet_like(items=24, depth=4), r)
    assert c.dtype == "A" and not c.has_nonblocking


def test_dead_probes_compile():
    """Unused Empty/Full probes are statically dead (paper Sec. 7.3.2):
    they cost one cycle and do not force a generator fallback."""
    from repro.core.program import Full

    def build():
        prog = Program("deadprobe", declared_type="A")
        f = prog.fifo("f", 2)

        @prog.module("p")
        def p():
            for i in range(4):
                yield Full(f, used=False)
                yield Write(f, i)

        @prog.module("c")
        def c():
            total = 0
            for _ in range(4):
                total += (yield Read(f))
            yield Emit("total", total)

        return prog

    r = simulate(build(), trace="always")
    assert r.stats.skipped_probes == 4
    _assert_equal_results(simulate(build(), trace="never"), r)


# ------------------------------------------- downstream incremental / DSE
def test_incremental_from_trace_result_matches_generator_base():
    """resimulate()/resimulate_batch() on a trace-compiled base must agree
    verdict-for-verdict and cycle-for-cycle with a generator-path base —
    the CompiledGraph is built directly from the trace."""
    builder = lambda: skynet_like(items=48, depth=6)
    base_tr = simulate(builder(), trace="always")
    base_gen = simulate(builder(), trace="never")
    assert getattr(base_tr.graph, "_incr_cache", None) is not None
    rng = np.random.default_rng(11)
    D = rng.integers(1, 13, size=(16, len(base_tr.depths)))
    out_tr = resimulate_batch(base_tr, D)
    out_gen = resimulate_batch(base_gen, D)
    np.testing.assert_array_equal(out_tr.ok, out_gen.ok)
    np.testing.assert_array_equal(out_tr.cycles, out_gen.cycles)
    np.testing.assert_array_equal(out_tr.status, out_gen.status)
    inc = resimulate(base_tr, tuple(int(x) for x in D[0]))
    full = simulate(builder(), depths=tuple(int(x) for x in D[0]),
                    trace="never")
    assert inc.result.cycles == full.cycles
