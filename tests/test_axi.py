"""AXI interface modeling (paper Table 1 AXI request types)."""
import numpy as np
import pytest

from repro.core import LightningSim, classify, simulate, simulate_rtl
from repro.core.axi import axi_master_design, axi_prefetch_design


def test_axi_master_matches_oracle():
    r1 = simulate(axi_master_design())
    r2 = simulate_rtl(axi_master_design())
    assert r1.outputs == r2.outputs
    assert r1.cycles == r2.cycles
    # the write phase doubled every word
    final = r1.outputs["memory_final"]
    data = [(i * 7 + 3) % 97 for i in range(64)]
    assert list(final) == [2 * v for v in data]


def test_axi_master_is_type_b_cyclic():
    # AXI request/response channels form a module-level cycle
    # (master -> ar -> memory -> r -> master), exactly the fig4_ex3
    # structure: blocking-only but concurrency-dependent = Type B.
    prog = axi_master_design()
    c = classify(prog, simulate(axi_master_design()))
    assert c.dtype == "B" and c.cyclic and not c.has_nonblocking
    from repro.core import UnsupportedDesignError
    with pytest.raises(UnsupportedDesignError):
        LightningSim(axi_master_design()).run()


def test_axi_read_latency_visible_in_cycles():
    fast = simulate(axi_master_design(read_latency=4)).cycles
    slow = simulate(axi_master_design(read_latency=40)).cycles
    assert slow > fast
    # 4 bursts, each paying the extra first-beat latency once
    assert slow - fast == 4 * 36


def test_axi_prefetch_type_c_matches_oracle():
    r1 = simulate(axi_prefetch_design())
    r2 = simulate_rtl(axi_prefetch_design())
    assert r1.outputs == r2.outputs
    assert r1.cycles == r2.cycles
    assert r1.outputs["prefetch_skipped"] > 0      # backpressure exercised


def test_axi_prefetch_schedule_independent():
    base = simulate(axi_prefetch_design())
    for seed in (0, 1, 2):
        r = simulate(axi_prefetch_design(), shuffle_seed=seed)
        assert r.outputs == base.outputs and r.cycles == base.cycles
