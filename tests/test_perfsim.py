"""perfsim: OmniSim as distributed-schedule simulator."""
import dataclasses

import pytest

from repro.perfsim.pipeline import (PipelineSpec, buffer_depth_dse,
                                    build_pipeline_program, simulate_pipeline)
from repro.core import simulate, simulate_rtl, classify


def test_pipeline_program_matches_rtl_oracle():
    spec = PipelineSpec(stages=4, microbatches=8, fwd_ticks=5, bwd_ticks=10,
                        buffer_depth=2)
    r1 = simulate(build_pipeline_program(spec))
    r2 = simulate_rtl(build_pipeline_program(spec))
    assert r1.cycles == r2.cycles
    assert r1.outputs == r2.outputs
    assert not r1.deadlock


@pytest.mark.parametrize("schedule", ["gpipe", "1f1b"])
def test_schedules_complete(schedule):
    spec = PipelineSpec(stages=4, microbatches=16, fwd_ticks=3, bwd_ticks=6,
                        schedule=schedule, dp_allreduce_ticks=20)
    out = simulate_pipeline(spec)
    assert not out.deadlock
    # lower bound: every microbatch's fwd+bwd through one stage
    assert out.step_ticks >= 16 * 9


def test_1f1b_beats_gpipe_with_small_buffers():
    """1F1B's early backwards drain buffers: with tight activation queues it
    stalls less than GPipe (the reason 1F1B exists)."""
    kw = dict(stages=4, microbatches=16, fwd_ticks=5, bwd_ticks=10,
              buffer_depth=1)
    g = simulate_pipeline(PipelineSpec(schedule="gpipe", **kw))
    f = simulate_pipeline(PipelineSpec(schedule="1f1b", **kw))
    assert not g.deadlock and not f.deadlock
    assert f.step_ticks <= g.step_ticks


def test_more_microbatches_lower_bubble():
    base = dict(stages=4, fwd_ticks=5, bwd_ticks=10, buffer_depth=2)
    small = simulate_pipeline(PipelineSpec(microbatches=4, **base))
    large = simulate_pipeline(PipelineSpec(microbatches=32, **base))
    assert large.bubble_fraction < small.bubble_fraction


def test_deeper_buffers_never_slower():
    base = dict(stages=4, microbatches=12, fwd_ticks=4, bwd_ticks=8,
                schedule="gpipe")
    prev = None
    for d in (1, 2, 4, 8):
        r = simulate_pipeline(PipelineSpec(buffer_depth=d, **base))
        if prev is not None:
            assert r.step_ticks <= prev
        prev = r.step_ticks


def test_buffer_dse_incremental_matches_full():
    """Depth sweep via incremental re-sim must agree with full re-sims."""
    spec = PipelineSpec(stages=4, microbatches=8, fwd_ticks=5, bwd_ticks=10,
                        schedule="gpipe", buffer_depth=1)
    sweep = buffer_depth_dse(spec, [1, 2, 4, 16])
    for depth, res, incr_s in sweep:
        full = simulate_pipeline(
            dataclasses.replace(spec, buffer_depth=depth))
        assert res.step_ticks == full.step_ticks, depth


def test_pipeline_program_is_type_b():
    spec = PipelineSpec(stages=3, microbatches=4, fwd_ticks=2, bwd_ticks=4)
    prog = build_pipeline_program(spec)
    c = classify(prog, simulate(build_pipeline_program(spec)))
    assert c.cyclic          # fwd/bwd queues form stage cycles
